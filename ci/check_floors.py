#!/usr/bin/env python3
"""Shared bench gate: validate BENCH_*.json files against ci/bench_floor.json.

Usage:
    python3 ci/check_floors.py [--floors ci/bench_floor.json] [--only SECTION] BENCH_x.json [...]

One script replaces the inline per-job python previously copy-pasted across
the five bench-smoke CI jobs.  Each BENCH file names its own bench
(`bench` key), which selects the matching check function below.  Three gate
kinds, matching the conventions documented in ci/bench_floor.json:

* floors        — wall-clock rates; fail only when the measured value drops
                  more than 30% below the checked-in floor, so shared-runner
                  noise cannot trip them (measured >= floor * 0.7).
* ceilings      — inverted floors for tail latencies; fail when measured
                  exceeds ceiling * 1.3.
* virtual gates — byte-stable seeded quantities (availability, MTTR,
                  parity, byte-identity); no tolerance, because two runs of
                  the same seed must agree exactly.

`--only SECTION` restricts a bench's checks to one named section (the
scale-smoke job uses `--only scale` against BENCH_simkernel.json so it does
not re-gate the dispatch/throughput sections bench-smoke already covers).
"""

import argparse
import json
import sys


class GateError(AssertionError):
    pass


def floor_gate(name, measured, floor, tolerance=0.7):
    limit = floor * tolerance
    if not (measured >= limit):
        raise GateError(f"{name} regressed: {measured:.4g} < {limit:.4g} "
                        f"(floor {floor:.4g} * {tolerance})")
    return limit


def ceiling_gate(name, measured, ceiling, tolerance=1.3):
    limit = ceiling * tolerance
    if measured is None or not (0 < measured <= limit):
        raise GateError(f"{name} breached ceiling: {measured} > {limit:.4g} "
                        f"(ceiling {ceiling:.4g} * {tolerance})")
    return limit


def virtual_gate(name, ok, detail):
    if not ok:
        raise GateError(f"{name}: {detail}")


def check_simkernel(bench, floors, only=None):
    if only in (None, "throughput"):
        pps = {t["threads"]: t["packets_per_sec"] for t in bench["throughput"]}
        limit = floor_gate("single-thread packets/sec", pps[1],
                           floors["packets_per_sec_floor"])
        virtual_gate("all_missions byte-identity",
                     bench["all_missions"]["byte_identical"] is True,
                     "--jobs N reports drifted")
        virtual_gate("dispatch sanity",
                     bench["dispatch"]["inline_ns_per_packet"] > 0,
                     bench["dispatch"])
        print(f"ok: {pps[1]:.0f} packets/s (floor {limit:.0f}), "
              f"--jobs 4 speedup {bench['all_missions']['speedup_jobs_4']:.2f}x")
    if only in (None, "scale"):
        scale = bench["scale"]
        sf = floors["scale"]
        virtual_gate("shard byte-identity",
                     scale["byte_identical"] is True,
                     "--shards T output diverged from --shards 1")
        # Thread-scaling efficiency is wall-clock, so it carries the same
        # 30% noise tolerance as the throughput floors.
        limit = floor_gate("thread-scaling efficiency",
                           scale["thread_scaling_efficiency"],
                           sf["thread_scaling_efficiency_floor"])
        ns = [row["uavs"] for row in bench["fleet"]]
        virtual_gate("megafleet sweep coverage",
                     {256, 1024, 4096, 16384} <= set(ns),
                     f"sweep covered only N={ns}")
        print(f"ok: shards={scale['shards']} byte-identical across "
              f"N={ns}, efficiency "
              f"{scale['thread_scaling_efficiency']:.2f} (floor {limit:.2f})")


def check_serving(bench, floors, only=None):
    f = floors["serving"]
    pps = {b["batch"]: b["packets_per_sec"] for b in bench["batch_sweep"]}
    p99 = {b["batch"]: b["p99_ms"] for b in bench["batch_sweep"]}
    limit = floor_gate("batch-8 packets/sec", pps[8],
                       f["batched_packets_per_sec_floor"])
    ceil = ceiling_gate("batch-8 p99", p99[8], f["batch8_p99_ms_ceiling"])
    hit = {c["uavs"]: c["hit_rate"] for c in bench["cache"]}
    hit_limit = floor_gate("N=16 cache hit rate", hit[16],
                           f["cache_hit_rate_floor"])
    virtual_gate("overload shed", bench["overload"]["shed"] > 0,
                 "bounded queue never shed under flood")
    dl = bench["deadline"]
    virtual_gate("deadline completions",
                 dl["fifo_completed"] > 0 and dl["edf_completed"] > 0, dl)
    virtual_gate("deadline p99s present",
                 dl["edf_ctx_p99_ms"] is not None
                 and dl["fifo_ctx_p99_ms"] is not None, dl)
    virtual_gate("EDF beats FIFO on ctx p99",
                 dl["edf_ctx_p99_ms"] < dl["fifo_ctx_p99_ms"],
                 f"EDF ctx p99 {dl['edf_ctx_p99_ms']} ms not better than "
                 f"FIFO {dl['fifo_ctx_p99_ms']} ms")
    print(f"ok: batch-8 {pps[8]:.0f} pkts/s (floor {limit:.0f}), "
          f"p99 {p99[8]:.2f} ms (ceiling {ceil:.0f}), "
          f"N=16 hit rate {hit[16]:.3f} (floor {hit_limit:.3f}), "
          f"shed rate {bench['overload']['shed_rate']:.3f}, "
          f"ctx p99 FIFO {dl['fifo_ctx_p99_ms']:.2f} -> "
          f"EDF {dl['edf_ctx_p99_ms']:.2f} ms")


def check_cluster(bench, floors, only=None):
    f = floors["cluster"]
    over = bench["overload"]
    virtual_gate("overload sweep shape",
                 [o["cells"] for o in over] == [1, 2, 4], over)
    pps = bench["cluster_packets_per_sec"]
    limit = floor_gate("K=4 cluster packets/sec", pps,
                       f["cluster_packets_per_sec_floor"])
    rates = [o["shed_rate"] for o in over]
    virtual_gate("shed falls with K", rates[-1] < rates[0],
                 f"shed rate did not fall with K: {rates}")
    for a, b in zip(rates, rates[1:]):
        virtual_gate("shed monotone-sane", b <= a + 0.05,
                     f"shed rate rose with K: {rates}")
    virtual_gate("overload spills", sum(over[-1]["spill_hops"][1:]) > 0,
                 "overload never spilled at K=4")
    rep = bench["replication"]
    virtual_gate("replication improves hit rate",
                 rep["hit_rate_with"] > rep["hit_rate_without"], rep)
    virtual_gate("remote hits", rep["remote_hits"] > 0, rep)
    print(f"ok: {pps:.0f} pkts/s at K=4 (floor {limit:.0f}), "
          f"shed rate {rates[0]:.3f} -> {rates[-1]:.3f}, "
          f"hit rate {rep['hit_rate_without']:.3f} -> "
          f"{rep['hit_rate_with']:.3f} ({rep['remote_hits']} remote hits)")


def check_chaos(bench, floors, only=None):
    # All virtual (seeded, event-ordered) quantities: no noise tolerance.
    f = floors["chaos"]
    avail = bench["availability"]
    virtual_gate("cell-kill availability", avail >= f["availability_floor"],
                 f"availability {avail:.3f} < floor {f['availability_floor']}")
    mttr = bench["mttr_p99_s"]
    virtual_gate("MTTR p99",
                 mttr is not None and 0 < mttr <= f["mttr_p99_s_ceiling"],
                 f"MTTR p99 {mttr} s breached ceiling {f['mttr_p99_s_ceiling']} s")
    virtual_gate("recoveries", bench["recoveries"] >= 1,
                 "killed cell never recovered")
    virtual_gate("baseline availability",
                 bench["baseline_availability"] == 1.0,
                 bench["baseline_availability"])
    sweep = bench["availability_vs_rate"]
    virtual_gate("rate sweep in range",
                 all(0 < s["availability"] <= 1 for s in sweep), sweep)
    virtual_gate("rate sweep retries", sweep[-1]["retries"] > 0,
                 "rate sweep never engaged the retry layer")
    print(f"ok: availability {avail:.3f} (floor {f['availability_floor']}), "
          f"MTTR p99 {mttr:.1f} s (ceiling {f['mttr_p99_s_ceiling']:.0f}), "
          f"{bench['recoveries']:.0f} recoveries, rate-sweep min availability "
          f"{bench['min_availability_rate_sweep']:.3f}")


def check_scenario_matrix(bench, floors, only=None):
    f = floors["scenario_matrix"]
    cps = bench["compile"]["compiles_per_sec"]
    limit = floor_gate("compile throughput", cps, f["compiles_per_sec_floor"])
    virtual_gate("corpus size", bench["compile"]["corpus_size"] >= 500,
                 bench["compile"])
    virtual_gate("manifest/builtin parity",
                 bench["parity"]["identical"] is True,
                 "manifest/builtin parity diverged")
    virtual_gate("matrix failures", bench["matrix"]["failed"] == 0,
                 bench["matrix"])
    print(f"ok: {cps:.0f} compiles/s (floor {limit:.0f}), "
          f"{bench['matrix']['passed']}/{bench['matrix']['count']} matrix pass, "
          f"parity identical")


CHECKS = {
    "simkernel": check_simkernel,
    "serving": check_serving,
    "cluster": check_cluster,
    "chaos": check_chaos,
    "scenario_matrix": check_scenario_matrix,
}


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--floors", default="ci/bench_floor.json")
    ap.add_argument("--only", default=None,
                    help="restrict to one section of a bench's checks")
    ap.add_argument("bench_files", nargs="+")
    args = ap.parse_args(argv)

    with open(args.floors) as fh:
        floors = json.load(fh)

    failed = 0
    for path in args.bench_files:
        with open(path) as fh:
            bench = json.load(fh)
        if bench.get("schema") != 1:
            raise GateError(f"{path}: unknown schema {bench.get('schema')}")
        name = bench.get("bench")
        check = CHECKS.get(name)
        if check is None:
            raise GateError(f"{path}: no gate registered for bench `{name}`")
        try:
            check(bench, floors, only=args.only)
        except GateError as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            failed += 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
