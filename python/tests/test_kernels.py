"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes/dtypes with hypothesis.  This is the core correctness signal for the
exported artifacts: model.py routes through these kernels when lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.bottleneck import bottleneck_decode, bottleneck_encode
from compile.kernels.layernorm import layernorm

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

@given(t=st.integers(1, 96), c=st.sampled_from([8, 16, 64, 128, 160]),
       seed=st.integers(0, 2**16))
def test_layernorm_matches_ref(t, c, seed):
    x = rand(seed, (t, c), scale=3.0)
    g = rand(seed + 1, (c,), scale=0.5) + 1.0
    b = rand(seed + 2, (c,), scale=0.5)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-4, atol=1e-5)


def test_layernorm_non_divisible_tokens():
    # 33 tokens (the LLM trunk's shape) exercises the tile-fallback path.
    x = rand(0, (33, 128))
    g, b = jnp.ones(128), jnp.zeros(128)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-4, atol=1e-5)


def test_layernorm_extreme_values():
    x = jnp.asarray([[1e4, -1e4, 1.0, 0.0] * 4] * 8, jnp.float32)
    g, b = jnp.ones(16), jnp.zeros(16)
    out = layernorm(x, g, b)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref.layernorm_ref(x, g, b), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@given(h=st.sampled_from([1, 2, 4]), t=st.sampled_from([8, 16, 33, 64, 80]),
       d=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**16))
def test_attention_matches_ref(h, t, d, seed):
    q = rand(seed, (h, t, d))
    k = rand(seed + 1, (h, t, d))
    v = rand(seed + 2, (h, t, d))
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention_ref(q, k, v), rtol=1e-4, atol=1e-5)


def test_attention_softmax_rows_bounded():
    q = rand(0, (4, 64, 32), scale=5.0)
    out = attention(q, q, q)
    # Attention output is a convex combination of V rows.
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(q))) + 1e-4


def test_attention_uniform_when_keys_identical():
    # Identical keys => probs uniform => output = mean of values.
    q = rand(0, (2, 16, 8))
    k = jnp.ones((2, 16, 8))
    v = rand(1, (2, 16, 8))
    out = attention(q, k, v)
    want = jnp.broadcast_to(v.mean(axis=1, keepdims=True), v.shape)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Bottleneck encode/decode (the edge hot-spot)
# ---------------------------------------------------------------------------

@given(t=st.sampled_from([8, 64]), c=st.sampled_from([32, 128]),
       m=st.sampled_from([3, 6, 13, 32]), seed=st.integers(0, 2**16))
def test_bottleneck_encode_matches_ref(t, c, m, seed):
    h = rand(seed, (t, c), scale=2.0)
    mu = jnp.asarray([0.3])
    sigma = jnp.asarray([1.7])
    w = rand(seed + 1, (c, m), scale=0.2)
    bb = rand(seed + 2, (m,), scale=0.1)
    np.testing.assert_allclose(
        bottleneck_encode(h, mu, sigma, w, bb),
        ref.bottleneck_encode_ref(h, mu, sigma, w, bb), rtol=1e-4, atol=1e-5)


@given(t=st.sampled_from([8, 64]), m=st.sampled_from([6, 13, 32]),
       c=st.sampled_from([64, 128]), seed=st.integers(0, 2**16))
def test_bottleneck_decode_matches_ref(t, m, c, seed):
    z = jnp.tanh(rand(seed, (t, m)))
    hdim = 96
    w1 = rand(seed + 1, (m, hdim), scale=0.2)
    b1 = rand(seed + 2, (hdim,), scale=0.1)
    w2 = rand(seed + 3, (hdim, c), scale=0.2)
    b2 = rand(seed + 4, (c,), scale=0.1)
    mu = jnp.asarray([-0.2])
    sigma = jnp.asarray([2.1])
    np.testing.assert_allclose(
        bottleneck_decode(z, w1, b1, w2, b2, mu, sigma),
        ref.bottleneck_decode_ref(z, w1, b1, w2, b2, mu, sigma),
        rtol=1e-4, atol=1e-5)


def test_bottleneck_code_is_tanh_bounded():
    h = rand(3, (64, 128), scale=50.0)
    w = rand(4, (128, 13), scale=1.0)
    code = bottleneck_encode(h, jnp.asarray([0.0]), jnp.asarray([1.0]), w, jnp.zeros(13))
    assert float(jnp.max(jnp.abs(code))) <= 1.0


def test_bottleneck_int8_wire_roundtrip_error():
    # The rust wire layer quantizes at scale 127; error must stay below 1 LSB.
    h = rand(5, (64, 128))
    w = rand(6, (128, 32), scale=0.2)
    code = bottleneck_encode(h, jnp.asarray([0.0]), jnp.asarray([1.0]), w, jnp.zeros(32))
    q = jnp.round(code * 127.0) / 127.0
    assert float(jnp.max(jnp.abs(q - code))) <= 0.5 / 127.0 + 1e-7
