"""Training utilities + a quick end-to-end AOT build smoke test (tiny
budget).  The full-budget build is exercised by `make artifacts`."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile import train as T


def small_arrays(n=6, kind="flood"):
    scenes = D.build_corpus(kind, n, seed0=50)
    return T.scenes_to_arrays(scenes)


def test_scenes_to_arrays_shapes():
    imgs, pids, masks, pres = small_arrays()
    n = imgs.shape[0]
    assert imgs.shape == (n, D.IMG, D.IMG, 3)
    assert pids.shape == (n, D.MAX_PROMPT_TOKENS)
    assert masks.shape == (n, D.IMG, D.IMG)
    assert pres.shape == (n, 2)


def test_adam_reduces_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = T.adam_init(p)
    for _ in range(300):
        g = {"w": 2.0 * p["w"]}
        p, opt = T.adam_update(p, g, opt, lr=0.05)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_losses_sane():
    logits = jnp.asarray([[10.0, -10.0]])
    targets = jnp.asarray([[1.0, 0.0]])
    assert float(T.bce_logits(logits, targets)) < 1e-3
    assert float(T.dice_loss(logits, targets)) < 0.5
    # Wrong predictions cost more.
    assert float(T.bce_logits(-logits, targets)) > 1.0


def test_pos_weight_scales_positive_errors():
    logits = jnp.asarray([[-5.0]])
    targets = jnp.asarray([[1.0]])
    plain = float(T.bce_logits(logits, targets, pos_weight=1.0))
    heavy = float(T.bce_logits(logits, targets, pos_weight=4.0))
    assert abs(heavy - 4.0 * plain) < 1e-5


def test_iou_stats_matches_rust_convention():
    pred = np.zeros((2, 4, 4), np.float32)
    gt = np.zeros((2, 4, 4), np.float32)
    pred[0, :2, :2] = 1.0
    gt[0, :2, :2] = 1.0  # perfect
    gt[1, 2:, 2:] = 1.0  # fully missed
    st = T.iou_stats(pred, gt)
    assert abs(st["giou"] - 0.5) < 1e-9
    assert abs(st["ciou"] - 4.0 / 8.0) < 1e-9


def test_one_train_step_decreases_loss():
    arrays = small_arrays(4)
    model = M.init_model(seed=2)
    before = float(T.batch_loss(model, *arrays))
    model = T.train_model(model, arrays, steps=8, batch=4, lr=2e-3, seed=3,
                          trainable=("decoder",), log=lambda *_: None)
    after = float(T.batch_loss(model, *arrays))
    assert after < before


def test_bottleneck_training_improves_reconstruction():
    arrays = small_arrays(4)
    model = M.init_model(seed=2)
    h = T.precompute_activations(model, arrays[0], split=1)

    def recon_err(bn):
        z = M.bottleneck_encode(bn, h.reshape(-1, M.DIM), use_pallas=False)
        h_hat = M.bottleneck_decode(bn, z, use_pallas=False)
        return float(jnp.mean(jnp.square(h_hat - h.reshape(-1, M.DIM))))

    bn0 = M.init_bottleneck(jax.random.PRNGKey(7), 0.25)
    err0 = recon_err(bn0)
    bn = T.train_bottleneck(model, 1, 0.25, arrays, steps=60, batch=8, lr=3e-3,
                            seed=7, log=lambda *_: None, activations=h)
    assert recon_err(bn) < err0 * 0.8


def test_tier_ratio_orders_reconstruction():
    """More aggressive compression must reconstruct worse — the LUT's
    fidelity ordering is an emergent property, assert it at train level."""
    arrays = small_arrays(4)
    model = M.init_model(seed=2)
    h = T.precompute_activations(model, arrays[0], split=1)
    errs = []
    for ratio in (0.25, 0.05):
        bn = T.train_bottleneck(model, 1, ratio, arrays, steps=80, batch=8,
                                lr=3e-3, seed=11, log=lambda *_: None,
                                activations=h)
        z = M.bottleneck_encode(bn, h.reshape(-1, M.DIM), use_pallas=False)
        h_hat = M.bottleneck_decode(bn, z, use_pallas=False)
        errs.append(float(jnp.mean(jnp.square(h_hat - h.reshape(-1, M.DIM)))))
    assert errs[0] < errs[1], errs


@pytest.mark.slow
def test_quick_aot_build(tmp_path):
    """End-to-end tiny-budget build: datasets, training, bottlenecks, HLO
    export, manifests.  ~4 minutes on one core; run with -m slow."""
    from compile.aot import build
    out = str(tmp_path / "artifacts")
    build(out, quick=True, log=lambda *_: None)
    for f in ("manifest.txt", "lut.txt", "manifest.json",
              "data/flood_val.bin", "fixtures/tokenizer.txt"):
        assert os.path.exists(os.path.join(out, f)), f
    # Every artifact's weight binary exists and has the manifest's size.
    import json
    man = json.load(open(os.path.join(out, "manifest.json")))
    assert len(man["artifacts"]) >= 20
    for name, a in man["artifacts"].items():
        want = sum(int(np.prod(p["shape"])) for p in a["params"]) * 4
        for rel in a["weights"].values():
            assert os.path.getsize(os.path.join(out, rel)) == want, name
