"""L2 correctness: mini-LISA shapes, pallas/oracle equivalence of every
execution path, and split-consistency invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M


@pytest.fixture(scope="module")
def model():
    return M.init_model(seed=1)


@pytest.fixture(scope="module")
def img():
    return jnp.asarray(D.make_flood_scene(7).image)


@pytest.fixture(scope="module")
def pids():
    return jnp.asarray(D.tokenize("highlight the stranded people"))


def test_param_count_reasonable(model):
    n = M.count_params(model)
    assert 8e5 < n < 5e6, n


def test_shapes_full_pipeline(model, img, pids):
    mask, pres = M.full_pipeline(model, img, pids, use_pallas=False)
    assert mask.shape == (M.IMG, M.IMG)
    assert pres.shape == (M.NUM_CLASSES,)


def test_prefix_suffix_shapes(model, img):
    for split in (1, 4, M.DEPTH):
        h = M.backbone_prefix(model["backbone"], img, split, use_pallas=False)
        assert h.shape == (M.TOKENS, M.DIM)
        feats = M.backbone_suffix(model["backbone"], h, split, use_pallas=False)
        assert feats.shape == (M.TOKENS, M.NECK)


def test_split_consistency(model, img, pids):
    """prefix(k) then suffix(k) must equal the full backbone for every k —
    the invariant that makes depth-wise splitting semantically lossless
    (before compression)."""
    full = M.backbone_suffix(
        model["backbone"],
        M.backbone_prefix(model["backbone"], img, M.DEPTH, use_pallas=False),
        M.DEPTH, use_pallas=False)
    for split in range(1, M.DEPTH + 1):
        h = M.backbone_prefix(model["backbone"], img, split, use_pallas=False)
        feats = M.backbone_suffix(model["backbone"], h, split, use_pallas=False)
        np.testing.assert_allclose(feats, full, rtol=1e-4, atol=1e-4)


def test_pallas_oracle_equivalence_full(model, img, pids):
    """The exported artifacts run the Pallas kernels; training ran the
    oracles.  They must agree to float tolerance end to end."""
    m_p, p_p = M.full_pipeline(model, img, pids, use_pallas=True)
    m_r, p_r = M.full_pipeline(model, img, pids, use_pallas=False)
    np.testing.assert_allclose(m_p, m_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(p_p, p_r, rtol=1e-3, atol=1e-3)


def test_pallas_oracle_equivalence_split(model, img, pids):
    bn = M.init_bottleneck(jax.random.PRNGKey(0), 0.25)
    m_p, _ = M.split_pipeline(model, bn, img, pids, split=1, use_pallas=True)
    m_r, _ = M.split_pipeline(model, bn, img, pids, split=1, use_pallas=False)
    np.testing.assert_allclose(m_p, m_r, rtol=1e-3, atol=1e-3)


def test_bottleneck_code_width():
    assert M.code_width(0.25) == 32
    assert M.code_width(0.10) == 13
    assert M.code_width(0.05) == 6


def test_bottleneck_shapes(model, img):
    for ratio in M.TIER_RATIOS.values():
        bn = M.init_bottleneck(jax.random.PRNGKey(3), ratio)
        h = M.backbone_prefix(model["backbone"], img, 1, use_pallas=False)
        z = M.bottleneck_encode(bn, h, use_pallas=False)
        assert z.shape == (M.TOKENS, M.code_width(ratio))
        assert float(jnp.max(jnp.abs(z))) <= 1.0
        h_hat = M.bottleneck_decode(bn, z, use_pallas=False)
        assert h_hat.shape == h.shape


def test_context_paths(model, img, pids):
    ct, cp = M.context_edge(model, img, use_pallas=False)
    assert ct.shape == (M.CLIP_TOKENS, M.CLIP_DIM)
    assert cp.shape == (M.CLIP_DIM,)
    pres = M.context_respond(model, ct, pids, use_pallas=False)
    assert pres.shape == (M.NUM_CLASSES,)


def test_prompt_conditioning_changes_output(model, img):
    """Different prompts must produce different masks (the promptable-seg
    property LISA's <SEG> token provides)."""
    p1 = jnp.asarray(D.tokenize("highlight the people stranded by the flood"))
    p2 = jnp.asarray(D.tokenize("mark every car trapped in the water"))
    m1, _ = M.full_pipeline(model, img, p1, use_pallas=False)
    m2, _ = M.full_pipeline(model, img, p2, use_pallas=False)
    assert float(jnp.max(jnp.abs(m1 - m2))) > 1e-3


def test_deterministic_init():
    a = M.init_model(seed=5)
    b = M.init_model(seed=5)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(la, lb)


def test_patchify_blocks():
    img = jnp.arange(M.IMG * M.IMG * 3, dtype=jnp.float32).reshape(M.IMG, M.IMG, 3)
    p = M.patchify(img, M.PATCH)
    assert p.shape == (M.TOKENS, M.PATCH * M.PATCH * 3)
    # First patch row-major: img[0:8, 0:8, :].
    np.testing.assert_array_equal(
        p[0], img[: M.PATCH, : M.PATCH, :].reshape(-1))
