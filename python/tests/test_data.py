"""Synthetic Flood-ReasonSeg generator: invariants, serialization round-trip,
and tokenizer behaviour (the rust side re-verifies parity from fixtures)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


@given(seed=st.integers(0, 10_000))
def test_flood_scene_invariants(seed):
    s = D.make_flood_scene(seed)
    assert s.image.shape == (D.IMG, D.IMG, 3)
    assert s.image.dtype == np.float32
    assert 0.0 <= s.image.min() and s.image.max() <= 1.0
    assert s.masks.shape == (2, D.IMG, D.IMG)
    assert set(np.unique(s.masks)).issubset({0.0, 1.0})
    assert len(s.prompts) >= 1
    for cls, text in s.prompts:
        assert cls in (D.PERSON, D.VEHICLE)
        # A prompt only exists if its class is present in the scene.
        assert s.masks[cls].sum() > 0
        assert len(text) > 5


@given(seed=st.integers(0, 10_000))
def test_generic_scene_invariants(seed):
    s = D.make_generic_scene(seed)
    assert s.image.shape == (D.IMG, D.IMG, 3)
    assert 0.0 <= s.image.min() and s.image.max() <= 1.0


def test_scene_determinism():
    a, b = D.make_flood_scene(42), D.make_flood_scene(42)
    np.testing.assert_array_equal(a.image, b.image)
    np.testing.assert_array_equal(a.masks, b.masks)
    assert a.prompts == b.prompts


def test_augment_preserves_masks():
    s = D.make_flood_scene(3)
    aug = D.photometric_augment(s, 9)
    np.testing.assert_array_equal(aug.masks, s.masks)
    assert aug.prompts == s.prompts
    assert not np.array_equal(aug.image, s.image)
    assert 0.0 <= aug.image.min() and aug.image.max() <= 1.0


def test_split_and_expand_protocol():
    scenes = D.build_corpus("flood", 100, seed0=0)
    train, val = D.train_val_split(scenes)
    assert len(train) == 70 and len(val) == 30
    expanded = D.expand_training(train)
    assert len(expanded) == 70 * 4  # originals + 3 augmented copies (~300)


def test_serialization_roundtrip(tmp_path):
    scenes = D.build_corpus("flood", 5, seed0=11)
    path = str(tmp_path / "scenes.bin")
    D.write_scenes(path, scenes)
    back = D.read_scenes(path)
    assert len(back) == 5
    for a, b in zip(scenes, back):
        np.testing.assert_allclose(a.image, b.image, rtol=1e-6)
        np.testing.assert_array_equal(a.masks, b.masks)
        assert a.prompts == b.prompts


# ---------------------------------------------------------------------------
# Tokenizer (python half of the parity pair)
# ---------------------------------------------------------------------------

def test_tokenize_shape_and_pad():
    ids = D.tokenize("find people")
    assert ids.shape == (D.MAX_PROMPT_TOKENS,)
    assert ids.dtype == np.int32
    assert ids[0] > 0 and ids[1] > 0 and (ids[2:] == 0).all()


def test_tokenize_case_punct():
    np.testing.assert_array_equal(D.tokenize("Find, People!"), D.tokenize("find people"))


@given(text=st.text(min_size=0, max_size=200))
def test_tokenize_never_crashes_and_bounded(text):
    ids = D.tokenize(text)
    assert ids.shape == (D.MAX_PROMPT_TOKENS,)
    assert (0 <= ids).all() and (ids < D.VOCAB).all()


def test_fnv_reference_values():
    # Pinned values — the rust tokenizer must match (util::fnv1a32 tests).
    assert D.fnv1a32("") == 0x811C9DC5
    assert D.fnv1a32("a") == 0xE40C292C
