"""L2 — "mini-LISA": the JAX compute graph AVERY splits.

This is the in-repo stand-in for LISA-7B (see DESIGN.md "Substitutions"):
the same *structure* — a SAM-style ViT vision backbone that can be split at
any block depth, a CLIP-style light encoder, a multi-modal LLM trunk fusing
vision tokens with an NL prompt through a <SEG>-style query token, and a
promptable mask decoder — at ~1.2 M parameters so it can be trained and
AOT-lowered inside `make artifacts`.

Everything is written as pure functions over explicit parameter pytrees so
each execution path (edge head per split point / tier, cloud tail, context
path, full pipeline) can be independently `jax.jit(...).lower()`-ed to HLO
text with the parameters exposed as HLO *parameters* (not baked constants);
the rust runtime feeds the weight binary at load time, which keeps artifacts
small and lets Original vs Fine-tuned share one HLO per path.

`use_pallas=True` routes LayerNorm / attention / bottleneck through the L1
Pallas kernels (interpret=True) — used for every exported artifact.
Training uses the pure-jnp oracles (`use_pallas=False`) because autodiff
does not flow through pallas_call; test_kernels.py proves the two are
numerically identical, so the trained weights are valid for both.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import attention as attention_pl
from .kernels.bottleneck import bottleneck_decode as bn_decode_pl
from .kernels.bottleneck import bottleneck_encode as bn_encode_pl
from .kernels.layernorm import layernorm as layernorm_pl

# ----------------------------------------------------------------------------
# Dimensions (mini-LISA).  Paper's SAM ViT-H has 32 blocks / 1280 dim over
# 1024x1024 images; we keep the same topology at 8 blocks / 128 dim over
# 64x64 images, giving an honest depth axis for the Fig 7/8 split sweep.
# ----------------------------------------------------------------------------
IMG = 64
PATCH = 8
TOKENS = (IMG // PATCH) ** 2          # 64 vision tokens
DIM = 128                             # backbone width
HEADS = 4
DEPTH = 8                             # ViT blocks (split points 1..DEPTH)
MLP = 256
NECK = 64                             # SAM neck / decoder width

CLIP_PATCH = 16
CLIP_TOKENS = (IMG // CLIP_PATCH) ** 2  # 16 tokens
CLIP_DIM = 64
CLIP_DEPTH = 2
CLIP_HEADS = 2

VOCAB = 512                           # hashed-vocab size (data.tokenize)
PROMPT_TOKENS = 16
LLM_DIM = 128
LLM_DEPTH = 3
LLM_HEADS = 4

NUM_CLASSES = 2                       # person, vehicle

# Bottleneck tiers (Table 3): compression ratio -> code width M = r*DIM.
TIER_RATIOS = {"high_accuracy": 0.25, "balanced": 0.10, "high_throughput": 0.05}


def code_width(ratio: float) -> int:
    return max(1, int(round(ratio * DIM)))


Params = Dict[str, jnp.ndarray]


# ----------------------------------------------------------------------------
# Primitive wrappers: pallas kernel or jnp oracle.
# ----------------------------------------------------------------------------

def _ln(x, gamma, beta, use_pallas: bool):
    if use_pallas and x.ndim == 2:
        return layernorm_pl(x, gamma, beta)
    return ref.layernorm_ref(x, gamma, beta)


def _mha(q, k, v, use_pallas: bool):
    if use_pallas:
        return attention_pl(q, k, v)
    return ref.attention_ref(q, k, v)


# ----------------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------------

def _dense_init(key, fan_in, fan_out):
    scale = jnp.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


def _block_init(key, dim, heads, mlp):
    ks = jax.random.split(key, 6)
    return {
        "ln1_g": jnp.ones((dim,)), "ln1_b": jnp.zeros((dim,)),
        "wqkv": _dense_init(ks[0], dim, 3 * dim), "bqkv": jnp.zeros((3 * dim,)),
        "wo": _dense_init(ks[1], dim, dim), "bo": jnp.zeros((dim,)),
        "ln2_g": jnp.ones((dim,)), "ln2_b": jnp.zeros((dim,)),
        "w1": _dense_init(ks[2], dim, mlp), "b1": jnp.zeros((mlp,)),
        "w2": _dense_init(ks[3], mlp, dim), "b2": jnp.zeros((dim,)),
    }


def _blocks_init(key, depth, dim, heads, mlp):
    """Stacked block params: every leaf gains a leading `depth` axis so the
    forward pass can lax.scan over layers (one traced block instead of
    `depth` unrolled copies — an order of magnitude off XLA compile time,
    which matters both here and when the rust runtime compiles the HLO)."""
    per = [_block_init(k, dim, heads, mlp) for k in jax.random.split(key, depth)]
    return {k: jnp.stack([p[k] for p in per]) for k in per[0]}


def run_blocks(p: Params, x: jnp.ndarray, heads: int, use_pallas: bool,
               start: int, stop: int) -> jnp.ndarray:
    """Apply stacked transformer blocks [start, stop) via lax.scan."""
    if stop <= start:
        return x
    sliced = {k: v[start:stop] for k, v in p.items()}

    def body(h, layer):
        return vit_block(layer, h, heads, use_pallas), None

    out, _ = jax.lax.scan(body, x, sliced)
    return out


def init_backbone(key) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "patch_w": _dense_init(ks[0], PATCH * PATCH * 3, DIM),
        "patch_b": jnp.zeros((DIM,)),
        "pos": jax.random.normal(ks[1], (TOKENS, DIM)) * 0.02,
        "neck_g": jnp.ones((DIM,)), "neck_b": jnp.zeros((DIM,)),
        "neck_w": _dense_init(ks[2], DIM, NECK), "neck_bias": jnp.zeros((NECK,)),
        "blocks": _blocks_init(ks[3], DEPTH, DIM, HEADS, MLP),
    }


def init_clip(key) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "patch_w": _dense_init(ks[0], CLIP_PATCH * CLIP_PATCH * 3, CLIP_DIM),
        "patch_b": jnp.zeros((CLIP_DIM,)),
        "pos": jax.random.normal(ks[1], (CLIP_TOKENS, CLIP_DIM)) * 0.02,
        "blocks": _blocks_init(ks[2], CLIP_DEPTH, CLIP_DIM, CLIP_HEADS, 2 * CLIP_DIM),
    }


def init_llm(key) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "tok_emb": jax.random.normal(ks[0], (VOCAB, LLM_DIM)) * 0.02,
        "prompt_pos": jax.random.normal(ks[1], (PROMPT_TOKENS, LLM_DIM)) * 0.02,
        "clip_proj": _dense_init(ks[2], CLIP_DIM, LLM_DIM),
        "clip_proj_b": jnp.zeros((LLM_DIM,)),
        "seg_query": jax.random.normal(ks[3], (1, LLM_DIM)) * 0.02,
        "out_g": jnp.ones((LLM_DIM,)), "out_b": jnp.zeros((LLM_DIM,)),
        "seg_w": _dense_init(ks[4], LLM_DIM, NECK), "seg_b": jnp.zeros((NECK,)),
        "cls_w": _dense_init(ks[5], LLM_DIM, NUM_CLASSES),
        "cls_b": jnp.zeros((NUM_CLASSES,)),
        "blocks": _blocks_init(ks[6], LLM_DEPTH, LLM_DIM, LLM_HEADS, 2 * LLM_DIM),
    }


def init_decoder(key) -> Params:
    ks = jax.random.split(key, 3)
    hidden = 128
    return {
        "w1": _dense_init(ks[0], NECK + NECK, hidden), "b1": jnp.zeros((hidden,)),
        "w2": _dense_init(ks[1], hidden, hidden), "b2": jnp.zeros((hidden,)),
        "w3": _dense_init(ks[2], hidden, PATCH * PATCH), "b3": jnp.zeros((PATCH * PATCH,)),
    }


def init_model(seed: int = 0) -> Dict[str, Params]:
    k = jax.random.PRNGKey(seed)
    kb, kc, kl, kd = jax.random.split(k, 4)
    return {
        "backbone": init_backbone(kb),
        "clip": init_clip(kc),
        "llm": init_llm(kl),
        "decoder": init_decoder(kd),
    }


BN_HIDDEN = 96  # decoder MLP hidden width


def init_bottleneck(key, ratio: float) -> Params:
    """BottleFit-style bottleneck: global standardize -> Linear -> tanh on
    the edge; MLP decode + un-standardize on the server.  mu/sigma are
    corpus statistics (set by train.train_bottleneck), exported as weights."""
    m = code_width(ratio)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jnp.zeros((1,)), "sigma": jnp.ones((1,)),
        "enc_w": _dense_init(k1, DIM, m), "enc_b": jnp.zeros((m,)),
        "dec_w1": _dense_init(k2, m, BN_HIDDEN), "dec_b1": jnp.zeros((BN_HIDDEN,)),
        "dec_w2": _dense_init(k3, BN_HIDDEN, DIM), "dec_b2": jnp.zeros((DIM,)),
    }


# ----------------------------------------------------------------------------
# Forward pieces
# ----------------------------------------------------------------------------

def _split_heads(x, heads):
    t, d = x.shape
    return x.reshape(t, heads, d // heads).transpose(1, 0, 2)


def _merge_heads(x):
    h, t, d = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * d)


def vit_block(p: Params, x: jnp.ndarray, heads: int, use_pallas: bool) -> jnp.ndarray:
    """Pre-LN transformer block (the unit of the Fig 7/8 split sweep)."""
    xn = _ln(x, p["ln1_g"], p["ln1_b"], use_pallas)
    qkv = xn @ p["wqkv"] + p["bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    o = _mha(_split_heads(q, heads), _split_heads(k, heads),
             _split_heads(v, heads), use_pallas)
    x = x + _merge_heads(o) @ p["wo"] + p["bo"]
    xn = _ln(x, p["ln2_g"], p["ln2_b"], use_pallas)
    return x + jax.nn.gelu(xn @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def patchify(img: jnp.ndarray, patch: int) -> jnp.ndarray:
    """(IMG, IMG, 3) -> (tokens, patch*patch*3), row-major patches."""
    n = IMG // patch
    x = img.reshape(n, patch, n, patch, 3).transpose(0, 2, 1, 3, 4)
    return x.reshape(n * n, patch * patch * 3)


def backbone_prefix(p: Params, img: jnp.ndarray, split: int,
                    use_pallas: bool = True) -> jnp.ndarray:
    """Edge-side SAM prefix: patch embed + blocks [0, split). -> (TOKENS, DIM)."""
    x = patchify(img, PATCH) @ p["patch_w"] + p["patch_b"] + p["pos"]
    nblk = p["blocks"]["wqkv"].shape[0]
    return run_blocks(p["blocks"], x, HEADS, use_pallas, 0, min(split, nblk))


def backbone_suffix(p: Params, h: jnp.ndarray, split: int,
                    use_pallas: bool = True) -> jnp.ndarray:
    """Cloud-side SAM suffix: blocks [split, DEPTH) + neck. -> (TOKENS, NECK).

    When `p["blocks"]` holds a pre-sliced suffix stack (artifact export), we
    run every block present; a missing "blocks" key (split == DEPTH export)
    means the suffix is just the neck.
    """
    if "blocks" in p:
        nblk = p["blocks"]["wqkv"].shape[0]
        start = split if nblk == DEPTH else 0
        x = run_blocks(p["blocks"], h, HEADS, use_pallas, start, nblk)
    else:
        x = h
    x = _ln(x, p["neck_g"], p["neck_b"], use_pallas)
    return x @ p["neck_w"] + p["neck_bias"]


def clip_encode(p: Params, img: jnp.ndarray,
                use_pallas: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CLIP-style light encoder -> (tokens (CLIP_TOKENS, CLIP_DIM), pooled)."""
    x = patchify(img, CLIP_PATCH) @ p["patch_w"] + p["patch_b"] + p["pos"]
    x = run_blocks(p["blocks"], x, CLIP_HEADS, use_pallas, 0, CLIP_DEPTH)
    return x, jnp.mean(x, axis=0)


def llm_trunk(p: Params, clip_tokens: jnp.ndarray, prompt_ids: jnp.ndarray,
              use_pallas: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-modal trunk: [clip tokens ; prompt ; <SEG> query] -> (seg_embed,
    presence_logits).  The <SEG>-query output is LISA's <SEG> token analog."""
    ct = clip_tokens @ p["clip_proj"] + p["clip_proj_b"]
    pt = p["tok_emb"][prompt_ids] + p["prompt_pos"]
    x = jnp.concatenate([ct, pt, p["seg_query"]], axis=0)
    x = run_blocks(p["blocks"], x, LLM_HEADS, use_pallas, 0, LLM_DEPTH)
    seg_tok = _ln(x[-1:], p["out_g"], p["out_b"], use_pallas)[0]
    return seg_tok @ p["seg_w"] + p["seg_b"], seg_tok @ p["cls_w"] + p["cls_b"]


def mask_decoder(p: Params, feats: jnp.ndarray, seg_embed: jnp.ndarray) -> jnp.ndarray:
    """SAM-style promptable decoder: per vision token, an MLP conditioned on
    the <SEG> embedding emits that token's PATCHxPATCH logit block; blocks are
    reassembled into the (IMG, IMG) mask logit map."""
    cond = jnp.broadcast_to(seg_embed, (feats.shape[0], seg_embed.shape[0]))
    x = jnp.concatenate([feats, cond], axis=-1)
    x = jax.nn.gelu(x @ p["w1"] + p["b1"])
    x = jax.nn.gelu(x @ p["w2"] + p["b2"])
    blocks = x @ p["w3"] + p["b3"]                      # (TOKENS, PATCH*PATCH)
    n = IMG // PATCH
    return blocks.reshape(n, n, PATCH, PATCH).transpose(0, 2, 1, 3).reshape(IMG, IMG)


# ----------------------------------------------------------------------------
# Bottleneck (learned compression around the split point)
# ----------------------------------------------------------------------------

def bottleneck_encode(p: Params, h: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    if use_pallas:
        return bn_encode_pl(h, p["mu"], p["sigma"], p["enc_w"], p["enc_b"])
    return ref.bottleneck_encode_ref(h, p["mu"], p["sigma"], p["enc_w"], p["enc_b"])


def bottleneck_decode(p: Params, z: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    if use_pallas:
        return bn_decode_pl(z, p["dec_w1"], p["dec_b1"], p["dec_w2"], p["dec_b2"],
                            p["mu"], p["sigma"])
    return ref.bottleneck_decode_ref(z, p["dec_w1"], p["dec_b1"], p["dec_w2"],
                                     p["dec_b2"], p["mu"], p["sigma"])


# ----------------------------------------------------------------------------
# End-to-end execution paths (these are what aot.py lowers to HLO)
# ----------------------------------------------------------------------------

def edge_insight_head(model: Dict[str, Params], bn: Params, img: jnp.ndarray,
                      split: int, use_pallas: bool = True):
    """UAV-side Insight path: SAM prefix -> bottleneck code, + CLIP features.
    Returns (code (TOKENS, M), clip_tokens, clip_pooled)."""
    h = backbone_prefix(model["backbone"], img, split, use_pallas)
    code = bottleneck_encode(bn, h, use_pallas)
    ct, cp = clip_encode(model["clip"], img, use_pallas)
    return code, ct, cp


def cloud_insight_tail(model: Dict[str, Params], bn: Params, code: jnp.ndarray,
                       clip_tokens: jnp.ndarray, prompt_ids: jnp.ndarray,
                       split: int, use_pallas: bool = True):
    """Server-side Insight path: bottleneck decode -> SAM suffix -> LLM trunk
    -> mask decoder.  Returns (mask_logits (IMG, IMG), presence_logits (2,))."""
    h = bottleneck_decode(bn, code, use_pallas)
    feats = backbone_suffix(model["backbone"], h, split, use_pallas)
    seg_embed, presence = llm_trunk(model["llm"], clip_tokens, prompt_ids, use_pallas)
    return mask_decoder(model["decoder"], feats, seg_embed), presence


def context_edge(model: Dict[str, Params], img: jnp.ndarray, use_pallas: bool = True):
    """UAV-side Context path: CLIP only (no SAM prefix) — the cheap stream."""
    return clip_encode(model["clip"], img, use_pallas)


def context_respond(model: Dict[str, Params], clip_tokens: jnp.ndarray,
                    prompt_ids: jnp.ndarray, use_pallas: bool = True):
    """Server-side Context path: text-level reasoning only (presence logits);
    no SAM features, no mask decoding."""
    _, presence = llm_trunk(model["llm"], clip_tokens, prompt_ids, use_pallas)
    return presence


def full_pipeline(model: Dict[str, Params], img: jnp.ndarray,
                  prompt_ids: jnp.ndarray, use_pallas: bool = True):
    """Uncompressed end-to-end pipeline (full-edge baseline / teacher /
    raw-image-compression server side).  Returns (mask_logits, presence)."""
    h = backbone_prefix(model["backbone"], img, DEPTH, use_pallas)
    feats = backbone_suffix(model["backbone"], h, DEPTH, use_pallas)
    ct, _ = clip_encode(model["clip"], img, use_pallas)
    seg_embed, presence = llm_trunk(model["llm"], ct, prompt_ids, use_pallas)
    return mask_decoder(model["decoder"], feats, seg_embed), presence


def split_pipeline(model: Dict[str, Params], bn: Params, img: jnp.ndarray,
                   prompt_ids: jnp.ndarray, split: int, use_pallas: bool = True):
    """Full split path in one graph (training / python-side LUT profiling)."""
    code, ct, _ = edge_insight_head(model, bn, img, split, use_pallas)
    return cloud_insight_tail(model, bn, code, ct, prompt_ids, split, use_pallas)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
