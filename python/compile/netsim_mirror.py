"""Cross-language mirror of rust/src/netsim/trace.rs + the scenario traces.

Reimplements the deterministic xorshift64* RNG and the bandwidth-trace
generator bit-for-bit (integer ops and IEEE-754 arithmetic are exact across
languages; only `normal()` touches libm, which the golden tolerances
absorb), then prints the per-scenario trace summaries that
rust/tests/scenario.rs pins as golden snapshots.

Regenerate the golden block after any intentional generator change:

    python -m compile.netsim_mirror
"""

import math

MASK = (1 << 64) - 1

STABLE, VOLATILE, DROP, OUTAGE, SAWTOOTH = range(5)
OUTAGE_FLOOR = 0.01
SAWTOOTH_HANDOFFS = 5.0


class Rng:
    """rust/src/util.rs::Rng (xorshift64*)."""

    def __init__(self, seed):
        self.state = ((max(seed, 1) * 0x9E3779B97F4A7C15) & MASK) | 1

    def next_u64(self):
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & MASK
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def range(self, lo, hi):
        return lo + (hi - lo) * self.f64()

    def below(self, n):
        return self.next_u64() % max(n, 1)

    def normal(self):
        u1 = max(self.f64(), 1e-12)
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def clamp(x, lo, hi):
    return min(max(x, lo), hi)


def markov_modulated(seed, duration, min_mbps, max_mbps, mean_dwell, kinds):
    rng = Rng(seed ^ 0x4D41524B4F56)
    phases = []
    ki = 0
    t = 0.0
    while t < duration:
        kind = kinds[ki % max(len(kinds), 1)]
        rem = duration - t
        dwell = max(mean_dwell * (0.5 + rng.f64()), 1.0)
        if rem - dwell < 2.0:
            dwell = rem
        if kind == STABLE:
            level = min_mbps + (max_mbps - min_mbps) * rng.range(0.6, 0.95)
        elif kind == VOLATILE:
            level = min_mbps + (max_mbps - min_mbps) * rng.range(0.4, 0.8)
        elif kind == DROP:
            level = min_mbps + (max_mbps - min_mbps) * rng.range(0.0, 0.15)
        elif kind == OUTAGE:
            level = OUTAGE_FLOOR
        else:
            level = min_mbps + (max_mbps - min_mbps) * rng.range(0.0, 0.3)
        phases.append((kind, dwell, level))
        t += dwell
        if len(kinds) > 1:
            ki = (ki + 1 + rng.below(len(kinds) - 1)) % len(kinds)
    return dict(phases=phases, min=min_mbps, max=max_mbps, dt=1.0, seed=seed)


def rust_round(x):
    """f64::round — half away from zero (x is always positive here)."""
    return int(math.floor(x + 0.5))


def generate(cfg):
    rng = Rng(cfg["seed"])
    lo, hi, dt = cfg["min"], cfg["max"], cfg["dt"]
    samples = []
    level = cfg["phases"][0][2] if cfg["phases"] else 15.0
    for kind, secs, anchor in cfg["phases"]:
        n = rust_round(secs / dt)
        if kind == STABLE:
            for _ in range(n):
                pull = (anchor - level) * 0.2
                level = clamp(level + pull + rng.normal() * 0.25, lo, hi)
                samples.append(level)
        elif kind == VOLATILE:
            for _ in range(n):
                pull = (anchor - level) * 0.05
                level = clamp(level + pull + rng.normal() * 1.4, lo, hi)
                samples.append(level)
        elif kind == OUTAGE:
            floor = max(anchor, OUTAGE_FLOOR)
            for _ in range(n):
                level = clamp(floor + rng.f64() * 0.02, OUTAGE_FLOOR, hi)
                samples.append(level)
        elif kind == SAWTOOTH:
            period = max(secs / SAWTOOTH_HANDOFFS, dt)
            for i in range(n):
                pos = ((i * dt) % period) / period
                v = hi + (anchor - hi) * pos
                level = clamp(v + rng.normal() * 0.2, lo, hi)
                samples.append(level)
        elif kind == DROP:
            fall = n // 4
            hold = n // 2
            start = level
            for i in range(n):
                if i < fall:
                    level = start + (anchor - start) * (i / max(fall, 1))
                elif i < fall + hold:
                    level = anchor + rng.normal() * 0.2
                else:
                    k = (i - fall - hold) / max(n - fall - hold, 1)
                    level = anchor + (start - anchor) * k
                level = clamp(level, lo, hi)
                samples.append(level)
    return samples


def phases(*rows):
    return list(rows)


def scenario_trace(name, seed, d):
    """Mirror of rust/src/scenario/mod.rs::build (trace part only)."""
    if name == "paper-baseline":
        cfg = dict(
            phases=phases(
                (STABLE, 180.0, 17.0), (VOLATILE, 240.0, 14.0), (DROP, 150.0, 8.5),
                (STABLE, 120.0, 16.0), (DROP, 180.0, 9.5), (VOLATILE, 180.0, 13.0),
                (STABLE, 150.0, 18.0),
            ),
            min=8.0, max=20.0, dt=1.0, seed=seed,
        )
        k = d / 1200.0
        cfg["phases"] = [(kk, s * k, l) for kk, s, l in cfg["phases"]]
        return cfg
    if name == "wildfire-ridge":
        return markov_modulated(seed, d, 8.0, 20.0, max(d / 12.0, 20.0),
                                [STABLE, VOLATILE, DROP])
    if name == "urban-flood":
        return dict(
            phases=phases(
                (STABLE, 0.15 * d, 16.0), (VOLATILE, 0.20 * d, 13.0),
                (DROP, 0.15 * d, 8.5), (STABLE, 0.10 * d, 15.0),
                (DROP, 0.20 * d, 9.0), (VOLATILE, 0.10 * d, 12.0),
                (STABLE, 0.10 * d, 17.0),
            ),
            min=8.0, max=20.0, dt=1.0, seed=seed,
        )
    if name == "earthquake-canyon":
        return dict(
            phases=phases(
                (STABLE, 0.20 * d, 15.0), (OUTAGE, 0.08 * d, 0.05),
                (VOLATILE, 0.22 * d, 12.0), (OUTAGE, 0.10 * d, 0.05),
                (DROP, 0.20 * d, 8.5), (STABLE, 0.20 * d, 16.0),
            ),
            min=8.0, max=20.0, dt=1.0, seed=seed,
        )
    if name == "coastal-satellite":
        return dict(
            phases=phases(
                (SAWTOOTH, 0.30 * d, 9.0), (STABLE, 0.10 * d, 18.0),
                (SAWTOOTH, 0.30 * d, 8.5), (VOLATILE, 0.10 * d, 12.0),
                (SAWTOOTH, 0.20 * d, 10.0),
            ),
            min=8.0, max=20.0, dt=1.0, seed=seed,
        )
    raise ValueError(name)


def summarize(cfg, samples):
    thresh = 0.5 * cfg["min"]
    return dict(
        mean=sum(samples) / max(len(samples), 1),
        min=min(samples),
        max=max(samples),
        outage_secs=sum(1 for s in samples if s < thresh) * cfg["dt"],
        regimes=len(cfg["phases"]),
        n=len(samples),
    )


NAMES = ["paper-baseline", "wildfire-ridge", "urban-flood",
         "earthquake-canyon", "coastal-satellite"]


def main(seed=7, duration=1200.0):
    print(f"// Golden trace snapshots @ seed {seed}, duration {duration:.0f} s")
    print("// (name, mean, min, max, outage_secs, regimes, samples)")
    for name in NAMES:
        cfg = scenario_trace(name, seed, duration)
        s = summarize(cfg, generate(cfg))
        print(
            f'    ("{name}", {s["mean"]:.4f}, {s["min"]:.4f}, {s["max"]:.4f}, '
            f'{s["outage_secs"]:.1f}, {s["regimes"]}, {s["n"]}),'
        )


if __name__ == "__main__":
    main()
