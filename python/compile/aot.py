"""AOT build path: train mini-LISA, profile the LUT, export every execution
path as HLO **text** + a weight binary + a manifest for the rust runtime.

Run via `make artifacts` (python -m compile.aot --out ../artifacts).  This is
the ONLY place python runs; the rust binary is self-contained afterwards.

Interchange format is HLO text, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Weights are exposed as HLO *parameters* rather than baked constants: the HLO
stays small, and the Original vs Fine-tuned models share one HLO per path
with two weight binaries.  The manifest records the exact flattened parameter
order (jax pytree order = dict keys sorted, tuples left-to-right) that the
rust runtime must feed.

Artifacts layout:
  artifacts/
    manifest.json            # artifact index: hlo path, param specs, weight sets
    lut.json                 # Table 3 analog: per-tier measured IoU + wire sizes
    hlo/<name>.hlo.txt
    weights/<name>.<set>.bin # f32 LE concatenation in parameter order
    data/{generic,flood}_val.bin, {generic,flood}_train.bin
    golden/<name>.<set>.bin  # input/output fixtures for rust integration tests
    fixtures/tokenizer.json  # python<->rust tokenizer parity fixture
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import train as T

SWEEP_SPLITS = list(range(1, M.DEPTH + 1))       # Fig 7/8 split sweep
TIER_SPLIT = 1                                   # the paper's split@1
TIERS = M.TIER_RATIOS                            # name -> ratio
SWEEP_TIER = "balanced"                          # Fig 7 uses r = 0.10

# Paper Table 3 wire payloads (MB) — used by the netsim wire model so that
# feasibility crossovers land exactly where the paper's do (DESIGN.md).
PAPER_DATA_SIZE_MB = {"high_accuracy": 2.92, "balanced": 1.35, "high_throughput": 0.83}
PAPER_SAM_ACTIVATION_MB = 10.49


# ----------------------------------------------------------------------------
# HLO text lowering (gen_hlo.py recipe)
# ----------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _specs_like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype), tree)


def _leaf_names(tree, prefix):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        s = jax.tree_util.keystr(path)
        for ch in "[]'\" ":
            s = s.replace(ch, ".")
        while ".." in s:
            s = s.replace("..", ".")
        names.append((prefix + s).strip("."))
    return names


class Exporter:
    def __init__(self, out_dir: str):
        self.out = out_dir
        for sub in ("hlo", "weights", "data", "golden", "fixtures"):
            os.makedirs(os.path.join(out_dir, sub), exist_ok=True)
        self.manifest = {"version": 1, "img": M.IMG, "tokens": M.TOKENS,
                         "dim": M.DIM, "depth": M.DEPTH,
                         "clip_tokens": M.CLIP_TOKENS, "clip_dim": M.CLIP_DIM,
                         "prompt_tokens": M.PROMPT_TOKENS, "vocab": M.VOCAB,
                         "num_classes": M.NUM_CLASSES, "artifacts": {}}

    def export(self, name: str, fn, weight_trees: dict, input_specs: dict,
               output_names: list, golden_inputs=None):
        """Lower fn(*weights, *inputs) to HLO text; write one weight binary per
        named weight set; record parameter order in the manifest.

        weight_trees: {set_name: (tree_0, tree_1, ...)}  — all sets share
        identical structure; the first set defines shapes.
        input_specs:  {input_name: ShapeDtypeStruct}
        """
        first = next(iter(weight_trees.values()))
        w_specs = tuple(_specs_like(t) for t in first)
        in_specs = tuple(input_specs.values())
        # keep_unused=True: the rust runtime feeds EVERY manifest parameter;
        # jit's default silently drops unused ones (e.g. seg_w/seg_b in the
        # context responder) and desyncs the parameter order.
        lowered = jax.jit(fn, keep_unused=True).lower(*w_specs, *in_specs)
        hlo = to_hlo_text(lowered)
        hlo_rel = f"hlo/{name}.hlo.txt"
        with open(os.path.join(self.out, hlo_rel), "w") as f:
            f.write(hlo)

        # Parameter metadata: weights first (flattened arg-by-arg), then inputs.
        params = []
        for i, tree in enumerate(first):
            names = _leaf_names(tree, f"w{i}")
            for nm, leaf in zip(names, jax.tree_util.tree_leaves(tree)):
                arr = np.asarray(leaf)
                params.append({"name": nm, "shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
        inputs = [{"name": k, "shape": list(v.shape), "dtype": str(np.dtype(v.dtype))}
                  for k, v in input_specs.items()]

        weight_files = {}
        for set_name, trees in weight_trees.items():
            rel = f"weights/{name}.{set_name}.bin"
            with open(os.path.join(self.out, rel), "wb") as f:
                for tree in trees:
                    for leaf in jax.tree_util.tree_leaves(tree):
                        f.write(np.asarray(leaf).astype("<f4").tobytes())
            weight_files[set_name] = rel

        self.manifest["artifacts"][name] = {
            "hlo": hlo_rel, "weights": weight_files, "params": params,
            "inputs": inputs, "outputs": output_names,
        }

        # Golden fixtures: run the jax fn on fixed inputs, save in/out pairs.
        if golden_inputs is not None:
            for set_name, trees in weight_trees.items():
                outs = fn(*trees, *golden_inputs)
                if not isinstance(outs, tuple):
                    outs = (outs,)
                rel = f"golden/{name}.{set_name}.bin"
                with open(os.path.join(self.out, rel), "wb") as f:
                    f.write(struct.pack("<II", len(golden_inputs), len(outs)))
                    for a in list(golden_inputs) + list(outs):
                        a = np.asarray(a)
                        kind = 1 if a.dtype == np.int32 else 0
                        f.write(struct.pack("<II", kind, a.size))
                        f.write(a.astype("<i4" if kind else "<f4").tobytes())
                self.manifest["artifacts"][name].setdefault("golden", {})[set_name] = rel

    def finish(self, lut):
        self.manifest["lut"] = lut
        # Human-readable JSON (debugging) + line-based .txt files that the
        # rust side parses without a JSON dependency (offline crate set).
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        with open(os.path.join(self.out, "lut.json"), "w") as f:
            json.dump(lut, f, indent=1)
        self._write_manifest_txt()
        self._write_lut_txt(lut)

    def _write_manifest_txt(self):
        m = self.manifest
        lines = [f"meta img {m['img']} tokens {m['tokens']} dim {m['dim']} "
                 f"depth {m['depth']} clip_tokens {m['clip_tokens']} "
                 f"clip_dim {m['clip_dim']} prompt_tokens {m['prompt_tokens']} "
                 f"vocab {m['vocab']} num_classes {m['num_classes']}"]
        for name, a in m["artifacts"].items():
            lines.append(f"artifact {name}")
            lines.append(f"hlo {a['hlo']}")
            for set_name, rel in a["weights"].items():
                lines.append(f"weights {set_name} {rel}")
            for p in a["params"]:
                dims = ",".join(str(d) for d in p["shape"]) or "scalar"
                lines.append(f"param {p['name']} {p['dtype']} {dims}")
            for i in a["inputs"]:
                dims = ",".join(str(d) for d in i["shape"]) or "scalar"
                lines.append(f"input {i['name']} {i['dtype']} {dims}")
            for o in a["outputs"]:
                lines.append(f"output {o}")
            for set_name, rel in a.get("golden", {}).items():
                lines.append(f"golden {set_name} {rel}")
            lines.append("end")
        with open(os.path.join(self.out, "manifest.txt"), "w") as f:
            f.write("\n".join(lines) + "\n")

    def _write_lut_txt(self, lut):
        lines = [f"sam_activation_mb {lut['paper_sam_activation_mb']}"]
        for tier, e in lut["tiers"].items():
            lines.append(
                f"tier {tier} ratio {e['ratio']} code_width {e['code_width']} "
                f"data_mb {e['data_size_mb']} payload_bytes {e['real_payload_bytes']} "
                f"orig_giou {e['acc_orig']['giou']:.6f} orig_ciou {e['acc_orig']['ciou']:.6f} "
                f"ft_giou {e['acc_ft']['giou']:.6f} ft_ciou {e['acc_ft']['ciou']:.6f}")
        for split, st in lut["sweep"].items():
            lines.append(f"sweep {split} giou {st['giou']:.6f} ciou {st['ciou']:.6f}")
        for mset, st in lut["full"].items():
            lines.append(f"full {mset} giou {st['giou']:.6f} ciou {st['ciou']:.6f}")
        with open(os.path.join(self.out, "lut.txt"), "w") as f:
            f.write("\n".join(lines) + "\n")


# ----------------------------------------------------------------------------
# Export-path wrapper fns (minimal parameter subsets per path)
# ----------------------------------------------------------------------------

def _bb_prefix_sub(bb, split):
    sub = {k: bb[k] for k in ("patch_w", "patch_b", "pos")}
    sub["blocks"] = {k: v[:split] for k, v in bb["blocks"].items()}
    return sub


def _bb_suffix_sub(bb, split):
    sub = {k: bb[k] for k in ("neck_g", "neck_b", "neck_w", "neck_bias")}
    if split < M.DEPTH:
        sub["blocks"] = {k: v[split:] for k, v in bb["blocks"].items()}
    return sub


def _bn_enc_sub(bn):
    return {k: bn[k] for k in ("mu", "sigma", "enc_w", "enc_b")}


def _bn_dec_sub(bn):
    return {k: bn[k] for k in ("dec_w1", "dec_b1", "dec_w2", "dec_b2", "mu", "sigma")}


def head_fn(split):
    def f(bb, clip, bne, img):
        h = M.backbone_prefix(bb, img, split, use_pallas=True)
        code = M.bottleneck_encode(bne, h, use_pallas=True)
        ct, cp = M.clip_encode(clip, img, use_pallas=True)
        return code, ct, cp
    return f


def tail_fn(split):
    def f(bb, llm, dec, bnd, code, ct, pids):
        h = M.bottleneck_decode(bnd, code, use_pallas=True)
        feats = M.backbone_suffix(bb, h, split, use_pallas=True)
        seg, pres = M.llm_trunk(llm, ct, pids, use_pallas=True)
        return M.mask_decoder(dec, feats, seg), pres
    return f


def context_edge_fn(clip, img):
    return M.clip_encode(clip, img, use_pallas=True)


def context_respond_fn(llm, ct, pids):
    return M.context_respond({"llm": llm}, ct, pids, use_pallas=True)


def full_fn(model, img, pids):
    return M.full_pipeline(model, img, pids, use_pallas=True)


# ----------------------------------------------------------------------------
# Main build
# ----------------------------------------------------------------------------

def build(out_dir: str, quick: bool = False, log=print):
    t0 = time.time()
    steps_orig, steps_ft, steps_bn = (60, 40, 80) if quick else (1300, 450, 2500)
    n_scenes = 24 if quick else 100

    for sub in ("hlo", "weights", "data", "golden", "fixtures"):
        os.makedirs(os.path.join(out_dir, sub), exist_ok=True)

    # ---- datasets (paper §5.1.2: ~100 images, 70/30, photometric x3) ----
    log("== datasets ==")
    generic = D.build_corpus("generic", n_scenes, seed0=1000)
    flood = D.build_corpus("flood", n_scenes, seed0=2000)
    g_train, g_val = D.train_val_split(generic)
    f_train, f_val = D.train_val_split(flood)
    g_train_x = D.expand_training(g_train)
    f_train_x = D.expand_training(f_train)
    for nm, scenes in (("generic_train", g_train_x), ("generic_val", g_val),
                       ("flood_train", f_train_x), ("flood_val", f_val)):
        D.write_scenes(os.path.join(out_dir, "data", f"{nm}.bin"), scenes)
    log(f"  generic train/val = {len(g_train_x)}/{len(g_val)}, "
        f"flood train/val = {len(f_train_x)}/{len(f_val)}")

    arr_g_train = T.scenes_to_arrays(g_train_x)
    arr_g_val = T.scenes_to_arrays(g_val)
    arr_f_train = T.scenes_to_arrays(f_train_x)
    arr_f_val = T.scenes_to_arrays(f_val)
    arr_mixed = tuple(jnp.concatenate([a, b], axis=0)
                      for a, b in zip(arr_g_train, arr_f_train))

    # ---- stages 1+2: model training (checkpointed so export iterations
    # don't retrain; delete artifacts/checkpoint.pkl to force a retrain) ----
    ckpt_path = os.path.join(out_dir, "checkpoint.pkl")
    if os.path.exists(ckpt_path):
        log("== loading cached checkpoint ==")
        import pickle
        with open(ckpt_path, "rb") as f:
            ck = pickle.load(f)
        model_o = jax.tree_util.tree_map(jnp.asarray, ck["orig"])
        model_f = jax.tree_util.tree_map(jnp.asarray, ck["ft"])
    else:
        log("== train Original model ==")
        model_o = M.init_model(seed=0)
        log(f"  params: {M.count_params(model_o):,}")
        model_o = T.train_model(model_o, arr_g_train, steps_orig, batch=16,
                                lr=2e-3, seed=1,
                                trainable=("backbone", "clip", "llm", "decoder"),
                                log=log, tag="orig")
        log("== fine-tune on Flood-ReasonSeg (backbone+CLIP frozen) ==")
        model_f = jax.tree_util.tree_map(lambda x: x, model_o)  # copy
        model_f = T.train_model(model_f, arr_f_train, steps_ft, batch=16,
                                lr=1e-3, seed=2, trainable=("llm", "decoder"),
                                log=log, tag="ft")
        import pickle
        with open(ckpt_path, "wb") as f:
            pickle.dump({"orig": jax.tree_util.tree_map(np.asarray, model_o),
                         "ft": jax.tree_util.tree_map(np.asarray, model_f)}, f)

    full_o = T.eval_full(model_o, arr_g_val)
    full_f = T.eval_full(model_f, arr_f_val)
    log(f"  full-pipeline avg IoU: orig(generic val)={full_o['avg_iou']:.4f} "
        f"ft(flood val)={full_f['avg_iou']:.4f}")

    # ---- stage 3: bottlenecks (BottleFit-style, frozen base) ----
    log("== train bottlenecks ==")
    bns = {}
    wanted = [(TIER_SPLIT, name, r) for name, r in TIERS.items()]
    wanted += [(s, SWEEP_TIER, TIERS[SWEEP_TIER]) for s in SWEEP_SPLITS if s != TIER_SPLIT]
    # Task distillation is available (train.distill_bottleneck) but disabled
    # by default: after the global-standardization fix the reconstruction-
    # trained bottleneck is already near-lossless (HA within ~4 IoU points of
    # the uncompressed pipeline), and distilling toward one model's decoder
    # measurably hurt the other's accuracy. See DESIGN.md "Substitutions" #5.
    act_cache = {}
    steps_distill = 0
    seg_o = T.precompute_seg_embeds(model_o, arr_mixed[0], arr_mixed[1])
    seg_f = T.precompute_seg_embeds(model_f, arr_mixed[0], arr_mixed[1])
    targets = [(model_o, seg_o), (model_f, seg_f)]
    for split, tier, ratio in wanted:
        if split not in act_cache:
            act_cache[split] = T.precompute_activations(model_o, arr_mixed[0], split)
        bn = T.train_bottleneck(
            model_o, split, ratio, arr_mixed, steps_bn, batch=16, lr=2e-3,
            seed=100 + split * 10 + int(ratio * 100), log=log,
            activations=act_cache[split])
        bn = T.distill_bottleneck(
            targets, bn, split, act_cache[split], arr_mixed[2],
            steps_distill, batch=8, lr=1e-3,
            seed=200 + split * 10 + int(ratio * 100), log=log)
        bns[(split, tier)] = bn

    # ---- LUT profiling (Table 3 analog) ----
    log("== profile LUT ==")
    lut = {"tiers": {}, "sweep": {}, "paper_sam_activation_mb": PAPER_SAM_ACTIVATION_MB,
           "full": {"orig": full_o, "ft": full_f}}
    for tier, ratio in TIERS.items():
        bn = bns[(TIER_SPLIT, tier)]
        st_o = T.eval_split_tier(model_o, bn, TIER_SPLIT, arr_g_val)
        st_f = T.eval_split_tier(model_f, bn, TIER_SPLIT, arr_f_val)
        m_width = M.code_width(ratio)
        real_payload = M.TOKENS * m_width + M.CLIP_TOKENS * M.CLIP_DIM + M.CLIP_DIM
        lut["tiers"][tier] = {
            "ratio": ratio, "code_width": m_width,
            "acc_orig": st_o, "acc_ft": st_f,
            "data_size_mb": PAPER_DATA_SIZE_MB[tier],
            "real_payload_bytes": int(real_payload),
        }
        log(f"  {tier:16s} r={ratio:.2f} IoU orig={st_o['avg_iou']:.4f} "
            f"ft={st_f['avg_iou']:.4f} wire={PAPER_DATA_SIZE_MB[tier]} MB")
    for split in SWEEP_SPLITS:
        tier = SWEEP_TIER if split != TIER_SPLIT else SWEEP_TIER
        bn = bns[(split, tier)]
        st = T.eval_split_tier(model_o, bn, split, arr_g_val)
        lut["sweep"][str(split)] = st
        log(f"  sweep sp{split} IoU={st['avg_iou']:.4f}")

    # ---- HLO export ----
    log("== export HLO artifacts ==")
    ex = Exporter(out_dir)
    img_spec = jax.ShapeDtypeStruct((M.IMG, M.IMG, 3), np.float32)
    pid_spec = jax.ShapeDtypeStruct((M.PROMPT_TOKENS,), np.int32)
    ct_spec = jax.ShapeDtypeStruct((M.CLIP_TOKENS, M.CLIP_DIM), np.float32)

    g_img = jnp.asarray(f_val[0].image)
    g_pids = jnp.asarray(D.tokenize(f_val[0].prompts[0][1]))
    g_ct, _ = M.clip_encode(model_o["clip"], g_img, use_pallas=False)

    # Heads (backbone+CLIP are shared/frozen -> single weight set).
    for split, tier in bns:
        name = f"head_sp{split}_{tier}"
        bn = bns[(split, tier)]
        ex.export(
            name, head_fn(split),
            {"shared": (_bb_prefix_sub(model_o["backbone"], split),
                        model_o["clip"], _bn_enc_sub(bn))},
            {"img": img_spec},
            ["code", "clip_tokens", "clip_pooled"],
            golden_inputs=(g_img,))
        log(f"  {name}")

    # Tails (orig + ft weight sets share one HLO).
    for split, tier in bns:
        name = f"tail_sp{split}_{tier}"
        bn = bns[(split, tier)]
        ratio = TIERS[tier]
        code_spec = jax.ShapeDtypeStruct((M.TOKENS, M.code_width(ratio)), np.float32)
        g_code = M.bottleneck_encode(
            bn, M.backbone_prefix(model_o["backbone"], g_img, split, use_pallas=False),
            use_pallas=False)
        sets = {
            "orig": (_bb_suffix_sub(model_o["backbone"], split), model_o["llm"],
                     model_o["decoder"], _bn_dec_sub(bn)),
            "ft": (_bb_suffix_sub(model_f["backbone"], split), model_f["llm"],
                   model_f["decoder"], _bn_dec_sub(bn)),
        }
        ex.export(name, tail_fn(split), sets,
                  {"code": code_spec, "clip_tokens": ct_spec, "prompt_ids": pid_spec},
                  ["mask_logits", "presence_logits"],
                  golden_inputs=(g_code, g_ct, g_pids))
        log(f"  {name}")

    # Context pair.
    ex.export("context_edge", context_edge_fn, {"shared": (model_o["clip"],)},
              {"img": img_spec}, ["clip_tokens", "clip_pooled"],
              golden_inputs=(g_img,))
    ex.export("context_respond", context_respond_fn,
              {"orig": (model_o["llm"],), "ft": (model_f["llm"],)},
              {"clip_tokens": ct_spec, "prompt_ids": pid_spec},
              ["presence_logits"], golden_inputs=(g_ct, g_pids))
    log("  context_edge / context_respond")

    # Full pipeline (full-edge baseline + raw-compression server side).
    ex.export("full_pipeline", full_fn, {"orig": (model_o,), "ft": (model_f,)},
              {"img": img_spec, "prompt_ids": pid_spec},
              ["mask_logits", "presence_logits"],
              golden_inputs=(g_img, g_pids))
    log("  full_pipeline")

    # Tokenizer parity fixture (ids<TAB>prompt per line for the rust test).
    prompts = sum(list(D.INSIGHT_PROMPTS.values()), []) + D.CONTEXT_PROMPTS
    with open(os.path.join(out_dir, "fixtures", "tokenizer.json"), "w") as f:
        json.dump([{"prompt": p, "ids": D.tokenize(p).tolist()} for p in prompts],
                  f, indent=1)
    with open(os.path.join(out_dir, "fixtures", "tokenizer.txt"), "w") as f:
        for p in prompts:
            f.write(",".join(map(str, D.tokenize(p).tolist())) + "\t" + p + "\n")

    ex.finish(lut)
    log(f"== done in {time.time() - t0:.1f}s ==")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training budget (CI / pytest smoke)")
    args = ap.parse_args()
    build(os.path.abspath(args.out), quick=args.quick)


if __name__ == "__main__":
    main()
