"""Synthetic Flood-ReasonSeg dataset generator.

The paper's Flood-ReasonSeg is a proprietary ~100-image flood corpus annotated
in ReasonSeg format (NL instruction + segmentation mask) for two classes:
stranded individuals and stranded vehicles.  We cannot obtain it, so this
module procedurally generates the closest synthetic equivalent (see DESIGN.md
"Substitutions"): flood scenes with a water plane, rooftops, person blobs and
partially-submerged vehicle rectangles, each paired with per-class GT masks
and NL instructions in both Context-level and Insight-level phrasings.

A second, "generic" corpus (same classes on dry random backgrounds) plays the
role of the original ReasonSeg-style training distribution used to train the
Base/Original model; the flood corpus fine-tunes it, mirroring the paper's
LoRA fine-tuning protocol (Section 5.1.2: ~100 images, 70/30 split,
photometric augmentation to ~300 training samples).

Everything is generated from fixed seeds so `make artifacts` is reproducible.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import List, Tuple

import numpy as np

IMG = 64  # image side (pixels)
CLASSES = ("person", "vehicle")
PERSON, VEHICLE = 0, 1

# Insight-level instruction templates (require grounded masks).
INSIGHT_PROMPTS = {
    PERSON: [
        "find and mark anyone who might need rescue",
        "detect individuals who may need to be rescued",
        "highlight the people stranded by the flood",
        "segment every person visible in the scene",
        "locate and outline individuals near the water",
    ],
    VEHICLE: [
        "recognize and mark cars stranded during flooding",
        "highlight the vehicles stranded by floodwater",
        "segment the partially submerged vehicles",
        "mark every car trapped in the water",
        "outline vehicles that are stuck in the flood",
    ],
}

# Context-level prompts (text-only triage; no mask needed).
CONTEXT_PROMPTS = [
    "what is happening in this sector",
    "are there any living beings on the rooftops",
    "is anyone visible in this area",
    "describe the current flood situation",
    "are there any stranded vehicles here",
    "give me a quick status of this scene",
]


@dataclasses.dataclass
class Scene:
    image: np.ndarray  # (IMG, IMG, 3) float32 in [0,1]
    masks: np.ndarray  # (2, IMG, IMG) float32 {0,1}, per class
    prompts: List[Tuple[int, str]]  # (class_id, insight prompt text)


def _disk(mask: np.ndarray, cy: float, cx: float, r: float) -> None:
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    mask[(yy - cy) ** 2 + (xx - cx) ** 2 <= r * r] = 1.0


def _rect(mask: np.ndarray, y0: int, x0: int, h: int, w: int) -> None:
    mask[max(0, y0) : min(IMG, y0 + h), max(0, x0) : min(IMG, x0 + w)] = 1.0


def _water_line(rng: np.random.Generator) -> np.ndarray:
    """Wavy horizontal waterline height per column (flood surface)."""
    base = rng.uniform(0.45, 0.7) * IMG
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.05, 0.15)
    amp = rng.uniform(1.0, 4.0)
    cols = np.arange(IMG)
    return base + amp * np.sin(freq * cols + phase)


def _paint_person(img: np.ndarray, masks: np.ndarray, rng: np.random.Generator,
                  cy: float, cx: float) -> None:
    """A person is a small bright red/orange blob (life vest) with a head dot."""
    r = rng.uniform(2.8, 4.2)
    m = np.zeros((IMG, IMG), np.float32)
    _disk(m, cy, cx, r)
    _disk(m, cy - r * 1.2, cx, r * 0.55)  # head
    color = np.array([rng.uniform(0.75, 1.0), rng.uniform(0.1, 0.35),
                      rng.uniform(0.05, 0.25)], np.float32)
    img[m > 0] = color
    masks[PERSON][m > 0] = 1.0


def _paint_vehicle(img: np.ndarray, masks: np.ndarray, rng: np.random.Generator,
                   y0: int, x0: int, submerge_to: int | None) -> None:
    """A vehicle is a dark rectangle with a lighter cabin; optionally clipped
    by the waterline (partially submerged)."""
    h, w = int(rng.integers(7, 11)), int(rng.integers(12, 19))
    m = np.zeros((IMG, IMG), np.float32)
    _rect(m, y0, x0, h, w)
    _rect(m, y0 - h // 2, x0 + w // 4, h // 2, w // 2)  # cabin
    if submerge_to is not None:
        m[submerge_to:, :] = 0.0  # everything below waterline is hidden
    body = np.array([rng.uniform(0.1, 0.3), rng.uniform(0.1, 0.3),
                     rng.uniform(0.35, 0.7)], np.float32)
    img[m > 0] = body
    masks[VEHICLE][m > 0] = 1.0


def make_flood_scene(seed: int) -> Scene:
    """One synthetic flood scene with GT masks and insight prompts."""
    rng = np.random.default_rng(seed)
    img = np.zeros((IMG, IMG, 3), np.float32)
    masks = np.zeros((2, IMG, IMG), np.float32)

    # Sky / terrain upper region.
    sky = np.array([0.55, 0.62, 0.55]) + rng.uniform(-0.08, 0.08, 3)
    img[:, :] = sky.astype(np.float32)
    # Murky floodwater below the waterline.
    wl = _water_line(rng)
    yy = np.arange(IMG)[:, None]
    water = yy >= wl[None, :]
    wcol = np.array([0.25, 0.38, 0.55]) + rng.uniform(-0.05, 0.05, 3)
    img[water] = wcol.astype(np.float32)
    # Ripples.
    ripple = 0.03 * np.sin(np.arange(IMG)[None, :] * 0.9 + yy * 0.7)
    img[..., 2] += np.where(water, ripple, 0.0).astype(np.float32)

    # Rooftops poking above the water (grey quadrilaterals).
    for _ in range(int(rng.integers(1, 4))):
        rx = int(rng.integers(4, IMG - 18))
        rw = int(rng.integers(10, 18))
        ry = int(np.clip(wl[rx] - rng.integers(4, 10), 2, IMG - 8))
        roof = np.zeros((IMG, IMG), np.float32)
        _rect(roof, ry, rx, int(rng.integers(4, 7)), rw)
        g = rng.uniform(0.42, 0.58)
        img[roof > 0] = np.array([g, g * 0.95, g * 0.9], np.float32)
        # Sometimes a person on the roof.
        if rng.random() < 0.7:
            _paint_person(img, masks, rng, ry - 1, rx + rng.integers(2, rw - 2))

    # Partially submerged vehicles near the waterline.
    for _ in range(int(rng.integers(1, 3))):
        vx = int(rng.integers(2, IMG - 16))
        vy = int(np.clip(wl[vx] - rng.integers(1, 4), 4, IMG - 10))
        _paint_vehicle(img, masks, rng, vy, vx, submerge_to=int(wl[vx] + 3))

    # People in the water.
    for _ in range(int(rng.integers(0, 3))):
        px = rng.uniform(4, IMG - 4)
        py = np.clip(wl[int(px)] + rng.uniform(0, 6), 4, IMG - 4)
        _paint_person(img, masks, rng, py, px)

    np.clip(img, 0.0, 1.0, out=img)
    prompts = []
    for cls in (PERSON, VEHICLE):
        if masks[cls].sum() > 0:
            t = INSIGHT_PROMPTS[cls][int(rng.integers(len(INSIGHT_PROMPTS[cls])))]
            prompts.append((cls, t))
    if not prompts:  # guarantee at least one queryable target
        _paint_person(img, masks, rng, IMG * 0.3, IMG * 0.5)
        prompts.append((PERSON, INSIGHT_PROMPTS[PERSON][0]))
    return Scene(image=img, masks=masks, prompts=prompts)


def make_generic_scene(seed: int) -> Scene:
    """Generic (non-flood) scene: same classes on dry random backgrounds.
    Plays the role of the original ReasonSeg-style training distribution."""
    rng = np.random.default_rng(seed + 10_000_019)
    img = np.zeros((IMG, IMG, 3), np.float32)
    masks = np.zeros((2, IMG, IMG), np.float32)
    base = rng.uniform(0.35, 0.7, 3).astype(np.float32)
    img[:, :] = base
    # Low-frequency background texture.
    gx = np.linspace(0, rng.uniform(2, 5) * np.pi, IMG)
    img += (0.05 * np.sin(gx)[None, :, None]).astype(np.float32)
    for _ in range(int(rng.integers(1, 4))):
        _paint_person(img, masks, rng, rng.uniform(6, IMG - 6), rng.uniform(6, IMG - 6))
    for _ in range(int(rng.integers(1, 3))):
        _paint_vehicle(img, masks, rng, int(rng.integers(6, IMG - 12)),
                       int(rng.integers(2, IMG - 16)), submerge_to=None)
    np.clip(img, 0.0, 1.0, out=img)
    prompts = []
    for cls in (PERSON, VEHICLE):
        if masks[cls].sum() > 0:
            t = INSIGHT_PROMPTS[cls][int(rng.integers(len(INSIGHT_PROMPTS[cls])))]
            prompts.append((cls, t))
    return Scene(image=img, masks=masks, prompts=prompts)


def photometric_augment(scene: Scene, seed: int) -> Scene:
    """Photometric-only augmentation (brightness/contrast/hue jitter + noise),
    as in the paper — geometry and masks unchanged."""
    rng = np.random.default_rng(seed + 77_777)
    img = scene.image.copy()
    img = img * rng.uniform(0.8, 1.2) + rng.uniform(-0.08, 0.08)
    img = 0.5 + (img - 0.5) * rng.uniform(0.85, 1.2)  # contrast
    img = img * (1.0 + rng.uniform(-0.06, 0.06, 3)).astype(np.float32)  # channel tint
    img = img + rng.normal(0, 0.015, img.shape).astype(np.float32)
    return Scene(image=np.clip(img, 0, 1).astype(np.float32),
                 masks=scene.masks, prompts=scene.prompts)


def build_corpus(kind: str, n: int, seed0: int) -> List[Scene]:
    make = make_flood_scene if kind == "flood" else make_generic_scene
    return [make(seed0 + i) for i in range(n)]


def train_val_split(scenes: List[Scene], train_frac: float = 0.7):
    k = int(round(len(scenes) * train_frac))
    return scenes[:k], scenes[k:]


def expand_training(scenes: List[Scene], factor: int = 3) -> List[Scene]:
    """70 originals -> ~300 samples via photometric augmentation (paper §5.1.2:
    originals are kept and each contributes `factor` augmented copies)."""
    out: List[Scene] = list(scenes)
    for i, s in enumerate(scenes):
        for j in range(factor):
            out.append(photometric_augment(s, seed=i * 31 + j))
    return out


# ---------------------------------------------------------------------------
# Hash tokenizer — MUST stay in exact sync with rust/src/coordinator/intent.rs
# (FNV-1a 32-bit over lowercase alphanumeric words, vocab 512, id 0 = PAD).
# ---------------------------------------------------------------------------

VOCAB = 512
MAX_PROMPT_TOKENS = 16


def fnv1a32(s: str) -> int:
    h = 0x811C9DC5
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * 0x01000193) & 0xFFFFFFFF
    return h


def tokenize(prompt: str) -> np.ndarray:
    """Prompt -> fixed-length int32 token ids (hashed vocab, PAD=0)."""
    words, cur = [], []
    for ch in prompt.lower():
        if ch.isalnum():
            cur.append(ch)
        elif cur:
            words.append("".join(cur))
            cur = []
    if cur:
        words.append("".join(cur))
    ids = [1 + fnv1a32(w) % (VOCAB - 1) for w in words[:MAX_PROMPT_TOKENS]]
    ids += [0] * (MAX_PROMPT_TOKENS - len(ids))
    return np.asarray(ids, np.int32)


# ---------------------------------------------------------------------------
# Binary serialization consumed by rust/src/dataset/loader.rs.
# Format (little-endian):
#   magic  u32 = 0x41565259 ("AVRY")
#   version u32 = 1
#   n_scenes u32, img u32
#   per scene:
#     image  f32[img*img*3]
#     masks  f32[2*img*img]
#     n_prompts u32
#     per prompt: class u32, len u32, utf8 bytes
# ---------------------------------------------------------------------------

MAGIC = 0x41565259


def write_scenes(path: str, scenes: List[Scene]) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<IIII", MAGIC, 1, len(scenes), IMG))
        for s in scenes:
            f.write(s.image.astype("<f4").tobytes())
            f.write(s.masks.astype("<f4").tobytes())
            f.write(struct.pack("<I", len(s.prompts)))
            for cls, text in s.prompts:
                raw = text.encode("utf-8")
                f.write(struct.pack("<II", cls, len(raw)))
                f.write(raw)


def read_scenes(path: str) -> List[Scene]:
    """Python-side reader (used by tests to check round-trip parity)."""
    scenes = []
    with open(path, "rb") as f:
        magic, ver, n, img = struct.unpack("<IIII", f.read(16))
        assert magic == MAGIC and ver == 1 and img == IMG
        for _ in range(n):
            image = np.frombuffer(f.read(img * img * 3 * 4), "<f4").reshape(img, img, 3)
            masks = np.frombuffer(f.read(2 * img * img * 4), "<f4").reshape(2, img, img)
            (np_,) = struct.unpack("<I", f.read(4))
            prompts = []
            for _ in range(np_):
                cls, ln = struct.unpack("<II", f.read(8))
                prompts.append((cls, f.read(ln).decode("utf-8")))
            scenes.append(Scene(image.copy(), masks.copy(), prompts))
    return scenes
