"""Build-time training for mini-LISA and the learned bottlenecks.

Mirrors the paper's training protocol at mini scale:

1. **Original model** — full training on the generic (ReasonSeg-style)
   corpus: mask BCE + Dice on the prompted class, plus presence BCE so the
   Context path (text-only triage) is also learned.
2. **Fine-tuned model** — starting from Original, the SAM backbone and CLIP
   encoder are *frozen* (the paper LoRA-tunes only the LLM side) and the LLM
   trunk + mask decoder are adapted on Flood-ReasonSeg.
3. **Bottlenecks** — one per (split point, compression ratio), trained with
   the base model frozen: activation-reconstruction MSE plus a downstream
   task-distillation term, exactly the BottleFit recipe the paper cites [11].
   Includes a straight-through int8 quantization step so the trained code is
   robust to the rust wire layer's quantizer.

Optimizer is a hand-rolled Adam (optax is not in the image).  All training
uses the pure-jnp oracles (use_pallas=False); the exported artifacts run the
Pallas kernels, which test_kernels.py proves numerically identical.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


# ----------------------------------------------------------------------------
# Hand-rolled Adam
# ----------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda x: x / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda x: x / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps),
                                 params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------------
# Dataset -> arrays
# ----------------------------------------------------------------------------

def scenes_to_arrays(scenes: List[D.Scene]):
    """One training sample per (scene, insight prompt): image, prompt ids,
    class mask target, per-scene presence target."""
    imgs, pids, masks, pres = [], [], [], []
    for s in scenes:
        presence = (s.masks.reshape(2, -1).sum(axis=1) > 0).astype(np.float32)
        for cls, text in s.prompts:
            imgs.append(s.image)
            pids.append(D.tokenize(text))
            masks.append(s.masks[cls])
            pres.append(presence)
    return (jnp.asarray(np.stack(imgs)), jnp.asarray(np.stack(pids)),
            jnp.asarray(np.stack(masks)), jnp.asarray(np.stack(pres)))


# ----------------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------------

def bce_logits(logits, targets, pos_weight: float = 1.0):
    """Binary cross-entropy on logits with optional positive-class weight
    (masks are ~2-5% positive pixels; pos_weight counters the imbalance)."""
    per = (jnp.maximum(logits, 0) - logits * targets +
           jnp.log1p(jnp.exp(-jnp.abs(logits))))
    if pos_weight != 1.0:
        per = per * (1.0 + (pos_weight - 1.0) * targets)
    return jnp.mean(per)


def dice_loss(logits, targets, eps=1.0):
    p = jax.nn.sigmoid(logits)
    num = 2.0 * jnp.sum(p * targets) + eps
    den = jnp.sum(p) + jnp.sum(targets) + eps
    return 1.0 - num / den


def _sample_loss(model, img, pids, mask, presence):
    logits, pres_logits = M.full_pipeline(model, img, pids, use_pallas=False)
    return (bce_logits(logits, mask, pos_weight=4.0) + dice_loss(logits, mask)
            + 0.5 * bce_logits(pres_logits, presence))


def batch_loss(model, imgs, pids, masks, pres):
    losses = jax.vmap(lambda i, p, m, q: _sample_loss(model, i, p, m, q))(
        imgs, pids, masks, pres)
    return jnp.mean(losses)


# ----------------------------------------------------------------------------
# Stage 1/2: model training
# ----------------------------------------------------------------------------

def _batches(n, batch, steps, seed):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        yield rng.integers(0, n, size=batch)


def train_model(model, arrays, steps: int, batch: int, lr: float, seed: int,
                trainable: Tuple[str, ...], log=print, tag="train"):
    """Train `trainable` sub-trees of the model; others stay frozen."""
    imgs, pids, masks, pres = arrays

    frozen = {k: v for k, v in model.items() if k not in trainable}
    live = {k: v for k, v in model.items() if k in trainable}

    @jax.jit
    def step_fn(live_p, opt, lr_t, bi, bp, bm, bq):
        def loss_fn(lp):
            return batch_loss({**frozen, **lp}, bi, bp, bm, bq)
        loss, grads = jax.value_and_grad(loss_fn)(live_p)
        live_n, opt = adam_update(live_p, grads, opt, lr=lr_t)
        return live_n, opt, loss

    opt = adam_init(live)
    n = imgs.shape[0]
    for i, idx in enumerate(_batches(n, batch, steps, seed)):
        # Cosine decay to 10% — squeezes convergence out of a small budget.
        lr_t = lr * (0.1 + 0.9 * 0.5 * (1.0 + np.cos(np.pi * i / steps)))
        live, opt, loss = step_fn(live, opt, lr_t, imgs[idx], pids[idx],
                                  masks[idx], pres[idx])
        if i % 50 == 0 or i == steps - 1:
            log(f"  [{tag}] step {i:4d}/{steps} loss {float(loss):.4f}")
    return {**frozen, **live}


# ----------------------------------------------------------------------------
# Stage 3: bottleneck training (BottleFit-style, frozen base model)
# ----------------------------------------------------------------------------

def _st_quant(z):
    """Straight-through int8 quantization of the tanh-bounded code: forward
    quantizes exactly like rust/src/packet (round to 127 levels), backward is
    identity — so the bottleneck trains against real wire error."""
    q = jnp.round(z * 127.0) / 127.0
    return z + jax.lax.stop_gradient(q - z)


def precompute_activations(model, imgs, split: int, batch: int = 16):
    """Run the frozen SAM prefix once over the corpus -> (N, TOKENS, DIM).
    Bottleneck training then never touches the base model again — the single
    biggest build-time saving on the 1-core CI box."""
    fwd = jax.jit(jax.vmap(
        lambda i: M.backbone_prefix(model["backbone"], i, split, use_pallas=False)),
        static_argnums=())
    outs = []
    for s in range(0, imgs.shape[0], batch):
        outs.append(np.asarray(fwd(imgs[s:s + batch])))
    return jnp.asarray(np.concatenate(outs, axis=0))


def train_bottleneck(model, split: int, ratio: float, arrays, steps: int,
                     batch: int, lr: float, seed: int, log=print,
                     activations=None):
    """BottleFit-style bottleneck at `split` with ratio `ratio`.

    Trained on *normalized* activation reconstruction with straight-through
    int8 wire quantization.  (The paper's recipe adds task distillation; at
    mini-LISA scale reconstruction alone recovers the same fidelity ordering
    and keeps `make artifacts` tractable on one core — noted in DESIGN.md.)
    """
    imgs = arrays[0]
    bn = M.init_bottleneck(jax.random.PRNGKey(seed), ratio)
    h_all = activations if activations is not None else \
        precompute_activations(model, imgs, split)
    # Corpus statistics for the global standardization (information-
    # preserving, unlike per-token LayerNorm — see kernels/ref.py).
    bn["mu"] = jnp.asarray([float(jnp.mean(h_all))])
    bn["sigma"] = jnp.asarray([float(jnp.std(h_all)) + 1e-6])
    h_scale = jnp.mean(jnp.square(h_all))  # normalize across depths

    @jax.jit
    def step_fn(bn_p, opt, h):
        def loss_fn(p):
            z = M.bottleneck_encode(p, h.reshape(-1, M.DIM), use_pallas=False)
            h_hat = M.bottleneck_decode(p, _st_quant(z), use_pallas=False)
            return jnp.mean(jnp.square(h_hat - h.reshape(-1, M.DIM))) / h_scale
        loss, grads = jax.value_and_grad(loss_fn)(bn_p)
        bn_n, opt = adam_update(bn_p, grads, opt, lr=lr)
        return bn_n, opt, loss

    opt = adam_init(bn)
    n = h_all.shape[0]
    for i, idx in enumerate(_batches(n, batch, steps, seed + 11)):
        bn, opt, loss = step_fn(bn, opt, h_all[idx])
        if i % 200 == 0 or i == steps - 1:
            log(f"  [bn sp{split} r{ratio:.2f}] step {i:4d}/{steps} "
                f"nmse {float(loss):.4f}")
    return bn


def distill_bottleneck(model_targets, bn, split: int, h_all, masks, steps: int,
                       batch: int, lr: float, seed: int, log=print):
    """Task-distillation fine-tune of a recon-pretrained bottleneck (the
    BottleFit recipe [11] the paper uses): with the base models frozen, push
    gradients through the frozen SAM suffix + decoder so the bottleneck keeps
    the information the *mask head* needs, not just what MSE needs.

    model_targets: list of (model, seg_all) — the bottleneck is shared
      between the Original and Fine-tuned deployments (the SAM backbone is
      frozen across both), so distillation alternates between both models'
      decoders to avoid over-fitting the code to one of them.
    h_all: (N, TOKENS, DIM) precomputed split activations (shared backbone)
    masks: (N, IMG, IMG) GT masks for the prompted class
    """

    def make_step(model):
        def path(bn_p, h, seg):
            z = M.bottleneck_encode(bn_p, h, use_pallas=False)
            h_hat = M.bottleneck_decode(bn_p, _st_quant(z), use_pallas=False)
            feats = M.backbone_suffix(model["backbone"], h_hat, split,
                                      use_pallas=False)
            return M.mask_decoder(model["decoder"], feats, seg)

        @jax.jit
        def step_fn(bn_p, opt, bh, bs, bm):
            def loss_fn(p):
                logits = jax.vmap(lambda h, s: path(p, h, s))(bh, bs)
                return bce_logits(logits, bm, pos_weight=4.0) + dice_loss(logits, bm)
            loss, grads = jax.value_and_grad(loss_fn)(bn_p)
            bn_n, opt = adam_update(bn_p, grads, opt, lr=lr)
            return bn_n, opt, loss

        return step_fn

    steps_fns = [make_step(m) for m, _ in model_targets]
    opt = adam_init(bn)
    n = h_all.shape[0]
    for i, idx in enumerate(_batches(n, batch, steps, seed + 31)):
        which = i % len(model_targets)
        seg_all = model_targets[which][1]
        bn, opt, loss = steps_fns[which](bn, opt, h_all[idx], seg_all[idx], masks[idx])
        if i % 40 == 0 or i == steps - 1:
            log(f"  [distill sp{split}] step {i:4d}/{steps} loss {float(loss):.4f}")
    return bn


def precompute_seg_embeds(model, imgs, pids, batch: int = 32):
    """Frozen prompt-side pass: CLIP + LLM trunk -> (N, NECK) seg embeds."""
    def one(img, pid):
        ct, _ = M.clip_encode(model["clip"], img, use_pallas=False)
        seg, _ = M.llm_trunk(model["llm"], ct, pid, use_pallas=False)
        return seg
    fwd = jax.jit(jax.vmap(one))
    outs = []
    for s in range(0, imgs.shape[0], batch):
        outs.append(np.asarray(fwd(imgs[s:s + batch], pids[s:s + batch])))
    return jnp.asarray(np.concatenate(outs, axis=0))


# ----------------------------------------------------------------------------
# Evaluation: gIoU / cIoU (LISA's metrics; "Average IoU" = their mean)
# ----------------------------------------------------------------------------

def iou_stats(pred_masks: np.ndarray, gt_masks: np.ndarray) -> Dict[str, float]:
    """pred/gt: (N, IMG, IMG) binary. gIoU = mean per-sample IoU; cIoU =
    cumulative-intersection / cumulative-union (as in LISA [17])."""
    inter = (pred_masks * gt_masks).reshape(len(pred_masks), -1).sum(axis=1)
    union = ((pred_masks + gt_masks) > 0).reshape(len(pred_masks), -1).sum(axis=1)
    per = np.where(union > 0, inter / np.maximum(union, 1), 1.0)
    giou = float(per.mean())
    ciou = float(inter.sum() / max(union.sum(), 1))
    return {"giou": giou, "ciou": ciou, "avg_iou": 0.5 * (giou + ciou)}


def eval_split_tier(model, bn, split: int, arrays, quantize: bool = True):
    """Run the compressed split pipeline (with wire int8 quantization, like
    the rust runtime does) over a val set and return IoU stats."""
    imgs, pids, masks, _ = arrays

    def fwd(img, pid):
        h = M.backbone_prefix(model["backbone"], img, split, use_pallas=False)
        z = M.bottleneck_encode(bn, h, use_pallas=False)
        if quantize:
            z = jnp.round(z * 127.0) / 127.0
        h_hat = M.bottleneck_decode(bn, z, use_pallas=False)
        feats = M.backbone_suffix(model["backbone"], h_hat, split, use_pallas=False)
        ct, _ = M.clip_encode(model["clip"], img, use_pallas=False)
        seg_embed, _ = M.llm_trunk(model["llm"], ct, pid, use_pallas=False)
        return M.mask_decoder(model["decoder"], feats, seg_embed)

    logits = jax.jit(jax.vmap(fwd))(imgs, pids)
    preds = (np.asarray(logits) > 0.0).astype(np.float32)
    return iou_stats(preds, np.asarray(masks))


def eval_full(model, arrays):
    imgs, pids, masks, _ = arrays
    fwd = lambda img, pid: M.full_pipeline(model, img, pid, use_pallas=False)[0]
    logits = jax.jit(jax.vmap(fwd))(imgs, pids)
    preds = (np.asarray(logits) > 0.0).astype(np.float32)
    return iou_stats(preds, np.asarray(masks))
