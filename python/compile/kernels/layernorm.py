"""Pallas fused LayerNorm kernel (L1).

TPU mental model (see DESIGN.md §Hardware-Adaptation): the token-major tile
lives in VMEM; mean/var/normalize/affine all happen in one pass without a
round-trip to HBM, which is the fusion the paper's GPU stack gets from a
handwritten CUDA LN.  Grid iterates over token tiles so arbitrarily long
token axes stream through a fixed VMEM footprint.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile height: 8 sublanes is the fp32 VPU tiling unit on TPU; tiles of
# (8, C) keep the reduction in-register for C up to a few hundred.
TOKEN_TILE = 8


def _layernorm_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * gamma_ref[...] + beta_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    """Fused LayerNorm over the last axis of a (T, C) tensor."""
    t, c = x.shape
    tile = TOKEN_TILE if t % TOKEN_TILE == 0 else t
    grid = (t // tile,)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, c), x.dtype),
        interpret=True,
    )(x, gamma, beta)
