"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts each Pallas kernel matches
its oracle to float tolerance.  The oracles are also used directly by
model.py when AVERY_USE_PALLAS=0 (debug mode), so they must be exact
functional equivalents, not approximations.
"""

from __future__ import annotations

import jax.numpy as jnp


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm over the last axis. x: (..., C)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Multi-head scaled dot-product attention.

    q, k, v: (H, T, Dh) -> (H, T, Dh).  Full (non-causal) attention, the
    pattern used by both the SAM-style ViT blocks and the LLM trunk.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("htd,hsd->hts", q, k) * scale
    return jnp.einsum("hts,hsd->htd", softmax_ref(logits), v)


def bottleneck_encode_ref(h: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                          w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fused learned-bottleneck encoder: global standardize -> Linear -> tanh.

    h: (T, C) split-point activation; w: (C, M) with M = round(r*C);
    mu/sigma: scalar corpus statistics baked at training time.  The
    standardization is *global* (not per-token LayerNorm): per-token
    magnitude is task information the decoder must be able to restore.
    tanh bounds the code in [-1, 1] so the rust wire layer can int8-quantize
    with a fixed scale (the paper's compressed-activation payload).
    """
    return jnp.tanh((h - mu) / sigma @ w + b)


def bottleneck_decode_ref(z: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                          w2: jnp.ndarray, b2: jnp.ndarray,
                          mu: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """Bottleneck decoder: 2-layer MLP back to the backbone width, then
    un-standardize. z: (T, M) -> (T, C)."""
    hdn = jnp.maximum(z @ w1 + b1, 0.0)
    return (hdn @ w2 + b2) * sigma + mu
