"""Pallas fused multi-head attention kernel (L1).

The paper's LISA-7B burns most of its FLOPs in SAM-ViT / LLM attention; on
the GPU testbed that is a fused flash-style CUDA kernel.  The TPU rethink
(DESIGN.md §Hardware-Adaptation): grid over heads, keep one head's full
(T, Dh) Q/K/V tiles resident in VMEM, and express QK^T and PV as MXU
matmuls.  At the mini-LISA scale (T=64..80, Dh=32) one head's working set is
~50 KB — far under VMEM, so no online-softmax streaming is needed; the win
is the fusion (no logits round-trip to HBM).

interpret=True: CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref):
    # One head per grid step; block shapes carry (1, T, Dh).
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = (q @ k.T) * scale                      # MXU matmul (T, T)
    m = jnp.max(logits, axis=-1, keepdims=True)     # stable softmax in VMEM
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = probs @ v                            # MXU matmul (T, Dh)


@jax.jit
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Fused full (non-causal) MHA. q, k, v: (H, T, Dh) -> (H, T, Dh)."""
    h, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _attention_kernel,
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, t, d), q.dtype),
        interpret=True,
    )(q, k, v)
