"""Pallas fused learned-bottleneck kernels (L1) — the edge hot-spot.

This is AVERY's critical on-UAV computation: compress the split-point SAM
activation before it leaves the device.  On the paper's GPU stack the
BottleFit-style encoder is a conv over a 10.49 MB HBM-resident activation;
the TPU rethink (DESIGN.md §Hardware-Adaptation) expresses it as a single
fused VMEM pass per token tile:

    LayerNorm -> (T_tile, C) @ (C, M) MXU matmul -> tanh

so the only HBM write is the (T, M) code — r x the input bytes.  That is the
same "compress before you leave fast memory" insight the paper applies to
the radio link, applied one level down the memory hierarchy.

The tanh bound lets the rust wire layer quantize the code to int8 with a
fixed scale (packet.rs), completing the paper's compressed-payload format.

interpret=True: CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOKEN_TILE = 8  # fp32 sublane tile; (8, 128) input tile + (128, M) weights « VMEM


def _encode_kernel(h_ref, mu_ref, sigma_ref, w_ref, b_ref, o_ref):
    x = (h_ref[...] - mu_ref[0]) / sigma_ref[0]
    o_ref[...] = jnp.tanh(x @ w_ref[...] + b_ref[...])


@jax.jit
def bottleneck_encode(h: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                      w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fused global-standardize -> Linear(C->M) -> tanh.
    h: (T, C), mu/sigma: (1,) scalars, w: (C, M) -> (T, M)."""
    t, c = h.shape
    m = w.shape[1]
    tile = TOKEN_TILE if t % TOKEN_TILE == 0 else t
    return pl.pallas_call(
        _encode_kernel,
        grid=(t // tile,),
        in_specs=[
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((c, m), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m), h.dtype),
        interpret=True,
    )(h, mu, sigma, w, b)


def _decode_kernel(z_ref, w1_ref, b1_ref, w2_ref, b2_ref, mu_ref, sigma_ref, o_ref):
    hdn = jnp.maximum(z_ref[...] @ w1_ref[...] + b1_ref[...], 0.0)
    o_ref[...] = (hdn @ w2_ref[...] + b2_ref[...]) * sigma_ref[0] + mu_ref[0]


@jax.jit
def bottleneck_decode(z: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                      w2: jnp.ndarray, b2: jnp.ndarray,
                      mu: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """Fused decoder MLP(M->H->C) + un-standardize on the server side.
    One VMEM pass per token tile: both matmuls hit the MXU back to back."""
    t, m = z.shape
    hdim = w1.shape[1]
    c = w2.shape[1]
    tile = TOKEN_TILE if t % TOKEN_TILE == 0 else t
    return pl.pallas_call(
        _decode_kernel,
        grid=(t // tile,),
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((m, hdim), lambda i: (0, 0)),
            pl.BlockSpec((hdim,), lambda i: (0,)),
            pl.BlockSpec((hdim, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, c), z.dtype),
        interpret=True,
    )(z, w1, b1, w2, b2, mu, sigma)
