//! Intent gating walkthrough: shows the hierarchy the paper argues for —
//! intent first selects the admissible stream, then resource adaptation
//! picks the operating point *within* it — across a grid of prompts and
//! bandwidths, without touching the network simulator.
//!
//!     cargo run --release --example intent_gating

use avery::coordinator::{
    classify_intent, ControllerDecision, ControllerError, Lut, MissionGoal, RuntimeState,
    SplitController,
};

fn main() -> anyhow::Result<()> {
    let mut controller = SplitController::new(Lut::paper(), 0.5, 6.0);
    let prompts = [
        "what is happening in this sector",
        "are there any living beings on the rooftops",
        "highlight the living beings on that roof",
        "segment the partially submerged vehicles",
        "describe the current flood situation",
        "find and mark anyone who might need rescue",
    ];
    let bandwidths = [4.0, 8.0, 11.68, 15.0, 20.0];

    println!(
        "{:<48} {:>6}  {}",
        "prompt", "Mbps", "decision (goal = PRIORITIZE_ACCURACY)"
    );
    println!("{}", "-".repeat(110));
    for prompt in prompts {
        let intent = classify_intent(prompt);
        for bw in bandwidths {
            let state = RuntimeState {
                bandwidth_mbps: bw,
                power_mode: "MODE_30W_ALL",
                intent: intent.clone(),
            };
            let decision =
                controller.select_configuration(&state, MissionGoal::PrioritizeAccuracy);
            let text = match decision {
                Ok(ControllerDecision::Context { max_pps }) => {
                    format!("Context stream ({max_pps:.1} PPS)")
                }
                Ok(ControllerDecision::Insight { tier, pps }) => {
                    format!("Insight / {} ({pps:.2} PPS)", tier.display())
                }
                Err(ControllerError::NoFeasibleInsightTier) => {
                    "NO FEASIBLE INSIGHT TIER".to_string()
                }
            };
            println!("{:<48} {:>6.2}  {}", prompt, bw, text);
        }
        println!();
    }
    println!("note how Context prompts never consume Insight bandwidth, and how the");
    println!("Insight tier degrades gracefully as bandwidth falls (11.68 Mbps is the");
    println!("High-Accuracy feasibility threshold from Table 3 at F_I = 0.5 PPS).");
    Ok(())
}
