//! Flood-response mission: a shortened Fig-9-style dynamic run — AVERY's
//! adaptive controller vs the static High-Accuracy baseline over the
//! scripted disaster-zone bandwidth trace, streaming the synthetic
//! Flood-ReasonSeg + generic corpora round-robin.
//!
//!     cargo run --release --example flood_mission -- [--duration 300]

use std::path::Path;

use avery::config::Kv;
use avery::coordinator::{MissionGoal, TierId};
use avery::mission::Env;
use avery::netsim::{BandwidthTrace, Link, LinkConfig, TraceConfig};
use avery::runtime::ExecMode;
use avery::streams::{run_insight_mission, MissionConfig, Policy};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut kv = Kv::default();
    kv.apply_cli(&args)?;
    let duration = kv.get_f64("duration", 300.0)?;

    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;

    let mut cfg = TraceConfig::paper_20min(11);
    let scale = duration / cfg.total_secs();
    for p in &mut cfg.phases {
        p.secs *= scale;
    }
    let trace = BandwidthTrace::generate(&cfg);
    let mission = MissionConfig {
        duration_secs: duration,
        goal: MissionGoal::PrioritizeAccuracy,
        ..MissionConfig::default()
    };

    println!("flood mission: {duration:.0}s scripted trace, Prioritize-Accuracy\n");
    for policy in [Policy::Avery, Policy::Static(TierId::HighAccuracy)] {
        let mut link = Link::new(trace.clone(), LinkConfig::default());
        let run = run_insight_mission(
            &env.engine,
            &env.datasets(),
            &env.lut,
            &env.device,
            &mut link,
            &mission,
            policy,
        )?;
        let s = &run.summary;
        println!(
            "{:<24} delivered {:>4}  avg {:.2} PPS  avg IoU {:.2}%  energy {:.0} J  \
             switches {}  infeasible {}s",
            s.policy,
            s.delivered,
            s.avg_pps,
            s.avg_iou * 100.0,
            s.total_energy_j,
            s.switches,
            s.infeasible_epochs
        );
    }
    println!("\nflood_mission OK");
    Ok(())
}
