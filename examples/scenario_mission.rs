//! Scenario-library mission: run any registered disaster/network regime —
//! Markov smoke attenuation, urban-flood drops, earthquake blackouts,
//! satellite sawtooths — with its intent schedule and fleet composition,
//! driven through the Mission API.
//!
//! Needs no artifacts: without `make artifacts` it runs the synthetic
//! closed-form engine (control plane exact, numerics simulated).
//!
//!     cargo run --release --example scenario_mission -- \
//!         [--name earthquake-canyon] [--duration 300] [--seed 7]

use std::path::Path;

use avery::config::Kv;
use avery::mission::{run_scenario, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut kv = Kv::default();
    kv.apply_cli(&args)?;

    let opts = RunOptions {
        // None falls back to mission::scenario::DEFAULT_SCENARIO.
        name: kv.get("name").map(String::from),
        duration_secs: kv.get_f64("duration", 300.0)?,
        seed: kv.get_u64("seed", 7)?,
        exec_every: kv.get_usize("exec-every", 4)?,
        ..RunOptions::default()
    };

    let env = Env::load_or_synthetic(None, Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let (run, report) = run_scenario(&env, &opts)?;
    emit_text(&report, &env.out_dir)?;
    println!(
        "\nscenario_mission OK — {} delivered, {} tier switches, {} intent switches",
        run.delivered_total, run.switches_total, run.intent_switches_total
    );
    Ok(())
}
