//! Distributed serving: the edge and cloud halves as two real endpoints over
//! TCP loopback — the deployment shape the paper describes (UAV process +
//! server process), demonstrating that the packet wire format and transport
//! carry the full system end to end.
//!
//!     cargo run --release --example distributed_serve

use std::net::TcpListener;
use std::path::Path;

use avery::cloud::{decode_response, CloudPool};
use avery::coordinator::TierId;
use avery::edge::EdgePipeline;
use avery::eval::mask_iou;
use avery::mission::Env;
use avery::runtime::ExecMode;
use avery::transport::{encode_request, Tcp, Transport};

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;

    // ---- server process (thread here; identical over a real network) ----
    // A two-worker CloudPool session loop: the same code path `avery fleet`
    // uses in-process, here behind the TCP framing.
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server_artifacts = artifacts.clone();
    let server = std::thread::spawn(move || -> anyhow::Result<()> {
        let env = Env::load(&server_artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;
        let pool = CloudPool::new(vec![env.engine.clone(), env.engine.clone()]);
        let (stream, _) = listener.accept()?;
        let mut t = Tcp::from_stream(stream);
        let served = pool.serve_session(&mut t, "ft")?;
        eprintln!("cloud session closed after {served} requests");
        Ok(())
    });

    // ---- edge (UAV) process ----
    let env = Env::load(&artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mut edge = EdgePipeline::new(env.engine.clone(), env.device.clone(), env.lut.clone());
    let mut t = Tcp::connect(addr)?;
    println!("edge connected to cloud at {addr}");

    let mut total = 0usize;
    let mut iou_sum = 0.0f64;
    for (i, scene) in env.flood_val.scenes.iter().take(5).enumerate() {
        let Some((class_id, prompt)) = scene.prompts.first() else { continue };
        let (pkt, cost) = edge.capture_insight(scene, 1, TierId::HighAccuracy, i as f64)?;
        let pkt_bytes = pkt.encode();
        t.send(&encode_request(&pkt_bytes, prompt, "ft"))?;
        let resp = t.recv()?;
        let (_presence, mask) = decode_response(&resp)?;
        let s = mask_iou(&mask, &scene.masks[*class_id], 0.0);
        let iou = if s.union > 0.0 { s.intersection / s.union } else { 1.0 };
        iou_sum += iou;
        total += 1;
        println!(
            "scene {i}: sent {} B (wire model {:.2} MB), edge {:.2} J, prompt {:?}, IoU {:.3}",
            pkt_bytes.len(),
            pkt.wire_bytes / 1e6,
            cost.energy_j,
            prompt,
            iou
        );
    }
    t.send(b"shutdown")?;
    server.join().unwrap()?;
    println!(
        "\ndistributed_serve OK — {total} packets over TCP, mean IoU {:.3}",
        iou_sum / total.max(1) as f64
    );
    Ok(())
}
