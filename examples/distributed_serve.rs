//! Distributed serving: the edge and cloud halves as two real endpoints over
//! TCP loopback — the deployment shape the paper describes (UAV process +
//! server process), demonstrating that the packet wire format and transport
//! carry the full system end to end.
//!
//!     cargo run --release --example distributed_serve

use std::net::TcpListener;
use std::path::Path;

use avery::cloud::CloudServer;
use avery::coordinator::{classify_intent, TierId};
use avery::edge::EdgePipeline;
use avery::eval::mask_iou;
use avery::mission::Env;
use avery::packet::Packet;
use avery::runtime::ExecMode;
use avery::transport::{decode_request, encode_request, Tcp, Transport};

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;

    // ---- server process (thread here; identical over a real network) ----
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server_artifacts = artifacts.clone();
    let server = std::thread::spawn(move || -> anyhow::Result<()> {
        let env = Env::load(&server_artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;
        let cloud = CloudServer::new(env.engine.clone());
        let (stream, _) = listener.accept()?;
        let mut t = Tcp::from_stream(stream);
        loop {
            let frame = match t.recv() {
                Ok(f) => f,
                Err(_) => break, // client closed
            };
            if frame == b"shutdown" {
                break;
            }
            let (pkt_bytes, prompt, set) = decode_request(&frame)?;
            let pkt = Packet::decode(&pkt_bytes)?;
            let intent = classify_intent(&prompt);
            let resp = cloud.process(&pkt, &intent.token_ids, &set)?;
            let mut out = Vec::new();
            let mask = resp.mask_logits.map(|m| m.as_f32().unwrap().to_vec()).unwrap_or_default();
            out.extend_from_slice(&(resp.presence.len() as u32).to_le_bytes());
            for p in &resp.presence {
                out.extend_from_slice(&p.to_le_bytes());
            }
            out.extend_from_slice(&(mask.len() as u32).to_le_bytes());
            for v in &mask {
                out.extend_from_slice(&v.to_le_bytes());
            }
            t.send(&out)?;
        }
        Ok(())
    });

    // ---- edge (UAV) process ----
    let env = Env::load(&artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mut edge = EdgePipeline::new(env.engine.clone(), env.device.clone(), env.lut.clone());
    let mut t = Tcp::connect(addr)?;
    println!("edge connected to cloud at {addr}");

    let mut total = 0usize;
    let mut iou_sum = 0.0f64;
    for (i, scene) in env.flood_val.scenes.iter().take(5).enumerate() {
        let Some((class_id, prompt)) = scene.prompts.first() else { continue };
        let (pkt, cost) = edge.capture_insight(scene, 1, TierId::HighAccuracy, i as f64)?;
        let pkt_bytes = pkt.encode();
        t.send(&encode_request(&pkt_bytes, prompt, "ft"))?;
        let resp = t.recv()?;
        // decode response
        let np = u32::from_le_bytes(resp[0..4].try_into().unwrap()) as usize;
        let mut off = 4 + np * 4;
        let nm = u32::from_le_bytes(resp[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        let mask: Vec<f32> = resp[off..off + nm * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let s = mask_iou(&mask, &scene.masks[*class_id], 0.0);
        let iou = if s.union > 0.0 { s.intersection / s.union } else { 1.0 };
        iou_sum += iou;
        total += 1;
        println!(
            "scene {i}: sent {} B (wire model {:.2} MB), edge {:.2} J, prompt {:?}, IoU {:.3}",
            pkt_bytes.len(),
            pkt.wire_bytes / 1e6,
            cost.energy_j,
            prompt,
            iou
        );
    }
    t.send(b"shutdown")?;
    server.join().unwrap()?;
    println!(
        "\ndistributed_serve OK — {total} packets over TCP, mean IoU {:.3}",
        iou_sum / total.max(1) as f64
    );
    Ok(())
}
