//! Fleet demo: six heterogeneous UAVs (mixed Insight/Context intents,
//! staggered launches) contending for one disaster-zone uplink while a
//! two-worker cloud pool serves every session — the `avery fleet`
//! subsystem in miniature (see DESIGN.md "Fleet subsystem").
//!
//!     cargo run --release --example fleet_mission

use std::path::Path;

use avery::coordinator::MissionGoal;
use avery::mission::{run_fleet, Env, FleetOptions};
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;

    let opts = FleetOptions {
        uavs: 6,
        workers: 2,
        duration_secs: 180.0,
        goal: MissionGoal::PrioritizeAccuracy,
        exec_every: 4, // subsample HLO execution to keep the demo quick
        seed: 7,
        scenario: None,
    };
    let run = run_fleet(&env, &opts)?;

    println!("\nWhat to look for:");
    println!(
        "  * contention: each Insight UAV senses roughly a 1/{} slice of the \
         8-20 Mbps trace and its controller drops tiers accordingly",
        opts.uavs
    );
    println!(
        "  * fairness: Jain index {:.3} across Insight UAVs (1.0 = perfectly even)",
        run.jain_pps
    );
    println!(
        "  * the cloud pool served {} packets at {:.1}% virtual utilization",
        run.delivered_total,
        run.server_utilization * 100.0
    );
    Ok(())
}
