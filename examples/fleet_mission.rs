//! Fleet demo: six heterogeneous UAVs (mixed Insight/Context intents,
//! staggered launches) contending for one disaster-zone uplink while a
//! two-worker cloud pool serves every session — the `avery fleet`
//! subsystem in miniature (see DESIGN.md "Fleet subsystem"), driven
//! through the Mission API.
//!
//!     cargo run --release --example fleet_mission

use std::path::Path;

use avery::mission::{run_fleet, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let env = Env::load_or_synthetic(None, Path::new("out"), ExecMode::PreuploadedBuffers)?;

    let uavs = 6;
    let opts = RunOptions {
        uavs: Some(uavs),
        workers: Some(2),
        duration_secs: 180.0,
        exec_every: 4, // subsample HLO execution to keep the demo quick
        seed: 7,
        ..RunOptions::default()
    };
    let (run, report) = run_fleet(&env, &opts)?;
    emit_text(&report, &env.out_dir)?;

    println!("\nWhat to look for:");
    println!(
        "  * contention: each Insight UAV senses roughly a 1/{uavs} slice of the \
         8-20 Mbps trace and its controller drops tiers accordingly"
    );
    println!(
        "  * fairness: Jain index {:.3} across Insight UAVs (1.0 = perfectly even)",
        run.jain_pps
    );
    println!(
        "  * the cloud pool served {} packets at {:.1}% virtual utilization",
        run.delivered_total,
        run.server_utilization * 100.0
    );
    Ok(())
}
