//! Quickstart: load the AOT artifacts, classify two operator prompts, route
//! each through the admissible stream, and print what the operator sees.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;

use avery::cloud::CloudServer;
use avery::coordinator::{classify_intent, IntentLevel, MissionGoal, RuntimeState,
    SplitController, ControllerDecision};
use avery::edge::EdgePipeline;
use avery::eval::mask_iou;
use avery::mission::Env;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mut edge = EdgePipeline::new(env.engine.clone(), env.device.clone(), env.lut.clone());
    let server = CloudServer::new(env.engine.clone());
    let mut controller = SplitController::new(
        env.lut.clone(),
        0.5,
        1.0 / env.device.context_edge().latency_s,
    );

    let scene = &env.flood_val.scenes[0];
    let bandwidth = 14.0; // Mbps, mid-range of the paper's 8–20 envelope

    for prompt in [
        "are there any living beings on the rooftops",
        "highlight the people stranded by the flood",
    ] {
        let intent = classify_intent(prompt);
        println!("\noperator> {prompt}");
        println!("  intent: {:?} (target class {:?})", intent.level, intent.target_class);
        let state = RuntimeState {
            bandwidth_mbps: bandwidth,
            power_mode: "MODE_30W_ALL",
            intent: intent.clone(),
        };
        match controller.select_configuration(&state, MissionGoal::PrioritizeAccuracy) {
            Ok(ControllerDecision::Context { max_pps }) => {
                let (pkt, cost) = edge.capture_context(scene, 0.0)?;
                let resp = server.process(&pkt, &intent.token_ids, "ft")?;
                println!(
                    "  context stream ({max_pps:.1} PPS max, {:.1} ms on-device): {}",
                    cost.latency_s * 1e3,
                    resp.text_answer(&["person", "vehicle"])
                );
            }
            Ok(ControllerDecision::Insight { tier, pps }) => {
                let (pkt, cost) = edge.capture_insight(scene, 1, tier, 0.0)?;
                let resp = server.process(&pkt, &intent.token_ids, "ft")?;
                let logits = resp.mask_logits.unwrap();
                let cls = intent.target_class.unwrap_or(0);
                let s = mask_iou(logits.as_f32()?, &scene.masks[cls], 0.0);
                let iou = if s.union > 0.0 { s.intersection / s.union } else { 1.0 };
                println!(
                    "  insight stream tier {} at {pps:.2} PPS ({:.2} J on-device): \
                     mask IoU vs GT = {iou:.3}",
                    tier.display(),
                    cost.energy_j,
                );
            }
            Err(e) => println!("  controller: {e}"),
        }
        assert!(matches!(
            intent.level,
            IntentLevel::Context | IntentLevel::Insight
        ));
    }
    println!("\nquickstart OK");
    Ok(())
}
