//! Bench: the multi-cell cloud cluster (DESIGN.md "Multi-cell cloud
//! cluster") — machine-readable `BENCH_cluster.json` for the perf
//! trajectory, parsed by CI's `cluster-smoke` job against
//! `ci/bench_floor.json`.
//!
//! Sections:
//!
//! * **overload** — the same blocking submission flood against K ∈ {1, 2,
//!   4} cells (one threaded worker and a fixed bounded queue per cell,
//!   overflow spill on): cluster packets/sec and shed rate vs K.  Adding
//!   cells grows admission capacity and gives the spill path somewhere to
//!   go, so the shed rate must fall as K grows.
//! * **spill_hops** — where the overloaded requests actually served, from
//!   the largest-K run's per-hop counters (hop 0 = home cell).
//! * **replication** — a hot home cell whose response cache thrashes (more
//!   live classes than entries) backed by a ring sibling with headroom.
//!   Without replication every repeat re-executes; with `--replicas 2`
//!   each executed fill also lands on the sibling, so repeats come back as
//!   remote cache hits (and read-repair refills the home cell).  The hit
//!   rate with replication must be strictly higher.
//!
//! Usage: `cargo bench --bench cluster -- [--quick] [--out PATH]`
//! (`--quick` is what CI runs; default writes `BENCH_cluster.json`).

use std::time::Instant;

use anyhow::Result;

use avery::bench::header;
use avery::cloud::{
    AdmissionPolicy, CloudCluster, CloudPool, ClusterConfig, ServeError, ServingConfig,
};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::packet::Packet;
use avery::runtime::Engine;

struct Args {
    quick: bool,
    out: String,
}

fn parse_args() -> Args {
    let mut args = Args { quick: false, out: "BENCH_cluster.json".to_string() };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--quick" => args.quick = true,
            "--out" => {
                if let Some(v) = argv.get(i + 1) {
                    args.out = v.clone();
                    i += 1;
                }
            }
            other => {
                if let Some(v) = other.strip_prefix("--out=") {
                    args.out = v.to_string();
                }
                // `cargo bench` passes `--bench`; ignore unknown flags.
            }
        }
        i += 1;
    }
    args
}

/// Insight packets spread over `classes` distinct (split, tier) routing
/// classes x `per_class` distinct scenes — a flood that exercises the
/// consistent-hash router, not just one cell.
fn build_class_mix(classes: usize, per_class: usize, img: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, per_class, img, 0xF10D0);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let mut pkts = Vec::with_capacity(classes * per_class);
    for c in 0..classes {
        let split = 1 + c % 3;
        let tier = TierId::ALL[(c / 3) % 3];
        for (i, s) in ds.scenes.iter().enumerate() {
            pkts.push(edge.capture_insight(s, split, tier, i as f64).unwrap().0);
        }
    }
    (pkts, classify_intent("highlight the stranded people").token_ids)
}

/// Flood a K-cell cluster (one threaded worker, bounded queue and shed
/// admission per cell, spill on) from `submitters` blocking threads.
/// Returns (completed, cluster_shed, packets_per_sec, shed_rate,
/// served_at_hop).
fn overload(
    cells: usize,
    pkts: &[Packet],
    ids: &[i32],
    submitters: usize,
    per: usize,
) -> (u64, u64, f64, f64, Vec<u64>) {
    let serving = ServingConfig {
        batch_max: 4,
        queue_depth: 2,
        admission: AdmissionPolicy::Shed,
        ..ServingConfig::default()
    };
    // One *fresh* threaded engine per cell — a cloned threaded handle would
    // share a single engine thread, and the point is that adding cells adds
    // real capacity.
    let pools = (0..cells)
        .map(|_| CloudPool::with_config(vec![Engine::synthetic_threaded()], serving.clone()))
        .collect();
    let cluster = CloudCluster::from_pools(
        pools,
        ClusterConfig { spill_max: 3, serving, ..ClusterConfig::default() },
    );
    for p in pkts.iter().take(8) {
        let _ = cluster.try_process(p, ids, "ft");
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..submitters {
            let cluster = &cluster;
            s.spawn(move || {
                for i in 0..per {
                    match cluster.try_process(&pkts[(t * per + i) % pkts.len()], ids, "ft") {
                        Ok(_) | Err(ServeError::Shed { .. }) => {}
                        Err(e) => panic!("overload flood hit a fatal error: {e}"),
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let st = cluster.stats();
    let completed = st.total.completed;
    let shed = st.shed;
    let pps = completed as f64 / elapsed.max(1e-9);
    let shed_rate = shed as f64 / (completed + shed).max(1) as f64;
    (completed, shed, pps, shed_rate, st.served_at_hop)
}

/// The replication arm: a two-cell cluster where every request class homes
/// at a cell whose cache holds fewer entries than the live class count
/// (guaranteed thrash), while the ring sibling has headroom.  Runs a
/// round-robin repeated-query mix and returns (hit_rate, cache_hits,
/// cache_misses, remote_hits).
fn replication_arm(replicas: usize, rounds: usize) -> (f64, u64, u64, u64) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, 1, 16, 0x5EED);
    let mut edge =
        EdgePipeline::new(engine.clone(), DeviceModel::jetson_mode_30w(8), Lut::paper());
    let ids = classify_intent("highlight the stranded people").token_ids;

    // Candidate classes over (split, tier); keep 5 that share a home cell.
    let probe_cfg = ClusterConfig { cells: 2, ..ClusterConfig::default() };
    let probe = CloudCluster::with_config(vec![engine.clone()], probe_cfg);
    let mut classes: Vec<Packet> = Vec::new();
    let mut home = None;
    'outer: for split in 1..=8usize {
        for tier in TierId::ALL {
            let (pkt, _) = edge.capture_insight(&ds.scenes[0], split, tier, 0.0).unwrap();
            let h = probe.placement(&pkt, "ft")[0];
            if *home.get_or_insert(h) == h {
                classes.push(pkt);
                if classes.len() == 5 {
                    break 'outer;
                }
            }
        }
    }
    let home = home.expect("no routing classes found");
    assert_eq!(classes.len(), 5, "not enough classes share home cell {home}");

    // Home cell: cache smaller than the class count (thrashes).  Sibling:
    // room for everything.  Both serve inline.
    let pool = |entries: usize| {
        CloudPool::with_config(
            vec![engine.clone()],
            ServingConfig { cache_entries: entries, ..ServingConfig::default() },
        )
    };
    let pools: Vec<CloudPool> =
        (0..2).map(|i| if i == home { pool(2) } else { pool(64) }).collect();
    let cluster = CloudCluster::from_pools(
        pools,
        ClusterConfig {
            replicas,
            serving: ServingConfig { cache_entries: 2, ..ServingConfig::default() },
            ..ClusterConfig::default()
        },
    );

    for r in 0..rounds {
        for pkt in &classes {
            cluster.process_sync(pkt, &ids, "ft").unwrap_or_else(|e| {
                panic!("replication mix failed on round {r}: {e}");
            });
        }
    }
    let st = cluster.stats();
    let lookups = (st.total.cache_hits + st.total.cache_misses).max(1);
    (
        st.total.cache_hits as f64 / lookups as f64,
        st.total.cache_hits,
        st.total.cache_misses,
        st.remote_hits_total(),
    )
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "null".to_string()
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let mode = if args.quick { "quick" } else { "full" };
    let flood_per = if args.quick { 400 } else { 2_000 };
    let rounds = if args.quick { 50 } else { 400 };
    let submitters = 8;

    // ---- overload: shed rate vs K ----------------------------------------
    header("cluster overload: shed rate vs cell count (fixed flood, spill on)");
    let (pkts, ids) = build_class_mix(6, 8, 16);
    let mut over: Vec<(usize, u64, u64, f64, f64, Vec<u64>)> = Vec::new();
    for &cells in &[1usize, 2, 4] {
        let (completed, shed, pps, shed_rate, hops) =
            overload(cells, &pkts, &ids, submitters, flood_per);
        println!(
            "K={cells}: {pps:>10.0} packets/s, {completed} served, {shed} shed \
             ({:.1}% shed rate)",
            shed_rate * 100.0
        );
        over.push((cells, completed, shed, pps, shed_rate, hops));
    }
    let (_, _, _, pps_kmax, shed_kmax, hops_kmax) = over.last().unwrap().clone();
    let shed_k1 = over[0].4;
    println!(
        "shed rate K=1 -> K=4: {:.1}% -> {:.1}%",
        shed_k1 * 100.0,
        shed_kmax * 100.0
    );

    // ---- spill-hop distribution (largest K) ------------------------------
    header("spill-hop distribution at K=4 (hop 0 = home cell)");
    for (h, n) in hops_kmax.iter().enumerate() {
        println!("hop {h}: {n} served");
    }

    // ---- replication: hit rate with/without ------------------------------
    header("cache replication: thrashing home cell backed by a ring sibling");
    let (rate_off, hits_off, misses_off, _) = replication_arm(1, rounds);
    let (rate_on, hits_on, misses_on, remote_on) = replication_arm(2, rounds);
    println!(
        "replicas=1: hit rate {:>5.1}%  ({hits_off} hits / {misses_off} misses)",
        rate_off * 100.0
    );
    println!(
        "replicas=2: hit rate {:>5.1}%  ({hits_on} hits / {misses_on} misses, \
         {remote_on} remote)",
        rate_on * 100.0
    );

    // ---- machine-readable output -----------------------------------------
    let over_json: Vec<String> = over
        .iter()
        .map(|(cells, completed, shed, pps, shed_rate, hops)| {
            let hops: Vec<String> = hops.iter().map(|n| n.to_string()).collect();
            format!(
                "{{\"cells\":{cells},\"completed\":{completed},\"shed\":{shed},\
                 \"packets_per_sec\":{},\"shed_rate\":{},\"spill_hops\":[{}]}}",
                jf(*pps),
                jf(*shed_rate),
                hops.join(",")
            )
        })
        .collect();
    let json = format!(
        "{{\"schema\":1,\"bench\":\"cluster\",\"mode\":\"{mode}\",\
         \"overload\":[{}],\
         \"cluster_packets_per_sec\":{},\
         \"shed_rate_k1\":{},\
         \"shed_rate_kmax\":{},\
         \"replication\":{{\"classes\":5,\"rounds\":{rounds},\
         \"hit_rate_without\":{},\"hit_rate_with\":{},\
         \"hits_without\":{hits_off},\"misses_without\":{misses_off},\
         \"hits_with\":{hits_on},\"misses_with\":{misses_on},\
         \"remote_hits\":{remote_on}}}}}",
        over_json.join(","),
        jf(pps_kmax),
        jf(shed_k1),
        jf(shed_kmax),
        jf(rate_off),
        jf(rate_on),
    );
    std::fs::write(&args.out, format!("{json}\n"))?;
    println!("\nwrote {}", args.out);
    Ok(())
}
