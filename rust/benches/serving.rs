//! Bench: the cloud serving layer (DESIGN.md "Cloud serving layer") —
//! machine-readable `BENCH_serving.json` for the perf trajectory, parsed by
//! CI's `serving-smoke` job against `ci/bench_floor.json`.
//!
//! Sections:
//!
//! * **batch_sweep** — served packets/sec AND client-observed p99 latency
//!   through the pool's queued path at `batch_max` ∈ {1, 2, 4, 8, 16}: one
//!   worker over a *threaded* synthetic engine (the engine-thread shape
//!   PJRT serving runs with), so the sweep measures exactly what
//!   micro-batching amortizes — the per-request queue pop, engine channel
//!   round-trip and reply.
//! * **cache** — fleet missions at N ∈ {4, 16, 64} UAVs with the
//!   content-addressed response cache enabled: hit rate vs fleet size
//!   (swarms over the same disaster zone produce redundant streams).
//! * **overload** — a bounded queue under a submission flood (shed policy):
//!   admitted vs shed.
//! * **deadline** — FIFO vs EDF + deadline-shed under a mixed
//!   Context/Insight flood with a tight Context budget: Context-class p99
//!   must improve when the drain order honors deadlines (DESIGN.md
//!   "Tail-latency discipline").
//!
//! Usage: `cargo bench --bench serving -- [--quick] [--out PATH]`
//! (`--quick` is what CI runs; default writes `BENCH_serving.json`).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use avery::bench::header;
use avery::cloud::{AdmissionPolicy, CloudPool, ServingConfig, Ticket};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::mission::{run_fleet, Env, RunOptions};
use avery::packet::Packet;
use avery::runtime::Engine;

struct Args {
    quick: bool,
    out: String,
}

fn parse_args() -> Args {
    let mut args = Args { quick: false, out: "BENCH_serving.json".to_string() };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--quick" => args.quick = true,
            "--out" => {
                if let Some(v) = argv.get(i + 1) {
                    args.out = v.clone();
                    i += 1;
                }
            }
            other => {
                if let Some(v) = other.strip_prefix("--out=") {
                    args.out = v.to_string();
                }
                // `cargo bench` passes `--bench`; ignore unknown flags.
            }
        }
        i += 1;
    }
    args
}

/// Distinct-scene Insight packets, all batch-compatible (same tier, split
/// and weight set).
fn build_packets(n_scenes: usize, img: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, n_scenes, img, 0xF10D0);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let pkts = ds
        .scenes
        .iter()
        .map(|s| edge.capture_insight(s, 1, TierId::Balanced, 0.0).unwrap().0)
        .collect();
    (pkts, classify_intent("highlight the stranded people").token_ids)
}

/// Served packets/sec and client-observed p99 latency (ms) through the
/// queued path at one `batch_max` setting.
fn sweep_pps(batch: usize, pkts: &[Packet], ids: &[i32], total: usize) -> (f64, f64) {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig { batch_max: batch, ..ServingConfig::default() },
    );
    for p in pkts.iter().take(64.min(pkts.len())) {
        pool.process_sync(p, ids, "ft").expect("warmup");
    }
    let t0 = Instant::now();
    let tickets: Vec<Ticket> = (0..total)
        .map(|i| pool.submit(&pkts[i % pkts.len()], ids, "ft").expect("submit"))
        .collect();
    for t in tickets {
        t.wait().expect("wait");
    }
    let pps = total as f64 / t0.elapsed().as_secs_f64();
    (pps, pool.stats().wall_lat_insight.p99() * 1e3)
}

/// Distinct-scene Context packets (the lightweight situational stream).
fn build_context_packets(n_scenes: usize, img: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, n_scenes, img, 0xC0411);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let pkts = ds.scenes.iter().map(|s| edge.capture_context(s, 0.0).unwrap().0).collect();
    (pkts, classify_intent("what is the overall situation").token_ids)
}

/// One arm of the deadline comparison: flood a bounded single-worker queue
/// with a mixed stream (every 5th request is Context) under a tight Context
/// budget and a loose Insight budget.  `edf: false` is the FIFO baseline;
/// `edf: true` also turns on predicted-miss shedding.  Returns
/// (ctx_p99_ms, ins_p99_ms, shed_context, shed_insight, completed).
fn deadline_arm(
    ctx: (&[Packet], &[i32]),
    ins: (&[Packet], &[i32]),
    total: usize,
    edf: bool,
) -> (f64, f64, u64, u64, u64) {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig {
            batch_max: 4,
            queue_depth: 128,
            admission: AdmissionPolicy::Shed,
            deadline_context_secs: 0.05,
            deadline_insight_secs: 30.0,
            edf,
            deadline_shed: edf,
            ..ServingConfig::default()
        },
    );
    for p in ins.0.iter().take(8) {
        pool.process_sync(p, ins.1, "ft").expect("warmup");
    }
    let mut tickets = Vec::with_capacity(total);
    for i in 0..total {
        let (pkts, ids) = if i % 5 == 4 { ctx } else { ins };
        let mut p = pkts[i % pkts.len()].clone();
        // Staggered virtual capture times give every request its own
        // absolute deadline (t_capture + class budget).
        p.t_capture = i as f64 * 1e-4;
        if let Ok(t) = pool.submit(&p, ids, "ft") {
            tickets.push(t);
        }
    }
    for t in tickets {
        let _ = t.wait();
    }
    let st = pool.stats();
    (
        st.wall_lat_context.p99() * 1e3,
        st.wall_lat_insight.p99() * 1e3,
        st.shed_context,
        st.shed_insight,
        st.completed,
    )
}

/// One cache-enabled fleet mission; returns (hit_rate, hits, misses,
/// evictions).
fn fleet_cache(n: usize, duration: f64, out_dir: &Path) -> Result<(f64, u64, u64, u64)> {
    let env = Env::synthetic(out_dir)?;
    let opts = RunOptions {
        duration_secs: duration,
        uavs: Some(n),
        workers: Some(2),
        seed: 7,
        batch_max: Some(8),
        cache_entries: Some(512),
        cache_ttl: Some(240.0),
        ..RunOptions::default()
    };
    let (_run, report) = run_fleet(&env, &opts)?;
    let g = |k: &str| report.scalar_value(k).unwrap_or(0.0);
    Ok((
        g("cache_hit_rate"),
        g("cache_hits") as u64,
        g("cache_misses") as u64,
        g("cache_evictions") as u64,
    ))
}

/// Flood a bounded queue from several submitter threads; returns
/// (admitted, shed).
fn overload(
    pkts: &[Packet],
    ids: &[i32],
    submitters: usize,
    per: usize,
    depth: usize,
) -> (u64, u64) {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig {
            batch_max: 4,
            queue_depth: depth,
            admission: AdmissionPolicy::Shed,
            ..ServingConfig::default()
        },
    );
    std::thread::scope(|s| {
        for t in 0..submitters {
            let pool = &pool;
            s.spawn(move || {
                let mut tickets = Vec::with_capacity(per);
                for i in 0..per {
                    if let Ok(tk) = pool.submit(&pkts[(t * per + i) % pkts.len()], ids, "ft") {
                        tickets.push(tk);
                    }
                }
                for tk in tickets {
                    let _ = tk.wait();
                }
            });
        }
    });
    let st = pool.stats();
    (st.completed, st.shed)
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "null".to_string()
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let mode = if args.quick { "quick" } else { "full" };
    let sweep_total = if args.quick { 4_000 } else { 20_000 };
    let fleet_duration = if args.quick { 120.0 } else { 600.0 };
    let overload_per = if args.quick { 1_500 } else { 6_000 };
    let deadline_total = if args.quick { 2_000 } else { 8_000 };

    // ---- batch-size sweep -------------------------------------------------
    header("micro-batch sweep: served packets/sec (1 worker, threaded synthetic)");
    let (pkts, ids) = build_packets(32, 16);
    let mut sweep: Vec<(usize, f64, f64)> = Vec::new();
    for batch in [1usize, 2, 4, 8, 16] {
        let (pps, p99_ms) = sweep_pps(batch, &pkts, &ids, sweep_total);
        println!("batch_max {batch:>2}: {pps:>12.0} packets/s   p99 {p99_ms:>9.3} ms");
        sweep.push((batch, pps, p99_ms));
    }
    let pps_of = |b: usize| sweep.iter().find(|(batch, _, _)| *batch == b).unwrap().1;
    let p99_of = |b: usize| sweep.iter().find(|(batch, _, _)| *batch == b).unwrap().2;
    let speedup8 = pps_of(8) / pps_of(1);
    println!("batch 8 vs batch 1: {speedup8:.2}x");

    // ---- cache hit rate vs fleet size ------------------------------------
    header("response cache: hit rate vs fleet size (512 entries, ttl 240 s)");
    let out_dir = Path::new("out/bench-serving");
    let mut cache_rows: Vec<(usize, f64, u64, u64, u64)> = Vec::new();
    for &n in &[4usize, 16, 64] {
        let (rate, hits, misses, evictions) = fleet_cache(n, fleet_duration, out_dir)?;
        println!(
            "N={n:<3} hit rate {:>6.1}%  ({hits} hits / {misses} misses, {evictions} evicted)",
            rate * 100.0
        );
        cache_rows.push((n, rate, hits, misses, evictions));
    }

    // ---- shed rate under overload ----------------------------------------
    header("admission control: bounded queue under submission flood (depth 64)");
    let (big_pkts, big_ids) = build_packets(16, 64);
    let (admitted, shed) = overload(&big_pkts, &big_ids, 4, overload_per, 64);
    let shed_rate = shed as f64 / (admitted + shed).max(1) as f64;
    println!("admitted {admitted}, shed {shed} ({:.1}% shed)", shed_rate * 100.0);

    // ---- deadline discipline: FIFO vs EDF + shed -------------------------
    header("deadline discipline: Context p99 under a mixed flood, FIFO vs EDF");
    let (ctx_pkts, ctx_ids) = build_context_packets(16, 64);
    let fifo = deadline_arm((&ctx_pkts, &ctx_ids), (&big_pkts, &big_ids), deadline_total, false);
    let edf = deadline_arm((&ctx_pkts, &ctx_ids), (&big_pkts, &big_ids), deadline_total, true);
    println!(
        "FIFO     : ctx p99 {:>9.3} ms  ins p99 {:>9.3} ms  shed {}/{} (ctx/ins), {} served",
        fifo.0, fifo.1, fifo.2, fifo.3, fifo.4
    );
    println!(
        "EDF+shed : ctx p99 {:>9.3} ms  ins p99 {:>9.3} ms  shed {}/{} (ctx/ins), {} served",
        edf.0, edf.1, edf.2, edf.3, edf.4
    );
    let ctx_p99_speedup = if edf.0 > 0.0 { fifo.0 / edf.0 } else { f64::INFINITY };
    println!("context p99: {ctx_p99_speedup:.1}x better under EDF + deadline-shed");

    // ---- machine-readable output -----------------------------------------
    let sweep_json: Vec<String> = sweep
        .iter()
        .map(|(b, pps, p99)| {
            format!("{{\"batch\":{b},\"packets_per_sec\":{},\"p99_ms\":{}}}", jf(*pps), jf(*p99))
        })
        .collect();
    let cache_json: Vec<String> = cache_rows
        .iter()
        .map(|(n, rate, hits, misses, evictions)| {
            format!(
                "{{\"uavs\":{n},\"hit_rate\":{},\"hits\":{hits},\"misses\":{misses},\
                 \"evictions\":{evictions}}}",
                jf(*rate)
            )
        })
        .collect();
    let deadline_json = format!(
        "{{\"queue_depth\":128,\"deadline_context_s\":0.05,\"deadline_insight_s\":30.0,\
         \"fifo_ctx_p99_ms\":{},\"fifo_ins_p99_ms\":{},\
         \"fifo_shed_context\":{},\"fifo_shed_insight\":{},\"fifo_completed\":{},\
         \"edf_ctx_p99_ms\":{},\"edf_ins_p99_ms\":{},\
         \"edf_shed_context\":{},\"edf_shed_insight\":{},\"edf_completed\":{},\
         \"ctx_p99_speedup\":{}}}",
        jf(fifo.0),
        jf(fifo.1),
        fifo.2,
        fifo.3,
        fifo.4,
        jf(edf.0),
        jf(edf.1),
        edf.2,
        edf.3,
        edf.4,
        jf(ctx_p99_speedup),
    );
    let json = format!(
        "{{\"schema\":1,\"bench\":\"serving\",\"mode\":\"{mode}\",\
         \"batch_sweep\":[{}],\
         \"batched_packets_per_sec\":{},\
         \"batch8_p99_ms\":{},\
         \"speedup_batch_8\":{},\
         \"cache\":[{}],\
         \"overload\":{{\"queue_depth\":64,\"admitted\":{admitted},\"shed\":{shed},\
         \"shed_rate\":{}}},\
         \"deadline\":{deadline_json}}}",
        sweep_json.join(","),
        jf(pps_of(8)),
        jf(p99_of(8)),
        jf(speedup8),
        cache_json.join(","),
        jf(shed_rate),
    );
    std::fs::write(&args.out, format!("{json}\n"))?;
    println!("\nwrote {}", args.out);
    Ok(())
}
