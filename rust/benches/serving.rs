//! Bench: the cloud serving layer (DESIGN.md "Cloud serving layer") —
//! machine-readable `BENCH_serving.json` for the perf trajectory, parsed by
//! CI's `serving-smoke` job against `ci/bench_floor.json`.
//!
//! Sections:
//!
//! * **batch_sweep** — served packets/sec through the pool's queued path at
//!   `batch_max` ∈ {1, 2, 4, 8, 16}: one worker over a *threaded* synthetic
//!   engine (the engine-thread shape PJRT serving runs with), so the sweep
//!   measures exactly what micro-batching amortizes — the per-request queue
//!   pop, engine channel round-trip and reply.
//! * **cache** — fleet missions at N ∈ {4, 16, 64} UAVs with the
//!   content-addressed response cache enabled: hit rate vs fleet size
//!   (swarms over the same disaster zone produce redundant streams).
//! * **overload** — a bounded queue under a submission flood (shed policy):
//!   admitted vs shed.
//!
//! Usage: `cargo bench --bench serving -- [--quick] [--out PATH]`
//! (`--quick` is what CI runs; default writes `BENCH_serving.json`).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use avery::bench::header;
use avery::cloud::{AdmissionPolicy, CloudPool, ServingConfig, Ticket};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::mission::{run_fleet, Env, RunOptions};
use avery::packet::Packet;
use avery::runtime::Engine;

struct Args {
    quick: bool,
    out: String,
}

fn parse_args() -> Args {
    let mut args = Args { quick: false, out: "BENCH_serving.json".to_string() };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--quick" => args.quick = true,
            "--out" => {
                if let Some(v) = argv.get(i + 1) {
                    args.out = v.clone();
                    i += 1;
                }
            }
            other => {
                if let Some(v) = other.strip_prefix("--out=") {
                    args.out = v.to_string();
                }
                // `cargo bench` passes `--bench`; ignore unknown flags.
            }
        }
        i += 1;
    }
    args
}

/// Distinct-scene Insight packets, all batch-compatible (same tier, split
/// and weight set).
fn build_packets(n_scenes: usize, img: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, n_scenes, img, 0xF10D0);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let pkts = ds
        .scenes
        .iter()
        .map(|s| edge.capture_insight(s, 1, TierId::Balanced, 0.0).unwrap().0)
        .collect();
    (pkts, classify_intent("highlight the stranded people").token_ids)
}

/// Served packets/sec through the queued path at one `batch_max` setting.
fn sweep_pps(batch: usize, pkts: &[Packet], ids: &[i32], total: usize) -> f64 {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig { batch_max: batch, ..ServingConfig::default() },
    );
    for p in pkts.iter().take(64.min(pkts.len())) {
        pool.process_sync(p, ids, "ft").expect("warmup");
    }
    let t0 = Instant::now();
    let tickets: Vec<Ticket> = (0..total)
        .map(|i| pool.submit(&pkts[i % pkts.len()], ids, "ft").expect("submit"))
        .collect();
    for t in tickets {
        t.wait().expect("wait");
    }
    total as f64 / t0.elapsed().as_secs_f64()
}

/// One cache-enabled fleet mission; returns (hit_rate, hits, misses,
/// evictions).
fn fleet_cache(n: usize, duration: f64, out_dir: &Path) -> Result<(f64, u64, u64, u64)> {
    let env = Env::synthetic(out_dir)?;
    let opts = RunOptions {
        duration_secs: duration,
        uavs: Some(n),
        workers: Some(2),
        seed: 7,
        batch_max: Some(8),
        cache_entries: Some(512),
        cache_ttl: Some(240.0),
        ..RunOptions::default()
    };
    let (_run, report) = run_fleet(&env, &opts)?;
    let g = |k: &str| report.scalar_value(k).unwrap_or(0.0);
    Ok((
        g("cache_hit_rate"),
        g("cache_hits") as u64,
        g("cache_misses") as u64,
        g("cache_evictions") as u64,
    ))
}

/// Flood a bounded queue from several submitter threads; returns
/// (admitted, shed).
fn overload(
    pkts: &[Packet],
    ids: &[i32],
    submitters: usize,
    per: usize,
    depth: usize,
) -> (u64, u64) {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig {
            batch_max: 4,
            queue_depth: depth,
            admission: AdmissionPolicy::Shed,
            ..ServingConfig::default()
        },
    );
    std::thread::scope(|s| {
        for t in 0..submitters {
            let pool = &pool;
            s.spawn(move || {
                let mut tickets = Vec::with_capacity(per);
                for i in 0..per {
                    if let Ok(tk) = pool.submit(&pkts[(t * per + i) % pkts.len()], ids, "ft") {
                        tickets.push(tk);
                    }
                }
                for tk in tickets {
                    let _ = tk.wait();
                }
            });
        }
    });
    let st = pool.stats();
    (st.completed, st.shed)
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "null".to_string()
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let mode = if args.quick { "quick" } else { "full" };
    let sweep_total = if args.quick { 4_000 } else { 20_000 };
    let fleet_duration = if args.quick { 120.0 } else { 600.0 };
    let overload_per = if args.quick { 1_500 } else { 6_000 };

    // ---- batch-size sweep -------------------------------------------------
    header("micro-batch sweep: served packets/sec (1 worker, threaded synthetic)");
    let (pkts, ids) = build_packets(32, 16);
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    for batch in [1usize, 2, 4, 8, 16] {
        let pps = sweep_pps(batch, &pkts, &ids, sweep_total);
        println!("batch_max {batch:>2}: {pps:>12.0} packets/s");
        sweep.push((batch, pps));
    }
    let pps_of = |b: usize| sweep.iter().find(|(batch, _)| *batch == b).unwrap().1;
    let speedup8 = pps_of(8) / pps_of(1);
    println!("batch 8 vs batch 1: {speedup8:.2}x");

    // ---- cache hit rate vs fleet size ------------------------------------
    header("response cache: hit rate vs fleet size (512 entries, ttl 240 s)");
    let out_dir = Path::new("out/bench-serving");
    let mut cache_rows: Vec<(usize, f64, u64, u64, u64)> = Vec::new();
    for &n in &[4usize, 16, 64] {
        let (rate, hits, misses, evictions) = fleet_cache(n, fleet_duration, out_dir)?;
        println!(
            "N={n:<3} hit rate {:>6.1}%  ({hits} hits / {misses} misses, {evictions} evicted)",
            rate * 100.0
        );
        cache_rows.push((n, rate, hits, misses, evictions));
    }

    // ---- shed rate under overload ----------------------------------------
    header("admission control: bounded queue under submission flood (depth 64)");
    let (big_pkts, big_ids) = build_packets(16, 64);
    let (admitted, shed) = overload(&big_pkts, &big_ids, 4, overload_per, 64);
    let shed_rate = shed as f64 / (admitted + shed).max(1) as f64;
    println!("admitted {admitted}, shed {shed} ({:.1}% shed)", shed_rate * 100.0);

    // ---- machine-readable output -----------------------------------------
    let sweep_json: Vec<String> = sweep
        .iter()
        .map(|(b, pps)| format!("{{\"batch\":{b},\"packets_per_sec\":{}}}", jf(*pps)))
        .collect();
    let cache_json: Vec<String> = cache_rows
        .iter()
        .map(|(n, rate, hits, misses, evictions)| {
            format!(
                "{{\"uavs\":{n},\"hit_rate\":{},\"hits\":{hits},\"misses\":{misses},\
                 \"evictions\":{evictions}}}",
                jf(*rate)
            )
        })
        .collect();
    let json = format!(
        "{{\"schema\":1,\"bench\":\"serving\",\"mode\":\"{mode}\",\
         \"batch_sweep\":[{}],\
         \"batched_packets_per_sec\":{},\
         \"speedup_batch_8\":{},\
         \"cache\":[{}],\
         \"overload\":{{\"queue_depth\":64,\"admitted\":{admitted},\"shed\":{shed},\
         \"shed_rate\":{}}}}}",
        sweep_json.join(","),
        jf(pps_of(8)),
        jf(speedup8),
        cache_json.join(","),
        jf(shed_rate),
    );
    std::fs::write(&args.out, format!("{json}\n"))?;
    println!("\nwrote {}", args.out);
    Ok(())
}
