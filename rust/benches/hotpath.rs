//! Bench: L3 hot-path microbenchmarks — the §Perf instrumentation.
//!
//! * controller decision latency (Algorithm 1 must be negligible)
//! * packet encode/decode + quantization
//! * head/tail artifact execution in both weight-delivery modes
//!   (LiteralsEachCall vs PreuploadedBuffers — the §Perf lever)

use avery::bench::{bench, bench_result, header};
use avery::coordinator::{
    classify_intent, Lut, MissionGoal, RuntimeState, SplitController,
};
use avery::mission::Env;
use avery::packet::Packet;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    header("controller decision (Algorithm 1)");
    let mut controller = SplitController::new(Lut::paper(), 0.5, 6.0);
    let intent = classify_intent("highlight the stranded vehicle");
    let mut bw = 8.0;
    bench("select_configuration", 1000, 100_000, || {
        bw = if bw > 19.0 { 8.0 } else { bw + 0.01 };
        let state = RuntimeState {
            bandwidth_mbps: bw,
            power_mode: "MODE_30W_ALL",
            intent: intent.clone(),
        };
        let _ = controller.select_configuration(&state, MissionGoal::PrioritizeAccuracy);
    });
    bench("classify_intent + tokenize", 100, 50_000, || {
        let _ = classify_intent("highlight individuals near submerged vehicles");
    });

    header("packet wire path");
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let scene = &env.flood_val.scenes[0];
    let mut edge =
        avery::edge::EdgePipeline::new(env.engine.clone(), env.device.clone(), env.lut.clone());
    let (pkt, _) = edge.capture_insight(scene, 1, avery::coordinator::TierId::HighAccuracy, 0.0)?;
    let encoded = pkt.encode();
    println!("insight packet real size: {} bytes (wire model {} MB)",
        encoded.len(), pkt.wire_bytes / 1e6);
    bench("packet encode", 100, 20_000, || {
        let _ = pkt.encode();
    });
    bench("packet decode", 100, 20_000, || {
        let _ = Packet::decode(&encoded).unwrap();
    });

    header("artifact execution: weight-delivery modes (the §Perf lever)");
    for (mode, label) in [
        (ExecMode::LiteralsEachCall, "literals-each-call"),
        (ExecMode::PreuploadedBuffers, "preuploaded-buffers"),
    ] {
        let env = Env::load(&artifacts, std::path::Path::new("out"), mode)?;
        let mut edge = avery::edge::EdgePipeline::new(
            env.engine.clone(),
            env.device.clone(),
            env.lut.clone(),
        );
        let server = avery::cloud::CloudServer::new(env.engine.clone());
        let intent = classify_intent("highlight the stranded people");
        let scene = &env.flood_val.scenes[0];
        bench_result(&format!("head sp1 HA [{label}]"), 3, 15, || {
            edge.capture_insight(scene, 1, avery::coordinator::TierId::HighAccuracy, 0.0)?;
            Ok(())
        });
        let (pkt, _) =
            edge.capture_insight(scene, 1, avery::coordinator::TierId::HighAccuracy, 0.0)?;
        bench_result(&format!("tail sp1 HA [{label}]"), 3, 15, || {
            server.process(&pkt, &intent.token_ids, "ft")?;
            Ok(())
        });
    }
    Ok(())
}
