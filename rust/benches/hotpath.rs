//! Bench: L3 hot-path microbenchmarks — the §Perf instrumentation.
//!
//! * controller decision latency (Algorithm 1 must be negligible)
//! * synthetic dispatch: inline (caller-thread, no channel) vs the
//!   engine-thread round-trip — the direct-dispatch backend win
//! * packet encode/decode + quantization (artifact-free: falls back to the
//!   synthetic engine on a fresh checkout)
//! * head/tail artifact execution in both weight-delivery modes
//!   (LiteralsEachCall vs PreuploadedBuffers — the §Perf lever); this
//!   section needs real artifacts and prints a skip note without them.

use avery::bench::{bench, bench_result, header};
use avery::coordinator::{
    classify_intent, Lut, MissionGoal, RuntimeState, SplitController,
};
use avery::dataset::{Corpus, Dataset};
use avery::mission::Env;
use avery::packet::Packet;
use avery::runtime::{Engine, ExecMode};
use avery::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    header("controller decision (Algorithm 1)");
    let mut controller = SplitController::new(Lut::paper(), 0.5, 6.0);
    let intent = classify_intent("highlight the stranded vehicle");
    let mut bw = 8.0;
    bench("select_configuration", 1000, 100_000, || {
        bw = if bw > 19.0 { 8.0 } else { bw + 0.01 };
        let state = RuntimeState {
            bandwidth_mbps: bw,
            power_mode: "MODE_30W_ALL",
            intent: intent.clone(),
        };
        let _ = controller.select_configuration(&state, MissionGoal::PrioritizeAccuracy);
    });
    bench("classify_intent + tokenize", 100, 50_000, || {
        let _ = classify_intent("highlight individuals near submerged vehicles");
    });

    header("synthetic dispatch: inline vs engine-thread round-trip");
    let scene = Dataset::synthetic(Corpus::Flood, 1, 16, 0xF10D0).scenes[0].image.clone();
    let intent = classify_intent("highlight the stranded people");
    let pids = Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone())?;
    for (engine, label) in
        [(Engine::synthetic(), "inline"), (Engine::synthetic_threaded(), "threaded")]
    {
        let head =
            engine.execute("head_sp1_balanced", "shared", std::slice::from_ref(&scene))?;
        let tail_inputs = [head[0].clone(), head[1].clone(), pids.clone()];
        bench_result(&format!("head sp1 BAL synthetic [{label}]"), 200, 20_000, || {
            engine.execute("head_sp1_balanced", "shared", std::slice::from_ref(&scene))?;
            Ok(())
        });
        bench_result(&format!("tail sp1 BAL synthetic [{label}]"), 200, 20_000, || {
            engine.execute("tail_sp1_balanced", "ft", &tail_inputs)?;
            Ok(())
        });
    }

    header("packet wire path");
    // Artifact-free capable: a fresh checkout benches the wire path over
    // the synthetic engine (packet sizes differ from the paper-scale wire
    // model either way — that is what `wire_bytes` is for).
    let env =
        Env::load_or_synthetic(None, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let scene = &env.flood_val.scenes[0];
    let mut edge =
        avery::edge::EdgePipeline::new(env.engine.clone(), env.device.clone(), env.lut.clone());
    let (pkt, _) = edge.capture_insight(scene, 1, avery::coordinator::TierId::HighAccuracy, 0.0)?;
    let encoded = pkt.encode();
    println!("insight packet real size: {} bytes (wire model {} MB)",
        encoded.len(), pkt.wire_bytes / 1e6);
    bench("packet encode", 100, 20_000, || {
        let _ = pkt.encode();
    });
    bench("packet decode", 100, 20_000, || {
        let _ = Packet::decode(&encoded).unwrap();
    });

    header("artifact execution: weight-delivery modes (the §Perf lever)");
    let Ok(artifacts) = avery::find_artifacts(None) else {
        println!(
            "skipping weight-delivery-mode section — artifacts/ not found \
             (`make artifacts` to bench the real PJRT path)"
        );
        return Ok(());
    };
    for (mode, label) in [
        (ExecMode::LiteralsEachCall, "literals-each-call"),
        (ExecMode::PreuploadedBuffers, "preuploaded-buffers"),
    ] {
        let env = Env::load(&artifacts, std::path::Path::new("out"), mode)?;
        let mut edge = avery::edge::EdgePipeline::new(
            env.engine.clone(),
            env.device.clone(),
            env.lut.clone(),
        );
        let server = avery::cloud::CloudServer::new(env.engine.clone());
        let intent = classify_intent("highlight the stranded people");
        let scene = &env.flood_val.scenes[0];
        bench_result(&format!("head sp1 HA [{label}]"), 3, 15, || {
            edge.capture_insight(scene, 1, avery::coordinator::TierId::HighAccuracy, 0.0)?;
            Ok(())
        });
        let (pkt, _) =
            edge.capture_insight(scene, 1, avery::coordinator::TierId::HighAccuracy, 0.0)?;
        bench_result(&format!("tail sp1 HA [{label}]"), 3, 15, || {
            server.process(&pkt, &intent.token_ids, "ft")?;
            Ok(())
        });
    }
    Ok(())
}
