//! Bench: regenerate Figure 8 (latency/energy per split point on the
//! calibrated Jetson model, through the Mission API) and time the real
//! edge-head execution per split (CPU PJRT wallclock — structure check,
//! not a Jetson proxy).

use avery::bench::{bench_result, header};
use avery::coordinator::TierId;
use avery::mission::{self, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mission = mission::find("fig8").expect("fig8 registered");
    let report = mission.run(&env, &RunOptions::default())?;
    emit_text(&report, &env.out_dir)?;

    header("real edge-head execution per split (CPU PJRT)");
    let scene = &env.flood_val.scenes[0];
    for split in 1..=env.manifest_meta.depth {
        let mut edge = avery::edge::EdgePipeline::new(
            env.engine.clone(),
            env.device.clone(),
            env.lut.clone(),
        );
        bench_result(&format!("edge head sp{split} (balanced)"), 1, 5, || {
            edge.capture_insight(scene, split, TierId::Balanced, 0.0)?;
            Ok(())
        });
    }
    Ok(())
}
