//! Bench: regenerate Figure 7 (split-point accuracy sweep at r = 0.10)
//! through the Mission API.

use avery::mission::{self, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mission = mission::find("fig7").expect("fig7 registered");
    let report = mission.run(&env, &RunOptions::default())?;
    emit_text(&report, &env.out_dir)
}
