//! Bench: regenerate Figure 7 (split-point accuracy sweep at r = 0.10).

use avery::mission::{run_fig7, Env};
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    run_fig7(&env)
}
