//! Bench: the simulation kernel — packets-simulated-per-wall-second and
//! end-to-end mission wall time, emitted as machine-readable
//! `BENCH_simkernel.json` so every future perf PR has a before/after
//! trajectory (schema below; CI's `bench-smoke` job parses it and enforces
//! a packets/sec floor from `ci/bench_floor.json`).
//!
//! Sections:
//!
//! * **dispatch** — one head+tail synthetic round-trip through the inline
//!   backend (caller-thread, no channel) vs the threaded backend (mpsc
//!   round-trip to a dedicated engine thread): the per-packet dispatch win.
//! * **throughput** — aggregate packets/sec over T threads hammering
//!   clones of ONE inline engine: the scaling the old single-consumer
//!   engine thread could not deliver.
//! * **fleet** — the megafleet shard sweep: `avery fleet` wall time at
//!   N ∈ {256, 1024, 4096, 16384} UAVs, `--shards 1` vs `--shards T`,
//!   with per-N byte-identity checks and thread-scaling efficiency
//!   (summarized in the `scale` object for the scale-smoke gate).
//! * **all_missions** — the 8 artifact-free registry missions through the
//!   parallel runner at `--jobs 1` vs `--jobs 4` vs `--jobs 8`, with a
//!   byte-identity check over every report's JSON.
//!
//! Usage: `cargo bench --bench simkernel -- [--quick] [--out PATH]`
//! (`--quick` is what CI runs; default writes `BENCH_simkernel.json` in
//! the current directory).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use avery::bench::{fmt_secs, header};
use avery::coordinator::classify_intent;
use avery::dataset::{Corpus, Dataset};
use avery::mission::{registry, run_collect, run_fleet, Env, EnvSpec, Mission, RunOptions};
use avery::report::to_json;
use avery::runtime::Engine;
use avery::tensor::Tensor;

struct Args {
    quick: bool,
    out: String,
}

fn parse_args() -> Args {
    let mut args = Args { quick: false, out: "BENCH_simkernel.json".to_string() };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--quick" => args.quick = true,
            "--out" => {
                if let Some(v) = argv.get(i + 1) {
                    args.out = v.clone();
                    i += 1;
                }
            }
            other => {
                if let Some(v) = other.strip_prefix("--out=") {
                    args.out = v.to_string();
                }
                // `cargo bench` passes `--bench`; ignore unknown flags so
                // the harness contract stays permissive.
            }
        }
        i += 1;
    }
    args
}

/// One synthetic Insight packet worth of execution: head then tail.
fn roundtrip(engine: &Engine, scene: &Tensor, tail_inputs: &[Tensor; 3]) {
    engine
        .execute("head_sp1_balanced", "shared", std::slice::from_ref(scene))
        .expect("head");
    engine.execute("tail_sp1_balanced", "ft", tail_inputs).expect("tail");
}

fn bench_scene() -> (Tensor, [Tensor; 3]) {
    let ds = Dataset::synthetic(Corpus::Flood, 1, 16, 0xF10D0);
    let scene = ds.scenes[0].image.clone();
    let intent = classify_intent("highlight the stranded people");
    let pids =
        Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone()).expect("pids");
    let engine = Engine::synthetic();
    let head = engine
        .execute("head_sp1_balanced", "shared", std::slice::from_ref(&scene))
        .expect("head outputs");
    let tail_inputs = [head[0].clone(), head[1].clone(), pids];
    (scene, tail_inputs)
}

/// Mean nanoseconds per head+tail round-trip on one thread.
fn ns_per_packet(engine: &Engine, scene: &Tensor, tail_inputs: &[Tensor; 3], iters: usize) -> f64 {
    for _ in 0..iters / 10 {
        roundtrip(engine, scene, tail_inputs);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        roundtrip(engine, scene, tail_inputs);
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Aggregate packets/sec over `threads` threads sharing one inline engine.
fn throughput(
    engine: &Engine,
    scene: &Tensor,
    tail_inputs: &[Tensor; 3],
    threads: usize,
    per_thread: usize,
) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                for _ in 0..per_thread {
                    roundtrip(engine, scene, tail_inputs);
                }
            });
        }
    });
    (threads * per_thread) as f64 / t0.elapsed().as_secs_f64()
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let mode = if args.quick { "quick" } else { "full" };
    let dispatch_iters = if args.quick { 20_000 } else { 200_000 };
    let fleet_duration = if args.quick { 60.0 } else { 300.0 };
    let all_duration = if args.quick { 120.0 } else { 600.0 };
    let all_exec_every = if args.quick { 4 } else { 1 };

    // ---- dispatch: inline vs threaded round-trip -------------------------
    header("dispatch: inline vs engine-thread synthetic round-trip");
    let (scene, tail_inputs) = bench_scene();
    let inline = Engine::synthetic();
    let threaded = Engine::synthetic_threaded();
    let inline_ns = ns_per_packet(&inline, &scene, &tail_inputs, dispatch_iters);
    let threaded_ns = ns_per_packet(&threaded, &scene, &tail_inputs, dispatch_iters);
    println!(
        "inline   {inline_ns:>10.0} ns/packet\nthreaded {threaded_ns:>10.0} ns/packet\n\
         channel+hop overhead: {:.2}x",
        threaded_ns / inline_ns
    );

    // ---- throughput scaling over shared inline engine --------------------
    header("throughput: packets/sec over T threads, one shared inline engine");
    let per_thread = if args.quick { 20_000 } else { 100_000 };
    let mut tputs: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let pps = throughput(&inline, &scene, &tail_inputs, threads, per_thread);
        println!("threads {threads:>2}: {pps:>12.0} packets/s");
        tputs.push((threads, pps));
    }

    // ---- megafleet shard sweep -------------------------------------------
    // The scaling axis this bench exists to watch: the sharded event core
    // (`--shards T`, DESIGN.md "Megafleet core") at N up to 16k agents.
    // Each N runs twice — `--shards 1` and `--shards T` — and the two
    // reports must be byte-identical; the efficiency column is
    // wall(1) / (wall(T) * T), the fraction of perfect thread scaling.
    // HLO execution is heavily subsampled (`exec_every`) so the sweep
    // times the scheduler + contention model, not the synthetic kernel.
    header("megafleet: sharded event core wall time (synthetic env)");
    let shard_t = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .clamp(1, 8);
    let mut fleet_rows: Vec<(usize, f64, f64, u64)> = Vec::new();
    let mut scale_identical = true;
    let env = Env::synthetic(Path::new("out/bench-simkernel"))?;
    for &n in &[256usize, 1024, 4096, 16384] {
        let mut walls = [0.0f64; 2];
        let mut jsons: Vec<String> = Vec::new();
        let mut delivered = 0u64;
        for (slot, shards) in [(0usize, 1usize), (1, shard_t)] {
            let opts = RunOptions {
                duration_secs: fleet_duration,
                uavs: Some(n),
                workers: Some(4),
                exec_every: 200, // scheduler sweep — skip most HLO
                seed: 7,
                shards: Some(shards),
                ..RunOptions::default()
            };
            let t0 = Instant::now();
            let (run, report) = run_fleet(&env, &opts)?;
            walls[slot] = t0.elapsed().as_secs_f64();
            delivered = run.delivered_total;
            jsons.push(to_json(&report));
        }
        let identical = jsons[0] == jsons[1];
        scale_identical &= identical;
        let eff = walls[0] / (walls[1] * shard_t as f64);
        println!(
            "N={n:<5} shards 1: {:>9}  shards {shard_t}: {:>9}  efficiency {eff:.2}  \
             ({delivered} packets, byte-identical: {identical})",
            fmt_secs(walls[0]),
            fmt_secs(walls[1]),
        );
        fleet_rows.push((n, walls[0], walls[1], delivered));
    }

    // ---- avery all: --jobs 1 vs --jobs 4 vs --jobs 8 ---------------------
    header("avery all (artifact-free registry) through the parallel runner");
    let missions: Vec<Box<dyn Mission>> =
        registry().into_iter().filter(|m| !m.needs_artifacts()).collect();
    let opts = RunOptions {
        duration_secs: all_duration,
        exec_every: all_exec_every,
        seed: 7,
        ..RunOptions::default()
    };
    let out_dir = Path::new("out/bench-simkernel");
    let mut walls: Vec<(usize, f64)> = Vec::new();
    let mut json_ref: Option<Vec<String>> = None;
    let mut byte_identical = true;
    // jobs=4 first so any warm-cache bias favors the serial run — the
    // reported speedup is conservative.
    for jobs in [4usize, 1, 8] {
        let t0 = Instant::now();
        let reports = run_collect(&missions, &EnvSpec::Synthetic, out_dir, &opts, jobs);
        let wall = t0.elapsed().as_secs_f64();
        let jsons: Vec<String> = reports
            .iter()
            .map(|r| to_json(r.as_ref().unwrap_or_else(|e| panic!("mission failed: {e:#}"))))
            .collect();
        match &json_ref {
            None => json_ref = Some(jsons),
            Some(want) => byte_identical &= *want == jsons,
        }
        println!("--jobs {jobs}: {} for {} missions", fmt_secs(wall), missions.len());
        walls.push((jobs, wall));
    }
    let wall_of = |j: usize| walls.iter().find(|(jobs, _)| *jobs == j).unwrap().1;
    let (w1, w4, w8) = (wall_of(1), wall_of(4), wall_of(8));
    println!(
        "speedup: --jobs 4 {:.2}x, --jobs 8 {:.2}x, reports byte-identical: {byte_identical}",
        w1 / w4,
        w1 / w8
    );

    // ---- machine-readable output -----------------------------------------
    let fleet_json: Vec<String> = fleet_rows
        .iter()
        .map(|(n, wall1, wall_t, pkts)| {
            format!(
                "{{\"uavs\":{n},\"wall_secs_shards_1\":{},\"wall_secs_sharded\":{},\
                 \"sim_packets\":{pkts},\"packets_per_wall_sec\":{},\"efficiency\":{}}}",
                jf(*wall1),
                jf(*wall_t),
                jf(*pkts as f64 / wall_t),
                jf(wall1 / (wall_t * shard_t as f64))
            )
        })
        .collect();
    // Scale summary for the scale-smoke gate: efficiency at the largest N
    // (where per-epoch work dwarfs the barrier cost) plus the sweep-wide
    // byte-identity verdict.
    let (_, big_w1, big_wt, _) = *fleet_rows.last().expect("sweep nonempty");
    let scale_json = format!(
        "{{\"shards\":{shard_t},\"byte_identical\":{scale_identical},\
         \"thread_scaling_efficiency\":{}}}",
        jf(big_w1 / (big_wt * shard_t as f64))
    );
    let tput_json: Vec<String> = tputs
        .iter()
        .map(|(t, pps)| format!("{{\"threads\":{t},\"packets_per_sec\":{}}}", jf(*pps)))
        .collect();
    let json = format!(
        "{{\"schema\":1,\"bench\":\"simkernel\",\"mode\":\"{mode}\",\
         \"dispatch\":{{\"inline_ns_per_packet\":{},\"threaded_ns_per_packet\":{},\
         \"threaded_over_inline\":{}}},\
         \"throughput\":[{}],\
         \"fleet\":[{}],\
         \"scale\":{scale_json},\
         \"all_missions\":{{\"missions\":{},\"jobs_1_wall_secs\":{},\
         \"jobs_4_wall_secs\":{},\"jobs_8_wall_secs\":{},\
         \"speedup_jobs_4\":{},\"speedup_jobs_8\":{},\
         \"byte_identical\":{byte_identical}}}}}",
        jf(inline_ns),
        jf(threaded_ns),
        jf(threaded_ns / inline_ns),
        tput_json.join(","),
        fleet_json.join(","),
        missions.len(),
        jf(w1),
        jf(w4),
        jf(w8),
        jf(w1 / w4),
        jf(w1 / w8),
    );
    std::fs::write(&args.out, format!("{json}\n"))?;
    println!("\nwrote {}", args.out);
    Ok(())
}
