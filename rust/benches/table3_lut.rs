//! Bench: regenerate Table 3 (per-tier accuracy through the runtime path,
//! driven through the Mission API) and time the per-packet split pipeline
//! at each tier.

use avery::bench::{bench_result, header};
use avery::coordinator::{classify_intent, TierId};
use avery::mission::{self, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    header("Table 3 — System LUT regeneration");
    let mission = mission::find("table3").expect("table3 registered");
    let report = mission.run(&env, &RunOptions::default())?;
    emit_text(&report, &env.out_dir)?;

    header("per-packet split pipeline latency by tier (head+tail, CPU PJRT)");
    let scene = &env.flood_val.scenes[0];
    let intent = classify_intent("highlight the stranded people");
    for tier in TierId::ALL {
        let mut edge = avery::edge::EdgePipeline::new(
            env.engine.clone(),
            env.device.clone(),
            env.lut.clone(),
        );
        let server = avery::cloud::CloudServer::new(env.engine.clone());
        bench_result(&format!("split@1 {}", tier.name()), 2, 10, || {
            let (pkt, _) = edge.capture_insight(scene, 1, tier, 0.0)?;
            server.process(&pkt, &intent.token_ids, "ft")?;
            Ok(())
        });
    }
    Ok(())
}
