//! Bench: fleet scaling sweep — N UAVs contending for one disaster-zone
//! uplink, N ∈ {1, 4, 16, 64} (DESIGN.md "Fleet subsystem"), driven
//! through the Mission API and consuming each run's structured `Report`.
//!
//! Reports, per fleet size: aggregate delivered PPS, mean per-UAV PPS,
//! Jain fairness, total tier switches, virtual server utilization, and the
//! wall-clock cost of simulating the fleet.  HLO execution is heavily
//! subsampled (`exec_every`) so the sweep times the *scheduler + contention
//! model*, which is the scaling axis this bench exists to watch.

use std::time::Instant;

use avery::mission::{self, Env, RunOptions};
use avery::runtime::ExecMode;
use avery::telemetry::{f, Table};

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mission = mission::find("fleet").expect("fleet registered");

    let mut table = Table::new(
        "Fleet scaling sweep (120 s mission, contended uplink)",
        &[
            "N", "Aggregate PPS", "Mean UAV PPS", "Jain", "Switches",
            "Infeasible s", "Server util", "Wall (s)",
        ],
    );
    for n in [1usize, 4, 16, 64] {
        let opts = RunOptions {
            uavs: Some(n),
            workers: Some(2),
            duration_secs: 120.0,
            exec_every: 1000, // throughput/contention sweep — skip most HLO
            ..RunOptions::default()
        };
        let t0 = Instant::now();
        let report = mission.run(&env, &opts)?;
        let wall = t0.elapsed().as_secs_f64();
        let scalar = |name: &str| report.scalar_value(name).unwrap_or(f64::NAN);
        table.row(&[
            n.to_string(),
            f(scalar("aggregate_pps"), 3),
            f(scalar("mean_insight_pps"), 3),
            f(scalar("jain_pps"), 3),
            f(scalar("tier_switches"), 0),
            f(scalar("infeasible_s"), 0),
            f(scalar("server_utilization"), 3),
            f(wall, 2),
        ]);
    }
    table.print();
    println!(
        "expect: aggregate PPS saturates as N grows (the 8-20 Mbps trace is the\n\
         shared bottleneck), per-UAV PPS shrinks ~1/N, and controllers shed tiers\n\
         toward High-Throughput — fairness should stay near 1.0 throughout."
    );
    Ok(())
}
