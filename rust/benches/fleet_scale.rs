//! Bench: fleet scaling sweep — N UAVs contending for one disaster-zone
//! uplink, N ∈ {1, 4, 16, 64} (DESIGN.md "Fleet subsystem").
//!
//! Reports, per fleet size: aggregate delivered PPS, mean per-UAV PPS,
//! Jain fairness, total tier switches, virtual server utilization, and the
//! wall-clock cost of simulating the fleet.  HLO execution is heavily
//! subsampled (`exec_every`) so the sweep times the *scheduler + contention
//! model*, which is the scaling axis this bench exists to watch.

use std::time::Instant;

use avery::mission::{run_fleet, Env, FleetOptions};
use avery::runtime::ExecMode;
use avery::telemetry::{f, Table};

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;

    let mut table = Table::new(
        "Fleet scaling sweep (120 s mission, contended uplink)",
        &[
            "N", "Aggregate PPS", "Mean UAV PPS", "Jain", "Switches",
            "Infeasible s", "Server util", "Wall (s)",
        ],
    );
    for n in [1usize, 4, 16, 64] {
        let opts = FleetOptions {
            uavs: n,
            workers: 2,
            duration_secs: 120.0,
            exec_every: 1000, // throughput/contention sweep — skip most HLO
            ..FleetOptions::default()
        };
        let t0 = Instant::now();
        let run = run_fleet(&env, &opts)?;
        let wall = t0.elapsed().as_secs_f64();
        let insight_pps: Vec<f64> = run
            .per_uav
            .iter()
            .filter(|o| o.role == avery::streams::UavRole::Insight)
            .map(|o| o.summary.avg_pps)
            .collect();
        let mean_uav_pps =
            insight_pps.iter().sum::<f64>() / insight_pps.len().max(1) as f64;
        table.row(&[
            n.to_string(),
            f(run.aggregate_pps, 3),
            f(mean_uav_pps, 3),
            f(run.jain_pps, 3),
            run.switches_total.to_string(),
            run.infeasible_total.to_string(),
            f(run.server_utilization, 3),
            f(wall, 2),
        ]);
    }
    table.print();
    println!(
        "expect: aggregate PPS saturates as N grows (the 8-20 Mbps trace is the\n\
         shared bottleneck), per-UAV PPS shrinks ~1/N, and controllers shed tiers\n\
         toward High-Throughput — fairness should stay near 1.0 throughout."
    );
    Ok(())
}
