//! Bench: the scenario matrix — every registered scenario-library regime
//! run end to end (DESIGN.md "Scenario library & artifact-free sim path").
//!
//! Reports, per scenario: fleet shape, delivered packets, aggregate PPS,
//! Jain fairness, tier/intent switches, infeasible (outage-starved)
//! seconds, scripted outage dwell, and the wall-clock cost of simulating
//! the regime.  Runs against real artifacts when present, else the
//! synthetic closed-form engine — the matrix itself is what this bench
//! times, not the numerics.

use std::time::Instant;

use avery::mission::{run_scenario, Env, ScenarioOptions};
use avery::runtime::ExecMode;
use avery::scenario::SCENARIO_NAMES;
use avery::telemetry::{f, Table};

fn main() -> anyhow::Result<()> {
    let env = Env::load_or_synthetic(
        None,
        std::path::Path::new("out"),
        ExecMode::PreuploadedBuffers,
    )?;

    let mut table = Table::new(
        "Scenario matrix (180 s missions, exec-every 50)",
        &[
            "Scenario", "UAVs", "Delivered", "Agg PPS", "Jain", "Tier sw",
            "Intent sw", "Infeasible s", "Wall (s)",
        ],
    );
    for name in SCENARIO_NAMES {
        let opts = ScenarioOptions {
            name: name.to_string(),
            duration_secs: 180.0,
            exec_every: 50, // regime/scheduler sweep — subsample the HLO
            ..ScenarioOptions::default()
        };
        let t0 = Instant::now();
        let run = run_scenario(&env, &opts)?;
        let wall = t0.elapsed().as_secs_f64();
        table.row(&[
            name.to_string(),
            run.per_uav.len().to_string(),
            run.delivered_total.to_string(),
            f(run.aggregate_pps, 3),
            f(run.jain_pps, 3),
            run.switches_total.to_string(),
            run.intent_switches_total.to_string(),
            run.infeasible_total.to_string(),
            f(wall, 2),
        ]);
    }
    table.print();
    println!(
        "expect: earthquake-canyon accrues infeasible seconds through its blackouts,\n\
         coastal-satellite sheds tiers under the sawtooth + 280 ms latency, and the\n\
         intent-switch scenarios pause tier occupancy while parked on Context."
    );
    Ok(())
}
