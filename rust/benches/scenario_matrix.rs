//! Bench: the scenario matrix — the scenario-library regimes end to end
//! through the Mission API, plus the scenario compiler's perf trajectory
//! (DESIGN.md "Scenario compiler"), emitted as machine-readable
//! `BENCH_scenario_matrix.json` (CI's `matrix-smoke` job parses it and
//! enforces a compile-throughput floor from `ci/bench_floor.json`).
//!
//! Sections:
//!
//! * **library** — every registered scenario run end to end (fleet shape,
//!   delivered packets, aggregate PPS, Jain fairness, tier/intent
//!   switches, infeasible seconds, wall-clock).
//! * **compile** — parse + validate + lower throughput over the full
//!   generated manifest corpus (8 traces × 4 links × 4 fleets × 4
//!   intents), plus the checked-in `scenarios/*.toml` files.
//! * **parity** — each checked-in manifest instantiated against its
//!   hand-coded `scenario::build` arm: the two `Scenario` values must be
//!   identical (bit-for-bit via `Debug`, which round-trips floats).
//! * **matrix** — `avery run matrix` over a seeded generated sample with
//!   the invariant gates on: scenarios/sec and the pass/fail tally.
//!
//! Usage: `cargo bench --bench scenario_matrix -- [--quick] [--out PATH]`
//! (`--quick` is what CI runs; default writes `BENCH_scenario_matrix.json`
//! in the current directory).

use std::path::Path;
use std::time::Instant;

use avery::bench::header;
use avery::mission::{self, Env, RunOptions};
use avery::runtime::ExecMode;
use avery::scenario::compile::{compile_file, compile_str};
use avery::scenario::{build, generate, SCENARIO_NAMES};
use avery::telemetry::{f, Table};

struct Args {
    quick: bool,
    out: String,
}

fn parse_args() -> Args {
    let mut args = Args { quick: false, out: "BENCH_scenario_matrix.json".to_string() };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--quick" => args.quick = true,
            "--out" => {
                if let Some(v) = argv.get(i + 1) {
                    args.out = v.clone();
                    i += 1;
                }
            }
            other => {
                if let Some(v) = other.strip_prefix("--out=") {
                    args.out = v.to_string();
                }
                // `cargo bench` passes `--bench`; ignore unknown flags so
                // the harness contract stays permissive.
            }
        }
        i += 1;
    }
    args
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn main() -> anyhow::Result<()> {
    let args = parse_args();
    let mode = if args.quick { "quick" } else { "full" };
    let compile_rounds = if args.quick { 2 } else { 10 };
    let matrix_count = if args.quick { 8 } else { 32 };

    let env = Env::load_or_synthetic(
        None,
        std::path::Path::new("out"),
        ExecMode::PreuploadedBuffers,
    )?;
    let mission = mission::find("scenario").expect("scenario registered");

    // ---- library: every built-in regime end to end -----------------------
    let mut table = Table::new(
        "Scenario matrix (180 s missions, exec-every 50)",
        &[
            "Scenario", "UAVs", "Delivered", "Agg PPS", "Jain", "Tier sw",
            "Intent sw", "Infeasible s", "Wall (s)",
        ],
    );
    let mut library_json = Vec::new();
    for name in SCENARIO_NAMES {
        let opts = RunOptions {
            name: Some(name.to_string()),
            duration_secs: 180.0,
            exec_every: 50, // regime/scheduler sweep — subsample the HLO
            ..RunOptions::default()
        };
        let t0 = Instant::now();
        let report = mission.run(&env, &opts)?;
        let wall = t0.elapsed().as_secs_f64();
        let scalar = |n: &str| report.scalar_value(n).unwrap_or(f64::NAN);
        table.row(&[
            name.to_string(),
            f(scalar("uavs"), 0),
            f(scalar("delivered"), 0),
            f(scalar("aggregate_pps"), 3),
            f(scalar("jain_pps"), 3),
            f(scalar("tier_switches"), 0),
            f(scalar("intent_switches"), 0),
            f(scalar("infeasible_s"), 0),
            f(wall, 2),
        ]);
        library_json.push(format!(
            "{{\"scenario\":\"{name}\",\"delivered\":{},\"jain\":{},\"wall_s\":{}}}",
            jf(scalar("delivered")),
            jf(scalar("jain_pps")),
            jf(wall)
        ));
    }
    table.print();
    println!(
        "expect: earthquake-canyon accrues infeasible seconds through its blackouts,\n\
         coastal-satellite sheds tiers under the sawtooth + 280 ms latency, and the\n\
         intent-switch scenarios pause tier occupancy while parked on Context."
    );

    // ---- compile: generator corpus + checked-in manifests ----------------
    header("compile: parse + validate + lower throughput");
    let corpus = generate::generate(7);
    let t0 = Instant::now();
    let mut compiled = 0usize;
    for _ in 0..compile_rounds {
        for m in &corpus {
            compile_str(&m.text)
                .unwrap_or_else(|e| panic!("generated `{}` failed to compile: {e}", m.name));
            compiled += 1;
        }
    }
    let compile_wall = t0.elapsed().as_secs_f64();
    let compiles_per_sec = compiled as f64 / compile_wall;
    println!(
        "corpus {} manifests x {compile_rounds} rounds: {compiled} compiles in {:.3} s \
         ({:.0}/s)",
        corpus.len(),
        compile_wall,
        compiles_per_sec
    );
    let t0 = Instant::now();
    for name in SCENARIO_NAMES {
        compile_file(Path::new(&format!("scenarios/{name}.toml")))
            .unwrap_or_else(|e| panic!("scenarios/{name}.toml: {e}"));
    }
    println!(
        "checked-in manifests: {} files in {:.1} ms",
        SCENARIO_NAMES.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // ---- parity: manifests reproduce the hand-coded build() arms ---------
    header("parity: scenarios/*.toml vs scenario::build");
    let mut parity_ok = true;
    for name in SCENARIO_NAMES {
        let compiled = compile_file(Path::new(&format!("scenarios/{name}.toml")))
            .unwrap_or_else(|e| panic!("scenarios/{name}.toml: {e}"));
        let a = format!("{:?}", compiled.instantiate(7, 180.0));
        let b = format!("{:?}", build(name, 7, 180.0)?);
        let same = a == b;
        parity_ok &= same;
        println!("{name}: {}", if same { "identical" } else { "DIVERGED" });
    }

    // ---- matrix: generated sample through the invariant gates ------------
    header("matrix: generated sample with invariant gates");
    let matrix = mission::find("matrix").expect("matrix registered");
    let opts = RunOptions {
        matrix_count: Some(matrix_count),
        seed: 7,
        exec_every: 25,
        ..RunOptions::default()
    };
    let t0 = Instant::now();
    let report = matrix.run(&env, &opts)?;
    let matrix_wall = t0.elapsed().as_secs_f64();
    let scalar = |n: &str| report.scalar_value(n).unwrap_or(f64::NAN);
    let (run, passed, failed) = (scalar("scenarios_run"), scalar("passed"), scalar("failed"));
    println!(
        "{run:.0} scenarios in {matrix_wall:.2} s ({:.2}/s): {passed:.0} passed, \
         {failed:.0} failed (corpus {})",
        run / matrix_wall,
        generate::MATRIX_SIZE
    );

    // ---- JSON ------------------------------------------------------------
    let json = format!(
        "{{\"schema\":1,\"bench\":\"scenario_matrix\",\"mode\":\"{mode}\",\
         \"compile\":{{\"corpus_size\":{},\"rounds\":{compile_rounds},\
         \"compiles_per_sec\":{},\"wall_s\":{}}},\
         \"parity\":{{\"scenarios\":{},\"identical\":{parity_ok}}},\
         \"matrix\":{{\"count\":{},\"passed\":{},\"failed\":{},\"wall_s\":{},\
         \"scenarios_per_sec\":{}}},\
         \"library\":[{}]}}",
        corpus.len(),
        jf(compiles_per_sec),
        jf(compile_wall),
        SCENARIO_NAMES.len(),
        run as usize,
        passed as usize,
        failed as usize,
        jf(matrix_wall),
        jf(run / matrix_wall),
        library_json.join(",")
    );
    std::fs::write(&args.out, format!("{json}\n"))?;
    println!("\nwrote {}", args.out);

    if !parity_ok {
        anyhow::bail!("manifest/builtin parity diverged");
    }
    if failed > 0.0 {
        anyhow::bail!("{failed:.0} matrix scenarios failed their invariant gates");
    }
    Ok(())
}
