//! Bench: the scenario matrix — every registered scenario-library regime
//! run end to end through the Mission API (DESIGN.md "Scenario library &
//! artifact-free sim path"), consuming each run's structured `Report`.
//!
//! Reports, per scenario: fleet shape, delivered packets, aggregate PPS,
//! Jain fairness, tier/intent switches, infeasible (outage-starved)
//! seconds, and the wall-clock cost of simulating the regime.  Runs
//! against real artifacts when present, else the synthetic closed-form
//! engine — the matrix itself is what this bench times, not the numerics.

use std::time::Instant;

use avery::mission::{self, Env, RunOptions};
use avery::runtime::ExecMode;
use avery::scenario::SCENARIO_NAMES;
use avery::telemetry::{f, Table};

fn main() -> anyhow::Result<()> {
    let env = Env::load_or_synthetic(
        None,
        std::path::Path::new("out"),
        ExecMode::PreuploadedBuffers,
    )?;
    let mission = mission::find("scenario").expect("scenario registered");

    let mut table = Table::new(
        "Scenario matrix (180 s missions, exec-every 50)",
        &[
            "Scenario", "UAVs", "Delivered", "Agg PPS", "Jain", "Tier sw",
            "Intent sw", "Infeasible s", "Wall (s)",
        ],
    );
    for name in SCENARIO_NAMES {
        let opts = RunOptions {
            name: Some(name.to_string()),
            duration_secs: 180.0,
            exec_every: 50, // regime/scheduler sweep — subsample the HLO
            ..RunOptions::default()
        };
        let t0 = Instant::now();
        let report = mission.run(&env, &opts)?;
        let wall = t0.elapsed().as_secs_f64();
        let scalar = |n: &str| report.scalar_value(n).unwrap_or(f64::NAN);
        table.row(&[
            name.to_string(),
            f(scalar("uavs"), 0),
            f(scalar("delivered"), 0),
            f(scalar("aggregate_pps"), 3),
            f(scalar("jain_pps"), 3),
            f(scalar("tier_switches"), 0),
            f(scalar("intent_switches"), 0),
            f(scalar("infeasible_s"), 0),
            f(wall, 2),
        ]);
    }
    table.print();
    println!(
        "expect: earthquake-canyon accrues infeasible seconds through its blackouts,\n\
         coastal-satellite sheds tiers under the sawtooth + 280 ms latency, and the\n\
         intent-switch scenarios pause tier occupancy while parked on Context."
    );
    Ok(())
}
