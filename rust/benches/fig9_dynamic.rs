//! Bench: regenerate Figure 9 (20-minute dynamic run, AVERY vs the three
//! static tiers over the scripted disaster-zone trace) through the Mission
//! API, including the hysteresis ablation called out in DESIGN.md.

use avery::mission::{self, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let opts = RunOptions {
        ablate_hysteresis: Some(0.10),
        exec_every: 4, // keep the bench under ~5 min on 1 core; accuracy is
        // a uniform subsample, throughput/energy are exact
        ..RunOptions::default()
    };
    let mission = mission::find("fig9").expect("fig9 registered");
    let report = mission.run(&env, &opts)?;
    emit_text(&report, &env.out_dir)
}
