//! Bench: regenerate Figure 9 (20-minute dynamic run, AVERY vs the three
//! static tiers over the scripted disaster-zone trace) including the
//! hysteresis ablation called out in DESIGN.md.

use avery::mission::{run_fig9, Env, Fig9Options};
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let opts = Fig9Options {
        ablate_hysteresis: Some(0.10),
        exec_every: 4, // keep the bench under ~5 min on 1 core; accuracy is
        // a uniform subsample, throughput/energy are exact
        ..Fig9Options::default()
    };
    run_fig9(&env, &opts)?;
    Ok(())
}
