//! Bench: regenerate Figure 10 (accuracy vs throughput trade-off scatter,
//! including the Prioritize-Throughput operating point) through the
//! Mission API.

use avery::mission::{self, Env, RunOptions};
use avery::report::emit_text;
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    let mission = mission::find("fig10").expect("fig10 registered");
    let report = mission.run(&env, &RunOptions { exec_every: 4, ..RunOptions::default() })?;
    emit_text(&report, &env.out_dir)
}
