//! Bench: regenerate Figure 10 (accuracy vs throughput trade-off scatter,
//! including the Prioritize-Throughput operating point).

use avery::mission::{run_fig10, Env, Fig9Options};
use avery::runtime::ExecMode;

fn main() -> anyhow::Result<()> {
    let artifacts = avery::find_artifacts(None)?;
    let env = Env::load(&artifacts, std::path::Path::new("out"), ExecMode::PreuploadedBuffers)?;
    run_fig10(&env, &Fig9Options { exec_every: 4, ..Fig9Options::default() })
}
