//! Bench: the chaos layer (DESIGN.md "Chaos & recovery") —
//! machine-readable `BENCH_chaos.json` for the resilience trajectory,
//! parsed by CI's `chaos-smoke` job against `ci/bench_floor.json`.
//!
//! Every measured quantity is *virtual*: the fleet mission replays the
//! same seeded timeline whatever the host, so availability/MTTR numbers
//! are byte-stable across machines and the CI floors never flake on a
//! slow runner (wall-clock is reported, never gated).
//!
//! Sections:
//!
//! * **cell_kill** — a two-cell fleet where cell 0 crashes mid-mission and
//!   recovers: availability, MTTR/TTD percentiles and the Insight p99
//!   against a fault-free baseline.  CI floors availability and ceilings
//!   MTTR p99.
//! * **mttr_vs_backoff** — the same crash under a sweep of re-probe base
//!   backoffs: recovery time as a function of the quarantine schedule.
//! * **availability_vs_rate** — an exec-error window under a failure-rate
//!   sweep with the default retry/degrade resilience: how hard the layer
//!   has to work (retries, degradations) to hold availability up.
//!
//! Usage: `cargo bench --bench chaos -- [--quick] [--out PATH]`
//! (`--quick` is what CI runs; default writes `BENCH_chaos.json`).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use avery::bench::header;
use avery::faults::{FaultKind, FaultSpec};
use avery::mission::{run_fleet, Env, RunOptions};
use avery::report::Report;
use avery::streams::fleet::FleetRun;

struct Args {
    quick: bool,
    out: String,
}

fn parse_args() -> Args {
    let mut args = Args { quick: false, out: "BENCH_chaos.json".to_string() };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--quick" => args.quick = true,
            "--out" => {
                if let Some(v) = argv.get(i + 1) {
                    args.out = v.clone();
                    i += 1;
                }
            }
            other => {
                if let Some(v) = other.strip_prefix("--out=") {
                    args.out = v.to_string();
                }
                // `cargo bench` passes `--bench`; ignore unknown flags.
            }
        }
        i += 1;
    }
    args
}

fn spec(
    kind: FaultKind,
    cell: usize,
    at: f64,
    duration: f64,
    rate: f64,
    stall_secs: f64,
) -> FaultSpec {
    FaultSpec { kind, cell, at, duration, rate, stall_secs }
}

/// One seeded fleet run over a fault schedule; returns the run, its report
/// and the wall-clock seconds it took to simulate.
fn run(env: &Env, opts: &RunOptions) -> (FleetRun, Report, f64) {
    let t0 = Instant::now();
    let (fleet, report) = run_fleet(env, opts).expect("fleet mission failed");
    (fleet, report, t0.elapsed().as_secs_f64())
}

fn availability(r: &FleetRun) -> f64 {
    (r.executed_total + r.degraded_total) as f64 / r.captures_total.max(1) as f64
}

fn scalar(report: &Report, name: &str) -> f64 {
    report.scalar_value(name).unwrap_or(0.0)
}

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "null".to_string()
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let mode = if args.quick { "quick" } else { "full" };
    let duration = if args.quick { 180.0 } else { 600.0 };
    let uavs = if args.quick { 6 } else { 12 };
    let env = Env::synthetic(Path::new("target/bench-out/chaos"))?;

    let base = RunOptions {
        duration_secs: duration,
        uavs: Some(uavs),
        workers: Some(2),
        cells: Some(2),
        seed: 7,
        exec_every: 1,
        ..RunOptions::default()
    };
    // Cell 0 dark for the middle fifth of the mission.
    let crash = vec![spec(FaultKind::CellCrash, 0, 0.4, 0.2, 0.0, 0.0)];

    // ---- cell kill vs fault-free baseline --------------------------------
    header("cell kill: two-cell fleet, cell 0 dark for 20% of the mission");
    let (baseline, _, wall_base) = run(&env, &base);
    let (killed, kreport, wall_kill) =
        run(&env, &RunOptions { fault_specs: crash.clone(), ..base.clone() });
    let avail_kill = availability(&killed);
    let mttr_p50 = scalar(&kreport, "mttr_p50_s");
    let mttr_p99 = scalar(&kreport, "mttr_p99_s");
    let ttd_p99 = scalar(&kreport, "ttd_p99_s");
    let recoveries = scalar(&kreport, "recoveries");
    println!(
        "baseline : {} captures, availability {:.4}, ins p99 {:.4}s  ({wall_base:.2}s wall)",
        baseline.captures_total,
        availability(&baseline),
        baseline.lat_insight.p99()
    );
    println!(
        "cell kill: {} captures, availability {avail_kill:.4}, ins p99 {:.4}s, \
         MTTR p50/p99 {mttr_p50:.2}/{mttr_p99:.2}s, TTD p99 {ttd_p99:.3}s, \
         {recoveries:.0} recoveries  ({wall_kill:.2}s wall)",
        killed.captures_total,
        killed.lat_insight.p99()
    );

    // ---- MTTR vs re-probe backoff ----------------------------------------
    header("MTTR vs re-probe base backoff (same crash, quarantine sweep)");
    let backoffs: &[f64] = if args.quick { &[0.25, 1.0, 4.0] } else { &[0.25, 0.5, 1.0, 2.0, 4.0] };
    let mut mttr_rows: Vec<String> = Vec::new();
    for &b in backoffs {
        let (_, report, _) = run(
            &env,
            &RunOptions {
                fault_specs: crash.clone(),
                probe_backoff: Some(b),
                ..base.clone()
            },
        );
        let p50 = scalar(&report, "mttr_p50_s");
        let p99 = scalar(&report, "mttr_p99_s");
        let rec = scalar(&report, "recoveries");
        println!("backoff {b:>5.2}s: MTTR p50 {p50:>7.2}s  p99 {p99:>7.2}s  ({rec:.0} recoveries)");
        mttr_rows.push(format!(
            "{{\"backoff_secs\":{},\"mttr_p50_s\":{},\"mttr_p99_s\":{},\"recoveries\":{}}}",
            jf(b),
            jf(p50),
            jf(p99),
            jf(rec)
        ));
    }

    // ---- availability vs exec-error rate ---------------------------------
    header("availability vs exec-error rate (default retry + degrade resilience)");
    let rates: &[f64] = if args.quick { &[0.1, 0.5, 0.9] } else { &[0.1, 0.3, 0.5, 0.7, 0.9] };
    let mut rate_rows: Vec<String> = Vec::new();
    let mut min_avail_rate = f64::INFINITY;
    for &r in rates {
        let faults = vec![spec(FaultKind::ExecError, 0, 0.2, 0.6, r, 0.0)];
        let (fleet, _, _) = run(&env, &RunOptions { fault_specs: faults, ..base.clone() });
        let avail = availability(&fleet);
        min_avail_rate = min_avail_rate.min(avail);
        println!(
            "rate {r:.1}: availability {avail:.4}  ({} retries, {} degraded, {} abandoned \
             of {} captures)",
            fleet.retries_total, fleet.degraded_total, fleet.abandoned_total,
            fleet.captures_total
        );
        rate_rows.push(format!(
            "{{\"rate\":{},\"availability\":{},\"retries\":{},\"degraded\":{},\
             \"abandoned\":{},\"captures\":{}}}",
            jf(r),
            jf(avail),
            fleet.retries_total,
            fleet.degraded_total,
            fleet.abandoned_total,
            fleet.captures_total
        ));
    }

    // ---- machine-readable output -----------------------------------------
    let json = format!(
        "{{\"schema\":1,\"bench\":\"chaos\",\"mode\":\"{mode}\",\
         \"availability\":{},\
         \"mttr_p50_s\":{},\
         \"mttr_p99_s\":{},\
         \"ttd_p99_s\":{},\
         \"recoveries\":{},\
         \"baseline_availability\":{},\
         \"baseline_ins_p99_s\":{},\
         \"cell_kill_ins_p99_s\":{},\
         \"min_availability_rate_sweep\":{},\
         \"mttr_vs_backoff\":[{}],\
         \"availability_vs_rate\":[{}]}}",
        jf(avail_kill),
        jf(mttr_p50),
        jf(mttr_p99),
        jf(ttd_p99),
        jf(recoveries),
        jf(availability(&baseline)),
        jf(baseline.lat_insight.p99()),
        jf(killed.lat_insight.p99()),
        jf(min_avail_rate),
        mttr_rows.join(","),
        rate_rows.join(",")
    );
    std::fs::write(&args.out, format!("{json}\n"))?;
    println!("\nwrote {}", args.out);
    Ok(())
}
