//! Mission API regression suite — no artifacts required, never skips.
//!
//! * **Registry completeness** — every legacy subcommand name resolves to
//!   a mission, the registry is exactly the nine drivers, and `avery all`
//!   order (= registry order) is pinned.
//! * **Golden JSON report** — a synthetic `scenario` run serialized
//!   through the JSON sink: schema-stable key layout, parseable by a
//!   strict JSON grammar, byte-deterministic per seed, and free of
//!   wall-clock or filesystem-path leakage.

use std::path::Path;

use avery::mission::{find, registry, Env, RunOptions};
use avery::report::to_json;

/// The nine legacy CLI subcommands, in pre-API `avery all` order.
const LEGACY_SUBCOMMANDS: [&str; 9] = [
    "table3", "fig7", "fig8", "fig9", "fig10", "headline", "streams", "fleet", "scenario",
];

#[test]
fn every_legacy_subcommand_resolves_to_a_mission() {
    for name in LEGACY_SUBCOMMANDS {
        let m = find(name).unwrap_or_else(|| panic!("`avery {name}` lost its mission"));
        assert_eq!(m.name(), name);
    }
}

#[test]
fn all_order_matches_registry_order() {
    let names: Vec<&str> = registry().iter().map(|m| m.name()).collect();
    assert_eq!(names, LEGACY_SUBCOMMANDS, "`avery all` order drifted");
}

#[test]
fn registry_is_closed_over_find() {
    // find() must agree with registry() and reject unknown names.
    for m in registry() {
        assert!(find(m.name()).is_some());
    }
    assert!(find("table4").is_none());
    assert!(find("").is_none());
}

// ---------------------------------------------------------------------------
// Golden JSON report (synthetic scenario run)
// ---------------------------------------------------------------------------

fn sim_env(tag: &str) -> Env {
    Env::synthetic(Path::new(&format!("target/test-out/mission-api-{tag}"))).unwrap()
}

fn scenario_json(tag: &str) -> String {
    let env = sim_env(tag);
    let mission = find("scenario").expect("scenario registered");
    let opts = RunOptions {
        name: Some("urban-flood".to_string()),
        duration_secs: 180.0,
        seed: 7,
        exec_every: 10,
        ..RunOptions::default()
    };
    to_json(&mission.run(&env, &opts).unwrap())
}

#[test]
fn scenario_report_json_is_schema_stable_and_deterministic() {
    let j = scenario_json("golden-a");
    // Golden schema prefix: fixed key order, version tag first.
    assert!(
        j.starts_with("{\"schema\":1,\"mission\":\"scenario\",\"title\":\""),
        "schema prefix drifted: {}",
        j.get(..42).unwrap_or(&j)
    );
    for key in ["\"scalars\":[", "\"tables\":[", "\"series\":[", "\"notes\":["] {
        assert!(j.contains(key), "missing section {key}");
    }
    // The report must not leak host paths or wall-clock: byte-identical
    // across two runs in *different* output directories.
    let j2 = scenario_json("golden-b");
    assert_eq!(j, j2, "same-seed JSON reports differ");
    // And the seed must matter.
    let env = sim_env("golden-c");
    let mission = find("scenario").expect("scenario registered");
    let opts = RunOptions {
        name: Some("urban-flood".to_string()),
        duration_secs: 180.0,
        seed: 8,
        exec_every: 10,
        ..RunOptions::default()
    };
    let j3 = to_json(&mission.run(&env, &opts).unwrap());
    assert_ne!(j, j3, "seed 8 reproduced seed 7's report");
    // Strict parse: the whole string is one valid JSON value.
    parse_json(&j).unwrap_or_else(|e| panic!("report JSON does not parse: {e}"));
}

#[test]
fn scenario_report_json_names_its_csv_series() {
    let j = scenario_json("series");
    for series in [
        "scenario_urban-flood_summary",
        "scenario_urban-flood_per_uav",
        "scenario_urban-flood_epochs",
    ] {
        assert!(j.contains(&format!("\"name\":\"{series}\"")), "missing series {series}");
    }
}

// ---------------------------------------------------------------------------
// Minimal strict JSON parser (validation only — no external crates)
// ---------------------------------------------------------------------------

fn parse_json(text: &str) -> Result<(), String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    skip_ws(bytes, &mut pos);
    parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing bytes at {pos}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<(), String> {
    match b.get(*pos) {
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => parse_string(b, pos),
        Some(b't') => parse_lit(b, pos, b"true"),
        Some(b'f') => parse_lit(b, pos, b"false"),
        Some(b'n') => parse_lit(b, pos, b"null"),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(b, pos),
        other => Err(format!("unexpected {other:?} at {pos}")),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &[u8]) -> Result<(), String> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at {pos}"))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && (b[*pos].is_ascii_digit() || matches!(b[*pos], b'.' | b'e' | b'E' | b'+' | b'-'))
    {
        *pos += 1;
    }
    let tok = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    tok.parse::<f64>().map_err(|e| format!("bad number `{tok}`: {e}"))?;
    Ok(())
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<(), String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at {pos}"));
    }
    *pos += 1;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            b'\\' => {
                match b.get(*pos + 1) {
                    Some(b'"') | Some(b'\\') | Some(b'/') | Some(b'b') | Some(b'f')
                    | Some(b'n') | Some(b'r') | Some(b't') => *pos += 2,
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 2..*pos + 6)
                            .ok_or_else(|| format!("short \\u escape at {pos}"))?;
                        if !hex.iter().all(|h| h.is_ascii_hexdigit()) {
                            return Err(format!("bad \\u escape at {pos}"));
                        }
                        *pos += 6;
                    }
                    other => return Err(format!("bad escape {other:?} at {pos}")),
                }
            }
            0x00..=0x1f => return Err(format!("raw control byte in string at {pos}")),
            _ => *pos += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<(), String> {
    *pos += 1; // [
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, pos);
        parse_value(b, pos)?;
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(());
            }
            other => return Err(format!("expected , or ] got {other:?} at {pos}")),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<(), String> {
    *pos += 1; // {
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, pos);
        parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at {pos}"));
        }
        *pos += 1;
        skip_ws(b, pos);
        parse_value(b, pos)?;
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(());
            }
            other => return Err(format!("expected , or }} got {other:?} at {pos}")),
        }
    }
}

#[test]
fn json_validator_sanity() {
    assert!(parse_json("{\"a\":[1,2.5,-3e2],\"b\":\"x\\n\",\"c\":null}").is_ok());
    assert!(parse_json("{\"a\":1,}").is_err());
    assert!(parse_json("{\"a\":1} extra").is_err());
    assert!(parse_json("{\"a\"}").is_err());
}
