//! Mission API regression suite — no artifacts required, never skips.
//!
//! * **Registry completeness** — every legacy subcommand name resolves to
//!   a mission, the registry is exactly the ten drivers, and `avery all`
//!   order (= registry order) is pinned.
//! * **Golden JSON report** — a synthetic `scenario` run serialized
//!   through the JSON sink: schema-stable key layout, parseable by a
//!   strict JSON grammar, byte-deterministic per seed, and free of
//!   wall-clock or filesystem-path leakage.
//! * **Backend parity** — the inline synthetic backend and the threaded
//!   engine return identical tensors for every artifact class, and the
//!   parallel runner (`avery all --jobs 8`) reproduces `--jobs 1` reports
//!   byte for byte.

mod common;

use std::path::Path;

use avery::coordinator::TierId;
use avery::dataset::{Corpus, Dataset};
use avery::mission::{find, registry, run_collect, Env, EnvSpec, Mission, RunOptions};
use avery::report::to_json;
use avery::runtime::Engine;
use avery::tensor::Tensor;

use common::parse_json;

/// The ten legacy CLI subcommands, in pre-API `avery all` order.
const LEGACY_SUBCOMMANDS: [&str; 10] = [
    "table3", "fig7", "fig8", "fig9", "fig10", "headline", "streams", "fleet", "scenario",
    "matrix",
];

#[test]
fn every_legacy_subcommand_resolves_to_a_mission() {
    for name in LEGACY_SUBCOMMANDS {
        let m = find(name).unwrap_or_else(|| panic!("`avery {name}` lost its mission"));
        assert_eq!(m.name(), name);
    }
}

#[test]
fn all_order_matches_registry_order() {
    let names: Vec<&str> = registry().iter().map(|m| m.name()).collect();
    assert_eq!(names, LEGACY_SUBCOMMANDS, "`avery all` order drifted");
}

#[test]
fn registry_is_closed_over_find() {
    // find() must agree with registry() and reject unknown names.
    for m in registry() {
        assert!(find(m.name()).is_some());
    }
    assert!(find("table4").is_none());
    assert!(find("").is_none());
}

// ---------------------------------------------------------------------------
// Golden JSON report (synthetic scenario run)
// ---------------------------------------------------------------------------

fn sim_env(tag: &str) -> Env {
    common::sim_env("mission-api", tag)
}

fn scenario_json(tag: &str) -> String {
    let env = sim_env(tag);
    let mission = find("scenario").expect("scenario registered");
    let opts = RunOptions {
        name: Some("urban-flood".to_string()),
        duration_secs: 180.0,
        seed: 7,
        exec_every: 10,
        ..RunOptions::default()
    };
    to_json(&mission.run(&env, &opts).unwrap())
}

#[test]
fn scenario_report_json_is_schema_stable_and_deterministic() {
    let j = scenario_json("golden-a");
    // Golden schema prefix: fixed key order, version tag first.
    assert!(
        j.starts_with("{\"schema\":1,\"mission\":\"scenario\",\"title\":\""),
        "schema prefix drifted: {}",
        j.get(..42).unwrap_or(&j)
    );
    for key in ["\"scalars\":[", "\"tables\":[", "\"series\":[", "\"notes\":["] {
        assert!(j.contains(key), "missing section {key}");
    }
    // The report must not leak host paths or wall-clock: byte-identical
    // across two runs in *different* output directories.
    let j2 = scenario_json("golden-b");
    assert_eq!(j, j2, "same-seed JSON reports differ");
    // And the seed must matter.
    let env = sim_env("golden-c");
    let mission = find("scenario").expect("scenario registered");
    let opts = RunOptions {
        name: Some("urban-flood".to_string()),
        duration_secs: 180.0,
        seed: 8,
        exec_every: 10,
        ..RunOptions::default()
    };
    let j3 = to_json(&mission.run(&env, &opts).unwrap());
    assert_ne!(j, j3, "seed 8 reproduced seed 7's report");
    // Strict parse: the whole string is one valid JSON value.
    parse_json(&j).unwrap_or_else(|e| panic!("report JSON does not parse: {e}"));
}

#[test]
fn scenario_report_json_names_its_csv_series() {
    let j = scenario_json("series");
    for series in [
        "scenario_urban-flood_summary",
        "scenario_urban-flood_per_uav",
        "scenario_urban-flood_epochs",
    ] {
        assert!(j.contains(&format!("\"name\":\"{series}\"")), "missing series {series}");
    }
}

// ---------------------------------------------------------------------------
// Backend parity: inline synthetic == threaded engine, --jobs 8 == --jobs 1
// ---------------------------------------------------------------------------

#[test]
fn inline_and_threaded_synthetic_backends_are_tensor_identical() {
    let inline = Engine::synthetic();
    let threaded = Engine::synthetic_threaded();
    assert!(inline.is_inline(), "Engine::synthetic must dispatch inline");
    assert!(!threaded.is_inline());
    let ds = Dataset::synthetic(Corpus::Flood, 3, 16, 0xF10D0);
    let intent = avery::coordinator::classify_intent("highlight the stranded people");
    let pids = Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone()).unwrap();
    for scene in &ds.scenes {
        let img = std::slice::from_ref(&scene.image);
        for (split, tier) in [
            (1, TierId::HighAccuracy),
            (2, TierId::Balanced),
            (4, TierId::HighThroughput),
        ] {
            let head = avery::edge::head_artifact(split, tier);
            let a = inline.execute(&head, "shared", img).unwrap();
            let b = threaded.execute(&head, "shared", img).unwrap();
            assert_eq!(a, b, "{head}");
            let tail = avery::edge::tail_artifact(split, tier);
            for set in ["orig", "ft"] {
                let tin = [a[0].clone(), a[1].clone(), pids.clone()];
                let ta = inline.execute(&tail, set, &tin).unwrap();
                let tb = threaded.execute(&tail, set, &tin).unwrap();
                assert_eq!(ta, tb, "{tail}.{set}");
            }
        }
        let ca = inline.execute("context_edge", "shared", img).unwrap();
        let cb = threaded.execute("context_edge", "shared", img).unwrap();
        assert_eq!(ca, cb, "context_edge");
        let rin = [ca[0].clone(), pids.clone()];
        let ra = inline.execute("context_respond", "ft", &rin).unwrap();
        let rb = threaded.execute("context_respond", "ft", &rin).unwrap();
        assert_eq!(ra, rb, "context_respond");
    }
}

#[test]
fn avery_all_jobs8_reports_match_jobs1_byte_for_byte() {
    // The in-process equivalent of `avery all --jobs 8 --format json` vs
    // `--jobs 1`: the runner computes in parallel, rendering is serial in
    // registry order, and reports are wall-clock/path-free — so the JSON
    // (which embeds every CSV series) must be byte-identical.
    let missions: Vec<Box<dyn Mission>> =
        registry().into_iter().filter(|m| !m.needs_artifacts()).collect();
    assert_eq!(missions.len(), 9, "artifact-free mission set drifted");
    let opts = RunOptions {
        duration_secs: 120.0,
        exec_every: 10,
        seed: 7,
        ..RunOptions::default()
    };
    let serial = run_collect(
        &missions,
        &EnvSpec::Synthetic,
        Path::new("target/test-out/jobs-serial"),
        &opts,
        1,
    );
    let parallel = run_collect(
        &missions,
        &EnvSpec::Synthetic,
        Path::new("target/test-out/jobs-parallel"),
        &opts,
        8,
    );
    assert_eq!(serial.len(), parallel.len());
    for ((a, b), m) in serial.iter().zip(&parallel).zip(&missions) {
        let ja = to_json(a.as_ref().unwrap_or_else(|e| panic!("{} serial: {e:#}", m.name())));
        let jb =
            to_json(b.as_ref().unwrap_or_else(|e| panic!("{} parallel: {e:#}", m.name())));
        assert_eq!(ja, jb, "mission `{}` diverged under --jobs 8", m.name());
    }
}

// ---------------------------------------------------------------------------
// Shared strict JSON validator (tests/common/mod.rs) sanity
// ---------------------------------------------------------------------------

#[test]
fn json_validator_sanity() {
    assert!(parse_json("{\"a\":[1,2.5,-3e2],\"b\":\"x\\n\",\"c\":null}").is_ok());
    assert!(parse_json("{\"a\":1,}").is_err());
    assert!(parse_json("{\"a\":1} extra").is_err());
    assert!(parse_json("{\"a\"}").is_err());
}
