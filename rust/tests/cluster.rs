//! Multi-cell cloud cluster integration tests (DESIGN.md "Multi-cell
//! cloud cluster") — no artifacts required, never skipped.
//!
//! * **Ring properties** — every (artifact, weight-set) route key maps
//!   deterministically; load over the interned artifact table stays within
//!   a bounded imbalance factor across K cells; removing one cell remaps
//!   only that cell's keys (consistent-hashing stability).
//! * **Aggregation** — merged cluster counters equal the sum of per-cell
//!   counters on a seeded run (`PoolStats::merge` cannot drift).
//! * **Fleet parity + determinism** — `--cells 1` (and all-default) fleet
//!   reports are byte-identical to the pre-cluster output and carry no
//!   cluster telemetry; two same-seed multi-cell runs are byte-identical
//!   and the cluster telemetry is present and consistent.

mod common;

use std::collections::BTreeMap;

use avery::cloud::{route_key, CloudCluster, ClusterConfig, HashRing, ServingConfig};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::mission::{run_fleet, RunOptions};
use avery::packet::{Packet, StreamKind};
use avery::report::{to_json, Report};
use avery::runtime::{Engine, MAX_STATIC_SPLIT};
use avery::streams::fleet::FleetRun;

use common::parse_json;

/// One captured Insight packet to derive routing variants from.
fn base_packet() -> Packet {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, 1, 16, 0xF10D0);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    edge.capture_insight(&ds.scenes[0], 1, TierId::Balanced, 0.0).unwrap().0
}

/// Every route key the interned artifact table can produce: all tail
/// artifacts (split 0..=MAX_STATIC_SPLIT x 3 tiers) x {orig, ft}, plus the
/// context responder per set — the full (artifact, weight-set) key space
/// the router sees in practice.
fn artifact_table_keys() -> Vec<u64> {
    let base = base_packet();
    let mut keys = Vec::new();
    for set in ["orig", "ft"] {
        let mut ctx = base.clone();
        ctx.kind = StreamKind::Context;
        keys.push(route_key(&ctx, set));
        for split in 0..=MAX_STATIC_SPLIT as u8 {
            for tier in 0..3u8 {
                let mut p = base.clone();
                p.kind = StreamKind::Insight;
                p.split = split;
                p.tier = tier;
                keys.push(route_key(&p, set));
            }
        }
    }
    keys.sort_unstable();
    keys.dedup();
    keys
}

// ---------------------------------------------------------------------------
// Ring properties over the interned artifact table
// ---------------------------------------------------------------------------

#[test]
fn routing_is_deterministic_across_ring_builds() {
    let keys = artifact_table_keys();
    assert!(keys.len() > 100, "artifact table yields {} keys", keys.len());
    for cells in [1usize, 2, 3, 5, 8] {
        let a = HashRing::new(cells);
        let b = HashRing::new(cells);
        for &k in &keys {
            assert_eq!(a.cell_for(k), b.cell_for(k), "key {k:#x} on {cells} cells");
            // The spill/replica order is a permutation of all cells with
            // the home cell first.
            let order = a.cells_from(k);
            assert_eq!(order[0], a.cell_for(k));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..cells).collect::<Vec<_>>(), "key {k:#x}");
        }
    }
}

#[test]
fn load_imbalance_is_bounded_on_the_artifact_table() {
    let keys = artifact_table_keys();
    for cells in 2usize..=8 {
        let ring = HashRing::new(cells);
        let mut load = vec![0usize; cells];
        for &k in &keys {
            load[ring.cell_for(k)] += 1;
        }
        let mean = keys.len() as f64 / cells as f64;
        for (cell, &n) in load.iter().enumerate() {
            assert!(n >= 1, "cell {cell}/{cells} got no keys: {load:?}");
            assert!(
                (n as f64) <= 3.0 * mean,
                "cell {cell}/{cells} holds {n} of {} keys (mean {mean:.1}): {load:?}",
                keys.len()
            );
        }
    }
}

#[test]
fn removing_one_cell_remaps_only_its_keys() {
    let keys = artifact_table_keys();
    let cells = 5usize;
    let victim = 2usize;
    let before: BTreeMap<u64, usize> =
        keys.iter().map(|&k| (k, HashRing::new(cells).cell_for(k))).collect();
    let mut ring = HashRing::new(cells);
    ring.remove_cell(victim);
    for (&k, &home) in &before {
        let after = ring.cell_for(k);
        if home == victim {
            assert_ne!(after, victim, "key {k:#x} still routes to the removed cell");
        } else {
            assert_eq!(after, home, "key {k:#x} moved off surviving cell {home}");
        }
    }
    // The removed cell also vanishes from every spill order.
    for &k in &keys {
        assert!(!ring.cells_from(k).contains(&victim));
    }
}

// ---------------------------------------------------------------------------
// Aggregation: merged counters == sum of per-cell counters
// ---------------------------------------------------------------------------

#[test]
fn merged_stats_equal_per_cell_sums() {
    // A seeded request mix spanning several routing classes so multiple
    // cells do real work, with the cache on so hit/miss counters move.
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, 6, 16, 0xC1A5);
    let mut edge =
        EdgePipeline::new(engine.clone(), DeviceModel::jetson_mode_30w(8), Lut::paper());
    let ids = classify_intent("highlight the stranded people").token_ids;
    let serving = ServingConfig { cache_entries: 32, ..ServingConfig::default() };
    let cluster = CloudCluster::with_config(
        vec![engine],
        ClusterConfig { cells: 3, replicas: 2, serving, ..ClusterConfig::default() },
    );
    for (i, scene) in ds.scenes.iter().enumerate() {
        let split = 1 + i % 3;
        let tier = TierId::ALL[i % 3];
        let (pkt, _) = edge.capture_insight(scene, split, tier, i as f64).unwrap();
        for set in ["orig", "ft"] {
            // Twice per class: the second pass exercises cache hits.
            cluster.process_sync(&pkt, &ids, set).unwrap();
            cluster.process_sync(&pkt, &ids, set).unwrap();
        }
    }
    let st = cluster.stats();
    assert!(st.per_cell.iter().filter(|p| p.completed > 0).count() >= 2, "one-cell run");
    let sum = |f: fn(&avery::cloud::PoolStats) -> u64| -> u64 {
        st.per_cell.iter().map(f).sum()
    };
    assert_eq!(st.total.completed, sum(|p| p.completed));
    assert_eq!(st.total.cache_hits, sum(|p| p.cache_hits));
    assert_eq!(st.total.cache_misses, sum(|p| p.cache_misses));
    assert_eq!(st.total.shed, sum(|p| p.shed));
    assert_eq!(st.total.batches, sum(|p| p.batches));
    assert_eq!(st.total.batched_requests, sum(|p| p.batched_requests));
    assert_eq!(
        st.total.wall_lat_insight.count(),
        st.per_cell.iter().map(|p| p.wall_lat_insight.count()).sum::<u64>()
    );
    assert!(st.total.cache_hits > 0, "repeat passes never hit the cache");
    assert_eq!(st.shed, 0);
}

// ---------------------------------------------------------------------------
// Fleet parity and determinism end to end
// ---------------------------------------------------------------------------

fn fleet_json(tag: &str, opts: &RunOptions) -> (FleetRun, Report, String) {
    let env = common::sim_env("cluster", tag);
    let (run, report) = run_fleet(&env, opts).unwrap();
    let json = to_json(&report);
    parse_json(&json).unwrap_or_else(|e| panic!("fleet report JSON does not parse: {e}"));
    (run, report, json)
}

fn base_opts() -> RunOptions {
    RunOptions {
        duration_secs: 120.0,
        uavs: Some(8),
        workers: Some(2),
        seed: 7,
        ..RunOptions::default()
    }
}

#[test]
fn single_cell_flags_are_byte_identical_to_flagless() {
    let (_, _, flagless) = fleet_json("flagless", &base_opts());
    let explicit = RunOptions {
        cells: Some(1),
        replicas: Some(1),
        spill_max: Some(1),
        ..base_opts()
    };
    let (_, report, single) = fleet_json("cells-1", &explicit);
    assert_eq!(flagless, single, "--cells 1 must be a byte-level no-op");
    // Single-cell reports carry no cluster telemetry at all.
    assert!(!single.contains("fleet_cluster"));
    assert!(report.scalar_value("cells").is_none());
    assert!(report.scalar_value("remote_hits").is_none());
}

#[test]
fn multi_cell_fleet_is_deterministic_with_consistent_telemetry() {
    let clustered = RunOptions {
        cells: Some(3),
        replicas: Some(2),
        cache_entries: Some(256),
        cache_ttl: Some(120.0),
        batch_max: Some(8),
        ..base_opts()
    };
    let (run_a, report, a) = fleet_json("multi-a", &clustered);
    let (_, _, b) = fleet_json("multi-b", &clustered);
    assert_eq!(a, b, "same-seed multi-cell fleet reports differ");

    assert_eq!(report.scalar_value("cells"), Some(3.0));
    assert_eq!(report.scalar_value("replicas"), Some(2.0));
    let cells_series = report
        .series
        .iter()
        .find(|s| s.name == "fleet_cluster_cells")
        .expect("per-cell series present on a multi-cell run");
    assert_eq!(cells_series.rows.len(), 3);
    let uav_series = report
        .series
        .iter()
        .find(|s| s.name == "fleet_cluster_uav_cells")
        .expect("per-UAV cells-hit series present");
    assert_eq!(uav_series.rows.len(), 8);

    // The fleet event loop keeps at most one request in flight per UAV, so
    // nothing sheds or spills; routing still fans the request classes out.
    assert_eq!(report.scalar_value("cluster_shed"), Some(0.0));
    assert_eq!(report.scalar_value("spilled"), Some(0.0));
    let cells_hit = report.scalar_value("cells_hit").unwrap();
    assert!(
        (1.0..=3.0).contains(&cells_hit),
        "cells_hit {cells_hit} outside [1, 3]"
    );
    assert_eq!(cells_hit, run_a.cells_hit as f64);
    // Serving telemetry rides along, merged across cells.
    assert!(run_a.cache_hits_total > 0, "no cache reuse across the fleet");
    let hit_rate = report.scalar_value("cache_hit_rate").unwrap();
    assert!(hit_rate > 0.0 && hit_rate <= 1.0);
}
