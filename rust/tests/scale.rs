//! Megafleet sharded-core integration tests (DESIGN.md "Megafleet core")
//! — no artifacts required, never skipped.
//!
//! Determinism is the sharded scheduler's correctness oracle:
//!
//! * **Shard-count invariance** — `--shards T` must reproduce `--shards 1`
//!   byte for byte (JSON report and every derived quantity) for any T,
//!   plain and with a fault plan armed.
//! * **Fair-share conservation** — the epoch-frozen window index must
//!   count exactly the windows the unsharded `SharedLink` would, no
//!   matter how the commit batches are partitioned across shards.
//! * **Jain parity** — epoch quantization may move individual transfers,
//!   but fleet-level fairness must stay in family with the legacy path.

mod common;

use avery::faults::{FaultKind, FaultSpec};
use avery::mission::{run_fleet, RunOptions};
use avery::report::to_json;
use avery::streams::fleet::FleetRun;
use avery::streams::shard::FrozenIndex;

use common::parse_json;

fn fleet_json(tag: &str, opts: &RunOptions) -> (FleetRun, String) {
    let env = common::sim_env("scale", tag);
    let (run, report) = run_fleet(&env, opts).unwrap();
    let json = to_json(&report);
    parse_json(&json).unwrap_or_else(|e| panic!("fleet report JSON does not parse: {e}"));
    (run, json)
}

fn base_opts() -> RunOptions {
    RunOptions {
        duration_secs: 90.0,
        uavs: Some(12),
        workers: Some(2),
        exec_every: 5,
        seed: 7,
        ..RunOptions::default()
    }
}

/// Seeded xorshift64* for the property tests (reimplemented locally so the
/// suite does not depend on crate internals).
fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s.wrapping_mul(0x2545_F491_4F6C_DD1D)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Shard-count determinism
// ---------------------------------------------------------------------------

#[test]
fn shard_count_is_invisible_in_the_output() {
    let sharded = |t: usize| RunOptions { shards: Some(t), ..base_opts() };
    let (run1, json1) = fleet_json("s1", &sharded(1));
    let (_, json2) = fleet_json("s2", &sharded(2));
    let (_, json3) = fleet_json("s3", &sharded(3));
    let (_, json5) = fleet_json("s5", &sharded(5));
    assert!(run1.delivered_total > 0, "sharded run delivered nothing");
    assert_eq!(json1, json2, "--shards 2 diverged from --shards 1");
    assert_eq!(json1, json3, "--shards 3 diverged from --shards 1");
    assert_eq!(json1, json5, "--shards 5 diverged from --shards 1");
    // More shards than agents must degrade gracefully, not panic or drift.
    let (_, json64) = fleet_json("s64", &sharded(64));
    assert_eq!(json1, json64, "--shards 64 (> N) diverged from --shards 1");
}

#[test]
fn sharded_replay_is_deterministic() {
    let opts = RunOptions { shards: Some(3), ..base_opts() };
    let (_, a) = fleet_json("replay-a", &opts);
    let (_, b) = fleet_json("replay-b", &opts);
    assert_eq!(a, b, "same-seed sharded replay drifted");
}

#[test]
fn fault_armed_runs_are_shard_invariant_and_conserved() {
    let spec = |kind, cell, at, duration, rate| FaultSpec {
        kind,
        cell,
        at,
        duration,
        rate,
        stall_secs: 0.0,
    };
    let armed = |t: usize| RunOptions {
        shards: Some(t),
        cells: Some(2),
        fault_specs: vec![
            spec(FaultKind::SessionDrop, 0, 0.3, 0.0, 0.0),
            spec(FaultKind::ExecError, 0, 0.5, 0.3, 0.5),
            spec(FaultKind::WireCorrupt, 0, 0.2, 0.4, 0.3),
        ],
        ..base_opts()
    };
    let (run1, json1) = fleet_json("fault-s1", &armed(1));
    let (run4, json4) = fleet_json("fault-s4", &armed(4));
    assert_eq!(json1, json4, "fault-armed --shards 4 diverged from --shards 1");
    // Conservation holds under shards: every capture is accounted for.
    assert_eq!(
        run4.executed_total + run4.shed_lost_total + run4.degraded_total
            + run4.abandoned_total,
        run4.captures_total,
        "sharded chaos run lost requests"
    );
    // The plan actually bit: the armed run differs from the unarmed one.
    let (_, plain) = fleet_json("fault-off", &RunOptions {
        shards: Some(4),
        cells: Some(2),
        ..base_opts()
    });
    assert_ne!(json4, plain, "fault plan was a no-op");
    assert_eq!(run1.captures_total, run4.captures_total);
}

// ---------------------------------------------------------------------------
// Fair-share conservation: the epoch-frozen window index
// ---------------------------------------------------------------------------

#[test]
fn frozen_index_counts_exactly_like_the_shared_link_filter() {
    // Random air-time windows; the index must reproduce the unsharded
    // predicate `from <= t && until > t` exactly at every probe.
    let ks = keys(4096, 0xFA1E);
    let windows: Vec<(f64, f64)> = ks
        .chunks(2)
        .map(|c| {
            let from = (c[0] % 100_000) as f64 / 100.0;
            let dur = 0.01 + (c[1] % 2_000) as f64 / 100.0;
            (from, from + dur)
        })
        .collect();
    let mut idx = FrozenIndex::default();
    idx.commit(&windows);
    assert_eq!(idx.len(), windows.len());
    for &probe in &[0.0, 1.0, 499.5, 500.0, 999.9, 1234.5678] {
        let brute = windows.iter().filter(|(f, u)| *f <= probe && *u > probe).count();
        assert_eq!(idx.active_at(probe), brute, "mismatch at t={probe}");
    }
    // Boundary semantics: a window is active at its start, gone at its end.
    let mut b = FrozenIndex::default();
    b.commit(&[(10.0, 20.0)]);
    assert_eq!(b.active_at(10.0), 1);
    assert_eq!(b.active_at(20.0), 0);
}

#[test]
fn partitioned_commits_conserve_the_global_allocation() {
    // Partition one window set across "shards" in several different ways;
    // every partition must produce the same index as the single-shard
    // commit — the conservation property behind shard-count invariance.
    let ks = keys(2048, 0x5EED);
    let windows: Vec<(f64, f64)> = ks
        .chunks(2)
        .map(|c| {
            let from = (c[0] % 60_000) as f64 / 100.0;
            (from, from + 0.05 + (c[1] % 500) as f64 / 100.0)
        })
        .collect();
    let mut single = FrozenIndex::default();
    single.commit(&windows);
    for shards in [2usize, 3, 7] {
        let mut parts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); shards];
        for (i, w) in windows.iter().enumerate() {
            parts[i % shards].push(*w);
        }
        let mut merged = FrozenIndex::default();
        // One commit per shard per epoch barrier, in shard order.
        for p in &parts {
            merged.commit(p);
        }
        assert_eq!(merged.len(), single.len());
        for &probe in &[0.0, 25.0, 100.0, 300.125, 599.99] {
            assert_eq!(
                merged.active_at(probe),
                single.active_at(probe),
                "{shards}-way partition diverged at t={probe}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Jain parity vs the legacy path
// ---------------------------------------------------------------------------

#[test]
fn sharded_fairness_stays_in_family_with_the_legacy_path() {
    // Epoch quantization may move individual transfers, so this is a
    // tolerance gate, not a byte gate: fleet-level fairness and delivery
    // must stay in the same family as the unsharded event loop.
    let (legacy, _) = fleet_json("jain-legacy", &base_opts());
    let (sharded, _) =
        fleet_json("jain-sharded", &RunOptions { shards: Some(4), ..base_opts() });
    assert!(legacy.jain_pps > 0.5 && legacy.jain_pps <= 1.0 + 1e-12, "{}", legacy.jain_pps);
    assert!(
        sharded.jain_pps > 0.5 && sharded.jain_pps <= 1.0 + 1e-12,
        "{}",
        sharded.jain_pps
    );
    assert!(
        (legacy.jain_pps - sharded.jain_pps).abs() < 0.2,
        "fairness diverged: legacy {} vs sharded {}",
        legacy.jain_pps,
        sharded.jain_pps
    );
    assert!(sharded.delivered_total > 0);
    let ratio = sharded.delivered_total as f64 / legacy.delivered_total.max(1) as f64;
    assert!(
        (0.5..=2.0).contains(&ratio),
        "delivery moved out of family: legacy {} vs sharded {}",
        legacy.delivered_total,
        sharded.delivered_total
    );
}
