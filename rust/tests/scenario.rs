//! Scenario-library regression suite — no artifacts required, never skips.
//!
//! * **Golden trace snapshots** — per-scenario seeded summary statistics
//!   (mean/min/max Mbps, outage seconds, regime count, sample count)
//!   committed with tolerances so the generators can't silently drift.
//!   Values come from the cross-language mirror
//!   `python/compile/netsim_mirror.py`; regenerate with
//!   `python -m compile.netsim_mirror` after any intentional change.
//! * **Invariants** — every scenario trace respects its clamp band and
//!   phase durations; `SharedLink` fair shares never exceed trace capacity
//!   and Jain stays in (0, 1]; the controller with hysteresis + dwell never
//!   *voluntarily* flaps tiers on consecutive epochs.
//! * **Artifact-free missions** — full scenario missions over the synthetic
//!   engine: byte-identical summary CSVs per seed, visible intent-schedule
//!   effects, and outage-driven infeasible epochs.

use std::path::Path;

use avery::coordinator::{
    classify_intent, ControllerDecision, MissionGoal, RuntimeState, SplitController, TierId,
};
use avery::mission::{run_scenario, Env, RunOptions};
use avery::netsim::{
    BandwidthEstimator, BandwidthTrace, LinkConfig, PhaseKind, SharedLink, OUTAGE_FLOOR_MBPS,
};
use avery::report::{CsvSink, Sink};
use avery::scenario::{build, summarize_trace, SCENARIO_NAMES};
use avery::streams::fleet::jain_index;
use avery::streams::UavRole;
use avery::util::Rng;

// ---------------------------------------------------------------------------
// Golden trace snapshots
// ---------------------------------------------------------------------------

/// Golden trace snapshots @ seed 7, duration 1200 s, from the python mirror.
/// (name, mean, min, max, outage_secs, regimes, samples)
const TRACE_GOLDENS: [(&str, f64, f64, f64, f64, usize, usize); 5] = [
    ("paper-baseline", 13.1524, 8.0000, 19.9226, 0.0, 7, 1200),
    ("wildfire-ridge", 13.7472, 8.0000, 20.0000, 0.0, 12, 1201),
    ("urban-flood", 12.1837, 8.0000, 18.5359, 0.0, 7, 1200),
    ("earthquake-canyon", 10.4726, 0.0501, 20.0000, 216.0, 6, 1200),
    ("coastal-satellite", 14.4839, 8.0000, 20.0000, 0.0, 5, 1200),
];

#[test]
fn golden_trace_snapshots_pin_generators() {
    assert_eq!(TRACE_GOLDENS.len(), SCENARIO_NAMES.len());
    for (name, mean, min, max, outage, regimes, samples) in TRACE_GOLDENS {
        let sc = build(name, 7, 1200.0).unwrap();
        let tr = BandwidthTrace::generate(&sc.trace);
        let s = summarize_trace(&sc.trace, &tr);
        // Sample-value stats tolerate libm (ln/cos) differences between the
        // python mirror and rust; structure (regimes, counts, outage dwell)
        // is pure integer/IEEE arithmetic and must match exactly.
        assert!((s.mean_mbps - mean).abs() < 0.25, "{name} mean {} vs {mean}", s.mean_mbps);
        assert!((s.min_mbps - min).abs() < 0.25, "{name} min {} vs {min}", s.min_mbps);
        assert!((s.max_mbps - max).abs() < 0.25, "{name} max {} vs {max}", s.max_mbps);
        assert!(
            (s.outage_secs - outage).abs() < 1.0,
            "{name} outage {} vs {outage}",
            s.outage_secs
        );
        assert_eq!(s.regimes, regimes, "{name} regimes");
        assert_eq!(tr.samples_mbps.len(), samples, "{name} samples");
    }
}

// ---------------------------------------------------------------------------
// Trace invariants
// ---------------------------------------------------------------------------

#[test]
fn every_scenario_trace_respects_clamps_and_durations() {
    for name in SCENARIO_NAMES {
        let sc = build(name, 11, 900.0).unwrap();
        let cfg = &sc.trace;
        assert!((cfg.total_secs() - 900.0).abs() < 1e-6, "{name} duration");
        let tr = BandwidthTrace::generate(cfg);
        // Per-phase rounding can drift the sample count by at most one
        // sample per phase.
        let n_expected = (900.0 / cfg.dt) as isize;
        let drift = (tr.samples_mbps.len() as isize - n_expected).unsigned_abs();
        assert!(drift <= cfg.phases.len(), "{name} sample count drift {drift}");
        // Walk samples phase by phase with the generator's own rounding, so
        // every sample is checked against the bounds of the phase that
        // produced it.
        let mut idx = 0usize;
        for p in &cfg.phases {
            let n = (p.secs / cfg.dt).round() as usize;
            for i in idx..(idx + n).min(tr.samples_mbps.len()) {
                let b = tr.samples_mbps[i];
                match p.kind {
                    PhaseKind::Outage => assert!(
                        (OUTAGE_FLOOR_MBPS - 1e-9..=cfg.max_mbps + 1e-9).contains(&b),
                        "{name} outage sample {b} at {i}"
                    ),
                    _ => assert!(
                        (cfg.min_mbps - 1e-9..=cfg.max_mbps + 1e-9).contains(&b),
                        "{name} {:?} sample {b} at {i} outside [{}, {}]",
                        p.kind,
                        cfg.min_mbps,
                        cfg.max_mbps
                    ),
                }
            }
            idx += n;
        }
        assert_eq!(idx, tr.samples_mbps.len(), "{name} phase walk covers trace");
        // Phase windows mirror the script.
        let windows = cfg.phase_windows();
        assert_eq!(windows.len(), cfg.phases.len());
        assert!((windows.last().unwrap().1 - 900.0).abs() < 1e-6);
    }
}

#[test]
fn scenario_traces_deterministic_per_seed() {
    for name in SCENARIO_NAMES {
        let a = BandwidthTrace::generate(&build(name, 5, 600.0).unwrap().trace);
        let b = BandwidthTrace::generate(&build(name, 5, 600.0).unwrap().trace);
        assert_eq!(a.samples_mbps, b.samples_mbps, "{name} not deterministic");
        let c = BandwidthTrace::generate(&build(name, 6, 600.0).unwrap().trace);
        assert_ne!(a.samples_mbps, c.samples_mbps, "{name} ignores seed");
    }
}

// ---------------------------------------------------------------------------
// SharedLink fair-share properties
// ---------------------------------------------------------------------------

#[test]
fn fair_share_never_exceeds_trace_capacity() {
    let sc = build("urban-flood", 9, 600.0).unwrap();
    let trace = BandwidthTrace::generate(&sc.trace);
    let n_uavs = 6;
    let mut link =
        SharedLink::new(trace.clone(), LinkConfig { seed: 9, ..LinkConfig::default() }, n_uavs);
    let mut rng = Rng::new(42);
    let mut t = 0.0;
    while t < 550.0 {
        let uav = rng.below(n_uavs);
        let bytes = 0.3e6 + rng.f64() * 2.6e6;
        let out = link.transmit(uav, t, bytes);
        assert!(out.tx_secs > 0.0);
        // Fair share at any probe point, for any UAV, never exceeds the
        // uncontended trace rate (processor sharing only divides).
        for u in 0..n_uavs {
            for dt in [0.0, 0.5, 1.5, 4.0] {
                let share = link.share_at(u, t + dt);
                let cap = trace.at(t + dt);
                assert!(
                    share <= cap + 1e-9,
                    "share {share} above capacity {cap} at t {}",
                    t + dt
                );
                assert!(share > 0.0);
            }
        }
        t += 0.4 + rng.f64() * 2.0;
    }
}

#[test]
fn jain_index_stays_in_unit_interval() {
    let mut rng = Rng::new(17);
    for _ in 0..500 {
        let n = 1 + rng.below(12);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 4.0).collect();
        let j = jain_index(&xs);
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "jain {j} for {xs:?}");
    }
}

// ---------------------------------------------------------------------------
// Controller anti-flap invariant
// ---------------------------------------------------------------------------

/// Drive the controller over a scenario trace exactly as the mission's
/// Sense stage does (EWMA α=0.4, one observation per decision epoch) and
/// record (estimate, decision) pairs.
fn controller_timeline(
    trace: &BandwidthTrace,
    hysteresis: f64,
    dwell: u64,
) -> Vec<(f64, Option<TierId>)> {
    let lut = avery::coordinator::Lut::paper();
    let mut c = SplitController::new(lut, 0.5, 6.0);
    c.hysteresis = hysteresis;
    c.min_dwell_decisions = dwell;
    let mut est = BandwidthEstimator::new(0.4);
    let intent = classify_intent("highlight the stranded people");
    let mut out = Vec::new();
    let mut t = 0.0;
    while t < trace.duration_secs() {
        let e = est.observe(trace.at(t));
        let state = RuntimeState {
            bandwidth_mbps: e,
            power_mode: "MODE_30W_ALL",
            intent: intent.clone(),
        };
        let d = match c.select_configuration(&state, MissionGoal::PrioritizeAccuracy) {
            Ok(ControllerDecision::Insight { tier, .. }) => Some(tier),
            Ok(ControllerDecision::Context { .. }) => unreachable!("insight intent"),
            Err(_) => None,
        };
        out.push((e, d));
        t += 1.0;
    }
    out
}

#[test]
fn controller_with_hysteresis_and_dwell_never_voluntarily_flaps() {
    let lut = avery::coordinator::Lut::paper();
    for name in SCENARIO_NAMES {
        let sc = build(name, 7, 900.0).unwrap();
        let trace = BandwidthTrace::generate(&sc.trace);
        let tl = controller_timeline(&trace, 0.15, 2);
        for w in tl.windows(3) {
            let (_, a) = w[0];
            let (_, b) = w[1];
            let (e2, c2) = w[2];
            let (Some(a), Some(b), Some(c2)) = (a, b, c2) else { continue };
            if a != b && c2 == a {
                // A→B→A on consecutive epochs: legal only as a forced
                // eviction — B must have become infeasible (dwell suppresses
                // every voluntary switch this early).
                let b_pps = lut.entry(b).max_pps(e2);
                assert!(
                    b_pps < 0.5,
                    "{name}: voluntary flap {a:?}->{b:?}->{c2:?} (B still feasible at \
                     {b_pps:.3} PPS)"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Artifact-free scenario missions (synthetic engine)
// ---------------------------------------------------------------------------

fn sim_env(tag: &str) -> Env {
    Env::synthetic(Path::new(&format!("target/test-out/scenario-{tag}"))).unwrap()
}

fn read_summary_csv(env: &Env, name: &str) -> String {
    std::fs::read_to_string(env.out_dir.join(format!("scenario_{name}_summary.csv")))
        .expect("summary csv written")
}

/// Run the scenario mission and persist its CSV series the way the CLI's
/// CSV sink does (drivers no longer write files themselves).
fn run_and_sink(env: &Env, opts: &RunOptions) -> avery::streams::fleet::FleetRun {
    let (run, report) = run_scenario(env, opts).unwrap();
    CsvSink::new(&env.out_dir).announce(false).emit(&report).unwrap();
    run
}

#[test]
fn scenario_mission_summary_csv_is_deterministic() {
    let opts = RunOptions {
        name: Some("urban-flood".to_string()),
        duration_secs: 240.0,
        seed: 7,
        ..RunOptions::default()
    };
    let env_a = sim_env("det-a");
    let env_b = sim_env("det-b");
    let a = run_and_sink(&env_a, &opts);
    let b = run_and_sink(&env_b, &opts);
    assert_eq!(a.delivered_total, b.delivered_total);
    assert_eq!(a.executed_total, b.executed_total);
    assert!((a.avg_iou - b.avg_iou).abs() < 1e-12);
    // The acceptance bar: byte-identical summary CSV across two runs.
    assert_eq!(
        read_summary_csv(&env_a, "urban-flood"),
        read_summary_csv(&env_b, "urban-flood")
    );
    assert!(a.delivered_total > 0, "nothing delivered");
    // A different seed must change the run (energy integrates every jitter
    // draw, so seed collisions there are measure-zero).
    let (c, _) = run_scenario(
        &sim_env("det-c"),
        &RunOptions { seed: 8, ..opts },
    )
    .unwrap();
    assert!(
        a.delivered_total != c.delivered_total
            || (a.total_energy_j - c.total_energy_j).abs() > 1e-9,
        "seed 8 reproduced seed 7's run"
    );
}

#[test]
fn intent_schedule_visibly_moves_agents_between_streams() {
    let env = sim_env("intent");
    let opts = RunOptions {
        name: Some("urban-flood".to_string()),
        duration_secs: 240.0,
        seed: 7,
        ..RunOptions::default()
    };
    let (run, _) = run_scenario(&env, &opts).unwrap();
    // The schedule fired on every UAV (two switches each, offset by start).
    assert!(run.intent_switches_total >= 2 * run.per_uav.len() as u64 - 2);
    let insight_launched: Vec<_> =
        run.per_uav.iter().filter(|o| o.role == UavRole::Insight).collect();
    assert!(!insight_launched.is_empty());
    for o in &insight_launched {
        assert!(o.summary.intent_switches >= 2, "uav {} saw no re-tasking", o.id);
    }
    // Tier occupancy visibly pauses: a launch-Insight UAV has epochs on both
    // streams — Insight epochs with a tier, Context epochs without.
    let probe = insight_launched[0].id;
    let mut saw_insight = false;
    let mut saw_context = false;
    for (uav, e) in &run.epochs {
        if *uav != probe {
            continue;
        }
        match e.level {
            avery::coordinator::IntentLevel::Insight => saw_insight |= e.tier.is_some(),
            avery::coordinator::IntentLevel::Context => {
                saw_context = true;
                assert!(e.tier.is_none(), "context epoch with a tier");
            }
        }
    }
    assert!(saw_insight, "no insight epochs for uav {probe}");
    assert!(saw_context, "intent switch never parked uav {probe} on context");
    // And the switch changed what was scored: the probe UAV answered
    // context queries mid-mission.
    assert!(insight_launched[0].context_accuracy > 0.0);
}

#[test]
fn outage_scenario_starves_the_controller() {
    let env = sim_env("outage");
    let opts = RunOptions {
        name: Some("earthquake-canyon".to_string()),
        duration_secs: 300.0,
        seed: 7,
        ..RunOptions::default()
    };
    let (run, _) = run_scenario(&env, &opts).unwrap();
    // The mission still delivers outside the blackouts...
    assert!(run.delivered_total > 0);
    // ...and the blackouts are visible in the per-second timeline: the
    // scripted windows cover ~54 s and every active agent backfills them
    // (either as infeasible no-tier waits or as epochs inside a stalled
    // transfer — both record the outage-floor ground truth).
    let dark = run
        .epochs
        .iter()
        .filter(|(_, e)| e.bandwidth_true_mbps < 1.0)
        .count();
    assert!(dark >= 20, "only {dark} outage-floor epochs recorded");
    // Starvation shows up as waits (no feasible tier) or as multi-second
    // stalled cycles pinning the estimate while the floor persists.
    let starved = run.infeasible_total > 0
        || run
            .epochs
            .iter()
            .any(|(_, e)| e.tier.is_none() && e.bandwidth_true_mbps < 1.0)
        || run.aggregate_pps < 2.0;
    assert!(starved, "outage left no trace on the control plane");
}

#[test]
fn every_scenario_runs_artifact_free() {
    // Short smoke across the whole scenario registry, driven through the
    // Mission trait — the CI scenario matrix in miniature (cargo test must
    // not depend on artifacts/).  Asserts on the structured report, the
    // surface programmatic consumers see.
    let mission = avery::mission::find("scenario").expect("scenario registered");
    for name in SCENARIO_NAMES {
        let env = sim_env(&format!("smoke-{name}"));
        let opts = RunOptions {
            name: Some(name.to_string()),
            duration_secs: 120.0,
            seed: 7,
            exec_every: 10,
            ..RunOptions::default()
        };
        let report = mission.run(&env, &opts).unwrap();
        assert_eq!(report.mission, "scenario", "{name}");
        let delivered = report.scalar_value("delivered").unwrap();
        let jain = report.scalar_value("jain_pps").unwrap();
        assert!(delivered > 0.0, "{name}: nothing delivered");
        assert!(jain > 0.0 && jain <= 1.0 + 1e-12, "{name}: jain {jain}");
        // Every scenario report carries its three CSV series.
        assert_eq!(report.series.len(), 3, "{name}: series");
        assert!(
            report.series.iter().any(|s| s.name == format!("scenario_{name}_summary")),
            "{name}: summary series missing"
        );
    }
}
