//! Shared helpers for the integration suites (`mission_api`, `serving`,
//! `matrix`).  Each test binary compiles this module independently, so
//! helpers unused by one suite are expected — hence the blanket allow.

#![allow(dead_code)]

use std::path::Path;

use avery::mission::Env;
use avery::report::Report;

/// Synthetic mission environment writing CSVs under
/// `target/test-out/{prefix}-{tag}` (unique per test to avoid races).
pub fn sim_env(prefix: &str, tag: &str) -> Env {
    Env::synthetic(Path::new(&format!("target/test-out/{prefix}-{tag}"))).unwrap()
}

/// Fetch a named scalar from a report, panicking with the name on miss.
pub fn scalar(report: &Report, name: &str) -> f64 {
    report
        .scalar_value(name)
        .unwrap_or_else(|| panic!("report `{}` has no scalar `{name}`", report.mission))
}

// ---------------------------------------------------------------------------
// Minimal strict JSON parser (validation only — no external crates)
// ---------------------------------------------------------------------------

pub fn parse_json(text: &str) -> Result<(), String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    skip_ws(bytes, &mut pos);
    parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing bytes at {pos}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<(), String> {
    match b.get(*pos) {
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => parse_string(b, pos),
        Some(b't') => parse_lit(b, pos, b"true"),
        Some(b'f') => parse_lit(b, pos, b"false"),
        Some(b'n') => parse_lit(b, pos, b"null"),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(b, pos),
        other => Err(format!("unexpected {other:?} at {pos}")),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &[u8]) -> Result<(), String> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at {pos}"))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && (b[*pos].is_ascii_digit() || matches!(b[*pos], b'.' | b'e' | b'E' | b'+' | b'-'))
    {
        *pos += 1;
    }
    let tok = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    tok.parse::<f64>().map_err(|e| format!("bad number `{tok}`: {e}"))?;
    Ok(())
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<(), String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at {pos}"));
    }
    *pos += 1;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            b'\\' => {
                match b.get(*pos + 1) {
                    Some(b'"') | Some(b'\\') | Some(b'/') | Some(b'b') | Some(b'f')
                    | Some(b'n') | Some(b'r') | Some(b't') => *pos += 2,
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 2..*pos + 6)
                            .ok_or_else(|| format!("short \\u escape at {pos}"))?;
                        if !hex.iter().all(|h| h.is_ascii_hexdigit()) {
                            return Err(format!("bad \\u escape at {pos}"));
                        }
                        *pos += 6;
                    }
                    other => return Err(format!("bad escape {other:?} at {pos}")),
                }
            }
            0x00..=0x1f => return Err(format!("raw control byte in string at {pos}")),
            _ => *pos += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<(), String> {
    *pos += 1; // [
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, pos);
        parse_value(b, pos)?;
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(());
            }
            other => return Err(format!("expected , or ] got {other:?} at {pos}")),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<(), String> {
    *pos += 1; // {
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, pos);
        parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at {pos}"));
        }
        *pos += 1;
        skip_ws(b, pos);
        parse_value(b, pos)?;
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(());
            }
            other => return Err(format!("expected , or }} got {other:?} at {pos}")),
        }
    }
}
