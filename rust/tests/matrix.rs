//! Scenario-compiler + generated-matrix property suite — no artifacts
//! required, never skips.
//!
//! * **Corpus validity** — every manifest in the full generated matrix
//!   (8 traces × 4 links × 4 fleets × 4 intents ≥ 500) parses and compiles
//!   clean, with unique names covering every axis value.
//! * **Invariant gates** — a 64-scenario seeded sample upholds the PR 2
//!   golden-trace invariants: per-phase clamp bounds, same-seed
//!   byte-determinism, controller anti-flap under hysteresis + dwell,
//!   fair-share conservation and Jain ∈ (0, 1] on the shared uplink.
//! * **Built-in parity** — each checked-in manifest under `scenarios/`
//!   compiles to a bit-identical [`Scenario`] and a byte-identical fleet
//!   CSV set versus its hand-coded `scenario::build` arm.
//! * **Diagnostics** — hand-written invalid manifests hit every
//!   [`CompileError`] variant, each naming the offending key path.
//! * **Matrix mission** — `avery run matrix` passes all gates on the
//!   default sample and reports byte-deterministically per seed.

mod common;

use std::path::Path;

use avery::coordinator::{
    classify_intent, ControllerDecision, Lut, MissionGoal, RuntimeState, SplitController, TierId,
};
use avery::mission::{find, run_compiled_scenario, run_scenario, RunOptions};
use avery::netsim::{BandwidthEstimator, BandwidthTrace, PhaseKind, SharedLink, OUTAGE_FLOOR_MBPS};
use avery::report::{to_json, CsvSink, Sink};
use avery::scenario::compile::{compile_file, compile_str, CompileError};
use avery::scenario::{build, generate, Scenario, SCENARIO_NAMES};
use avery::streams::fleet::jain_index;
use avery::util::Rng;

// ---------------------------------------------------------------------------
// Corpus validity: every generated manifest compiles, axes are covered
// ---------------------------------------------------------------------------

#[test]
fn full_generated_corpus_compiles_clean() {
    let all = generate::generate(7);
    assert!(all.len() >= 500, "corpus shrank to {}", all.len());
    assert_eq!(all.len(), generate::MATRIX_SIZE);
    let mut names: Vec<&str> = Vec::with_capacity(all.len());
    for m in &all {
        let c = compile_str(&m.text)
            .unwrap_or_else(|e| panic!("generated `{}` failed to compile: {e}", m.name));
        assert_eq!(c.name, m.name, "manifest name drifted from generator name");
        assert!(!c.summary.is_empty(), "{}: empty summary", m.name);
        names.push(&m.name);
    }
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), all.len(), "duplicate names in the corpus");
}

#[test]
fn corpus_covers_every_axis_value() {
    let all = generate::generate(7);
    const TRACES: [&str; 8] =
        ["steady", "canyon", "droppy", "sawtooth", "relay", "mksmoke", "mkstorm", "mkpass"];
    for trace in TRACES {
        let prefix = format!("gen-{trace}-");
        assert!(all.iter().any(|m| m.name.starts_with(&prefix)), "no {trace} trace");
    }
    for link in ["clean", "lossy", "jittery", "sat"] {
        let tag = format!("-{link}-");
        assert!(all.iter().any(|m| m.name.contains(&tag)), "no {link} link");
    }
    for fleet in ["solo", "patrol", "swarm", "wing"] {
        let tag = format!("-{fleet}-");
        assert!(all.iter().any(|m| m.name.contains(&tag)), "no {fleet} fleet");
    }
    for intent in ["hold", "escalate", "retask", "triage"] {
        let suffix = format!("-{intent}");
        assert!(all.iter().any(|m| m.name.ends_with(&suffix)), "no {intent} intent");
    }
    // Both mission goals appear in the corpus.
    let goals: Vec<MissionGoal> = all.iter().map(|m| compile_str(&m.text).unwrap().goal).collect();
    assert!(goals.contains(&MissionGoal::PrioritizeAccuracy));
    assert!(goals.contains(&MissionGoal::PrioritizeThroughput));
}

// ---------------------------------------------------------------------------
// Invariant gates over a 64-scenario seeded sample (the PR 2 golden-trace
// properties, applied to compiler output instead of the built-ins)
// ---------------------------------------------------------------------------

/// Walk samples phase by phase with the generator's own rounding; every
/// sample must sit inside the band of the phase that produced it.
fn assert_clamp_band(name: &str, sc: &Scenario, trace: &BandwidthTrace) {
    let cfg = &sc.trace;
    let mut idx = 0usize;
    for p in &cfg.phases {
        let n = (p.secs / cfg.dt).round() as usize;
        let lo = match p.kind {
            PhaseKind::Outage => OUTAGE_FLOOR_MBPS,
            _ => cfg.min_mbps,
        };
        for i in idx..(idx + n).min(trace.samples_mbps.len()) {
            let b = trace.samples_mbps[i];
            assert!(
                (lo - 1e-9..=cfg.max_mbps + 1e-9).contains(&b),
                "{name}: {:?} sample {b} at {i} outside [{lo}, {}]",
                p.kind,
                cfg.max_mbps
            );
        }
        idx += n;
    }
    assert_eq!(idx, trace.samples_mbps.len(), "{name}: phase walk misses samples");
}

/// Drive the controller over the trace exactly as the mission's Sense
/// stage does (EWMA α = 0.4, one observation per decision epoch).
fn controller_timeline(
    trace: &BandwidthTrace,
    hysteresis: f64,
    dwell: u64,
) -> Vec<(f64, Option<TierId>)> {
    let mut c = SplitController::new(Lut::paper(), 0.5, 6.0);
    c.hysteresis = hysteresis;
    c.min_dwell_decisions = dwell;
    let mut est = BandwidthEstimator::new(0.4);
    let intent = classify_intent("highlight the stranded people");
    let mut out = Vec::new();
    let mut t = 0.0;
    while t < trace.duration_secs() {
        let e = est.observe(trace.at(t));
        let state = RuntimeState {
            bandwidth_mbps: e,
            power_mode: "MODE_30W_ALL",
            intent: intent.clone(),
        };
        let d = match c.select_configuration(&state, MissionGoal::PrioritizeAccuracy) {
            Ok(ControllerDecision::Insight { tier, .. }) => Some(tier),
            Ok(ControllerDecision::Context { .. }) => None,
            Err(_) => None,
        };
        out.push((e, d));
        t += 1.0;
    }
    out
}

#[test]
fn sixty_four_sampled_scenarios_pass_trace_invariants() {
    let sample = generate::sample(7, 64);
    assert_eq!(sample.len(), 64);
    let lut = Lut::paper();
    for m in &sample {
        let compiled = compile_str(&m.text)
            .unwrap_or_else(|e| panic!("sampled `{}` failed to compile: {e}", m.name));
        let sc = compiled.instantiate(7, 300.0);
        assert!((sc.trace.total_secs() - 300.0).abs() < 1e-6, "{}: duration", m.name);
        let trace = BandwidthTrace::generate(&sc.trace);

        // Clamp bounds, phase by phase.
        assert_clamp_band(&m.name, &sc, &trace);

        // Same-seed byte-determinism through the whole pipeline: re-compile
        // the same text, re-instantiate, re-generate.  And the seed must
        // matter.
        let regen = |seed: u64| {
            BandwidthTrace::generate(&compile_str(&m.text).unwrap().instantiate(seed, 300.0).trace)
        };
        assert_eq!(trace.samples_mbps, regen(7).samples_mbps, "{}: not deterministic", m.name);
        assert_ne!(trace.samples_mbps, regen(8).samples_mbps, "{}: seed ignored", m.name);

        // Anti-flap: with the scenario's hysteresis + dwell, an A→B→A on
        // consecutive epochs is legal only as a forced eviction of an
        // infeasible B.
        if sc.min_dwell > 0 {
            let tl = controller_timeline(&trace, sc.hysteresis, sc.min_dwell);
            for w in tl.windows(3) {
                let ((_, a), (_, b), (e2, c2)) = (w[0], w[1], w[2]);
                let (Some(a), Some(b), Some(c2)) = (a, b, c2) else { continue };
                if a != b && c2 == a {
                    let b_pps = lut.entry(b).max_pps(e2);
                    assert!(
                        b_pps < 0.5,
                        "{}: voluntary flap {a:?}->{b:?}->{c2:?} (B at {b_pps:.3} PPS)",
                        m.name
                    );
                }
            }
        }
    }
}

#[test]
fn sampled_scenarios_conserve_fair_share_and_jain() {
    for m in generate::sample(21, 6) {
        let sc = compile_str(&m.text).unwrap().instantiate(21, 300.0);
        let trace = BandwidthTrace::generate(&sc.trace);
        let n_uavs = sc.fleet.n_uavs.max(2);
        let mut link = SharedLink::new(trace.clone(), sc.link.clone(), n_uavs);
        let mut rng = Rng::new(42);
        let mut t = 0.0;
        while t < 260.0 {
            let uav = rng.below(n_uavs);
            let bytes = 0.3e6 + rng.f64() * 2.6e6;
            let out = link.transmit(uav, t, bytes);
            assert!(out.tx_secs > 0.0, "{}", m.name);
            let mut shares = Vec::with_capacity(n_uavs);
            for u in 0..n_uavs {
                let share = link.share_at(u, t + 0.5);
                let cap = trace.at(t + 0.5);
                // Processor sharing only divides: no UAV's share exceeds
                // the uncontended trace capacity.
                assert!(
                    share <= cap + 1e-9,
                    "{}: share {share} above capacity {cap}",
                    m.name
                );
                assert!(share > 0.0, "{}", m.name);
                shares.push(share);
            }
            let j = jain_index(&shares);
            assert!(j > 0.0 && j <= 1.0 + 1e-12, "{}: jain {j}", m.name);
            t += 0.7 + rng.f64() * 2.3;
        }
    }
}

// ---------------------------------------------------------------------------
// Generated scenarios end to end (full fleet mission over the synthetic
// engine, via the same driver the matrix mission uses)
// ---------------------------------------------------------------------------

fn e2e_opts(seed: u64) -> RunOptions {
    RunOptions { duration_secs: 120.0, exec_every: 25, seed, ..RunOptions::default() }
}

#[test]
fn sampled_scenarios_run_end_to_end_with_fair_outcomes() {
    let env = common::sim_env("matrix", "e2e");
    let opts = e2e_opts(7);
    for m in generate::sample(7, 4) {
        let sc = compile_str(&m.text).unwrap().instantiate(7, 120.0);
        let (run, report) = run_compiled_scenario(&env, &opts, &sc).unwrap();
        assert!(run.delivered_total > 0, "{}: nothing delivered", m.name);
        assert!(
            run.jain_pps > 0.0 && run.jain_pps <= 1.0 + 1e-12,
            "{}: jain {}",
            m.name,
            run.jain_pps
        );
        assert_eq!(report.mission, "scenario", "{}", m.name);
        assert_eq!(common::scalar(&report, "uavs"), sc.fleet.n_uavs as f64, "{}", m.name);
    }
}

#[test]
fn generated_scenario_reports_are_byte_deterministic() {
    let opts = e2e_opts(9);
    for m in generate::sample(9, 2) {
        let sc = compile_str(&m.text).unwrap().instantiate(9, 120.0);
        let (_, ra) = run_compiled_scenario(
            &common::sim_env("matrix", &format!("det-a-{}", m.name)),
            &opts,
            &sc,
        )
        .unwrap();
        let (_, rb) = run_compiled_scenario(
            &common::sim_env("matrix", &format!("det-b-{}", m.name)),
            &opts,
            &sc,
        )
        .unwrap();
        assert_eq!(to_json(&ra), to_json(&rb), "{}: report diverged", m.name);
    }
}

// ---------------------------------------------------------------------------
// Checked-in manifests reproduce the built-ins, bit for bit
// ---------------------------------------------------------------------------

fn assert_scenarios_bit_identical(tag: &str, a: &Scenario, b: &Scenario) {
    assert_eq!(a.name, b.name, "{tag}: name");
    assert_eq!(a.summary, b.summary, "{tag}: summary");
    assert_eq!(a.goal, b.goal, "{tag}: goal");
    assert_eq!(a.hysteresis.to_bits(), b.hysteresis.to_bits(), "{tag}: hysteresis");
    assert_eq!(a.min_dwell, b.min_dwell, "{tag}: min_dwell");

    assert_eq!(a.trace.min_mbps.to_bits(), b.trace.min_mbps.to_bits(), "{tag}: min_mbps");
    assert_eq!(a.trace.max_mbps.to_bits(), b.trace.max_mbps.to_bits(), "{tag}: max_mbps");
    assert_eq!(a.trace.dt.to_bits(), b.trace.dt.to_bits(), "{tag}: dt");
    assert_eq!(a.trace.seed, b.trace.seed, "{tag}: trace seed");
    assert_eq!(a.trace.phases.len(), b.trace.phases.len(), "{tag}: phase count");
    for (i, (pa, pb)) in a.trace.phases.iter().zip(&b.trace.phases).enumerate() {
        assert_eq!(pa.kind, pb.kind, "{tag}: phase[{i}].kind");
        assert_eq!(pa.secs.to_bits(), pb.secs.to_bits(), "{tag}: phase[{i}].secs");
        assert_eq!(
            pa.level_mbps.to_bits(),
            pb.level_mbps.to_bits(),
            "{tag}: phase[{i}].level_mbps"
        );
    }

    assert_eq!(a.link.loss_prob.to_bits(), b.link.loss_prob.to_bits(), "{tag}: loss");
    assert_eq!(a.link.jitter_std.to_bits(), b.link.jitter_std.to_bits(), "{tag}: jitter");
    assert_eq!(
        a.link.extra_latency_s.to_bits(),
        b.link.extra_latency_s.to_bits(),
        "{tag}: latency"
    );
    assert_eq!(a.link.seed, b.link.seed, "{tag}: link seed");

    assert_eq!(a.fleet.n_uavs, b.fleet.n_uavs, "{tag}: uavs");
    assert_eq!(a.fleet.context_every, b.fleet.context_every, "{tag}: context_every");
    assert_eq!(
        a.fleet.stagger_secs.to_bits(),
        b.fleet.stagger_secs.to_bits(),
        "{tag}: stagger"
    );
    assert_eq!(a.fleet.workers, b.fleet.workers, "{tag}: workers");

    assert_eq!(a.schedule.len(), b.schedule.len(), "{tag}: schedule length");
    for (i, (sa, sb)) in a.schedule.iter().zip(&b.schedule).enumerate() {
        assert_eq!(sa.t.to_bits(), sb.t.to_bits(), "{tag}: schedule[{i}].t");
        assert_eq!(sa.prompt, sb.prompt, "{tag}: schedule[{i}].prompt");
    }
}

#[test]
fn checked_in_manifests_compile_to_bit_identical_builtins() {
    for name in SCENARIO_NAMES {
        let path = format!("scenarios/{name}.toml");
        let compiled = compile_file(Path::new(&path))
            .unwrap_or_else(|e| panic!("{path}: {e}"));
        for (seed, dur) in [(7u64, 1200.0), (11, 600.0), (42, 181.5)] {
            let from_manifest = compiled.instantiate(seed, dur);
            let built = build(name, seed, dur).unwrap();
            assert_scenarios_bit_identical(
                &format!("{name} seed {seed} dur {dur}"),
                &from_manifest,
                &built,
            );
        }
    }
}

#[test]
fn manifest_mission_reproduces_builtin_fleet_csvs_byte_for_byte() {
    // The full acceptance path for two representative scenarios (one
    // phase-scripted with an intent schedule, one absolute-seconds): run
    // `--manifest scenarios/X.toml` and `--name X` through the mission and
    // CSV sink, then diff every emitted series byte for byte.  CI repeats
    // this for all five via the built binary.
    for name in ["urban-flood", "paper-baseline"] {
        let base = RunOptions {
            duration_secs: 120.0,
            seed: 7,
            exec_every: 10,
            ..RunOptions::default()
        };
        let env_n = common::sim_env("matrix", &format!("builtin-{name}"));
        let (_, by_name) = run_scenario(
            &env_n,
            &RunOptions { name: Some(name.to_string()), ..base.clone() },
        )
        .unwrap();
        CsvSink::new(&env_n.out_dir).announce(false).emit(&by_name).unwrap();

        let env_m = common::sim_env("matrix", &format!("manifest-{name}"));
        let (_, by_manifest) = run_scenario(
            &env_m,
            &RunOptions { manifest: Some(format!("scenarios/{name}.toml")), ..base },
        )
        .unwrap();
        CsvSink::new(&env_m.out_dir).announce(false).emit(&by_manifest).unwrap();

        assert_eq!(to_json(&by_name), to_json(&by_manifest), "{name}: JSON reports differ");
        for series in ["summary", "per_uav", "epochs"] {
            let file = format!("scenario_{name}_{series}.csv");
            let a = std::fs::read_to_string(env_n.out_dir.join(&file))
                .unwrap_or_else(|e| panic!("{file}: {e}"));
            let b = std::fs::read_to_string(env_m.out_dir.join(&file))
                .unwrap_or_else(|e| panic!("{file}: {e}"));
            assert_eq!(a, b, "{name}: {series} CSV differs between name and manifest runs");
        }
    }
}

// ---------------------------------------------------------------------------
// Compiler diagnostics: every CompileError variant, with key paths
// ---------------------------------------------------------------------------

fn variant(e: &CompileError) -> &'static str {
    match e {
        CompileError::Parse { .. } => "Parse",
        CompileError::Io { .. } => "Io",
        CompileError::IncludeCycle { .. } => "IncludeCycle",
        CompileError::MissingKey { .. } => "MissingKey",
        CompileError::UnknownKey { .. } => "UnknownKey",
        CompileError::BadValue { .. } => "BadValue",
        CompileError::PhaseWindow { .. } => "PhaseWindow",
        CompileError::RateBound { .. } => "RateBound",
        CompileError::ScheduleOrder { .. } => "ScheduleOrder",
        CompileError::FleetSpec { .. } => "FleetSpec",
    }
}

/// One valid phase table, appended so each case isolates a single defect.
const PHASE: &str = "[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n";

#[test]
fn invalid_manifests_hit_every_semantic_variant_with_key_paths() {
    let cases: [(&str, String, &str, &str); 14] = [
        ("missing name", PHASE.to_string(), "MissingKey", "name"),
        (
            "unknown section",
            format!("name = \"x\"\n[turbo]\nboost = 1\n{PHASE}"),
            "UnknownKey",
            "[turbo]",
        ),
        (
            "unknown array",
            format!("name = \"x\"\n{PHASE}[[phases]]\nkind = \"stable\"\n"),
            "UnknownKey",
            "[[phases]]",
        ),
        (
            "unsupported schema",
            format!("schema = 2\nname = \"x\"\n{PHASE}"),
            "BadValue",
            "schema",
        ),
        (
            "bad phase kind",
            "name = \"x\"\n[[phase]]\nkind = \"misty\"\nfrac = 1.0\nlevel_mbps = 16\n"
                .to_string(),
            "BadValue",
            "phase[0].kind",
        ),
        (
            "fractions not summing to 1",
            "name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 0.9\nlevel_mbps = 16\n"
                .to_string(),
            "PhaseWindow",
            "phase",
        ),
        (
            "frac and secs together",
            "name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\nsecs = 60\n\
             level_mbps = 16\n"
                .to_string(),
            "PhaseWindow",
            "phase[0].secs",
        ),
        (
            "markov alongside phases",
            format!("name = \"x\"\n[trace]\nmarkov_kinds = [\"stable\"]\n{PHASE}"),
            "PhaseWindow",
            "trace.markov_kinds",
        ),
        (
            "inverted clamp band",
            format!("name = \"x\"\n[trace]\nmin_mbps = 12\nmax_mbps = 9\n{PHASE}"),
            "RateBound",
            "trace.max_mbps",
        ),
        (
            "anchor outside the band",
            "name = \"x\"\n[[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 40\n"
                .to_string(),
            "RateBound",
            "phase[0].level_mbps",
        ),
        (
            "loss probability over 1",
            format!("name = \"x\"\n[link]\nloss_prob = 1.5\n{PHASE}"),
            "RateBound",
            "link.loss_prob",
        ),
        (
            "intent switch outside the mission",
            format!("name = \"x\"\n{PHASE}[[intent]]\nat_frac = 1.5\nprompt = \"p\"\n"),
            "ScheduleOrder",
            "intent[0].at_frac",
        ),
        (
            "intent switches out of order",
            format!(
                "name = \"x\"\n{PHASE}[[intent]]\nat_frac = 0.6\nprompt = \"p\"\n\
                 [[intent]]\nat_frac = 0.4\nprompt = \"q\"\n"
            ),
            "ScheduleOrder",
            "intent[1].at_frac",
        ),
        (
            "empty fleet",
            format!("name = \"x\"\n[fleet]\nuavs = 0\n{PHASE}"),
            "FleetSpec",
            "fleet.uavs",
        ),
    ];
    for (what, text, want_variant, want_key) in &cases {
        let err = compile_str(text)
            .map(|c| c.name)
            .expect_err(&format!("{what}: compiled anyway"));
        assert_eq!(variant(&err), *want_variant, "{what}: {err}");
        assert_eq!(err.key_path(), Some(*want_key), "{what}: {err}");
    }

    // A few more key-path spot checks on the same machinery.
    let text = format!("name = \"x\"\n{PHASE}[[intent]]\nat_frac = 0.5\nprompt = \"\"\n");
    let err = compile_str(&text).unwrap_err();
    assert_eq!(variant(&err), "BadValue");
    assert_eq!(err.key_path(), Some("intent[0].prompt"));
    let err = compile_str("name = \"x\"\n").unwrap_err();
    assert_eq!(variant(&err), "MissingKey");
    assert_eq!(err.key_path(), Some("phase"));
    let err = compile_str(&format!("name = \"x\"\n[fleet]\nworkers = 2000\n{PHASE}")).unwrap_err();
    assert_eq!(variant(&err), "FleetSpec");
    assert_eq!(err.key_path(), Some("fleet.workers"));
}

#[test]
fn file_level_errors_parse_io_and_include_cycle() {
    // Syntax errors carry the file path and line; key_path is None.
    let dir = Path::new("target/test-out/matrix-manifests");
    std::fs::create_dir_all(dir).unwrap();
    let bad = dir.join("bad.toml");
    std::fs::write(&bad, "name = \"x\"\n???\n").unwrap();
    let err = compile_file(&bad).unwrap_err();
    match &err {
        CompileError::Parse { path, line, .. } => {
            assert!(path.ends_with("bad.toml"), "{path}");
            assert_eq!(*line, 2);
        }
        other => panic!("expected Parse, got {other}"),
    }
    assert_eq!(err.key_path(), None);

    // Unreadable file -> Io.
    let err = compile_file(Path::new("scenarios/does-not-exist.toml")).unwrap_err();
    assert_eq!(variant(&err), "Io");
    assert_eq!(err.key_path(), None);

    // Two manifests including each other -> IncludeCycle.
    let a = dir.join("cycle-a.toml");
    let b = dir.join("cycle-b.toml");
    std::fs::write(&a, "include = \"cycle-b.toml\"\nname = \"a\"\n").unwrap();
    std::fs::write(&b, "include = \"cycle-a.toml\"\nname = \"b\"\n").unwrap();
    let err = compile_file(&a).unwrap_err();
    assert_eq!(variant(&err), "IncludeCycle", "{err}");
}

#[test]
fn include_overlays_base_manifests() {
    let dir = Path::new("target/test-out/matrix-manifests");
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("base.toml"),
        "name = \"base\"\nhysteresis = 0.15\n\
         [fleet]\nuavs = 2\nworkers = 1\n\
         [[phase]]\nkind = \"stable\"\nfrac = 1.0\nlevel_mbps = 16\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("child.toml"),
        "include = \"base.toml\"\nname = \"child\"\n\
         [fleet]\nuavs = 5\n\
         [[phase]]\nkind = \"drop\"\nfrac = 0.4\nlevel_mbps = 9\n\
         [[phase]]\nkind = \"stable\"\nfrac = 0.6\nlevel_mbps = 17\n",
    )
    .unwrap();
    let c = compile_file(&dir.join("child.toml")).unwrap();
    // Root keys override; untouched base keys survive.
    assert_eq!(c.name, "child");
    assert_eq!(c.hysteresis, 0.15);
    // Tables merge key-wise: uavs overridden, workers inherited.
    assert_eq!(c.fleet.n_uavs, 5);
    assert_eq!(c.fleet.workers, 1);
    // Arrays replace whole: the child's two-phase script wins.
    let sc = c.instantiate(7, 100.0);
    assert_eq!(sc.trace.phases.len(), 2);
    assert_eq!(sc.trace.phases[0].kind, PhaseKind::Drop);
}

// ---------------------------------------------------------------------------
// The matrix mission end to end
// ---------------------------------------------------------------------------

#[test]
fn matrix_mission_passes_all_gates_and_reports_deterministically() {
    let mission = find("matrix").expect("matrix registered");
    let opts = RunOptions {
        matrix_count: Some(16),
        seed: 7,
        exec_every: 25,
        ..RunOptions::default()
    };
    let ra = mission.run(&common::sim_env("matrix", "mission-a"), &opts).unwrap();
    assert_eq!(ra.mission, "matrix");
    assert_eq!(common::scalar(&ra, "scenarios_run"), 16.0);
    assert_eq!(common::scalar(&ra, "failed"), 0.0, "a gated scenario failed: {}", ra.title);
    assert_eq!(common::scalar(&ra, "passed"), 16.0);
    assert_eq!(common::scalar(&ra, "corpus_size"), generate::MATRIX_SIZE as f64);
    assert!(
        ra.series.iter().any(|s| s.name == "matrix_summary" && s.rows.len() == 16),
        "matrix_summary series missing or short"
    );

    // Byte-deterministic per seed (the `avery all --jobs` parity bar).
    let rb = mission.run(&common::sim_env("matrix", "mission-b"), &opts).unwrap();
    assert_eq!(to_json(&ra), to_json(&rb), "same-seed matrix reports differ");

    // And `--matrix-count` actually sizes the sweep.
    let small = RunOptions { matrix_count: Some(3), ..opts };
    let rc = mission.run(&common::sim_env("matrix", "mission-c"), &small).unwrap();
    assert_eq!(common::scalar(&rc, "scenarios_run"), 3.0);
}
