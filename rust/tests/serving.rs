//! Cloud serving layer integration tests (DESIGN.md "Cloud serving layer")
//! — no artifacts required, never skipped.
//!
//! * **Batcher parity** — `Engine::execute_batch` is element-for-element
//!   identical to sequential `execute` calls on both the inline and the
//!   threaded synthetic backend, across every artifact class.
//! * **Off-mode parity** — a fleet mission with the serving defaults
//!   (`--batch-max 1 --cache-entries 0`) produces a byte-identical JSON
//!   report to one with the options entirely unset, and emits no serving
//!   telemetry at all.
//! * **Enabled-mode determinism** — two same-seed fleet runs with
//!   batching + cache on are byte-identical, show nonzero reuse, and
//!   charge *less* virtual server time than the unbatched/uncached run.
//! * **Admission control** — the wait policy backpressures without loss;
//!   a full bounded queue sheds with the wire protocol's `busy` frame.

use std::path::Path;

use avery::cloud::{
    decode_reply, AdmissionPolicy, CloudPool, ServerReply, ServingConfig,
};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::mission::{run_fleet, Env, RunOptions};
use avery::packet::Packet;
use avery::report::{to_json, Report};
use avery::runtime::Engine;
use avery::streams::fleet::FleetRun;
use avery::tensor::Tensor;
use avery::transport::{encode_request, InProc, Transport};

/// Batch-compatible Insight packets over distinct synthetic scenes.
fn insight_packets(n: usize, img: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, n, img, 0xF10D0);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let pkts = ds
        .scenes
        .iter()
        .map(|s| edge.capture_insight(s, 1, TierId::Balanced, 0.0).unwrap().0)
        .collect();
    (pkts, classify_intent("highlight the stranded people").token_ids)
}

// ---------------------------------------------------------------------------
// Batcher parity: execute_batch == N sequential executes, both backends
// ---------------------------------------------------------------------------

#[test]
fn execute_batch_parity_across_backends_and_artifacts() {
    let ds = Dataset::synthetic(Corpus::Generic, 3, 16, 0xA5E17);
    let scenes: Vec<&[Tensor]> =
        ds.scenes.iter().map(|s| std::slice::from_ref(&s.image)).collect();
    let intent = classify_intent("highlight the stranded people");
    let pids = Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone()).unwrap();
    for engine in [Engine::synthetic(), Engine::synthetic_threaded()] {
        for artifact in ["head_sp1_balanced", "head_sp2_high_accuracy", "context_edge"] {
            let batch = engine.execute_batch(artifact, "shared", &scenes).unwrap();
            for (inputs, outs) in scenes.iter().zip(&batch) {
                assert_eq!(&engine.execute(artifact, "shared", inputs).unwrap(), outs,
                    "{artifact}");
            }
        }
        // Tail + context responder over per-scene inputs.
        let heads: Vec<Vec<Tensor>> = scenes
            .iter()
            .map(|s| engine.execute("head_sp1_balanced", "shared", s).unwrap())
            .collect();
        for set in ["orig", "ft"] {
            let tails: Vec<Vec<Tensor>> = heads
                .iter()
                .map(|h| vec![h[0].clone(), h[1].clone(), pids.clone()])
                .collect();
            let refs: Vec<&[Tensor]> = tails.iter().map(|t| t.as_slice()).collect();
            let batch = engine.execute_batch("tail_sp1_balanced", set, &refs).unwrap();
            for (inputs, outs) in refs.iter().zip(&batch) {
                assert_eq!(
                    &engine.execute("tail_sp1_balanced", set, inputs).unwrap(),
                    outs,
                    "tail.{set}"
                );
            }
        }
        let ctx: Vec<Vec<Tensor>> = scenes
            .iter()
            .map(|s| {
                let clip = engine.execute("context_edge", "shared", s).unwrap();
                vec![clip[0].clone(), pids.clone()]
            })
            .collect();
        let refs: Vec<&[Tensor]> = ctx.iter().map(|c| c.as_slice()).collect();
        let batch = engine.execute_batch("context_respond", "ft", &refs).unwrap();
        for (inputs, outs) in refs.iter().zip(&batch) {
            assert_eq!(&engine.execute("context_respond", "ft", inputs).unwrap(), outs);
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet missions: off-mode byte parity, enabled-mode determinism + reuse
// ---------------------------------------------------------------------------

fn fleet_json(tag: &str, opts: &RunOptions) -> (FleetRun, Report, String) {
    let env = Env::synthetic(Path::new(&format!("target/test-out/serving-{tag}"))).unwrap();
    let (run, report) = run_fleet(&env, opts).unwrap();
    let json = to_json(&report);
    (run, report, json)
}

fn base_opts() -> RunOptions {
    RunOptions {
        duration_secs: 120.0,
        uavs: Some(8),
        workers: Some(2),
        seed: 7,
        ..RunOptions::default()
    }
}

#[test]
fn serving_defaults_are_byte_identical_to_unset_options() {
    let (_, _, unset) = fleet_json("unset", &base_opts());
    let explicit = RunOptions {
        batch_max: Some(1),
        cache_entries: Some(0),
        queue_depth: Some(0),
        ..base_opts()
    };
    let (_, report, off) = fleet_json("explicit-off", &explicit);
    assert_eq!(unset, off, "--batch-max 1 --cache-entries 0 must be a no-op");
    // Off-mode reports carry no serving telemetry at all.
    assert!(!off.contains("fleet_serving"));
    assert!(report.scalar_value("cache_hit_rate").is_none());
    assert!(report.scalar_value("batch_max").is_none());
}

#[test]
fn serving_enabled_fleet_is_deterministic_and_reuses() {
    let enabled = RunOptions {
        batch_max: Some(8),
        cache_entries: Some(256),
        cache_ttl: Some(120.0),
        ..base_opts()
    };
    let (run_a, report, a) = fleet_json("on-a", &enabled);
    let (_, _, b) = fleet_json("on-b", &enabled);
    assert_eq!(a, b, "same-seed serving-enabled fleet reports differ");

    // The redundant swarm stream actually reuses responses...
    assert!(run_a.cache_hits_total > 0, "no cache hits across an 8-UAV fleet");
    let hit_rate = report.scalar_value("cache_hit_rate").unwrap();
    assert!(hit_rate > 0.0 && hit_rate <= 1.0, "hit rate {hit_rate}");
    assert_eq!(report.scalar_value("batch_max"), Some(8.0));
    assert_eq!(report.scalar_value("shed"), Some(0.0), "sim path must never shed");
    let serving = report
        .series
        .iter()
        .find(|s| s.name == "fleet_serving")
        .expect("serving series present when enabled");
    assert_eq!(serving.rows.len(), 8);

    // ...and batching + hits charge less virtual server time than the
    // unbatched/uncached baseline (both runs are deterministic).
    let (_, baseline, _) = fleet_json("baseline", &base_opts());
    let util_on = report.scalar_value("server_utilization").unwrap();
    let util_off = baseline.scalar_value("server_utilization").unwrap();
    assert!(
        util_on < util_off,
        "batched+cached utilization {util_on} not below baseline {util_off}"
    );
}

#[test]
fn lone_uav_gets_no_batch_amortization() {
    // The timing model caps batch amortization at the fleet size: a batch
    // can only fill from concurrent UAVs, so N=1 charges the unbatched
    // tail no matter how large the flag is.
    let solo = RunOptions {
        duration_secs: 120.0,
        uavs: Some(1),
        workers: Some(1),
        seed: 7,
        ..RunOptions::default()
    };
    let (_, base, _) = fleet_json("solo-base", &solo);
    let batched = RunOptions { batch_max: Some(64), ..solo };
    let (_, on, _) = fleet_json("solo-batch", &batched);
    assert_eq!(
        base.scalar_value("server_utilization"),
        on.scalar_value("server_utilization"),
        "a lone UAV must not be granted batch-setup amortization"
    );
}

// ---------------------------------------------------------------------------
// Admission control end to end
// ---------------------------------------------------------------------------

#[test]
fn wait_policy_backpressures_without_loss() {
    let (pkts, ids) = insight_packets(4, 16);
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig {
            batch_max: 2,
            queue_depth: 2,
            admission: AdmissionPolicy::Wait,
            ..ServingConfig::default()
        },
    );
    let mut tickets = Vec::new();
    for i in 0..20 {
        tickets.push(pool.submit(&pkts[i % pkts.len()], &ids, "ft").unwrap());
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let st = pool.stats();
    assert_eq!(st.shed, 0);
    assert_eq!(st.completed, 20);
    assert_eq!(st.batched_requests, 20);
}

#[test]
fn session_replies_busy_while_queue_is_full() {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig { queue_depth: 1, ..ServingConfig::default() },
    );
    // Occupy the single in-flight slot with a slow request (2048x2048
    // scene: ~100 ms of closed-form work — a wide window for the shed
    // assertion below even on a loaded CI runner).
    let (big, big_ids) = insight_packets(1, 2048);
    let blocker = pool.submit(&big[0], &big_ids, "ft").unwrap();

    let (small, _) = insight_packets(1, 16);
    let frame =
        encode_request(&small[0].encode(), "highlight the stranded people", "ft");
    let (mut client, mut server_side) = InProc::pair();
    std::thread::scope(|s| {
        let pool = &pool;
        s.spawn(move || {
            let served = pool.serve_session(&mut server_side, "ft").unwrap();
            assert!(served >= 1, "session never served once the slot freed");
        });
        // While the blocker holds the slot, the session request is shed
        // with the wire protocol's busy frame.
        client.send(&frame).unwrap();
        assert_eq!(decode_reply(&client.recv().unwrap()).unwrap(), ServerReply::Busy);
        // Drain the blocker, then retry until the slot frees.
        blocker.wait().unwrap();
        let mut served = false;
        for _ in 0..200 {
            client.send(&frame).unwrap();
            match decode_reply(&client.recv().unwrap()).unwrap() {
                ServerReply::Busy => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                ServerReply::Response { presence, mask } => {
                    assert_eq!(presence.len(), 2);
                    assert!(!mask.is_empty());
                    served = true;
                    break;
                }
            }
        }
        assert!(served, "slot never freed after the blocker completed");
        client.send(b"shutdown").unwrap();
    });
    assert!(pool.stats().shed >= 1, "no shed was recorded");
}
