//! Cloud serving layer integration tests (DESIGN.md "Cloud serving layer")
//! — no artifacts required, never skipped.
//!
//! * **Batcher parity** — `Engine::execute_batch` is element-for-element
//!   identical to sequential `execute` calls on both the inline and the
//!   threaded synthetic backend, across every artifact class.
//! * **Off-mode parity** — a fleet mission with the serving defaults
//!   (`--batch-max 1 --cache-entries 0`) produces a byte-identical JSON
//!   report to one with the options entirely unset, and emits no serving
//!   telemetry at all.
//! * **Enabled-mode determinism** — two same-seed fleet runs with
//!   batching + cache on are byte-identical, show nonzero reuse, and
//!   charge *less* virtual server time than the unbatched/uncached run.
//! * **Admission control** — the wait policy backpressures without loss;
//!   a full bounded queue sheds with the wire protocol's `busy` frame.
//! * **Wire robustness** — truncated, oversized-length, bit-flipped and
//!   random frames come back as typed errors from every decoder; no input
//!   can panic the codec layer.
//! * **Cache semantics** — TTL boundary behavior (strictly-greater-than
//!   expiry), deterministic LRU eviction order, and hit/miss/eviction/
//!   expiration counter consistency.

mod common;

use avery::cloud::{
    cache_key, decode_reply, decode_response, encode_response, route_key, AdmissionPolicy,
    CloudCluster, CloudPool, CloudResponse, ClusterConfig, HashRing, ResponseCache, ServeError,
    ServerReply, ServingConfig,
};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::mission::{run_fleet, RunOptions};
use avery::packet::Packet;
use avery::report::{to_json, Report};
use avery::runtime::Engine;
use avery::streams::fleet::FleetRun;
use avery::tensor::Tensor;
use avery::transport::{decode_request, encode_request, InProc, Transport};
use avery::util::Rng;

use common::parse_json;

/// Batch-compatible Insight packets over distinct synthetic scenes.
fn insight_packets(n: usize, img: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, n, img, 0xF10D0);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let pkts = ds
        .scenes
        .iter()
        .map(|s| edge.capture_insight(s, 1, TierId::Balanced, 0.0).unwrap().0)
        .collect();
    (pkts, classify_intent("highlight the stranded people").token_ids)
}

// ---------------------------------------------------------------------------
// Batcher parity: execute_batch == N sequential executes, both backends
// ---------------------------------------------------------------------------

#[test]
fn execute_batch_parity_across_backends_and_artifacts() {
    let ds = Dataset::synthetic(Corpus::Generic, 3, 16, 0xA5E17);
    let scenes: Vec<&[Tensor]> =
        ds.scenes.iter().map(|s| std::slice::from_ref(&s.image)).collect();
    let intent = classify_intent("highlight the stranded people");
    let pids = Tensor::i32(vec![intent.token_ids.len()], intent.token_ids.clone()).unwrap();
    for engine in [Engine::synthetic(), Engine::synthetic_threaded()] {
        for artifact in ["head_sp1_balanced", "head_sp2_high_accuracy", "context_edge"] {
            let batch = engine.execute_batch(artifact, "shared", &scenes).unwrap();
            for (inputs, outs) in scenes.iter().zip(&batch) {
                assert_eq!(&engine.execute(artifact, "shared", inputs).unwrap(), outs,
                    "{artifact}");
            }
        }
        // Tail + context responder over per-scene inputs.
        let heads: Vec<Vec<Tensor>> = scenes
            .iter()
            .map(|s| engine.execute("head_sp1_balanced", "shared", s).unwrap())
            .collect();
        for set in ["orig", "ft"] {
            let tails: Vec<Vec<Tensor>> = heads
                .iter()
                .map(|h| vec![h[0].clone(), h[1].clone(), pids.clone()])
                .collect();
            let refs: Vec<&[Tensor]> = tails.iter().map(|t| t.as_slice()).collect();
            let batch = engine.execute_batch("tail_sp1_balanced", set, &refs).unwrap();
            for (inputs, outs) in refs.iter().zip(&batch) {
                assert_eq!(
                    &engine.execute("tail_sp1_balanced", set, inputs).unwrap(),
                    outs,
                    "tail.{set}"
                );
            }
        }
        let ctx: Vec<Vec<Tensor>> = scenes
            .iter()
            .map(|s| {
                let clip = engine.execute("context_edge", "shared", s).unwrap();
                vec![clip[0].clone(), pids.clone()]
            })
            .collect();
        let refs: Vec<&[Tensor]> = ctx.iter().map(|c| c.as_slice()).collect();
        let batch = engine.execute_batch("context_respond", "ft", &refs).unwrap();
        for (inputs, outs) in refs.iter().zip(&batch) {
            assert_eq!(&engine.execute("context_respond", "ft", inputs).unwrap(), outs);
        }
    }
}

// ---------------------------------------------------------------------------
// Fleet missions: off-mode byte parity, enabled-mode determinism + reuse
// ---------------------------------------------------------------------------

fn fleet_json(tag: &str, opts: &RunOptions) -> (FleetRun, Report, String) {
    let env = common::sim_env("serving", tag);
    let (run, report) = run_fleet(&env, opts).unwrap();
    let json = to_json(&report);
    parse_json(&json).unwrap_or_else(|e| panic!("fleet report JSON does not parse: {e}"));
    (run, report, json)
}

fn base_opts() -> RunOptions {
    RunOptions {
        duration_secs: 120.0,
        uavs: Some(8),
        workers: Some(2),
        seed: 7,
        ..RunOptions::default()
    }
}

#[test]
fn serving_defaults_are_byte_identical_to_unset_options() {
    let (_, _, unset) = fleet_json("unset", &base_opts());
    let explicit = RunOptions {
        batch_max: Some(1),
        cache_entries: Some(0),
        queue_depth: Some(0),
        ..base_opts()
    };
    let (_, report, off) = fleet_json("explicit-off", &explicit);
    assert_eq!(unset, off, "--batch-max 1 --cache-entries 0 must be a no-op");
    // Off-mode reports carry no serving telemetry at all.
    assert!(!off.contains("fleet_serving"));
    assert!(report.scalar_value("cache_hit_rate").is_none());
    assert!(report.scalar_value("batch_max").is_none());
}

#[test]
fn serving_enabled_fleet_is_deterministic_and_reuses() {
    let enabled = RunOptions {
        batch_max: Some(8),
        cache_entries: Some(256),
        cache_ttl: Some(120.0),
        ..base_opts()
    };
    let (run_a, report, a) = fleet_json("on-a", &enabled);
    let (_, _, b) = fleet_json("on-b", &enabled);
    assert_eq!(a, b, "same-seed serving-enabled fleet reports differ");

    // The redundant swarm stream actually reuses responses...
    assert!(run_a.cache_hits_total > 0, "no cache hits across an 8-UAV fleet");
    let hit_rate = report.scalar_value("cache_hit_rate").unwrap();
    assert!(hit_rate > 0.0 && hit_rate <= 1.0, "hit rate {hit_rate}");
    assert_eq!(report.scalar_value("batch_max"), Some(8.0));
    assert_eq!(report.scalar_value("shed"), Some(0.0), "sim path must never shed");
    let serving = report
        .series
        .iter()
        .find(|s| s.name == "fleet_serving")
        .expect("serving series present when enabled");
    assert_eq!(serving.rows.len(), 8);

    // ...and batching + hits charge less virtual server time than the
    // unbatched/uncached baseline (both runs are deterministic).
    let (_, baseline, _) = fleet_json("baseline", &base_opts());
    let util_on = report.scalar_value("server_utilization").unwrap();
    let util_off = baseline.scalar_value("server_utilization").unwrap();
    assert!(
        util_on < util_off,
        "batched+cached utilization {util_on} not below baseline {util_off}"
    );
}

#[test]
fn lone_uav_gets_no_batch_amortization() {
    // The timing model caps batch amortization at the fleet size: a batch
    // can only fill from concurrent UAVs, so N=1 charges the unbatched
    // tail no matter how large the flag is.
    let solo = RunOptions {
        duration_secs: 120.0,
        uavs: Some(1),
        workers: Some(1),
        seed: 7,
        ..RunOptions::default()
    };
    let (_, base, _) = fleet_json("solo-base", &solo);
    let batched = RunOptions { batch_max: Some(64), ..solo };
    let (_, on, _) = fleet_json("solo-batch", &batched);
    assert_eq!(
        base.scalar_value("server_utilization"),
        on.scalar_value("server_utilization"),
        "a lone UAV must not be granted batch-setup amortization"
    );
}

// ---------------------------------------------------------------------------
// Admission control end to end
// ---------------------------------------------------------------------------

#[test]
fn wait_policy_backpressures_without_loss() {
    let (pkts, ids) = insight_packets(4, 16);
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig {
            batch_max: 2,
            queue_depth: 2,
            admission: AdmissionPolicy::Wait,
            ..ServingConfig::default()
        },
    );
    let mut tickets = Vec::new();
    for i in 0..20 {
        tickets.push(pool.submit(&pkts[i % pkts.len()], &ids, "ft").unwrap());
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let st = pool.stats();
    assert_eq!(st.shed, 0);
    assert_eq!(st.completed, 20);
    assert_eq!(st.batched_requests, 20);
}

#[test]
fn session_replies_busy_while_queue_is_full() {
    let pool = CloudPool::with_config(
        vec![Engine::synthetic_threaded()],
        ServingConfig { queue_depth: 1, ..ServingConfig::default() },
    );
    // Occupy the single in-flight slot with a slow request (2048x2048
    // scene: ~100 ms of closed-form work — a wide window for the shed
    // assertion below even on a loaded CI runner).
    let (big, big_ids) = insight_packets(1, 2048);
    let blocker = pool.submit(&big[0], &big_ids, "ft").unwrap();

    let (small, _) = insight_packets(1, 16);
    let frame =
        encode_request(&small[0].encode(), "highlight the stranded people", "ft");
    let (mut client, mut server_side) = InProc::pair();
    std::thread::scope(|s| {
        let pool = &pool;
        s.spawn(move || {
            let served = pool.serve_session(&mut server_side, "ft").unwrap();
            assert!(served >= 1, "session never served once the slot freed");
        });
        // While the blocker holds the slot, the session request is shed
        // with the wire protocol's busy frame.
        client.send(&frame).unwrap();
        assert_eq!(decode_reply(&client.recv().unwrap()).unwrap(), ServerReply::Busy);
        // Drain the blocker, then retry until the slot frees.
        blocker.wait().unwrap();
        let mut served = false;
        for _ in 0..200 {
            client.send(&frame).unwrap();
            match decode_reply(&client.recv().unwrap()).unwrap() {
                ServerReply::Busy => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                ServerReply::Response { presence, mask } => {
                    assert_eq!(presence.len(), 2);
                    assert!(!mask.is_empty());
                    served = true;
                    break;
                }
            }
        }
        assert!(served, "slot never freed after the blocker completed");
        client.send(b"shutdown").unwrap();
    });
    assert!(pool.stats().shed >= 1, "no shed was recorded");
}

// ---------------------------------------------------------------------------
// Cluster sessions on the wire: spill before busy, typed shed hop counts
// ---------------------------------------------------------------------------

/// A two-cell cluster where the test request's home cell always sheds
/// (workerless, single admission slot held by a parked ticket) while its
/// ring sibling serves inline.  Returns the cluster, the home cell index
/// and the parked ticket (dropping it frees the slot).
fn shedding_home_cluster(
    pkt: &Packet,
    ids: &[i32],
) -> (CloudCluster, usize, avery::cloud::Ticket) {
    let serving = ServingConfig { queue_depth: 1, ..ServingConfig::default() };
    let home = HashRing::new(2).cell_for(route_key(pkt, "ft"));
    let pools = (0..2)
        .map(|i| {
            let engines = if i == home { Vec::new() } else { vec![Engine::synthetic()] };
            CloudPool::with_config(engines, serving.clone())
        })
        .collect();
    let cluster = CloudCluster::from_pools(
        pools,
        ClusterConfig { spill_max: 1, serving, ..ClusterConfig::default() },
    );
    let parked = cluster.cell(home).submit(pkt, ids, "ft").unwrap();
    (cluster, home, parked)
}

#[test]
fn cluster_session_spills_to_sibling_before_busy() {
    let (pkts, ids) = insight_packets(1, 16);
    let (cluster, home, _parked) = shedding_home_cluster(&pkts[0], &ids);

    let frame = encode_request(&pkts[0].encode(), "highlight the stranded people", "ft");
    let (mut client, mut server_side) = InProc::pair();
    std::thread::scope(|s| {
        let cluster = &cluster;
        s.spawn(move || {
            let served = cluster.serve_session(&mut server_side, "ft").unwrap();
            assert_eq!(served, 1, "session served {served} requests");
        });
        // The home cell refuses, the sibling answers: the client sees a
        // normal response, never the busy frame.
        client.send(&frame).unwrap();
        match decode_reply(&client.recv().unwrap()).unwrap() {
            ServerReply::Response { presence, mask } => {
                assert_eq!(presence.len(), 2);
                assert!(!mask.is_empty());
            }
            ServerReply::Busy => panic!("home-cell shed surfaced as busy with an idle sibling"),
        }
        client.send(b"shutdown").unwrap();
    });

    let st = cluster.stats();
    assert_eq!(st.served_at_hop, vec![0, 1], "request did not serve at hop 1");
    assert_eq!(st.per_cell[home].shed, 1);
    assert_eq!(st.shed, 0, "a spilled request is not a cluster-level shed");
}

#[test]
fn exhausted_cluster_sheds_typed_in_process_and_busy_on_the_wire() {
    let (pkts, ids) = insight_packets(1, 16);
    let serving = ServingConfig { queue_depth: 1, ..ServingConfig::default() };
    let cluster = CloudCluster::from_pools(
        (0..3)
            .map(|_| CloudPool::with_config(Vec::new(), serving.clone()))
            .collect(),
        ClusterConfig { spill_max: 2, serving, ..ClusterConfig::default() },
    );
    // Park every cell's only admission slot: the spill walk finds no room
    // anywhere on the ring.
    let _parked: Vec<_> =
        (0..3).map(|i| cluster.cell(i).submit(&pkts[0], &ids, "ft").unwrap()).collect();

    // In process the walk surfaces as a typed shed carrying the hop count.
    match cluster.try_process(&pkts[0], &ids, "ft") {
        Err(ServeError::Shed { hops }) => assert_eq!(hops, 2, "walk length"),
        Err(e) => panic!("expected a shed, got {e:?}"),
        Ok(_) => panic!("served from a fully parked cluster"),
    }

    // On the wire the same walk degrades to the protocol's busy frame.
    let frame = encode_request(&pkts[0].encode(), "highlight the stranded people", "ft");
    let (mut client, mut server_side) = InProc::pair();
    std::thread::scope(|s| {
        let cluster = &cluster;
        s.spawn(move || {
            cluster.serve_session(&mut server_side, "ft").unwrap();
        });
        client.send(&frame).unwrap();
        assert_eq!(decode_reply(&client.recv().unwrap()).unwrap(), ServerReply::Busy);
        client.send(b"shutdown").unwrap();
    });

    let st = cluster.stats();
    assert_eq!(st.shed, 2, "both exhausted walks count at the cluster");
    assert_eq!(st.total.shed, 6, "each walk refuses once per cell");
    assert_eq!(st.served_at_hop, vec![0, 0, 0]);
}

#[test]
fn spill_reply_frames_survive_truncation_and_bit_flips() {
    // A reply produced by the spill path is framed exactly like a
    // home-served one: every strict prefix errors, and no single-bit
    // corruption can panic either decoder.
    let (pkts, ids) = insight_packets(1, 16);
    let (cluster, _, _parked) = shedding_home_cluster(&pkts[0], &ids);
    let frame = encode_request(&pkts[0].encode(), "highlight the stranded people", "ft");
    let (mut client, mut server_side) = InProc::pair();
    let mut reply = Vec::new();
    std::thread::scope(|s| {
        let cluster = &cluster;
        s.spawn(move || {
            cluster.serve_session(&mut server_side, "ft").unwrap();
        });
        client.send(&frame).unwrap();
        reply = client.recv().unwrap();
        client.send(b"shutdown").unwrap();
    });
    assert!(decode_reply(&reply).is_ok());

    for n in 0..reply.len() {
        assert!(decode_reply(&reply[..n]).is_err(), "{n}-byte reply prefix decoded");
        assert!(decode_response(&reply[..n]).is_err(), "{n}-byte response prefix decoded");
    }
    let mut rng = Rng::new(0xC1F11);
    for _ in 0..400 {
        let mut bad = reply.clone();
        let bit = (rng.next_u64() as usize) % (bad.len() * 8);
        bad[bit / 8] ^= 1 << (bit % 8);
        // Any outcome but a panic is acceptable: a flipped float payload
        // still decodes, a flipped length prefix must error.
        let _ = decode_reply(&bad);
        let _ = decode_response(&bad);
    }
}

// ---------------------------------------------------------------------------
// Wire protocol under corruption: typed errors, never a panic
// ---------------------------------------------------------------------------

#[test]
fn request_codec_round_trips() {
    let (pkts, _) = insight_packets(1, 16);
    let frame = encode_request(&pkts[0].encode(), "highlight the stranded people", "ft");
    let (pkt, prompt, set) = decode_request(&frame).unwrap();
    assert_eq!(pkt, pkts[0].encode());
    assert_eq!(prompt, "highlight the stranded people");
    assert_eq!(set, "ft");
}

#[test]
fn every_truncated_request_prefix_errors() {
    let (pkts, _) = insight_packets(1, 16);
    let frame = encode_request(&pkts[0].encode(), "highlight the stranded people", "ft");
    for n in 0..frame.len() {
        assert!(decode_request(&frame[..n]).is_err(), "{n}-byte prefix decoded");
    }
    assert!(decode_request(&frame).is_ok());
}

#[test]
fn hostile_length_prefixes_error_before_allocating() {
    // A 4 GiB declared packet section on a tiny frame.
    let mut frame = u32::MAX.to_le_bytes().to_vec();
    frame.extend_from_slice(&[0u8; 64]);
    assert!(decode_request(&frame).is_err());

    // An oversized *middle* section: corrupt the prompt-length prefix of an
    // otherwise valid frame (layout: 4 + pkt + 4 + prompt + 4 + set).
    let good = encode_request(b"pkt", "p", "ft");
    let mut f2 = good.clone();
    f2[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode_request(&f2).is_err());
    assert!(decode_request(&good).is_ok());

    // And a response declaring u32::MAX presence values.
    let mut f3 = encode_response(&CloudResponse { mask_logits: None, presence: vec![1.0] });
    f3[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode_response(&f3).is_err());
    assert!(decode_reply(&f3).is_err());
}

#[test]
fn bit_flipped_frames_never_panic_any_decoder() {
    let (pkts, _) = insight_packets(1, 16);
    let req = encode_request(&pkts[0].encode(), "highlight the stranded people", "ft");
    let resp = encode_response(&CloudResponse {
        mask_logits: Some(Tensor::f32(vec![2, 2], vec![0.1, -0.2, 0.3, -0.4]).unwrap()),
        presence: vec![0.5, -1.5],
    });
    for frame in [&req, &resp] {
        for i in 0..frame.len() {
            for bit in 0..8 {
                let mut f = frame.clone();
                f[i] ^= 1 << bit;
                // Any outcome but a panic is legal: a content flip decodes
                // to different bytes, a length flip is (usually) rejected.
                let _ = decode_request(&f);
                let _ = decode_response(&f);
                let _ = decode_reply(&f);
            }
        }
    }
}

#[test]
fn random_frames_error_or_decode_without_panic() {
    let mut rng = Rng::new(0xF4A2);
    for len in [0usize, 1, 3, 4, 7, 8, 11, 12, 16, 64, 257] {
        for _ in 0..32 {
            let frame: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = decode_request(&frame);
            let _ = decode_response(&frame);
            let _ = decode_reply(&frame);
        }
    }
}

#[test]
fn busy_frame_decodes_busy_for_reply_and_errors_elsewhere() {
    assert_eq!(decode_reply(b"busy").unwrap(), ServerReply::Busy);
    assert!(decode_response(b"busy").is_err());
    assert!(decode_request(b"busy").is_err());
}

#[test]
fn response_codec_round_trips_with_and_without_mask() {
    let with_mask = CloudResponse {
        mask_logits: Some(
            Tensor::f32(vec![2, 3], vec![0.1, 0.2, 0.3, -0.1, -0.2, -0.3]).unwrap(),
        ),
        presence: vec![0.25, -0.75],
    };
    let frame = encode_response(&with_mask);
    let (p, m) = decode_response(&frame).unwrap();
    assert_eq!(p, vec![0.25, -0.75]);
    assert_eq!(m, vec![0.1, 0.2, 0.3, -0.1, -0.2, -0.3]);
    match decode_reply(&frame).unwrap() {
        ServerReply::Response { presence, mask } => {
            assert_eq!(presence, p);
            assert_eq!(mask, m);
        }
        ServerReply::Busy => panic!("real response decoded as busy"),
    }

    let context = CloudResponse { mask_logits: None, presence: vec![1.0, 0.0] };
    let (p, m) = decode_response(&encode_response(&context)).unwrap();
    assert_eq!(p, vec![1.0, 0.0]);
    assert!(m.is_empty(), "Context responses carry no mask");
}

#[test]
fn cache_key_discriminates_packet_prompt_and_weight_set() {
    let (pkts, ids) = insight_packets(2, 16);
    let k = cache_key(&pkts[0], &ids, "ft");
    assert_eq!(k, cache_key(&pkts[0], &ids, "ft"), "cache key must be deterministic");
    assert_ne!(k, cache_key(&pkts[0], &ids, "orig"));
    assert_ne!(k, cache_key(&pkts[1], &ids, "ft"));
    assert_ne!(k, cache_key(&pkts[0], &[1, 2, 3], "ft"));
}

// ---------------------------------------------------------------------------
// Response cache: TTL boundary, LRU order, counter consistency
// ---------------------------------------------------------------------------

fn resp(tag: f32) -> CloudResponse {
    CloudResponse { mask_logits: None, presence: vec![tag] }
}

#[test]
fn cache_entry_exactly_at_ttl_still_hits() {
    let mut c = ResponseCache::new(8, 60.0);
    c.insert(1, resp(1.0), 100.0);
    // Expiry is strictly-greater-than: an entry aged exactly TTL serves.
    assert!(c.get(1, 160.0).is_some());
    let st = c.stats();
    assert_eq!((st.hits, st.misses, st.expirations), (1, 1, 0));
    // A hair past the TTL expires it, exactly once.
    assert!(c.get(1, 160.0 + 1e-6).is_none());
    let st = c.stats();
    assert_eq!((st.hits, st.misses, st.expirations), (1, 1, 1));
    assert!(c.is_empty());
    // The expired entry is gone: a later get is a plain miss, not a second
    // expiration.
    assert!(c.get(1, 170.0).is_none());
    assert_eq!(c.stats().expirations, 1);
}

#[test]
fn lru_eviction_prefers_stalest_and_get_refreshes_recency() {
    let mut c = ResponseCache::new(2, f64::INFINITY);
    c.insert(1, resp(1.0), 0.0);
    c.insert(2, resp(2.0), 1.0);
    // Touch 1 so 2 becomes the least recently used...
    assert!(c.get(1, 2.0).is_some());
    // ...then overflow: 2 must be the victim, not the older-inserted 1.
    c.insert(3, resp(3.0), 3.0);
    assert_eq!(c.stats().evictions, 1);
    assert!(c.get(2, 4.0).is_none(), "refreshed entry evicted instead of stalest");
    assert!(c.get(1, 4.0).is_some());
    assert!(c.get(3, 4.0).is_some());
    assert_eq!(c.len(), 2);
}

#[test]
fn cache_counters_stay_consistent_and_capacity_zero_stores_nothing() {
    let mut c = ResponseCache::new(2, 10.0);
    c.insert(1, resp(1.0), 0.0);
    c.insert(2, resp(2.0), 0.0);
    c.insert(3, resp(3.0), 0.0); // over capacity: evicts key 1 (oldest tick)
    assert!(c.get(3, 5.0).is_some()); // hit
    assert!(c.get(2, 20.0).is_none()); // aged out: expiration
    assert!(c.get(1, 5.0).is_none()); // evicted: plain miss, no counter
    let st = c.stats();
    assert_eq!(st.misses, 3, "one miss per insert");
    assert_eq!(st.hits, 1);
    assert_eq!(st.evictions, 1);
    assert_eq!(st.expirations, 1);
    assert_eq!(c.len(), 1, "only the hit entry remains");

    // Capacity 0 disables storage but still counts executed misses.
    let mut z = ResponseCache::new(0, 10.0);
    z.insert(7, resp(7.0), 0.0);
    assert!(z.is_empty());
    assert!(z.get(7, 0.0).is_none());
    let st = z.stats();
    assert_eq!((st.hits, st.misses, st.evictions), (0, 1, 0));
}
