//! Chaos-layer integration tests (DESIGN.md "Chaos & recovery") — no
//! artifacts required, never skipped.
//!
//! * **Ring churn** — removing a cell and re-adding it restores the ring
//!   byte-for-byte (same points, same routing for every key); removal
//!   remaps only the victim's keys; the survivor load stays bounded.
//! * **Worker death** — dropping a pool with queued tickets resolves every
//!   `Ticket::wait` to the typed [`ServeError::Closed`], never a hang or
//!   an `Exec` mislabel.
//! * **End-to-end chaos** — a fault-armed fleet run conserves requests
//!   (`executed + shed + degraded + abandoned == captures`), replays
//!   byte-identically for a fixed seed, degrades only Insight requests,
//!   and resilience knobs with no armed faults are a byte-level no-op.

mod common;

use avery::cloud::{CloudPool, HashRing, ServeError, ServingConfig};
use avery::coordinator::{classify_intent, Lut, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::edge::EdgePipeline;
use avery::energy::DeviceModel;
use avery::faults::{FaultKind, FaultSpec};
use avery::mission::{run_fleet, RunOptions};
use avery::packet::Packet;
use avery::report::{to_json, Report};
use avery::runtime::Engine;
use avery::streams::fleet::FleetRun;

use common::parse_json;

/// Seeded key stream for ring property tests (xorshift64* — the same
/// family the library uses, reimplemented locally so the test does not
/// depend on crate internals).
fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s.wrapping_mul(0x2545_F491_4F6C_DD1D)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Ring churn properties
// ---------------------------------------------------------------------------

#[test]
fn remove_then_readd_restores_routing_byte_for_byte() {
    let ks = keys(4096, 0xC0FFEE);
    let cells = 5usize;
    let pristine = HashRing::new(cells);
    let before: Vec<usize> = ks.iter().map(|&k| pristine.cell_for(k)).collect();

    let mut ring = HashRing::new(cells);
    for victim in 0..cells {
        assert!(ring.has_cell(victim));
        ring.remove_cell(victim);
        assert!(!ring.has_cell(victim));
        assert_eq!(ring.live_cells(), cells - 1);
        // Removal remaps only the victim's keys.
        for (&k, &home) in ks.iter().zip(&before) {
            let after = ring.cell_for(k);
            if home == victim {
                assert_ne!(after, victim, "key {k:#x} still routes to removed cell");
            } else {
                assert_eq!(after, home, "key {k:#x} moved off surviving cell {home}");
            }
        }
        // Re-adding rebuilds the exact same vnode points: every key —
        // including the remapped ones — routes exactly as before.
        ring.add_cell(victim);
        assert!(ring.has_cell(victim));
        assert_eq!(ring.live_cells(), cells);
        for (&k, &home) in ks.iter().zip(&before) {
            assert_eq!(ring.cell_for(k), home, "re-add did not restore key {k:#x}");
        }
    }
    // Re-adding a present cell is a no-op.
    ring.add_cell(0);
    for (&k, &home) in ks.iter().zip(&before) {
        assert_eq!(ring.cell_for(k), home);
    }
}

#[test]
fn survivor_load_stays_bounded_after_removal() {
    let ks = keys(4096, 0xBA1A);
    for cells in 3usize..=6 {
        let mut ring = HashRing::new(cells);
        ring.remove_cell(cells - 1);
        let mut load = vec![0usize; cells];
        for &k in &ks {
            load[ring.cell_for(k)] += 1;
        }
        assert_eq!(load[cells - 1], 0, "removed cell still receives keys");
        let mean = ks.len() as f64 / (cells - 1) as f64;
        for (cell, &n) in load.iter().take(cells - 1).enumerate() {
            assert!(n >= 1, "cell {cell}/{cells} got no keys after removal: {load:?}");
            assert!(
                (n as f64) <= 3.0 * mean,
                "cell {cell}/{cells} holds {n} of {} keys (mean {mean:.1}): {load:?}",
                ks.len()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Worker death: queued tickets resolve to the typed Closed error
// ---------------------------------------------------------------------------

/// Distinct Insight packets (different scene content → different cache /
/// route keys) to queue against a pool.
fn sample_packets(n: usize) -> (Vec<Packet>, Vec<i32>) {
    let engine = Engine::synthetic();
    let ds = Dataset::synthetic(Corpus::Flood, n, 16, 0xDEAD);
    let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
    let pkts = ds
        .scenes
        .iter()
        .enumerate()
        .map(|(i, s)| edge.capture_insight(s, 1, TierId::Balanced, i as f64).unwrap().0)
        .collect();
    (pkts, classify_intent("highlight the stranded people").token_ids)
}

#[test]
fn dropping_a_pool_with_queued_tickets_closes_every_wait() {
    // A zero-worker pool never drains, so every submission stays queued —
    // the deterministic worst case of a worker dying mid-flight.
    let (pkts, ids) = sample_packets(4);
    let pool = CloudPool::with_config(Vec::new(), ServingConfig::default());
    let tickets: Vec<_> =
        pkts.iter().map(|p| pool.submit(p, &ids, "ft").expect("admission is unbounded")).collect();
    drop(pool);
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Err(ServeError::Closed) => {}
            Err(e) => panic!("ticket {i}: expected ServeError::Closed after pool death, got {e}"),
            Ok(_) => panic!("ticket {i}: zero-worker pool served a request"),
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end chaos: conservation, determinism, degradation, parity
// ---------------------------------------------------------------------------

fn fleet_json(tag: &str, opts: &RunOptions) -> (FleetRun, Report, String) {
    let env = common::sim_env("chaos", tag);
    let (run, report) = run_fleet(&env, opts).unwrap();
    let json = to_json(&report);
    parse_json(&json).unwrap_or_else(|e| panic!("fleet report JSON does not parse: {e}"));
    (run, report, json)
}

fn base_opts() -> RunOptions {
    RunOptions {
        duration_secs: 120.0,
        uavs: Some(6),
        workers: Some(2),
        seed: 7,
        ..RunOptions::default()
    }
}

fn spec(
    kind: FaultKind,
    cell: usize,
    at: f64,
    duration: f64,
    rate: f64,
    stall_secs: f64,
) -> FaultSpec {
    FaultSpec { kind, cell, at, duration, rate, stall_secs }
}

fn conserved(run: &FleetRun) -> bool {
    run.executed_total + run.shed_lost_total + run.degraded_total + run.abandoned_total
        == run.captures_total
}

#[test]
fn resilience_knobs_without_faults_are_a_byte_level_noop() {
    let (flagless_run, _, flagless) = fleet_json("flagless", &base_opts());
    // Explicit off-values for every chaos knob: still a pass-through.
    let explicit = RunOptions {
        retry_budget: Some(0),
        retry_backoff: Some(0.05),
        degrade: Some(false),
        ..base_opts()
    };
    let (_, report, off) = fleet_json("knobs-off", &explicit);
    assert_eq!(flagless, off, "resilience knobs at their defaults must be a byte-level no-op");
    // No chaos telemetry on an unarmed run, and conservation is trivial:
    // every capture executed.
    assert!(!off.contains("fleet_chaos"));
    assert!(report.scalar_value("availability").is_none());
    assert!(conserved(&flagless_run));
    assert_eq!(flagless_run.captures_total, flagless_run.executed_total);
    assert!(flagless_run.captures_total > 0);
}

#[test]
fn armed_chaos_conserves_requests_and_replays_byte_identically() {
    let armed = RunOptions {
        cells: Some(2),
        fault_specs: vec![
            spec(FaultKind::CellCrash, 0, 0.25, 0.25, 0.0, 0.0),
            spec(FaultKind::ExecError, 1, 0.55, 0.30, 0.4, 0.0),
            spec(FaultKind::SessionDrop, 0, 0.85, 0.0, 0.0, 0.0),
        ],
        ..base_opts()
    };
    let (run, report, a) = fleet_json("armed-a", &armed);
    let (_, _, b) = fleet_json("armed-b", &armed);
    assert_eq!(a, b, "same-seed chaos replays must be byte-identical");

    assert!(conserved(&run), "conservation violated: {} + {} + {} + {} != {}",
        run.executed_total, run.shed_lost_total, run.degraded_total, run.abandoned_total,
        run.captures_total);
    assert!(run.captures_total > 0);
    // Faults really fired and the resilience layer really engaged.
    let injected = common::scalar(&report, "faults_injected");
    assert!(injected > 0.0, "schedule armed but nothing injected");
    assert!(run.retries_total + run.degraded_total + run.abandoned_total > 0);
    let availability = common::scalar(&report, "availability");
    assert!((0.0..=1.0).contains(&availability));
    assert_eq!(
        availability,
        (run.executed_total + run.degraded_total) as f64 / run.captures_total as f64
    );
    // Chaos telemetry rides along: per-kind series + health timeline.
    assert!(report.series.iter().any(|s| s.name == "fleet_chaos_faults"));
    assert!(a.contains("fleet_chaos"));
}

#[test]
fn total_outage_degrades_insight_and_abandons_context() {
    // Both cells crashed for the whole mission: no cloud serve can land,
    // so every Insight capture degrades to edge-local Context-tier
    // execution and every Context capture is abandoned.
    let dark = RunOptions {
        cells: Some(2),
        fault_specs: vec![
            spec(FaultKind::CellCrash, 0, 0.0, 1.0, 0.0, 0.0),
            spec(FaultKind::CellCrash, 1, 0.0, 1.0, 0.0, 0.0),
        ],
        retry_budget: Some(1),
        ..base_opts()
    };
    let (run, report, _) = fleet_json("dark", &dark);
    assert!(conserved(&run));
    assert_eq!(run.executed_total, 0, "a fully-crashed cluster served a request");
    assert!(run.degraded_total > 0, "no Insight request degraded to the edge");
    assert!(run.degraded_secs_total > 0.0);
    assert!(run.retries_total > 0, "retry budget 1 never consumed");
    assert_eq!(common::scalar(&report, "availability"),
        run.degraded_total as f64 / run.captures_total as f64);
    // The health machine saw the outage: both cells quarantined and —
    // with crash windows spanning the whole mission — never recovered.
    assert_eq!(common::scalar(&report, "cells_down_now"), 2.0);
    assert_eq!(common::scalar(&report, "recoveries"), 0.0);
}

#[test]
fn fault_plan_files_arm_the_fleet_like_programmatic_specs() {
    // The same schedule, once as a standalone [[fault]] manifest and once
    // as programmatic specs, produces byte-identical reports.
    let dir = std::path::Path::new("target/test-out/chaos-plan");
    std::fs::create_dir_all(dir).unwrap();
    let path = dir.join("plan.toml");
    std::fs::write(
        &path,
        "[[fault]]\nkind = \"exec-error\"\ncell = 0\nat = 0.3\nduration = 0.4\nrate = 0.5\n",
    )
    .unwrap();
    let from_file = RunOptions {
        cells: Some(2),
        fault_plan: Some(path.to_string_lossy().into_owned()),
        ..base_opts()
    };
    let programmatic = RunOptions {
        cells: Some(2),
        fault_specs: vec![spec(FaultKind::ExecError, 0, 0.3, 0.4, 0.5, 0.0)],
        ..base_opts()
    };
    let (run_f, _, a) = fleet_json("plan-file", &from_file);
    let (_, _, b) = fleet_json("plan-specs", &programmatic);
    assert_eq!(a, b, "manifest and programmatic schedules must agree byte-for-byte");
    assert!(conserved(&run_f));
}
