//! Integration tests.
//!
//! Two gates apply:
//! * **artifact-gated** (golden parity, tokenizer parity, LUT parity,
//!   fidelity ordering, raw-compression baseline): these validate the real
//!   PJRT path bit-for-bit against python's build-time measurements, so
//!   without `make artifacts` they *skip* (they used to panic);
//! * **control-plane smoke** (context responder, dynamic mission, static-HA
//!   collapse): these exercise controller + netsim + scheduler + engine
//!   together and always run — against real artifacts when present, the
//!   synthetic closed-form engine otherwise.

use std::path::Path;
use std::sync::OnceLock;

use avery::coordinator::{classify_intent, tokenize, Lut, MissionGoal, TierId};
use avery::dataset::{Corpus, Dataset};
use avery::energy::DeviceModel;
use avery::manifest::Manifest;
use avery::mission::Env;
use avery::netsim::{BandwidthTrace, Link, LinkConfig, TraceConfig};
use avery::runtime::{Engine, ExecMode};
use avery::streams::{run_insight_mission, MissionConfig, Policy};
use avery::tensor::Tensor;

/// Artifacts dir, or None on a fresh checkout (gated tests skip).
fn try_artifacts_dir() -> Option<&'static Path> {
    static DIR: OnceLock<Option<std::path::PathBuf>> = OnceLock::new();
    DIR.get_or_init(|| avery::find_artifacts(None).ok()).as_deref()
}

macro_rules! artifacts_or_skip {
    () => {
        match try_artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// One shared engine for the whole test binary (PJRT client startup is
/// slow).  Only called by artifact-gated tests, after the skip gate.
fn engine() -> &'static Engine {
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    ENGINE.get_or_init(|| {
        let manifest = Manifest::load(try_artifacts_dir().expect("gated")).unwrap();
        Engine::start(manifest, ExecMode::PreuploadedBuffers).unwrap()
    })
}

/// Mission-smoke environment: artifact-backed when available, synthetic
/// closed-form otherwise (control-plane behavior is identical).
fn smoke_env() -> &'static Env {
    static ENV: OnceLock<Env> = OnceLock::new();
    ENV.get_or_init(|| {
        Env::load_or_synthetic(None, Path::new("target/test-out"), ExecMode::LiteralsEachCall)
            .expect("environment (synthetic fallback) must load")
    })
}

/// Parse a golden fixture: header (n_in, n_out) then kind/size-tagged arrays.
fn read_golden(path: &Path) -> (Vec<Tensor>, Vec<Vec<f32>>) {
    let bytes = std::fs::read(path).unwrap();
    let u32at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()) as usize;
    let n_in = u32at(0);
    let n_out = u32at(4);
    let mut off = 8;
    let mut arrays: Vec<(bool, Vec<f32>, Vec<i32>)> = Vec::new();
    for _ in 0..(n_in + n_out) {
        let kind = u32at(off);
        let size = u32at(off + 4);
        off += 8;
        if kind == 1 {
            let v: Vec<i32> = bytes[off..off + size * 4]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            arrays.push((true, Vec::new(), v));
        } else {
            let v: Vec<f32> = bytes[off..off + size * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            arrays.push((false, v, Vec::new()));
        }
        off += size * 4;
    }
    let inputs = arrays[..n_in].to_vec();
    let outputs = arrays[n_in..]
        .iter()
        .map(|(_, f, i)| {
            if f.is_empty() && !i.is_empty() {
                i.iter().map(|&x| x as f32).collect()
            } else {
                f.clone()
            }
        })
        .collect();
    // Input tensors get shapes from the manifest at call time; here we only
    // carry flat data + dtype and let the caller reshape.
    let input_tensors = inputs
        .into_iter()
        .map(|(is_i32, f, i)| {
            if is_i32 {
                Tensor::i32(vec![i.len()], i).unwrap()
            } else {
                Tensor::f32(vec![f.len()], f).unwrap()
            }
        })
        .collect();
    (input_tensors, outputs)
}

fn reshape_like(t: &Tensor, dims: &[usize]) -> Tensor {
    match t {
        Tensor::F32 { data, .. } => Tensor::f32(dims.to_vec(), data.clone()).unwrap(),
        Tensor::I32 { data, .. } => Tensor::i32(dims.to_vec(), data.clone()).unwrap(),
    }
}

#[test]
fn golden_parity_every_artifact() {
    let dir = artifacts_or_skip!();
    let manifest = Manifest::load(dir).unwrap();
    let eng = engine();
    let mut checked = 0;
    for (name, spec) in &manifest.artifacts {
        for (set, golden_path) in &spec.golden {
            let (flat_inputs, want_outputs) = read_golden(golden_path);
            assert_eq!(flat_inputs.len(), spec.inputs.len(), "{name}");
            let inputs: Vec<Tensor> = flat_inputs
                .iter()
                .zip(&spec.inputs)
                .map(|(t, ispec)| reshape_like(t, &ispec.dims))
                .collect();
            let outs = eng.execute(name, set, &inputs).unwrap();
            assert_eq!(outs.len(), want_outputs.len(), "{name} output arity");
            for (o, want) in outs.iter().zip(&want_outputs) {
                let got = o.as_f32().unwrap();
                assert_eq!(got.len(), want.len(), "{name} output size");
                let mut max_err = 0.0f32;
                for (a, b) in got.iter().zip(want) {
                    max_err = max_err.max((a - b).abs());
                }
                assert!(
                    max_err < 2e-3,
                    "{name}.{set}: max |err| {max_err} vs python golden"
                );
            }
            checked += 1;
        }
    }
    assert!(checked >= 20, "only {checked} golden fixtures checked");
}

#[test]
fn tokenizer_parity_with_python() {
    let dir = artifacts_or_skip!();
    let text = std::fs::read_to_string(dir.join("fixtures/tokenizer.txt")).unwrap();
    let mut n = 0;
    for line in text.lines() {
        let (ids_s, prompt) = line.split_once('\t').unwrap();
        let want: Vec<i32> = ids_s.split(',').map(|t| t.parse().unwrap()).collect();
        assert_eq!(tokenize(prompt), want, "prompt: {prompt}");
        n += 1;
    }
    assert!(n >= 10);
}

#[test]
fn lut_parity_runtime_vs_buildtime() {
    // Re-measure the High-Accuracy tier through the runtime path and compare
    // to the python-profiled LUT value; they share datasets and quantizer so
    // they must agree closely.
    let dir = artifacts_or_skip!();
    let lut = Lut::load(dir).unwrap();
    let env_ds =
        Dataset::load(&dir.join("data/generic_val.bin"), Corpus::Generic).unwrap();
    let device = DeviceModel::jetson_mode_30w(8);
    let (acc, _) = avery::baselines::eval_split_path(
        engine(),
        &env_ds,
        &lut,
        &device,
        1,
        TierId::HighAccuracy,
    )
    .unwrap();
    let lut_acc = lut.entry(TierId::HighAccuracy).acc_orig;
    assert!(
        (acc - lut_acc).abs() < 0.02,
        "runtime {acc} vs build-time {lut_acc}"
    );
}

#[test]
fn fidelity_ordering_through_runtime() {
    let dir = artifacts_or_skip!();
    let lut = Lut::load(dir).unwrap();
    // Emergent Table 3 property: higher ratio => higher accuracy, bigger wire.
    let ha = lut.entry(TierId::HighAccuracy);
    let bal = lut.entry(TierId::Balanced);
    let ht = lut.entry(TierId::HighThroughput);
    assert!(ha.acc_orig > bal.acc_orig && bal.acc_orig > ht.acc_orig);
    assert!(ha.acc_ft > bal.acc_ft && bal.acc_ft > ht.acc_ft);
    assert!(ha.wire_bytes > bal.wire_bytes && bal.wire_bytes > ht.wire_bytes);
}

#[test]
fn context_responder_runs() {
    let env = smoke_env();
    let mut edge = avery::edge::EdgePipeline::new(
        env.engine.clone(),
        env.device.clone(),
        env.lut.clone(),
    );
    let server = avery::cloud::CloudServer::new(env.engine.clone());
    let intent = classify_intent("are there any living beings on the rooftops");
    let scene = &env.flood_val.scenes[0];
    let (pkt, cost) = edge.capture_context(scene, 0.0).unwrap();
    assert!(cost.latency_s < env.device.insight_edge(1).latency_s);
    let resp = server.process(&pkt, &intent.token_ids, "ft").unwrap();
    assert!(resp.mask_logits.is_none());
    assert_eq!(resp.presence.len(), 2);
}

#[test]
fn short_dynamic_mission_adapts() {
    let env = smoke_env();
    let trace = BandwidthTrace::generate(&TraceConfig::paper_20min(7).scaled_to(120.0));
    let mission = MissionConfig {
        duration_secs: 120.0,
        goal: MissionGoal::PrioritizeAccuracy,
        exec_every: 4,
        ..MissionConfig::default()
    };
    let mut link = Link::new(trace.clone(), LinkConfig::default());
    let run = run_insight_mission(
        &env.engine,
        &env.datasets(),
        &env.lut,
        &env.device,
        &mut link,
        &mission,
        Policy::Avery,
    )
    .unwrap();
    let s = &run.summary;
    assert!(s.delivered > 20, "delivered {}", s.delivered);
    assert!(s.avg_pps > 0.3, "pps {}", s.avg_pps);
    assert!(s.executed > 0 && s.avg_iou > 0.2, "iou {}", s.avg_iou);
    // The compressed trace includes a drop below the HA threshold: AVERY
    // must visit more than one tier.
    let tiers_used = s.tier_secs.iter().filter(|&&x| x > 0.0).count();
    assert!(tiers_used >= 2, "tier_secs {:?}", s.tier_secs);
}

#[test]
fn static_high_accuracy_collapses_under_drop() {
    // Fig 9(d)'s qualitative claim: under the same trace, static HA delivers
    // fewer packets than AVERY.
    let env = smoke_env();
    let trace = BandwidthTrace::generate(&TraceConfig::paper_20min(7).scaled_to(120.0));
    let mission = MissionConfig {
        duration_secs: 120.0,
        exec_every: 1000, // throughput check only — skip HLO for speed
        ..MissionConfig::default()
    };
    let mut run = |p: Policy| {
        let mut link = Link::new(trace.clone(), LinkConfig::default());
        run_insight_mission(
            &env.engine,
            &env.datasets(),
            &env.lut,
            &env.device,
            &mut link,
            &mission,
            p,
        )
        .unwrap()
        .summary
    };
    let avery = run(Policy::Avery);
    let ha = run(Policy::Static(TierId::HighAccuracy));
    assert!(
        avery.avg_pps > ha.avg_pps,
        "AVERY {} PPS vs static HA {} PPS",
        avery.avg_pps,
        ha.avg_pps
    );
}

#[test]
fn raw_compression_loses_to_learned_bottleneck() {
    // H2's direction: split@1 + learned bottleneck beats raw image
    // compression at matched payload.
    let dir = artifacts_or_skip!();
    let lut = Lut::load(dir).unwrap();
    let ds = Dataset::load(&dir.join("data/generic_val.bin"), Corpus::Generic)
        .unwrap();
    let device = DeviceModel::jetson_mode_30w(8);
    let (split_acc, _) = avery::baselines::eval_split_path(
        engine(), &ds, &lut, &device, 1, TierId::HighAccuracy).unwrap();
    let (raw_acc, _) = avery::baselines::eval_raw_compression(
        engine(), &ds, &lut, TierId::HighAccuracy).unwrap();
    assert!(
        split_acc > raw_acc,
        "split {split_acc} should beat raw-compression {raw_acc}"
    );
}

#[test]
fn artifact_free_missions_run_through_the_trait() {
    // Every mission that declares itself artifact-free-capable must
    // actually complete against the synthetic fallback environment and
    // return a well-formed report.  (fig9/fig10/fleet/scenario get deeper
    // coverage in their own suites; the quick static missions run here.)
    let env = smoke_env();
    for name in ["table3", "fig7", "fig8", "streams"] {
        let mission = avery::mission::find(name).expect("registered");
        assert!(!mission.needs_artifacts(), "{name} should be artifact-free");
        let report = mission.run(env, &avery::mission::RunOptions::default()).unwrap();
        assert_eq!(report.mission, name);
        assert!(!report.tables.is_empty(), "{name}: no tables");
        assert!(!report.scalars.is_empty(), "{name}: no scalars");
    }
}
