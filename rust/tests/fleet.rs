//! Fleet-subsystem integration tests:
//! * determinism — same seed + same N must reproduce the identical
//!   aggregate summary (the event-ordered scheduler is a pure function of
//!   the configuration),
//! * N=1 parity — a one-UAV fleet over the contended link must match the
//!   single-UAV `fig9` mission within jitter tolerance,
//! * cloud pool — concurrent in-process sessions and transport-framed
//!   sessions both serve correct responses.
//!
//! These are control-plane tests: they run against real artifacts when
//! `make artifacts` has been built, and otherwise against the synthetic
//! closed-form engine (`Env::synthetic`) — never skipped.  Golden/PJRT
//! parity checks live in tests/integration.rs and stay artifact-gated.

use std::path::Path;
use std::sync::OnceLock;

use avery::cloud::{decode_response, CloudPool, CloudServer};
use avery::coordinator::{classify_intent, TierId};
use avery::edge::EdgePipeline;
use avery::mission::Env;
use avery::netsim::{BandwidthTrace, Link, LinkConfig, SharedLink, TraceConfig};
use avery::runtime::ExecMode;
use avery::streams::fleet::{run_fleet_mission, FleetConfig, FleetRun};
use avery::streams::{run_insight_mission, MissionConfig, Policy};
use avery::transport::{encode_request, InProc, Transport};

/// Shared environment: artifact-backed when available, synthetic otherwise.
fn env() -> &'static Env {
    static ENV: OnceLock<Env> = OnceLock::new();
    ENV.get_or_init(|| {
        Env::load_or_synthetic(None, Path::new("target/test-out"), ExecMode::LiteralsEachCall)
            .expect("environment (synthetic fallback) must load")
    })
}

/// 120-second variant of the paper trace (same phase structure).
fn short_trace(seed: u64, secs: f64) -> BandwidthTrace {
    BandwidthTrace::generate(&TraceConfig::paper_20min(seed).scaled_to(secs))
}

fn run_fleet_once(e: &Env, n: usize, seed: u64, exec_every: usize, secs: f64) -> FleetRun {
    let trace = short_trace(seed, secs);
    let mut link =
        SharedLink::new(trace, LinkConfig { seed, ..LinkConfig::default() }, n);
    let cfg = FleetConfig {
        n_uavs: n,
        mission: MissionConfig {
            duration_secs: secs,
            exec_every,
            seed,
            ..MissionConfig::default()
        },
        workers: 1,
        ..FleetConfig::default()
    };
    let server = CloudServer::new(e.engine.clone());
    run_fleet_mission(&e.engine, &e.datasets(), &e.lut, &e.device, &mut link, &cfg, &server)
        .unwrap()
}

#[test]
fn fleet_deterministic_under_fixed_seed() {
    let e = env();
    let a = run_fleet_once(e, 4, 11, 1000, 90.0);
    let b = run_fleet_once(e, 4, 11, 1000, 90.0);
    assert_eq!(a.delivered_total, b.delivered_total);
    assert_eq!(a.executed_total, b.executed_total);
    assert_eq!(a.switches_total, b.switches_total);
    assert_eq!(a.infeasible_total, b.infeasible_total);
    assert!((a.jain_pps - b.jain_pps).abs() < 1e-12);
    assert!((a.total_energy_j - b.total_energy_j).abs() < 1e-9);
    for (x, y) in a.per_uav.iter().zip(&b.per_uav) {
        assert_eq!(x.summary.delivered, y.summary.delivered, "uav {}", x.id);
        assert_eq!(x.summary.switches, y.summary.switches, "uav {}", x.id);
        for k in 0..3 {
            assert!(
                (x.summary.tier_secs[k] - y.summary.tier_secs[k]).abs() < 1e-9,
                "uav {} tier {k}",
                x.id
            );
        }
    }
    // A different seed must actually change the run.
    let c = run_fleet_once(e, 4, 12, 1000, 90.0);
    assert_ne!(
        (a.delivered_total, a.switches_total),
        (c.delivered_total, c.switches_total)
    );
}

#[test]
fn n1_fleet_matches_single_uav_mission() {
    let e = env();
    let secs = 120.0;
    let seed = 7u64;
    let fleet = run_fleet_once(e, 1, seed, 1000, secs);
    assert_eq!(fleet.per_uav.len(), 1);
    let f = &fleet.per_uav[0].summary;

    let trace = short_trace(seed, secs);
    let mut link = Link::new(trace, LinkConfig { seed, ..LinkConfig::default() });
    let mission = MissionConfig {
        duration_secs: secs,
        exec_every: 1000,
        seed,
        ..MissionConfig::default()
    };
    let single = run_insight_mission(
        &e.engine,
        &e.datasets(),
        &e.lut,
        &e.device,
        &mut link,
        &mission,
        Policy::Avery,
    )
    .unwrap()
    .summary;

    // Same trace, same controller, same workload; only the per-link jitter
    // RNG streams differ, so throughput agrees within a tight band.
    let rel = (f.avg_pps - single.avg_pps).abs() / single.avg_pps.max(1e-9);
    assert!(
        rel < 0.10,
        "fleet N=1 {} PPS vs single {} PPS (rel {rel:.3})",
        f.avg_pps,
        single.avg_pps
    );
    // Tier residency must tell the same adaptation story.
    let total_f: f64 = f.tier_secs.iter().sum();
    let total_s: f64 = single.tier_secs.iter().sum();
    for k in 0..3 {
        let share_f = f.tier_secs[k] / total_f.max(1e-9);
        let share_s = single.tier_secs[k] / total_s.max(1e-9);
        assert!(
            (share_f - share_s).abs() < 0.15,
            "tier {k}: fleet share {share_f:.3} vs single {share_s:.3}"
        );
    }
    // Fairness over one UAV is trivially 1.
    assert!((fleet.jain_pps - 1.0).abs() < 1e-12);
}

#[test]
fn fleet_contention_reduces_per_uav_throughput() {
    // 8 UAVs on the same trace: each Insight UAV's share must be well below
    // the solo rate, while aggregate throughput exceeds it.
    let e = env();
    let solo = run_fleet_once(e, 1, 7, 1000, 180.0);
    let fleet = run_fleet_once(e, 8, 7, 1000, 180.0);
    let solo_pps = solo.per_uav[0].summary.avg_pps;
    let mean_fleet_pps: f64 = {
        let xs: Vec<f64> = fleet
            .per_uav
            .iter()
            .filter(|o| o.role == avery::streams::UavRole::Insight)
            .map(|o| o.summary.avg_pps)
            .collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    assert!(
        mean_fleet_pps < solo_pps * 0.6,
        "contended mean {mean_fleet_pps} vs solo {solo_pps}"
    );
    assert!(fleet.aggregate_pps > solo.aggregate_pps);
    assert!(fleet.jain_pps > 0.5, "jain {}", fleet.jain_pps);
}

#[test]
fn fleet_numerics_flow_through_pool() {
    // Small real-execution fleet: IoU must come out sane through the
    // concurrent pool path (2 workers sharing one engine).
    let e = env();
    let trace = short_trace(7, 40.0);
    let mut link = SharedLink::new(trace, LinkConfig { seed: 7, ..LinkConfig::default() }, 2);
    let cfg = FleetConfig {
        n_uavs: 2,
        mission: MissionConfig {
            duration_secs: 40.0,
            exec_every: 4,
            seed: 7,
            ..MissionConfig::default()
        },
        workers: 2,
        ..FleetConfig::default()
    };
    let pool = CloudPool::new(vec![e.engine.clone(), e.engine.clone()]);
    let run = run_fleet_mission(
        &e.engine, &e.datasets(), &e.lut, &e.device, &mut link, &cfg, &pool,
    )
    .unwrap();
    assert!(run.executed_total > 0, "no packets executed");
    assert!(run.avg_iou > 0.2, "avg IoU {}", run.avg_iou);
    assert!(run.server_utilization > 0.0);
    assert_eq!(pool.stats().completed, run.executed_total);
}

#[test]
fn cloud_pool_serves_concurrent_clients() {
    let e = env();
    let pool = CloudPool::new(vec![e.engine.clone(), e.engine.clone()]);
    let scene = &e.flood_val.scenes[0];
    let mut edge = EdgePipeline::new(e.engine.clone(), e.device.clone(), e.lut.clone());
    let (insight_pkt, _) = edge.capture_insight(scene, 1, TierId::HighAccuracy, 0.0).unwrap();
    let (context_pkt, _) = edge.capture_context(scene, 0.0).unwrap();
    let intent = classify_intent("highlight the stranded people");
    let ctx_intent = classify_intent("are there any living beings on the rooftops");

    std::thread::scope(|s| {
        for i in 0..4 {
            let pool = &pool;
            let (pkt, ids) = if i % 2 == 0 {
                (&insight_pkt, &intent.token_ids)
            } else {
                (&context_pkt, &ctx_intent.token_ids)
            };
            s.spawn(move || {
                for _ in 0..3 {
                    let served = pool.process_sync(pkt, ids, "ft").unwrap();
                    assert!(!served.cache_hit, "cache is off by default");
                    assert_eq!(served.resp.presence.len(), 2);
                    assert_eq!(served.resp.mask_logits.is_some(), i % 2 == 0);
                }
            });
        }
    });
    assert_eq!(pool.stats().completed, 12);
}

#[test]
fn pool_session_routes_weight_sets_over_transport() {
    let e = env();
    let pool = CloudPool::new(vec![e.engine.clone()]);
    let scene = &e.flood_val.scenes[0];
    let mut edge = EdgePipeline::new(e.engine.clone(), e.device.clone(), e.lut.clone());
    let (pkt, _) = edge.capture_context(scene, 0.0).unwrap();
    let pkt_bytes = pkt.encode();

    let (mut client, mut server_side) = InProc::pair();
    std::thread::scope(|s| {
        s.spawn(|| {
            let served = pool.serve_session(&mut server_side, "orig").unwrap();
            assert_eq!(served, 2);
        });
        // Pin the session to the fine-tuned weights, then send requests with
        // an empty per-request set — both must route to "ft".
        client.send(b"hello ft").unwrap();
        assert_eq!(client.recv().unwrap(), b"ok");
        for _ in 0..2 {
            client
                .send(&encode_request(&pkt_bytes, "what is happening in this sector", ""))
                .unwrap();
            let (presence, mask) = decode_response(&client.recv().unwrap()).unwrap();
            assert_eq!(presence.len(), 2);
            assert!(mask.is_empty());
        }
        client.send(b"shutdown").unwrap();
    });
}

#[test]
fn fleet_mission_via_trait_reports_aggregates() {
    // The `avery fleet` driver behind the Mission API: the structured
    // report must carry the aggregate scalars and all three CSV series,
    // and honor RunOptions overrides (fleet size, workers).
    let e = env();
    let mission = avery::mission::find("fleet").expect("fleet registered");
    let opts = avery::mission::RunOptions {
        duration_secs: 60.0,
        exec_every: 1000,
        uavs: Some(2),
        workers: Some(1),
        ..avery::mission::RunOptions::default()
    };
    let report = mission.run(e, &opts).unwrap();
    assert_eq!(report.mission, "fleet");
    assert_eq!(report.scalar_value("uavs"), Some(2.0));
    assert_eq!(report.scalar_value("workers"), Some(1.0));
    assert!(report.scalar_value("delivered").unwrap() > 0.0);
    let jain = report.scalar_value("jain_pps").unwrap();
    assert!(jain > 0.0 && jain <= 1.0 + 1e-12, "jain {jain}");
    let names: Vec<&str> = report.series.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, ["fleet_per_uav", "fleet_epochs", "fleet_summary"]);
    // The per-UAV series has one row per UAV.
    assert_eq!(report.series[0].rows.len(), 2);
}
