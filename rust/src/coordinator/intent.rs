//! Operator-intent classification and prompt tokenization.
//!
//! Intent is the *first-class* input of AVERY's hierarchy: a Context-level
//! intent (coarse triage, text answer suffices) admits only the Context
//! Stream, an Insight-level intent (grounded masks) requires the Insight
//! Stream (paper §3.1-3.2).  The paper treats intent as given by the
//! operator's phrasing; we implement the natural reading: a lightweight
//! lexical classifier over the prompt, plus target-class extraction so the
//! mission knows which GT mask to score against.
//!
//! The tokenizer MUST stay in exact sync with python/compile/data.py
//! (FNV-1a 32-bit hashed vocab, 512 entries, PAD=0, 16 tokens) — verified by
//! the tokenizer-parity integration test against artifacts/fixtures.

use crate::util::fnv1a32;

pub const VOCAB: u32 = 512;
pub const PROMPT_TOKENS: usize = 16;

/// Semantic level an operator query demands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntentLevel {
    /// Coarse awareness / triage — a text-level response suffices.
    Context,
    /// Fine-grained spatial grounding — requires segmentation masks.
    Insight,
}

/// A classified operator query.
#[derive(Clone, Debug)]
pub struct Intent {
    pub level: IntentLevel,
    /// Target class if the prompt names one (0 = person, 1 = vehicle).
    pub target_class: Option<usize>,
    /// Hashed token ids, PAD=0 — the prompt tensor fed to the LLM trunk.
    pub token_ids: Vec<i32>,
}

/// Lowercase-alphanumeric word split (identical to python's tokenize()).
fn words(prompt: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in prompt.to_lowercase().chars() {
        if ch.is_alphanumeric() {
            cur.push(ch);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Prompt -> fixed-length token ids (hashed vocab, PAD=0).
pub fn tokenize(prompt: &str) -> Vec<i32> {
    let mut ids: Vec<i32> = words(prompt)
        .iter()
        .take(PROMPT_TOKENS)
        .map(|w| (1 + fnv1a32(w) % (VOCAB - 1)) as i32)
        .collect();
    ids.resize(PROMPT_TOKENS, 0);
    ids
}

/// Verbs/phrases that demand spatially grounded output (Insight-level).
const INSIGHT_CUES: &[&str] = &[
    "highlight", "mark", "segment", "outline", "locate", "localize", "pinpoint",
    "draw", "mask", "detect", "find", "identify", "recognize", "trace", "show",
];

/// Cues of coarse awareness queries (Context-level).
const CONTEXT_CUES: &[&str] = &[
    "what", "describe", "status", "overview", "happening", "situation", "any",
    "anyone", "anything", "is", "are", "how", "summary", "report", "visible",
];

const PERSON_WORDS: &[&str] = &[
    "person", "people", "individual", "individuals", "anyone", "survivor",
    "survivors", "human", "humans", "victim", "victims", "being", "beings",
];

const VEHICLE_WORDS: &[&str] = &[
    "vehicle", "vehicles", "car", "cars", "truck", "trucks", "automobile",
];

/// Recover the target class (0 = person, 1 = vehicle) from *hashed token
/// ids* — the only prompt view the server side has.  Mirrors
/// [`classify_intent`]'s word-list precedence (person outranks vehicle) up
/// to the wire format's inherent lossiness: only the first
/// [`PROMPT_TOKENS`] words survive tokenization, and the 511-bucket hashed
/// vocab can collide — the same information boundary the real tail
/// operates under.  Used by the synthetic cloud tail, which must ground
/// the mask to the class the mission scores against.
pub fn target_class_of_tokens(ids: &[i32]) -> Option<usize> {
    let id_of = |w: &str| (1 + fnv1a32(w) % (VOCAB - 1)) as i32;
    if PERSON_WORDS.iter().any(|w| ids.contains(&id_of(w))) {
        return Some(0);
    }
    if VEHICLE_WORDS.iter().any(|w| ids.contains(&id_of(w))) {
        return Some(1);
    }
    None
}

/// Classify an operator prompt into AVERY's two intent levels and extract
/// the target class.  Scoring: grounded-output verbs vote Insight,
/// awareness interrogatives vote Context; question-shaped prompts lean
/// Context, imperative prompts lean Insight.  Ties fall to Context (the
/// cheap stream — escalation is one prompt away, §4.3).
pub fn classify_intent(prompt: &str) -> Intent {
    let ws = words(prompt);
    let mut insight = 0i32;
    let mut context = 0i32;
    for w in &ws {
        if INSIGHT_CUES.contains(&w.as_str()) {
            insight += 2;
        }
        if CONTEXT_CUES.contains(&w.as_str()) {
            context += 1;
        }
    }
    // Interrogative shape => awareness; imperative leading verb => grounding.
    if prompt.trim_end().ends_with('?') {
        context += 2;
    }
    if let Some(first) = ws.first() {
        if INSIGHT_CUES.contains(&first.as_str()) {
            insight += 2;
        }
    }
    let mut target_class = None;
    for w in &ws {
        if PERSON_WORDS.contains(&w.as_str()) {
            target_class = Some(0);
            break;
        }
        if VEHICLE_WORDS.contains(&w.as_str()) {
            target_class = Some(1);
        }
    }
    Intent {
        level: if insight > context { IntentLevel::Insight } else { IntentLevel::Context },
        target_class,
        token_ids: tokenize(prompt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_insight_examples_classify_insight() {
        for p in [
            "highlight the living beings on that roof",
            "find and mark anyone who might need rescue",
            "segment the partially submerged vehicles",
            "recognize and mark cars stranded during flooding",
            "locate and outline individuals near the water",
        ] {
            assert_eq!(classify_intent(p).level, IntentLevel::Insight, "{p}");
        }
    }

    #[test]
    fn paper_context_examples_classify_context() {
        for p in [
            "what is happening in this sector",
            "are there any living beings on the rooftops?",
            "describe the current flood situation",
            "give me a quick status of this scene",
        ] {
            assert_eq!(classify_intent(p).level, IntentLevel::Context, "{p}");
        }
    }

    #[test]
    fn target_class_extraction() {
        assert_eq!(
            classify_intent("highlight the people stranded by the flood").target_class,
            Some(0)
        );
        assert_eq!(
            classify_intent("mark every car trapped in the water").target_class,
            Some(1)
        );
        assert_eq!(classify_intent("what is happening here").target_class, None);
    }

    #[test]
    fn person_outranks_vehicle_when_both_present() {
        let i = classify_intent("highlight individuals near submerged vehicles");
        assert_eq!(i.target_class, Some(0));
    }

    #[test]
    fn token_class_recovery_matches_classifier() {
        for p in [
            "highlight the stranded people",
            "mark every car trapped in the water",
            "segment the partially submerged vehicles",
            "highlight individuals near submerged vehicles",
            "what is happening here",
        ] {
            assert_eq!(
                target_class_of_tokens(&tokenize(p)),
                classify_intent(p).target_class,
                "{p}"
            );
        }
    }

    #[test]
    fn tokenizer_shape_and_padding() {
        let ids = tokenize("find people");
        assert_eq!(ids.len(), PROMPT_TOKENS);
        assert!(ids[0] > 0 && ids[1] > 0);
        assert!(ids[2..].iter().all(|&i| i == 0));
        for &i in &ids {
            assert!((0..VOCAB as i32).contains(&i));
        }
    }

    #[test]
    fn tokenizer_case_and_punct_insensitive() {
        assert_eq!(tokenize("Find, People!"), tokenize("find people"));
    }

    #[test]
    fn tokenizer_truncates_long_prompts() {
        let long = vec!["word"; 40].join(" ");
        assert_eq!(tokenize(&long).len(), PROMPT_TOKENS);
    }
}
