//! The paper's system contribution (L3): operator-intent classification,
//! the pre-profiled System LUT (Table 3), and the self-aware Split
//! Controller implementing Algorithm 1's Sense -> Gate -> Evaluate -> Select
//! pipeline, wrapped in hierarchical runtime adaptation (Section 3).

mod controller;
mod intent;
mod lut;

pub use controller::{
    ControllerDecision, ControllerError, MissionGoal, RuntimeState, SplitController,
};
pub use intent::{
    classify_intent, target_class_of_tokens, tokenize, Intent, IntentLevel, PROMPT_TOKENS, VOCAB,
};
pub use lut::{Lut, LutEntry, SweepEntry, TierId};
