//! The self-aware Split Controller — paper Algorithm 1 (§4.4.2), operating
//! the hierarchical decision model of §3.2–3.3:
//!
//! * **Sense** — acquire the current bandwidth estimate (EWMA over goodput).
//! * **Gate**  — operator intent selects the admissible stream; Context
//!   intents return immediately with the Context configuration.
//! * **Evaluate** — for Insight intents, filter LUT tiers by the timeliness
//!   requirement `f_max(B, tier) >= F_I`.
//! * **Select** — among feasible tiers, pick per the mission goal
//!   (PRIORITIZE_ACCURACY -> highest fidelity, PRIORITIZE_THROUGHPUT ->
//!   highest update rate).
//!
//! Extensions over the paper's pseudocode (flagged as such):
//! * an optional switching-hysteresis margin so the tier doesn't flap when
//!   bandwidth hovers exactly at a feasibility threshold; the ablation bench
//!   (`fig9_dynamic --ablate-hysteresis`) quantifies its effect,
//! * an optional minimum-dwell window: after any tier change, *voluntary*
//!   switches (the current tier is still feasible but another now scores
//!   higher) are suppressed for `min_dwell_decisions` decisions.  Forced
//!   evictions — the current tier dropping below F_I — are always honored
//!   immediately, so dwell never compromises timeliness.  Scenario missions
//!   run with dwell 2, which makes "no voluntary flap on consecutive
//!   epochs" a structural guarantee (pinned by `rust/tests/scenario.rs`).
//!
//! With both knobs at 0 the controller is literally Algorithm 1.

use super::intent::{Intent, IntentLevel};
use super::lut::{Lut, TierId};

/// Mission goal G_mission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MissionGoal {
    PrioritizeAccuracy,
    PrioritizeThroughput,
}

/// UAV runtime state x_t = (B_t, P_t, I_t).
#[derive(Clone, Debug)]
pub struct RuntimeState {
    /// Sensed bandwidth estimate B_t (Mbps).
    pub bandwidth_mbps: f64,
    /// Onboard compute-power budget P_t — fixed operating mode in the
    /// prototype (paper: MODE_30W_ALL), carried for the formal model.
    pub power_mode: &'static str,
    /// Operator intent I_t.
    pub intent: Intent,
}

/// C* — the configuration Algorithm 1 returns.
#[derive(Clone, Debug, PartialEq)]
pub enum ControllerDecision {
    /// Context-level intent: lightweight stream, max context throughput.
    Context { max_pps: f64 },
    /// Insight-level intent: selected tier and its induced throughput f*.
    Insight { tier: TierId, pps: f64 },
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ControllerError {
    /// Algorithm 1 lines 26–28: no tier satisfies F_I at current bandwidth.
    NoFeasibleInsightTier,
}

impl std::fmt::Display for ControllerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControllerError::NoFeasibleInsightTier => {
                write!(f, "no feasible Insight tier under current runtime condition")
            }
        }
    }
}

impl std::error::Error for ControllerError {}

/// The onboard controller: LUT + policy knobs.
#[derive(Clone, Debug)]
pub struct SplitController {
    lut: Lut,
    /// F_I for Insight intents (paper deployment: 0.5 PPS).
    pub min_insight_pps: f64,
    /// Context stream max update rate (bounded by on-device CLIP latency;
    /// §5.2.2: 6.4x faster than the Insight head).
    pub max_context_pps: f64,
    /// Hysteresis margin (fraction of F_I) a *new* tier must clear before
    /// the controller switches away from the current one. 0 = Algorithm 1.
    pub hysteresis: f64,
    /// Minimum decisions to dwell on a tier before another *voluntary*
    /// switch; forced evictions (current tier infeasible) bypass it.
    /// 0 = Algorithm 1.
    pub min_dwell_decisions: u64,
    /// Last Insight tier selected (hysteresis state).
    last_tier: Option<TierId>,
    /// Decision index of the most recent tier adoption/switch (dwell state).
    last_switch_decision: u64,
    /// Decision counters (telemetry).
    pub decisions: u64,
    pub switches: u64,
}

impl SplitController {
    pub fn new(lut: Lut, min_insight_pps: f64, max_context_pps: f64) -> Self {
        Self {
            lut,
            min_insight_pps,
            max_context_pps,
            hysteresis: 0.0,
            min_dwell_decisions: 0,
            last_tier: None,
            last_switch_decision: 0,
            decisions: 0,
            switches: 0,
        }
    }

    pub fn lut(&self) -> &Lut {
        &self.lut
    }

    /// Algorithm 1 `SelectConfiguration`.
    pub fn select_configuration(
        &mut self,
        state: &RuntimeState,
        goal: MissionGoal,
    ) -> Result<ControllerDecision, ControllerError> {
        self.decisions += 1;
        // ---- Stage 2: Gate (lines 11–18) ----
        if state.intent.level == IntentLevel::Context {
            return Ok(ControllerDecision::Context { max_pps: self.max_context_pps });
        }
        // ---- Stage 3: Evaluate feasible Insight tiers (lines 19–28) ----
        let b = state.bandwidth_mbps;
        let mut feasible: Vec<(TierId, f64)> = Vec::with_capacity(3);
        for e in &self.lut.tiers {
            let f_max = e.max_pps(b); // line 21
            let need = if Some(e.tier) == self.last_tier {
                self.min_insight_pps
            } else {
                // A switch target must clear F_I by the hysteresis margin.
                self.min_insight_pps * (1.0 + self.hysteresis)
            };
            if f_max >= need {
                feasible.push((e.tier, f_max));
            }
        }
        if feasible.is_empty() {
            self.last_tier = None;
            return Err(ControllerError::NoFeasibleInsightTier); // lines 26–28
        }
        // ---- Stage 4: Select by mission goal (lines 29–35) ----
        let (mut tier, mut pps) = match goal {
            MissionGoal::PrioritizeAccuracy => {
                // Highest-fidelity tier: TierId orders by fidelity desc.
                *feasible.iter().min_by_key(|(t, _)| t.index()).unwrap()
            }
            MissionGoal::PrioritizeThroughput => {
                *feasible
                    .iter()
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap()
            }
        };
        // ---- Dwell extension: hold a freshly adopted tier against
        // *voluntary* switches while it remains feasible.  A forced switch
        // (current tier not in the feasible set) is never delayed. ----
        if let Some(last) = self.last_tier {
            if tier != last
                && self.min_dwell_decisions > 0
                && self.decisions - self.last_switch_decision <= self.min_dwell_decisions
            {
                if let Some(&(t, p)) = feasible.iter().find(|(t, _)| *t == last) {
                    tier = t;
                    pps = p;
                }
            }
        }
        if self.last_tier != Some(tier) {
            if self.last_tier.is_some() {
                self.switches += 1;
            }
            self.last_switch_decision = self.decisions;
        }
        self.last_tier = Some(tier);
        Ok(ControllerDecision::Insight { tier, pps })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::intent::classify_intent;
    use crate::util::Rng;

    fn controller() -> SplitController {
        SplitController::new(Lut::paper(), 0.5, 6.0)
    }

    fn state(bw: f64, prompt: &str) -> RuntimeState {
        RuntimeState {
            bandwidth_mbps: bw,
            power_mode: "MODE_30W_ALL",
            intent: classify_intent(prompt),
        }
    }

    #[test]
    fn context_intent_gates_early() {
        let mut c = controller();
        let d = c
            .select_configuration(
                &state(15.0, "what is happening in this sector"),
                MissionGoal::PrioritizeAccuracy,
            )
            .unwrap();
        assert!(matches!(d, ControllerDecision::Context { .. }));
    }

    #[test]
    fn high_bandwidth_accuracy_mode_picks_high_accuracy() {
        let mut c = controller();
        let d = c
            .select_configuration(
                &state(18.0, "highlight the stranded vehicle"),
                MissionGoal::PrioritizeAccuracy,
            )
            .unwrap();
        assert_eq!(d, ControllerDecision::Insight {
            tier: TierId::HighAccuracy,
            pps: Lut::paper().entry(TierId::HighAccuracy).max_pps(18.0)
        });
    }

    #[test]
    fn below_ha_threshold_falls_to_balanced() {
        // Paper §3.3: below 11.68 Mbps High-Accuracy is infeasible but
        // Balanced still satisfies 0.5 PPS -> switch, don't stall.
        let mut c = controller();
        let d = c
            .select_configuration(
                &state(10.0, "highlight the stranded vehicle"),
                MissionGoal::PrioritizeAccuracy,
            )
            .unwrap();
        assert!(matches!(d, ControllerDecision::Insight { tier: TierId::Balanced, .. }));
    }

    #[test]
    fn throughput_mode_picks_smallest_payload() {
        let mut c = controller();
        let d = c
            .select_configuration(
                &state(18.0, "segment the submerged cars"),
                MissionGoal::PrioritizeThroughput,
            )
            .unwrap();
        assert!(matches!(d, ControllerDecision::Insight { tier: TierId::HighThroughput, .. }));
    }

    #[test]
    fn no_feasible_tier_reported() {
        let mut c = controller();
        // 0.83 MB needs 3.32 Mbps for 0.5 PPS; go far below.
        let r = c.select_configuration(
            &state(1.0, "highlight the people on the roof"),
            MissionGoal::PrioritizeAccuracy,
        );
        assert_eq!(r.unwrap_err(), ControllerError::NoFeasibleInsightTier);
    }

    #[test]
    fn induced_pps_matches_line_21() {
        let mut c = controller();
        let d = c
            .select_configuration(
                &state(11.68, "mark the survivors"),
                MissionGoal::PrioritizeAccuracy,
            )
            .unwrap();
        if let ControllerDecision::Insight { tier, pps } = d {
            assert_eq!(tier, TierId::HighAccuracy);
            assert!((pps - 0.5).abs() < 1e-9);
        } else {
            panic!("expected insight");
        }
    }

    #[test]
    fn hysteresis_suppresses_flapping() {
        let mut with_h = controller();
        with_h.hysteresis = 0.10;
        let mut without_h = controller();
        // Bandwidth oscillating tightly around the HA threshold.
        let mut rng = Rng::new(3);
        let (mut sw_with, mut sw_without) = (0u64, 0u64);
        for _ in 0..200 {
            let bw = 11.68 + rng.normal() * 0.25;
            let s = state(bw, "highlight the stranded vehicle");
            let _ = with_h.select_configuration(&s, MissionGoal::PrioritizeAccuracy);
            let _ = without_h.select_configuration(&s, MissionGoal::PrioritizeAccuracy);
            sw_with = with_h.switches;
            sw_without = without_h.switches;
        }
        assert!(
            sw_with < sw_without,
            "hysteresis {sw_with} switches vs {sw_without} without"
        );
    }

    #[test]
    fn dwell_suppresses_voluntary_switch_but_not_eviction() {
        let mut c = controller();
        c.min_dwell_decisions = 2;
        let prompt = "highlight the stranded vehicle";
        // Adopt Balanced at 10 Mbps (HA infeasible below 11.68).
        let d0 = c
            .select_configuration(&state(10.0, prompt), MissionGoal::PrioritizeAccuracy)
            .unwrap();
        assert!(matches!(d0, ControllerDecision::Insight { tier: TierId::Balanced, .. }));
        // Bandwidth recovers immediately: the voluntary upgrade to HA must
        // wait out the dwell window...
        let d1 = c
            .select_configuration(&state(18.0, prompt), MissionGoal::PrioritizeAccuracy)
            .unwrap();
        assert!(matches!(d1, ControllerDecision::Insight { tier: TierId::Balanced, .. }));
        let d2 = c
            .select_configuration(&state(18.0, prompt), MissionGoal::PrioritizeAccuracy)
            .unwrap();
        assert!(matches!(d2, ControllerDecision::Insight { tier: TierId::Balanced, .. }));
        // ...and lands once the window expires.
        let d3 = c
            .select_configuration(&state(18.0, prompt), MissionGoal::PrioritizeAccuracy)
            .unwrap();
        assert!(matches!(d3, ControllerDecision::Insight { tier: TierId::HighAccuracy, .. }));
        // Forced eviction bypasses dwell: HA was just adopted, but a
        // collapse below every HA-feasible bandwidth must switch at once.
        let d4 = c
            .select_configuration(&state(6.0, prompt), MissionGoal::PrioritizeAccuracy)
            .unwrap();
        assert!(matches!(d4, ControllerDecision::Insight { tier: TierId::Balanced, .. }));
    }

    /// Property: over random bandwidths/goals, every Insight decision is
    /// feasible (pps >= F_I) and matches the goal's argmax over the LUT.
    #[test]
    fn property_decisions_feasible_and_goal_optimal() {
        let mut rng = Rng::new(99);
        let lut = Lut::paper();
        for _ in 0..2000 {
            let bw = rng.range(0.5, 25.0);
            let goal = if rng.f64() < 0.5 {
                MissionGoal::PrioritizeAccuracy
            } else {
                MissionGoal::PrioritizeThroughput
            };
            let mut c = controller();
            match c.select_configuration(&state(bw, "segment the people"), goal) {
                Ok(ControllerDecision::Insight { tier, pps }) => {
                    assert!(pps >= 0.5 - 1e-12, "infeasible pps {pps} at bw {bw}");
                    // Goal-optimality among feasible tiers.
                    let feas: Vec<TierId> = TierId::ALL
                        .iter()
                        .copied()
                        .filter(|&t| lut.entry(t).max_pps(bw) >= 0.5)
                        .collect();
                    let want = match goal {
                        MissionGoal::PrioritizeAccuracy => {
                            *feas.iter().min_by_key(|t| t.index()).unwrap()
                        }
                        MissionGoal::PrioritizeThroughput => *feas
                            .iter()
                            .max_by(|a, b| {
                                lut.entry(**a)
                                    .max_pps(bw)
                                    .partial_cmp(&lut.entry(**b).max_pps(bw))
                                    .unwrap()
                            })
                            .unwrap(),
                    };
                    assert_eq!(tier, want, "bw {bw} goal {goal:?}");
                }
                Ok(ControllerDecision::Context { .. }) => panic!("insight prompt gated"),
                Err(ControllerError::NoFeasibleInsightTier) => {
                    // Must truly be infeasible for every tier.
                    for t in TierId::ALL {
                        assert!(lut.entry(t).max_pps(bw) < 0.5, "bw {bw} tier {t:?}");
                    }
                }
            }
        }
    }
}
