//! The pre-profiled System Configuration LUT (paper Table 3 + §4.4.1).
//!
//! Each Insight operating tier stores its compression ratio, expected
//! segmentation quality (Average IoU, for both the Original and Fine-tuned
//! models), and the compressed payload size used by the wire model.  The
//! accuracy columns are **measured at artifact-build time** by
//! python/compile/aot.py over the validation sets (the paper profiles
//! offline on its testbed); payload sizes are the paper's (2.92/1.35/0.83
//! MB).  `artifacts/lut.txt` carries the measurements; `Lut::paper()`
//! provides Table 3's published values for comparisons/tests.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Insight tier identity, ordered by fidelity (descending).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TierId {
    HighAccuracy = 0,
    Balanced = 1,
    HighThroughput = 2,
}

impl TierId {
    pub const ALL: [TierId; 3] =
        [TierId::HighAccuracy, TierId::Balanced, TierId::HighThroughput];

    pub fn name(self) -> &'static str {
        match self {
            TierId::HighAccuracy => "high_accuracy",
            TierId::Balanced => "balanced",
            TierId::HighThroughput => "high_throughput",
        }
    }

    pub fn display(self) -> &'static str {
        match self {
            TierId::HighAccuracy => "High Accuracy",
            TierId::Balanced => "Balanced",
            TierId::HighThroughput => "High Throughput",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "high_accuracy" => Ok(TierId::HighAccuracy),
            "balanced" => Ok(TierId::Balanced),
            "high_throughput" => Ok(TierId::HighThroughput),
            other => bail!("unknown tier {other}"),
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// One LUT row.
#[derive(Clone, Copy, Debug)]
pub struct LutEntry {
    pub tier: TierId,
    pub ratio: f64,
    /// Bottleneck code width M = round(ratio * DIM).
    pub code_width: usize,
    /// Average IoU of the Original model at this tier.
    pub acc_orig: f64,
    /// Average IoU of the Fine-tuned model at this tier.
    pub acc_ft: f64,
    /// Paper-scale compressed payload (bytes) — drives the link model.
    pub wire_bytes: f64,
    /// Actual mini-LISA payload bytes (reported, not used for timing).
    pub real_payload_bytes: usize,
}

impl LutEntry {
    /// Max achievable Insight update rate (PPS) at bandwidth `mbps` —
    /// Algorithm 1 line 21: f_max = (B/8) / data_size.
    pub fn max_pps(&self, mbps: f64) -> f64 {
        (mbps * 1e6 / 8.0) / self.wire_bytes
    }

    /// Minimum bandwidth (Mbps) needed to sustain `pps` updates per second.
    pub fn min_mbps_for(&self, pps: f64) -> f64 {
        pps * self.wire_bytes * 8.0 / 1e6
    }
}

/// Fig 7 sweep rows (accuracy per split point at r = 0.10).
#[derive(Clone, Copy, Debug)]
pub struct SweepEntry {
    pub split: usize,
    pub giou: f64,
    pub ciou: f64,
}

/// The full knowledge base loaded from artifacts/lut.txt.
#[derive(Clone, Debug)]
pub struct Lut {
    pub tiers: Vec<LutEntry>,
    pub sweep: Vec<SweepEntry>,
    /// Full uncompressed pipeline accuracy (orig, ft) — baselines.
    pub full_orig: f64,
    pub full_ft: f64,
    /// Paper's uncompressed SAM split@1 activation size (10.49 MB).
    pub sam_activation_bytes: f64,
}

impl Lut {
    /// Table 3 as published (for comparisons and unit tests).
    pub fn paper() -> Self {
        let mk = |tier, ratio, acc_o: f64, acc_f: f64, mb: f64, m| LutEntry {
            tier,
            ratio,
            code_width: m,
            acc_orig: acc_o,
            acc_ft: acc_f,
            wire_bytes: mb * 1e6,
            real_payload_bytes: 0,
        };
        Lut {
            tiers: vec![
                mk(TierId::HighAccuracy, 0.25, 0.8442, 0.8112, 2.92, 32),
                mk(TierId::Balanced, 0.10, 0.8289, 0.7920, 1.35, 13),
                mk(TierId::HighThroughput, 0.05, 0.8067, 0.7848, 0.83, 6),
            ],
            sweep: Vec::new(),
            full_orig: 0.8442,
            full_ft: 0.8112,
            sam_activation_bytes: 10.49e6,
        }
    }

    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("lut.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut lut = Lut {
            tiers: Vec::new(),
            sweep: Vec::new(),
            full_orig: 0.0,
            full_ft: 0.0,
            sam_activation_bytes: 10.49e6,
        };
        for (lineno, line) in text.lines().enumerate() {
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.is_empty() {
                continue;
            }
            let ctx = || format!("lut.txt line {}", lineno + 1);
            let get = |key: &str| -> Result<f64> {
                toks.iter()
                    .position(|&t| t == key)
                    .and_then(|i| toks.get(i + 1))
                    .with_context(|| format!("{}: missing {key}", ctx()))?
                    .parse::<f64>()
                    .with_context(ctx)
            };
            match toks[0] {
                "sam_activation_mb" => {
                    lut.sam_activation_bytes =
                        toks[1].parse::<f64>().with_context(ctx)? * 1e6;
                }
                "tier" => {
                    let tier = TierId::from_name(toks[1])?;
                    lut.tiers.push(LutEntry {
                        tier,
                        ratio: get("ratio")?,
                        code_width: get("code_width")? as usize,
                        acc_orig: 0.5 * (get("orig_giou")? + get("orig_ciou")?),
                        acc_ft: 0.5 * (get("ft_giou")? + get("ft_ciou")?),
                        wire_bytes: get("data_mb")? * 1e6,
                        real_payload_bytes: get("payload_bytes")? as usize,
                    });
                }
                "sweep" => {
                    lut.sweep.push(SweepEntry {
                        split: toks[1].parse().with_context(ctx)?,
                        giou: get("giou")?,
                        ciou: get("ciou")?,
                    });
                }
                "full" => {
                    let acc = 0.5 * (get("giou")? + get("ciou")?);
                    match toks[1] {
                        "orig" => lut.full_orig = acc,
                        "ft" => lut.full_ft = acc,
                        other => bail!("{}: unknown full set {other}", ctx()),
                    }
                }
                other => bail!("{}: unknown tag {other}", ctx()),
            }
        }
        if lut.tiers.is_empty() {
            bail!("lut.txt has no tiers");
        }
        lut.tiers.sort_by_key(|e| e.tier);
        Ok(lut)
    }

    pub fn entry(&self, tier: TierId) -> &LutEntry {
        self.tiers.iter().find(|e| e.tier == tier).expect("tier present")
    }

    /// Accuracy column for a given weight set name ("orig"/"ft").
    pub fn accuracy(&self, tier: TierId, set: &str) -> f64 {
        let e = self.entry(tier);
        if set == "ft" {
            e.acc_ft
        } else {
            e.acc_orig
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_lut_feasibility_threshold() {
        // Paper §3.3: High-Accuracy needs >= 11.68 Mbps for 0.5 PPS.
        let lut = Lut::paper();
        let ha = lut.entry(TierId::HighAccuracy);
        assert!((ha.min_mbps_for(0.5) - 11.68).abs() < 1e-9);
        // And exactly 0.5 PPS at that bandwidth.
        assert!((ha.max_pps(11.68) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn paper_lut_ordering() {
        let lut = Lut::paper();
        let accs: Vec<f64> = TierId::ALL.iter().map(|&t| lut.entry(t).acc_orig).collect();
        assert!(accs[0] > accs[1] && accs[1] > accs[2]);
        let sizes: Vec<f64> = TierId::ALL.iter().map(|&t| lut.entry(t).wire_bytes).collect();
        assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2]);
    }

    #[test]
    fn parse_roundtrip() {
        let text = "\
sam_activation_mb 10.49
tier high_accuracy ratio 0.25 code_width 32 data_mb 2.92 payload_bytes 3136 orig_giou 0.84 orig_ciou 0.85 ft_giou 0.80 ft_ciou 0.82
tier balanced ratio 0.10 code_width 13 data_mb 1.35 payload_bytes 1900 orig_giou 0.82 orig_ciou 0.83 ft_giou 0.78 ft_ciou 0.80
sweep 1 giou 0.82 ciou 0.83
full orig giou 0.84 ciou 0.85
";
        let lut = Lut::parse(text).unwrap();
        assert_eq!(lut.tiers.len(), 2);
        assert!((lut.entry(TierId::HighAccuracy).acc_orig - 0.845).abs() < 1e-9);
        assert!((lut.accuracy(TierId::Balanced, "ft") - 0.79).abs() < 1e-9);
        assert_eq!(lut.sweep.len(), 1);
        assert!((lut.full_orig - 0.845).abs() < 1e-9);
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(Lut::parse("bogus 1\n").is_err());
        assert!(Lut::parse("").is_err());
    }

    #[test]
    fn tier_names_roundtrip() {
        for t in TierId::ALL {
            assert_eq!(TierId::from_name(t.name()).unwrap(), t);
        }
    }
}
