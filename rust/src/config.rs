//! Configuration: a small `key = value` file format (TOML subset — no tables,
//! comments with `#`) plus CLI `--key value` overrides.  The offline crate
//! set has no clap/serde, so this is the hand-rolled equivalent; every
//! mission binary and example goes through [`RunConfig`], and the mission
//! layer consumes it through `mission::RunOptions::from_config` — the one
//! place config becomes mission options.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::MissionGoal;
use crate::report::OutputFormat;
use crate::runtime::ExecMode;

/// Flat key-value configuration store with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Kv {
    map: BTreeMap<String, String>,
}

impl Kv {
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value", lineno + 1);
            };
            map.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(Self { map })
    }

    /// Apply CLI overrides of the form `--key value` (also accepts
    /// `--key=value`); returns unconsumed positional args.
    pub fn apply_cli(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    self.map.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    self.map.insert(rest.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    // bare flag -> boolean true
                    self.map.insert(rest.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(positional)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v} not a number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v} not an integer")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v} not an integer")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.map.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config {key}={v} not a bool"),
        }
    }
}

/// Fully-resolved run configuration shared by the CLI and examples.
/// Optional knobs stay `None` when unset so the mission layer can
/// distinguish "user asked for this" from "use the mission's (or the
/// scenario regime's) default" without parallel `*_explicit` flags.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub artifacts: Option<String>,
    pub out_dir: String,
    pub duration_secs: f64,
    /// `--goal accuracy|throughput`; `None` = mission/scenario default.
    pub goal: Option<MissionGoal>,
    pub exec_every: usize,
    pub seed: u64,
    /// fig9 hysteresis ablation margin.
    pub hysteresis: Option<f64>,
    pub exec_mode: ExecMode,
    /// Fleet size; `None` = mission/scenario default.
    pub uavs: Option<usize>,
    /// Cloud pool worker count; `None` = mission/scenario default.
    pub workers: Option<usize>,
    /// Scenario-library regime overlay (`--scenario NAME`).
    pub scenario: Option<String>,
    /// Scenario name for the `scenario` mission (`--name NAME`).
    pub name: Option<String>,
    /// Scenario manifest path for the `scenario` mission
    /// (`--manifest PATH`); compiled by the scenario compiler.
    pub manifest: Option<String>,
    /// Matrix mission sample size (`--matrix-count N`); `None` = default.
    pub matrix_count: Option<usize>,
    /// Cloud serving layer: max compatible requests per micro-batch
    /// (`--batch-max N`); `None` = 1 (unbatched).
    pub batch_max: Option<usize>,
    /// Cloud serving layer: response-cache capacity in entries
    /// (`--cache-entries N`); `None` = 0 (cache off).
    pub cache_entries: Option<usize>,
    /// Cloud serving layer: cache TTL in virtual seconds
    /// (`--cache-ttl SECS`); `None` = never expire.
    pub cache_ttl: Option<f64>,
    /// Cloud serving layer: bound on in-flight requests
    /// (`--queue-depth N`); `None` = 0 (unbounded).
    pub queue_depth: Option<usize>,
    /// Deadline budget for Context-class requests in virtual seconds
    /// (`--deadline-context SECS`); `None` = infinite.
    pub deadline_context: Option<f64>,
    /// Deadline budget for Insight-class requests in virtual seconds
    /// (`--deadline-insight SECS`); `None` = infinite.
    pub deadline_insight: Option<f64>,
    /// Drain the serving queue earliest-deadline-first (`--edf`);
    /// false = FIFO.
    pub edf: bool,
    /// Shed the request predicted to miss its deadline instead of the
    /// newest arrival (`--deadline-shed`).
    pub deadline_shed: bool,
    /// Cloud cluster: number of serving cells behind the consistent-hash
    /// router (`--cells K`); `None` = 1 (single pool, cluster inert).
    pub cells: Option<usize>,
    /// Cloud cluster: response-cache replication factor
    /// (`--replicas R`); `None` = 1 (home cell only).
    pub replicas: Option<usize>,
    /// Cloud cluster: modeled inter-cell latency per ring hop in virtual
    /// seconds (`--hop-latency SECS`); `None` = the cluster default.
    pub hop_latency: Option<f64>,
    /// Cloud cluster: max spill hops past the home cell before a typed
    /// shed (`--spill-max H`); `None` = 1.
    pub spill_max: Option<u32>,
    /// Chaos layer: standalone fault-plan manifest path
    /// (`--fault-plan PATH`, `[[fault]]` sections only); `None` = no
    /// injected faults unless the scenario manifest declares them.
    pub fault_plan: Option<String>,
    /// Agent resilience: per-request retry budget against retryable
    /// cloud failures (`--retry-budget N`); `None` = mission default
    /// (0, or 2 once a fault plan arms the chaos layer).
    pub retry_budget: Option<u32>,
    /// Agent resilience: first retry backoff in virtual seconds,
    /// doubling per attempt (`--retry-backoff SECS`); `None` = 0.05.
    pub retry_backoff: Option<f64>,
    /// Agent resilience: accumulated-backoff deadline in virtual seconds
    /// (`--retry-deadline SECS`); `None` = infinite (budget-only).
    pub retry_deadline: Option<f64>,
    /// Agent resilience: degrade unreachable Insight requests to
    /// edge-local Context execution (`--degrade`); `None` = mission
    /// default (off, or on once a fault plan arms the chaos layer).
    pub degrade: Option<bool>,
    /// Cell health: first re-probe backoff after quarantine in virtual
    /// seconds, doubling per failed probe (`--probe-backoff SECS`);
    /// `None` = the health-machine default (0.5).
    pub probe_backoff: Option<f64>,
    /// Megafleet core: number of scheduler shards for the fleet loop
    /// (`--shards T`); `None` = the legacy single-threaded event loop.
    /// Any `Some(T)` selects the epoch-quantized sharded core, whose
    /// output is identical for every T at a given seed.
    pub shards: Option<usize>,
    /// `avery scenario --list`.
    pub list: bool,
    /// Report rendering (`--format text|json`); CSVs are always written.
    pub format: OutputFormat,
    /// Parallel mission fan-out for `avery all` (`--jobs N`); rendering
    /// stays serial so output bytes match a `--jobs 1` run.
    pub jobs: usize,
}

impl RunConfig {
    pub fn from_kv(kv: &Kv) -> Result<Self> {
        let goal = match kv.get("goal") {
            None => None,
            Some("accuracy") => Some(MissionGoal::PrioritizeAccuracy),
            Some("throughput") => Some(MissionGoal::PrioritizeThroughput),
            Some(other) => bail!("goal must be accuracy|throughput, got {other}"),
        };
        let exec_mode = match kv.get("exec-mode").unwrap_or("buffers") {
            "buffers" => ExecMode::PreuploadedBuffers,
            "literals" => ExecMode::LiteralsEachCall,
            other => bail!("exec-mode must be buffers|literals, got {other}"),
        };
        let format = match kv.get("format") {
            None => OutputFormat::Text,
            Some(s) => OutputFormat::parse(s)?,
        };
        let cache_entries = match kv.get("cache-entries") {
            None => None,
            Some(v) => Some(
                v.parse::<usize>()
                    .with_context(|| format!("config cache-entries={v} not an integer"))?,
            ),
        };
        let cache_ttl = match kv.get("cache-ttl") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config cache-ttl={v} not a number"))?,
            ),
        };
        // A TTL without a cache would be a silent no-op — reject it so the
        // user learns the cache never existed instead of trusting phantom
        // reuse.
        if cache_ttl.is_some() && cache_entries.unwrap_or(0) == 0 {
            bail!("cache-ttl requires cache-entries > 0 (the cache is off without it)");
        }
        let deadline_context = match kv.get("deadline-context") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config deadline-context={v} not a number"))?,
            ),
        };
        let deadline_insight = match kv.get("deadline-insight") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config deadline-insight={v} not a number"))?,
            ),
        };
        // A zero/negative/NaN deadline budget would shed every request (or
        // none, for NaN) — reject it up front; `inf` spells "no deadline".
        for (key, d) in
            [("deadline-context", deadline_context), ("deadline-insight", deadline_insight)]
        {
            if let Some(d) = d {
                if d.is_nan() || d <= 0.0 {
                    bail!("config {key}={d} must be a positive number of seconds");
                }
            }
        }
        let cells = match kv.get("cells") {
            None => None,
            Some(v) => Some(
                v.parse::<usize>()
                    .with_context(|| format!("config cells={v} not an integer"))?,
            ),
        };
        if cells == Some(0) {
            bail!("config cells=0: the cluster needs at least one cell");
        }
        let replicas = match kv.get("replicas") {
            None => None,
            Some(v) => Some(
                v.parse::<usize>()
                    .with_context(|| format!("config replicas={v} not an integer"))?,
            ),
        };
        if replicas == Some(0) {
            bail!("config replicas=0: the cache needs at least one replica (its home cell)");
        }
        let hop_latency = match kv.get("hop-latency") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config hop-latency={v} not a number"))?,
            ),
        };
        if let Some(h) = hop_latency {
            if !h.is_finite() || h < 0.0 {
                bail!("config hop-latency={h} must be a finite number of seconds >= 0");
            }
        }
        let spill_max = match kv.get("spill-max") {
            None => None,
            Some(v) => Some(
                v.parse::<u32>()
                    .with_context(|| format!("config spill-max={v} not an integer"))?,
            ),
        };
        let retry_budget = match kv.get("retry-budget") {
            None => None,
            Some(v) => Some(
                v.parse::<u32>()
                    .with_context(|| format!("config retry-budget={v} not an integer"))?,
            ),
        };
        let retry_backoff = match kv.get("retry-backoff") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config retry-backoff={v} not a number"))?,
            ),
        };
        // A non-positive (or non-finite) backoff would retry in zero
        // virtual time — an infinite-rate hammer the simulation can't
        // model honestly.
        if let Some(b) = retry_backoff {
            if !b.is_finite() || b <= 0.0 {
                bail!("config retry-backoff={b} must be a finite number of seconds > 0");
            }
        }
        let retry_deadline = match kv.get("retry-deadline") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config retry-deadline={v} not a number"))?,
            ),
        };
        // `inf` spells "budget-only"; zero/negative/NaN would silently
        // disable every retry while leaving the budget knob lying.
        if let Some(d) = retry_deadline {
            if d.is_nan() || d <= 0.0 {
                bail!("config retry-deadline={d} must be a positive number of seconds");
            }
        }
        let degrade = match kv.get("degrade") {
            None => None,
            Some("true") | Some("1") | Some("yes") => Some(true),
            Some("false") | Some("0") | Some("no") => Some(false),
            Some(v) => bail!("config degrade={v} not a bool"),
        };
        let probe_backoff = match kv.get("probe-backoff") {
            None => None,
            Some(v) => Some(
                v.parse::<f64>()
                    .with_context(|| format!("config probe-backoff={v} not a number"))?,
            ),
        };
        if let Some(p) = probe_backoff {
            if !p.is_finite() || p <= 0.0 {
                bail!("config probe-backoff={p} must be a finite number of seconds > 0");
            }
        }
        let shards = match kv.get("shards") {
            None => None,
            Some(v) => Some(
                v.parse::<usize>()
                    .with_context(|| format!("config shards={v} not an integer"))?,
            ),
        };
        if shards == Some(0) {
            bail!("config shards=0: the sharded core needs at least one shard");
        }
        Ok(Self {
            artifacts: kv.get("artifacts").map(|s| s.to_string()),
            out_dir: kv.get("out").unwrap_or("out").to_string(),
            duration_secs: kv.get_f64("duration", 1200.0)?,
            goal,
            exec_every: kv.get_usize("exec-every", 1)?,
            seed: kv.get_u64("seed", 7)?,
            hysteresis: match kv.get("hysteresis") {
                None => None,
                Some(v) => Some(v.parse().context("hysteresis not a number")?),
            },
            exec_mode,
            uavs: match kv.get("uavs") {
                None => None,
                Some(v) => {
                    Some(v.parse().with_context(|| format!("config uavs={v} not an integer"))?)
                }
            },
            workers: match kv.get("workers") {
                None => None,
                Some(v) => Some(
                    v.parse().with_context(|| format!("config workers={v} not an integer"))?,
                ),
            },
            scenario: kv.get("scenario").map(|s| s.to_string()),
            name: kv.get("name").map(|s| s.to_string()),
            manifest: kv.get("manifest").map(|s| s.to_string()),
            matrix_count: match kv.get("matrix-count") {
                None => None,
                Some(v) => Some(
                    v.parse()
                        .with_context(|| format!("config matrix-count={v} not an integer"))?,
                ),
            },
            batch_max: match kv.get("batch-max") {
                None => None,
                Some(v) => Some(
                    v.parse()
                        .with_context(|| format!("config batch-max={v} not an integer"))?,
                ),
            },
            cache_entries,
            cache_ttl,
            queue_depth: match kv.get("queue-depth") {
                None => None,
                Some(v) => Some(
                    v.parse()
                        .with_context(|| format!("config queue-depth={v} not an integer"))?,
                ),
            },
            deadline_context,
            deadline_insight,
            edf: kv.get_bool("edf", false)?,
            deadline_shed: kv.get_bool("deadline-shed", false)?,
            cells,
            replicas,
            hop_latency,
            spill_max,
            fault_plan: kv.get("fault-plan").map(|s| s.to_string()),
            retry_budget,
            retry_backoff,
            retry_deadline,
            degrade,
            probe_backoff,
            shards,
            list: kv.get_bool("list", false)?,
            format,
            jobs: kv.get_usize("jobs", 1)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kv_file() {
        let kv = Kv::parse("a = 1\n# comment\nb = \"two\"  # inline\n\n").unwrap();
        assert_eq!(kv.get("a"), Some("1"));
        assert_eq!(kv.get("b"), Some("two"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Kv::parse("not a pair\n").is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut kv = Kv::parse("duration = 10\n").unwrap();
        let pos = kv
            .apply_cli(&[
                "fig9".to_string(),
                "--duration".to_string(),
                "300".to_string(),
                "--goal=throughput".to_string(),
                "--verbose".to_string(),
            ])
            .unwrap();
        assert_eq!(pos, vec!["fig9"]);
        assert_eq!(kv.get("duration"), Some("300"));
        assert_eq!(kv.get("goal"), Some("throughput"));
        assert_eq!(kv.get_bool("verbose", false).unwrap(), true);
    }

    #[test]
    fn run_config_defaults() {
        let kv = Kv::default();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.duration_secs, 1200.0);
        assert_eq!(rc.goal, None);
        assert_eq!(rc.exec_mode, ExecMode::PreuploadedBuffers);
        assert_eq!(rc.uavs, None);
        assert_eq!(rc.workers, None);
        assert_eq!(rc.format, OutputFormat::Text);
        assert_eq!(rc.jobs, 1);
    }

    #[test]
    fn jobs_key_parses_and_rejects() {
        let rc = RunConfig::from_kv(&Kv::parse("jobs = 8\n").unwrap()).unwrap();
        assert_eq!(rc.jobs, 8);
        assert!(RunConfig::from_kv(&Kv::parse("jobs = many\n").unwrap()).is_err());
    }

    #[test]
    fn fleet_keys_parse() {
        let kv = Kv::parse("uavs = 16\nworkers = 8\n").unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.uavs, Some(16));
        assert_eq!(rc.workers, Some(8));
        assert_eq!(rc.goal, None);
    }

    #[test]
    fn scenario_keys_parse() {
        let kv = Kv::parse("name = urban-flood\nscenario = coastal-satellite\nlist = true\n")
            .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.name.as_deref(), Some("urban-flood"));
        assert_eq!(rc.scenario.as_deref(), Some("coastal-satellite"));
        assert!(rc.list);
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.name.is_none() && rc0.scenario.is_none() && !rc0.list);
    }

    #[test]
    fn manifest_and_matrix_keys_parse_and_reject() {
        let kv =
            Kv::parse("manifest = scenarios/urban-flood.toml\nmatrix-count = 24\n").unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.manifest.as_deref(), Some("scenarios/urban-flood.toml"));
        assert_eq!(rc.matrix_count, Some(24));
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.manifest.is_none() && rc0.matrix_count.is_none());
        assert!(RunConfig::from_kv(&Kv::parse("matrix-count = lots\n").unwrap()).is_err());
    }

    #[test]
    fn run_config_rejects_bad_goal() {
        let kv = Kv::parse("goal = fastest\n").unwrap();
        assert!(RunConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn format_key_parses_and_rejects() {
        let rc = RunConfig::from_kv(&Kv::parse("format = json\n").unwrap()).unwrap();
        assert_eq!(rc.format, OutputFormat::Json);
        assert!(RunConfig::from_kv(&Kv::parse("format = yaml\n").unwrap()).is_err());
    }

    #[test]
    fn run_config_rejects_bad_fleet_counts() {
        assert!(RunConfig::from_kv(&Kv::parse("uavs = many\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("workers = -1\n").unwrap()).is_err());
    }

    #[test]
    fn serving_keys_parse_and_reject() {
        let kv = Kv::parse(
            "batch-max = 8\ncache-entries = 256\ncache-ttl = 60.5\nqueue-depth = 128\n",
        )
        .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.batch_max, Some(8));
        assert_eq!(rc.cache_entries, Some(256));
        assert_eq!(rc.cache_ttl, Some(60.5));
        assert_eq!(rc.queue_depth, Some(128));
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.batch_max.is_none() && rc0.cache_entries.is_none());
        assert!(rc0.cache_ttl.is_none() && rc0.queue_depth.is_none());
        assert!(RunConfig::from_kv(&Kv::parse("batch-max = big\n").unwrap()).is_err());
        assert!(
            RunConfig::from_kv(&Kv::parse("cache-entries = 8\ncache-ttl = soon\n").unwrap())
                .is_err()
        );
        assert!(RunConfig::from_kv(&Kv::parse("queue-depth = -2\n").unwrap()).is_err());
        // A TTL without a cache is a silent no-op — rejected.
        assert!(RunConfig::from_kv(&Kv::parse("cache-ttl = 60\n").unwrap()).is_err());
        assert!(
            RunConfig::from_kv(&Kv::parse("cache-ttl = 60\ncache-entries = 0\n").unwrap())
                .is_err()
        );
    }

    #[test]
    fn cluster_keys_parse_and_reject() {
        let kv = Kv::parse(
            "cells = 3\nreplicas = 2\nhop-latency = 0.004\nspill-max = 2\n",
        )
        .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.cells, Some(3));
        assert_eq!(rc.replicas, Some(2));
        assert_eq!(rc.hop_latency, Some(0.004));
        assert_eq!(rc.spill_max, Some(2));
        // Defaults keep the cluster inert (single pool).
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.cells.is_none() && rc0.replicas.is_none());
        assert!(rc0.hop_latency.is_none() && rc0.spill_max.is_none());
        // Type and range errors are hard.
        assert!(RunConfig::from_kv(&Kv::parse("cells = many\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("cells = 0\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("replicas = 0\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("hop-latency = soon\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("hop-latency = -0.1\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("hop-latency = inf\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("hop-latency = NaN\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("spill-max = -1\n").unwrap()).is_err());
        // A spill bound of 0 is legal — it means "never spill past home".
        let rcz = RunConfig::from_kv(&Kv::parse("spill-max = 0\n").unwrap()).unwrap();
        assert_eq!(rcz.spill_max, Some(0));
    }

    #[test]
    fn shards_key_parses_and_rejects() {
        let rc = RunConfig::from_kv(&Kv::parse("shards = 8\n").unwrap()).unwrap();
        assert_eq!(rc.shards, Some(8));
        // Unset keeps the legacy single-threaded event loop.
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.shards.is_none());
        assert!(RunConfig::from_kv(&Kv::parse("shards = many\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("shards = 0\n").unwrap()).is_err());
    }

    #[test]
    fn chaos_keys_parse_and_reject() {
        let kv = Kv::parse(
            "fault-plan = plans/killcell.toml\nretry-budget = 3\nretry-backoff = 0.1\n\
             retry-deadline = 4\ndegrade = true\nprobe-backoff = 0.25\n",
        )
        .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.fault_plan.as_deref(), Some("plans/killcell.toml"));
        assert_eq!(rc.retry_budget, Some(3));
        assert_eq!(rc.retry_backoff, Some(0.1));
        assert_eq!(rc.retry_deadline, Some(4.0));
        assert_eq!(rc.degrade, Some(true));
        assert_eq!(rc.probe_backoff, Some(0.25));
        // Defaults keep the chaos layer disarmed (every knob unset).
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.fault_plan.is_none() && rc0.retry_budget.is_none());
        assert!(rc0.retry_backoff.is_none() && rc0.retry_deadline.is_none());
        assert!(rc0.degrade.is_none() && rc0.probe_backoff.is_none());
        // `--degrade` as a bare CLI flag arrives as `degrade = true`;
        // an explicit `degrade = false` survives as Some(false) so the
        // mission layer can tell "user said no" from "unset".
        let mut flags = Kv::default();
        flags.apply_cli(&["--degrade".to_string()]).unwrap();
        assert_eq!(RunConfig::from_kv(&flags).unwrap().degrade, Some(true));
        let off = RunConfig::from_kv(&Kv::parse("degrade = false\n").unwrap()).unwrap();
        assert_eq!(off.degrade, Some(false));
        // Type and range errors are hard.
        assert!(RunConfig::from_kv(&Kv::parse("retry-budget = lots\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("retry-backoff = 0\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("retry-backoff = inf\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("retry-deadline = -1\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("retry-deadline = NaN\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("degrade = maybe\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("probe-backoff = -0.5\n").unwrap()).is_err());
        // `inf` retry-deadline spells "budget-only" and is accepted.
        let inf = RunConfig::from_kv(&Kv::parse("retry-deadline = inf\n").unwrap()).unwrap();
        assert_eq!(inf.retry_deadline, Some(f64::INFINITY));
    }

    #[test]
    fn deadline_keys_parse_and_reject() {
        let kv = Kv::parse(
            "deadline-context = 0.05\ndeadline-insight = 2.5\nedf = true\n\
             deadline-shed = true\n",
        )
        .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.deadline_context, Some(0.05));
        assert_eq!(rc.deadline_insight, Some(2.5));
        assert!(rc.edf && rc.deadline_shed);
        // Defaults keep the whole deadline discipline off.
        let rc0 = RunConfig::from_kv(&Kv::default()).unwrap();
        assert!(rc0.deadline_context.is_none() && rc0.deadline_insight.is_none());
        assert!(!rc0.edf && !rc0.deadline_shed);
        // Bare CLI flags (`--edf`) arrive as `edf = true` via apply_cli.
        let mut flags = Kv::default();
        flags.apply_cli(&["--edf".to_string(), "--deadline-shed".to_string()]).unwrap();
        let rcf = RunConfig::from_kv(&flags).unwrap();
        assert!(rcf.edf && rcf.deadline_shed);
        // Type and range errors are hard.
        assert!(RunConfig::from_kv(&Kv::parse("deadline-context = soon\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("deadline-insight = 0\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("deadline-context = -1\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("deadline-context = NaN\n").unwrap()).is_err());
        assert!(RunConfig::from_kv(&Kv::parse("edf = maybe\n").unwrap()).is_err());
        // `inf` spells "no deadline" and is accepted.
        let inf = RunConfig::from_kv(&Kv::parse("deadline-insight = inf\n").unwrap()).unwrap();
        assert_eq!(inf.deadline_insight, Some(f64::INFINITY));
    }
}
