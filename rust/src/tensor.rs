//! Host tensors exchanged with the PJRT runtime and across the (simulated)
//! radio link.  Deliberately minimal: row-major `f32`/`i32` with shape.

use anyhow::{bail, Result};

/// A row-major host tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor::I32 { shape, data })
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    /// Bytes on the (real) wire before the paper-scale wire model is applied.
    pub fn nbytes(&self) -> usize {
        self.len() * 4
    }

    /// Convert to an XLA literal for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read back from an XLA literal (f32 or i32 arrays).
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Self> {
        match lit.ty()? {
            xla::ElementType::F32 => Tensor::f32(shape, lit.to_vec::<f32>()?),
            xla::ElementType::S32 => Tensor::i32(shape, lit.to_vec::<i32>()?),
            other => bail!("unsupported literal element type {:?}", other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![2], vec![1, 2, 3]).is_err());
    }

    #[test]
    fn roundtrip_shapes() {
        let t = Tensor::f32(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.shape(), &[4, 2]);
        assert_eq!(t.len(), 8);
        assert_eq!(t.nbytes(), 32);
    }

    #[test]
    fn zeros_builder() {
        let t = Tensor::zeros_f32(vec![3, 3]);
        assert_eq!(t.len(), 9);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
