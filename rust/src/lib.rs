//! AVERY — Intent-Driven Adaptive VLM Split Computing (rust coordinator, L3).
//!
//! This crate is the runtime half of the three-layer reproduction described
//! in `DESIGN.md`: python/JAX trains and AOT-lowers the "mini-LISA" VLM into
//! HLO-text artifacts (`make artifacts`); this crate loads those artifacts
//! through the PJRT CPU client (`runtime`), and implements the paper's
//! system contribution on top:
//!
//! * [`coordinator`] — operator-intent classification, the System LUT
//!   (Table 3) and the Split Controller (Algorithm 1).
//! * [`streams`] — the dual-stream scheduler: per-UAV mission state
//!   machines (a high-frequency Context loop and a low-frequency Insight
//!   loop) over a shared virtual clock, plus the fleet scheduler that
//!   drives N heterogeneous UAVs in global event order.
//! * [`netsim`] — the scripted disaster-zone bandwidth trace and link model
//!   (8–20 Mbps, stable / volatile / sustained-drop phases plus blackout
//!   and satellite-sawtooth regimes), including the contended multi-UAV
//!   shared uplink.
//! * [`scenario`] — the scenario library: named disaster/network regimes
//!   (Markov-modulated switching, outages, satellite handoffs) with timed
//!   operator intent schedules and fleet composition (`avery scenario`).
//! * [`energy`] — the Jetson AGX Xavier (MODE_30W_ALL) latency/energy model
//!   calibrated to the paper's published split-point profile.
//! * [`packet`] — the wire format: int8-quantized bottleneck codes + CLIP
//!   features with CRC32 integrity.
//! * [`baselines`] — static tiers, raw-image-compression offload, full-edge
//!   and cloud-only execution.
//! * [`mission`] — the Mission API: every table/figure of the paper's
//!   evaluation (Table 3, Figures 7–10, headline claims) plus the
//!   fleet-scale and scenario missions behind one `Mission` trait and a
//!   registry (`avery run <name>` / `avery list` / `avery all`), served by
//!   the concurrent [`cloud`] worker pool.
//! * [`report`] — the structured `Report` every mission returns (scalars,
//!   tables, CSV series, notes) with pluggable stdout/CSV/JSON sinks.
//!
//! Python never runs on any path in this crate; the binary is self-contained
//! once `artifacts/` exists — and the control plane (controller, netsim,
//! scheduler, scenario library) additionally runs with **no artifacts at
//! all** through the synthetic closed-form engine
//! ([`runtime::Engine::synthetic`] / `Env::synthetic`).

pub mod baselines;
pub mod bench;
pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod edge;
pub mod energy;
pub mod eval;
pub mod faults;
pub mod manifest;
pub mod mission;
pub mod netsim;
pub mod packet;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod streams;
pub mod telemetry;
pub mod tensor;
pub mod transport;
pub mod util;

/// Repo-relative default artifact directory (overridable via `--artifacts`).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Locate the artifacts directory: explicit arg, `AVERY_ARTIFACTS` env var,
/// or walk up from the current directory looking for `artifacts/manifest.txt`.
pub fn find_artifacts(explicit: Option<&str>) -> anyhow::Result<std::path::PathBuf> {
    if let Some(p) = explicit {
        return Ok(std::path::PathBuf::from(p));
    }
    if let Ok(p) = std::env::var("AVERY_ARTIFACTS") {
        return Ok(std::path::PathBuf::from(p));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS);
        if cand.join("manifest.txt").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            anyhow::bail!(
                "artifacts/manifest.txt not found — run `make artifacts` first \
                 (or set AVERY_ARTIFACTS)"
            );
        }
    }
}
