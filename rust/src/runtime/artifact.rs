//! Interned artifact names — the allocation-free half of the direct-dispatch
//! execution backend.
//!
//! Every hot-path execute used to build its artifact name with `format!`
//! (`head_sp{k}_{tier}`) and then `to_string` it again into the engine
//! request — two heap allocations per packet before any work happened.  The
//! artifact namespace is tiny and closed (head/tail × split × tier, plus the
//! context pair and the full-pipeline baseline), so this module precomputes
//! every name as a `&'static str` at compile time and maps hot names to
//! dense *stat slots* so the inline synthetic backend can keep per-artifact
//! [`super::ExecStats`] in plain atomics instead of a locked map.
//!
//! Splits above [`MAX_STATIC_SPLIT`] simply fall back to the old `format!`
//! path (see [`crate::edge::head_artifact_name`]) — correctness never
//! depends on the table.

use crate::coordinator::TierId;

/// Highest split index with a precomputed static name.  Paper depth is 8;
/// 16 leaves generous headroom for deeper manifests.
pub const MAX_STATIC_SPLIT: usize = 16;

const N_TIERS: usize = 3;

macro_rules! tier_names {
    ($prefix:tt, $k:tt) => {
        [
            concat!($prefix, $k, "_high_accuracy"),
            concat!($prefix, $k, "_balanced"),
            concat!($prefix, $k, "_high_throughput"),
        ]
    };
}

macro_rules! split_table {
    ($prefix:tt) => {
        [
            tier_names!($prefix, 0),
            tier_names!($prefix, 1),
            tier_names!($prefix, 2),
            tier_names!($prefix, 3),
            tier_names!($prefix, 4),
            tier_names!($prefix, 5),
            tier_names!($prefix, 6),
            tier_names!($prefix, 7),
            tier_names!($prefix, 8),
            tier_names!($prefix, 9),
            tier_names!($prefix, 10),
            tier_names!($prefix, 11),
            tier_names!($prefix, 12),
            tier_names!($prefix, 13),
            tier_names!($prefix, 14),
            tier_names!($prefix, 15),
            tier_names!($prefix, 16),
        ]
    };
}

static HEAD_NAMES: [[&str; N_TIERS]; MAX_STATIC_SPLIT + 1] = split_table!("head_sp");
static TAIL_NAMES: [[&str; N_TIERS]; MAX_STATIC_SPLIT + 1] = split_table!("tail_sp");

/// Precomputed `head_sp{split}_{tier}`; `None` above [`MAX_STATIC_SPLIT`].
pub fn head_name(split: usize, tier: TierId) -> Option<&'static str> {
    HEAD_NAMES.get(split).map(|row| row[tier.index()])
}

/// Precomputed `tail_sp{split}_{tier}`; `None` above [`MAX_STATIC_SPLIT`].
pub fn tail_name(split: usize, tier: TierId) -> Option<&'static str> {
    TAIL_NAMES.get(split).map(|row| row[tier.index()])
}

/// Map an arbitrary artifact name onto its static interned equivalent, so
/// the engine-thread request can carry a `Cow::Borrowed` instead of an
/// owned `String`.  Unknown names return `None` (caller clones — cold
/// path).  Strictly an identity map: a name that parses but is not
/// byte-equal to its canonical spelling (e.g. `head_sp07_balanced`) is
/// NOT interned — the request must reach the manifest under the exact
/// name the caller used.
pub fn intern_artifact(name: &str) -> Option<&'static str> {
    match name {
        "context_edge" => Some("context_edge"),
        "context_respond" => Some("context_respond"),
        "full_pipeline" => Some("full_pipeline"),
        _ => {
            let (table, rest) = if let Some(r) = name.strip_prefix("head_sp") {
                (&HEAD_NAMES, r)
            } else if let Some(r) = name.strip_prefix("tail_sp") {
                (&TAIL_NAMES, r)
            } else {
                return None;
            };
            let (digits, tier_name) = rest.split_once('_')?;
            let split: usize = digits.parse().ok()?;
            let tier = TierId::from_name(tier_name).ok()?;
            table.get(split).map(|row| row[tier.index()]).filter(|&s| s == name)
        }
    }
}

/// Intern the (closed) weight-set namespace: `shared`/`orig`/`ft`.
pub fn intern_set(set: &str) -> Option<&'static str> {
    match set {
        "shared" => Some("shared"),
        "orig" => Some("orig"),
        "ft" => Some("ft"),
        _ => None,
    }
}

/// Number of dense stat slots the inline backend keeps in atomics:
/// the context pair plus head/tail × split × tier.
pub(crate) const N_STAT_SLOTS: usize = 2 + 2 * N_TIERS * (MAX_STATIC_SPLIT + 1);

/// Dense stat slot of a hot artifact name; `None` routes to the (locked)
/// overflow map — only ever taken by unknown or out-of-table names.
/// Keyed through [`intern_artifact`] so a non-canonical spelling never
/// aliases a canonical name's slot.
pub(crate) fn stat_slot(artifact: &str) -> Option<usize> {
    match intern_artifact(artifact)? {
        "context_edge" => Some(0),
        "context_respond" => Some(1),
        canonical => {
            let (base, rest) = if let Some(r) = canonical.strip_prefix("head_sp") {
                (2, r)
            } else if let Some(r) = canonical.strip_prefix("tail_sp") {
                (2 + N_TIERS * (MAX_STATIC_SPLIT + 1), r)
            } else {
                return None; // full_pipeline: interned but not synthetic-served
            };
            let (digits, tier_name) = rest.split_once('_')?;
            let split: usize = digits.parse().ok()?;
            let tier = TierId::from_name(tier_name).ok()?;
            Some(base + split * N_TIERS + tier.index())
        }
    }
}

/// Inverse of [`stat_slot`] for stats snapshots.
pub(crate) fn stat_slot_name(slot: usize) -> &'static str {
    match slot {
        0 => "context_edge",
        1 => "context_respond",
        s => {
            let s = s - 2;
            let heads = N_TIERS * (MAX_STATIC_SPLIT + 1);
            if s < heads {
                HEAD_NAMES[s / N_TIERS][s % N_TIERS]
            } else {
                let s = s - heads;
                TAIL_NAMES[s / N_TIERS][s % N_TIERS]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_names_match_format() {
        for split in 0..=MAX_STATIC_SPLIT {
            for tier in TierId::ALL {
                assert_eq!(
                    head_name(split, tier).unwrap(),
                    format!("head_sp{split}_{}", tier.name())
                );
                assert_eq!(
                    tail_name(split, tier).unwrap(),
                    format!("tail_sp{split}_{}", tier.name())
                );
            }
        }
        assert!(head_name(MAX_STATIC_SPLIT + 1, TierId::Balanced).is_none());
    }

    #[test]
    fn intern_roundtrips_and_rejects() {
        for name in ["context_edge", "context_respond", "full_pipeline", "head_sp3_balanced",
            "tail_sp8_high_throughput"]
        {
            assert_eq!(intern_artifact(name), Some(name), "{name}");
        }
        assert!(intern_artifact("head_sp99_balanced").is_none());
        assert!(intern_artifact("head_spX_balanced").is_none());
        assert!(intern_artifact("bogus").is_none());
        // Parsable but non-canonical spellings must NOT be canonicalized:
        // the request has to reach the manifest under the caller's name.
        assert!(intern_artifact("head_sp07_balanced").is_none());
        assert!(intern_artifact("tail_sp+1_balanced").is_none());
        assert!(stat_slot("head_sp07_balanced").is_none());
        assert_eq!(intern_set("ft"), Some("ft"));
        assert!(intern_set("custom").is_none());
    }

    #[test]
    fn stat_slots_are_dense_and_invertible() {
        let mut seen = vec![false; N_STAT_SLOTS];
        for name in ["context_edge", "context_respond"] {
            let slot = stat_slot(name).unwrap();
            assert_eq!(stat_slot_name(slot), name);
            seen[slot] = true;
        }
        for split in 0..=MAX_STATIC_SPLIT {
            for tier in TierId::ALL {
                for name in [head_name(split, tier).unwrap(), tail_name(split, tier).unwrap()] {
                    let slot = stat_slot(name).unwrap();
                    assert!(slot < N_STAT_SLOTS, "{name} -> {slot}");
                    assert!(!seen[slot], "slot collision at {name}");
                    assert_eq!(stat_slot_name(slot), name);
                    seen[slot] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "unreachable stat slots");
        assert!(stat_slot("full_pipeline").is_none());
        assert!(stat_slot("head_sp17_balanced").is_none());
    }
}
