//! Closed-form synthetic execution backend — the artifact-free sim path.
//!
//! [`crate::runtime::Engine::synthetic`] serves the exact artifact surface
//! the missions call (`head_sp{k}_{tier}`, `tail_sp{k}_{tier}`,
//! `context_edge`, `context_respond`) without PJRT, HLO text or trained
//! weights, so every control-plane test (fleet determinism, N=1 parity,
//! mission smoke, scenario missions) runs under plain `cargo test -q` on a
//! fresh checkout.  Golden/PJRT parity tests remain artifact-gated — this
//! module simulates *numerics*, it does not reproduce them.
//!
//! The model is deliberately simple and fully deterministic (pure functions
//! of the request — no interior state, so the concurrent [`CloudPool`]
//! serves identical results regardless of worker interleaving):
//!
//! * Synthetic scenes ([`crate::dataset::Dataset::synthetic`]) encode their
//!   GT masks into the image channels (channel c = mask of class c).
//! * The head recovers the per-class planes as a tanh-bounded "code"
//!   (±1 per pixel) and summarizes presence into the CLIP row per class:
//!   `[mask_fraction, presence_flag, 0.25, 0]`.
//! * The tail grounds the mask to the prompt's target class (recovered from
//!   the hashed token ids via
//!   [`crate::coordinator::target_class_of_tokens`]) and flips a
//!   tier/weight-set-dependent fraction of pixels, reproducing Table 3's
//!   fidelity ordering: High-Accuracy > Balanced > High-Throughput, and
//!   fine-tuned ("ft") > original ("orig").
//! * The context responder answers presence from the CLIP flags with a
//!   small deterministic error rate.
//!
//! [`CloudPool`]: crate::cloud::CloudPool

use anyhow::{bail, Result};

use crate::coordinator::{target_class_of_tokens, TierId};
use crate::tensor::Tensor;

/// Per-pixel flip probability of the synthetic tail, by tier: preserves the
/// LUT's fidelity ordering (HA > BAL > HT) in measured IoU.
fn flip_prob(tier: TierId, set: &str) -> f64 {
    let base = match tier {
        TierId::HighAccuracy => 0.015,
        TierId::Balanced => 0.035,
        TierId::HighThroughput => 0.06,
    };
    // Fine-tuned weights are modestly better on everything (Table 3's ft
    // column trails orig only because flood scenes are harder; here the
    // set is the only knob, so ft simply flips less).
    if set == "ft" {
        base * 0.8
    } else {
        base
    }
}

/// splitmix64 finalizer — stateless position hashing for deterministic
/// pseudo-noise (never draw from a stateful RNG here: results must be a
/// pure function of the request).
fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform [0,1) from a hash.
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// FNV-ish fold of a weight-set name into hash salt.
fn set_salt(set: &str) -> u64 {
    set.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
    })
}

/// Content salt over a code plane: different scenes flip different pixels.
fn code_salt(code: &[f32]) -> u64 {
    code.iter()
        .enumerate()
        .take(64)
        .fold(0u64, |h, (i, &v)| h ^ (((v > 0.0) as u64) << (i % 64)))
        ^ code.len() as u64
}

/// Parse `head_sp{split}_{tier}` / `tail_sp{split}_{tier}`.
fn parse_split_tier(rest: &str) -> Result<(usize, TierId)> {
    let Some((digits, tier_name)) = rest.split_once('_') else {
        bail!("malformed artifact suffix `{rest}`");
    };
    let split: usize = digits.parse()?;
    let tier = TierId::from_name(tier_name)?;
    Ok((split, tier))
}

/// A resolved artifact name: what the closed-form model will run.  Resolving
/// once per *batch* (instead of once per request) is the inline backend's
/// share of the micro-batching win — see [`execute_synthetic_batch`].
enum SynthOp {
    Head,
    Tail(TierId),
    ContextEdge,
    ContextRespond,
}

/// Resolve an artifact name to its closed-form operation.  Error cases and
/// messages match the pre-batching single-request path exactly.
fn resolve_op(artifact: &str) -> Result<SynthOp> {
    if let Some(rest) = artifact.strip_prefix("head_sp") {
        let (_split, _tier) = parse_split_tier(rest)?;
        return Ok(SynthOp::Head);
    }
    if let Some(rest) = artifact.strip_prefix("tail_sp") {
        let (_split, tier) = parse_split_tier(rest)?;
        return Ok(SynthOp::Tail(tier));
    }
    match artifact {
        "context_edge" => Ok(SynthOp::ContextEdge),
        "context_respond" => Ok(SynthOp::ContextRespond),
        other => bail!("synthetic engine has no artifact `{other}`"),
    }
}

/// Validate an (img, img, 3) scene image and return its side length.
fn scene_side(image: &Tensor) -> Result<usize> {
    let shape = image.shape();
    if shape.len() != 3 || shape[2] != 3 || shape[0] != shape[1] {
        bail!("synthetic head wants (img, img, 3) image, got {shape:?}");
    }
    Ok(shape[0])
}

/// Per-class on-pixel counts of an (img, img, 3) scene, read straight from
/// the interleaved channels — the packet hot path allocates no intermediate
/// plane buffers (the old `planes()` cost two `Vec`s per call).
fn plane_counts(data: &[f32], n: usize) -> (usize, usize) {
    let (mut on0, mut on1) = (0usize, 0usize);
    for i in 0..n {
        on0 += (data[i * 3] > 0.5) as usize;
        on1 += (data[i * 3 + 1] > 0.5) as usize;
    }
    (on0, on1)
}

/// CLIP summary rows `(2, 4)`: `[fraction, presence flag, 0.25, 0]` per
/// class.  The constant third column keeps the per-packet quantizer scale
/// bounded away from zero even for empty scenes.
fn clip_rows(on0: usize, on1: usize, n: usize) -> Result<Tensor> {
    let row = |on: usize| {
        let frac = on as f32 / n.max(1) as f32;
        let flag = if on > 0 { 1.0f32 } else { 0.0 };
        [frac, flag, 0.25, 0.0]
    };
    let (a, b) = (row(on0), row(on1));
    Tensor::f32(vec![2, 4], a.iter().chain(b.iter()).copied().collect())
}

/// Serve one synthetic execution request.  Artifact names match aot.py's.
///
/// Allocation discipline: this runs inline in the caller's thread on every
/// simulated packet, so the only `Vec`s built here are the ones the output
/// [`Tensor`]s must own — no intermediate plane/scratch buffers.
pub fn execute_synthetic(artifact: &str, set: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    run_op(&resolve_op(artifact)?, set, inputs)
}

/// Serve a micro-batch of compatible requests (same artifact + weight set):
/// the artifact name is resolved once, then the pure closed-form kernel
/// loops over the batch.  Results are element-for-element identical to
/// calling [`execute_synthetic`] once per request (pinned by
/// `rust/tests/serving.rs`); any failing element fails the whole batch.
pub fn execute_synthetic_batch(
    artifact: &str,
    set: &str,
    batches: &[&[Tensor]],
) -> Result<Vec<Vec<Tensor>>> {
    let op = resolve_op(artifact)?;
    batches.iter().map(|inputs| run_op(&op, set, inputs)).collect()
}

/// Run one resolved closed-form operation.
fn run_op(op: &SynthOp, set: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if matches!(op, SynthOp::Head) {
        if inputs.len() != 1 {
            bail!("head wants 1 input, got {}", inputs.len());
        }
        let img = scene_side(&inputs[0])?;
        let data = inputs[0].as_f32()?;
        let n = img * img;
        let mut code = vec![0.0f32; 2 * n];
        let (mut on0, mut on1) = (0usize, 0usize);
        for i in 0..n {
            let a = data[i * 3] > 0.5;
            let b = data[i * 3 + 1] > 0.5;
            on0 += a as usize;
            on1 += b as usize;
            code[i] = if a { 1.0 } else { -1.0 };
            code[n + i] = if b { 1.0 } else { -1.0 };
        }
        let clip = clip_rows(on0, on1, n)?;
        let pooled = Tensor::f32(
            vec![1, 4],
            vec![on0 as f32 / n as f32, on1 as f32 / n as f32, 0.0, 0.0],
        )?;
        return Ok(vec![Tensor::f32(vec![2, n], code)?, clip, pooled]);
    }

    if let SynthOp::Tail(tier) = op {
        let tier = *tier;
        if inputs.len() != 3 {
            bail!("tail wants (code, clip, prompt_ids), got {} inputs", inputs.len());
        }
        let code = inputs[0].as_f32()?;
        let clip = inputs[1].as_f32()?;
        let pids = inputs[2].as_i32()?;
        let cshape = inputs[0].shape();
        if cshape.len() != 2 || cshape[0] != 2 {
            bail!("synthetic tail wants (2, img*img) code, got {cshape:?}");
        }
        let n = cshape[1];
        let img = (n as f64).sqrt().round() as usize;
        if img * img != n {
            bail!("code plane length {n} is not square");
        }
        let cls = target_class_of_tokens(pids);
        let p = flip_prob(tier, set);
        let salt = code_salt(code) ^ set_salt(set) ^ ((tier.index() as u64) << 56);
        let mut logits = vec![0.0f32; n];
        for (i, logit) in logits.iter_mut().enumerate() {
            let base = match cls {
                Some(0) => code[i],
                Some(_) => code[n + i],
                // Ungrounded prompt: union of both classes.
                None => code[i].max(code[n + i]),
            };
            // Tier-dependent degradation: flip a deterministic pseudo-random
            // pixel subset (sign flip crosses the IoU threshold at 0).
            let flip = unit(hash64(salt ^ i as u64)) < p;
            *logit = if flip { -base } else { base };
        }
        let presence: Vec<f32> = (0..2)
            .map(|c| if clip[c * 4 + 1] > 0.5 { 1.0 } else { -1.0 })
            .collect();
        return Ok(vec![
            Tensor::f32(vec![img, img], logits)?,
            Tensor::f32(vec![2], presence)?,
        ]);
    }

    match op {
        SynthOp::ContextEdge => {
            if inputs.len() != 1 {
                bail!("context_edge wants 1 input, got {}", inputs.len());
            }
            let img = scene_side(&inputs[0])?;
            let n = img * img;
            let (on0, on1) = plane_counts(inputs[0].as_f32()?, n);
            Ok(vec![clip_rows(on0, on1, n)?])
        }
        SynthOp::ContextRespond => {
            if inputs.len() != 2 {
                bail!("context_respond wants (clip, prompt_ids), got {}", inputs.len());
            }
            let clip = inputs[0].as_f32()?;
            if clip.len() < 8 {
                bail!("context_respond wants (2, 4) clip, got {} values", clip.len());
            }
            // Presence from the flags, with a small deterministic error rate
            // (the text responder is not an oracle).
            let err = if set == "ft" { 0.02 } else { 0.03 };
            let salt = clip
                .iter()
                .fold(0u64, |h, &v| hash64(h ^ v.to_bits() as u64))
                ^ set_salt(set);
            let presence: Vec<f32> = (0..2)
                .map(|c| {
                    let truth = clip[c * 4 + 1] > 0.5;
                    let wrong = unit(hash64(salt ^ ((c as u64) << 32))) < err;
                    if truth != wrong {
                        1.0
                    } else {
                        -1.0
                    }
                })
                .collect();
            Ok(vec![Tensor::f32(vec![2], presence)?])
        }
        // Handled by the early returns above.
        SynthOp::Head | SynthOp::Tail(_) => unreachable!("handled above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tokenize;

    /// A 4x4 scene: class 0 mask fills the top half, class 1 empty.
    fn scene_image() -> Tensor {
        let img = 4;
        let mut data = vec![0.0f32; img * img * 3];
        for i in 0..img * img / 2 {
            data[i * 3] = 1.0;
        }
        Tensor::f32(vec![img, img, 3], data).unwrap()
    }

    #[test]
    fn head_tail_roundtrip_recovers_mask() {
        let outs = execute_synthetic("head_sp1_high_accuracy", "shared", &[scene_image()])
            .unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].shape(), &[2, 16]);
        assert_eq!(outs[1].shape(), &[2, 4]);
        let pids = Tensor::i32(vec![16], tokenize("highlight the stranded people")).unwrap();
        let tail = execute_synthetic(
            "tail_sp1_high_accuracy",
            "orig",
            &[outs[0].clone(), outs[1].clone(), pids],
        )
        .unwrap();
        let logits = tail[0].as_f32().unwrap();
        assert_eq!(tail[0].shape(), &[4, 4]);
        // Top half mostly positive, bottom half mostly negative (<= a few
        // tier flips out of 16 pixels).
        let top_pos = logits[..8].iter().filter(|&&v| v > 0.0).count();
        let bot_neg = logits[8..].iter().filter(|&&v| v < 0.0).count();
        assert!(top_pos >= 6, "top {top_pos}/8 positive");
        assert!(bot_neg >= 6, "bottom {bot_neg}/8 negative");
        // Presence: class 0 present, class 1 absent.
        let presence = tail[1].as_f32().unwrap();
        assert!(presence[0] > 0.0 && presence[1] < 0.0, "presence {presence:?}");
    }

    #[test]
    fn deterministic_across_calls() {
        let head = execute_synthetic("head_sp1_balanced", "shared", &[scene_image()]).unwrap();
        let pids = Tensor::i32(vec![16], tokenize("mark the submerged vehicles")).unwrap();
        let a = execute_synthetic(
            "tail_sp1_balanced",
            "ft",
            &[head[0].clone(), head[1].clone(), pids.clone()],
        )
        .unwrap();
        let b = execute_synthetic(
            "tail_sp1_balanced",
            "ft",
            &[head[0].clone(), head[1].clone(), pids],
        )
        .unwrap();
        assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
        assert_eq!(a[1].as_f32().unwrap(), b[1].as_f32().unwrap());
    }

    #[test]
    fn fidelity_orders_by_tier() {
        // Flip probabilities must preserve Table 3's ordering.
        for set in ["orig", "ft"] {
            assert!(
                flip_prob(TierId::HighAccuracy, set) < flip_prob(TierId::Balanced, set)
            );
            assert!(
                flip_prob(TierId::Balanced, set) < flip_prob(TierId::HighThroughput, set)
            );
        }
        assert!(flip_prob(TierId::Balanced, "ft") < flip_prob(TierId::Balanced, "orig"));
    }

    #[test]
    fn context_path_answers_presence() {
        let outs = execute_synthetic("context_edge", "shared", &[scene_image()]).unwrap();
        assert_eq!(outs.len(), 1);
        let pids = Tensor::i32(vec![16], tokenize("what is happening in this sector"))
            .unwrap();
        let resp =
            execute_synthetic("context_respond", "ft", &[outs[0].clone(), pids]).unwrap();
        assert_eq!(resp[0].shape(), &[2]);
    }

    #[test]
    fn unknown_artifact_rejected() {
        assert!(execute_synthetic("bogus", "shared", &[]).is_err());
        assert!(execute_synthetic("head_spX_balanced", "shared", &[scene_image()]).is_err());
        assert!(execute_synthetic_batch("bogus", "shared", &[]).is_err());
    }

    #[test]
    fn batch_matches_sequential_execution() {
        let a = [scene_image()];
        let mut flipped = vec![0.0f32; 4 * 4 * 3];
        for i in 8..16 {
            flipped[i * 3 + 1] = 1.0;
        }
        let b = [Tensor::f32(vec![4, 4, 3], flipped).unwrap()];
        let batch = execute_synthetic_batch("head_sp1_balanced", "shared", &[&a, &b]).unwrap();
        assert_eq!(batch.len(), 2);
        for (inputs, outs) in [(&a[..], &batch[0]), (&b[..], &batch[1])] {
            let single = execute_synthetic("head_sp1_balanced", "shared", inputs).unwrap();
            assert_eq!(&single, outs);
        }
        // An empty batch resolves the artifact but runs nothing.
        assert!(execute_synthetic_batch("head_sp1_balanced", "shared", &[])
            .unwrap()
            .is_empty());
        // One bad element fails the whole batch.
        assert!(execute_synthetic_batch("head_sp1_balanced", "shared", &[&a, &[]]).is_err());
    }
}
