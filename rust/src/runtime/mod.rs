//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! XLA handles (`PjRtClient`, `PjRtLoadedExecutable`, `Literal`) are
//! `Rc`-based and therefore `!Send`, so all PJRT state lives on a dedicated
//! **engine thread**; the rest of the system talks to it through an mpsc
//! request channel via the cloneable [`Engine`] handle.  Artifacts are
//! compiled lazily on first use and cached; weight binaries are uploaded to
//! device buffers once per (artifact, weight-set) and reused by every call
//! (`execute_b`), so the steady-state request path moves only the runtime
//! inputs.
//!
//! [`Engine::synthetic`] swaps the PJRT worker for the closed-form model in
//! [`synth`] — the artifact-free sim path used by `Env::synthetic`, the
//! scenario CLI fallback and the un-gated control-plane tests.

mod engine;
mod loader;
mod synth;

pub use engine::{Engine, ExecMode, ExecStats};
pub use loader::{load_weight_tensors, WeightFile};
pub use synth::execute_synthetic;
