//! Execution runtime: the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and the artifact-free closed-form model, behind
//! one backend-agnostic [`Engine`] handle.
//!
//! Two backends (see DESIGN.md "Execution backends & parallel runner"):
//!
//! * **PJRT, threaded** — XLA handles (`PjRtClient`,
//!   `PjRtLoadedExecutable`, `Literal`) are `Rc`-based and therefore
//!   `!Send`, so all PJRT state lives on a dedicated **engine thread**; the
//!   rest of the system talks to it through an mpsc request channel whose
//!   envelopes carry interned (`&'static str`) artifact/set names — no
//!   per-call `String`s.  Artifacts are compiled lazily on first use and
//!   cached; weight binaries are uploaded to device buffers once per
//!   (artifact, weight-set) and reused by every call (`execute_b`), so the
//!   steady-state request path moves only the runtime inputs.
//! * **Synthetic, inline** — [`Engine::synthetic`] executes the pure
//!   closed-form model in [`synth`] **in the caller's thread**: no engine
//!   thread, no channel round-trip, atomic per-artifact stats.  Clones of
//!   one inline engine execute truly in parallel, which is what makes the
//!   cloud pool and the `--jobs` mission fan-out scale with cores.
//!   [`Engine::synthetic_threaded`] keeps the old single-consumer dispatch
//!   shape for parity tests and queueing-model experiments.

mod artifact;
mod engine;
mod loader;
mod synth;

pub use artifact::{head_name, intern_artifact, intern_set, tail_name, MAX_STATIC_SPLIT};
pub use engine::{Engine, ExecMode, ExecStats};
pub use loader::{load_weight_tensors, WeightFile};
pub use synth::execute_synthetic;
