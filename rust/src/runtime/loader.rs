//! Weight-binary loading: `weights/<artifact>.<set>.bin` is the f32
//! little-endian concatenation of every parameter leaf in exact HLO
//! parameter order (see aot.py `Exporter.export`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::{ArtifactSpec, DType};
use crate::tensor::Tensor;

/// A parsed weight file: one tensor per leading HLO parameter, in order.
#[derive(Debug)]
pub struct WeightFile {
    pub tensors: Vec<Tensor>,
    pub total_bytes: usize,
}

/// Read and split a weight binary according to the artifact's param specs.
pub fn load_weight_tensors(spec: &ArtifactSpec, path: &Path) -> Result<WeightFile> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading weights {}", path.display()))?;
    let want: usize = spec.weight_numel() * 4;
    if bytes.len() != want {
        bail!(
            "weight file {} has {} bytes, manifest wants {} ({} params)",
            path.display(),
            bytes.len(),
            want,
            spec.params.len()
        );
    }
    let mut tensors = Vec::with_capacity(spec.params.len());
    let mut off = 0usize;
    for p in &spec.params {
        let n = p.numel();
        let slice = &bytes[off..off + n * 4];
        off += n * 4;
        match p.dtype {
            DType::F32 => {
                let data: Vec<f32> = slice
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                tensors.push(Tensor::f32(p.dims.clone(), data)?);
            }
            DType::I32 => {
                let data: Vec<i32> = slice
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                tensors.push(Tensor::i32(p.dims.clone(), data)?);
            }
        }
    }
    Ok(WeightFile { tensors, total_bytes: bytes.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::ParamSpec;
    use std::collections::BTreeMap;
    use std::io::Write;

    fn spec_with(params: Vec<ParamSpec>) -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            hlo: "t.hlo.txt".into(),
            weights: BTreeMap::new(),
            params,
            inputs: vec![],
            outputs: vec![],
            golden: BTreeMap::new(),
        }
    }

    #[test]
    fn splits_in_order() {
        let spec = spec_with(vec![
            ParamSpec { name: "a".into(), dtype: DType::F32, dims: vec![2] },
            ParamSpec { name: "b".into(), dtype: DType::F32, dims: vec![1, 3] },
        ]);
        let dir = std::env::temp_dir().join("avery_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        let w = load_weight_tensors(&spec, &path).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.tensors[0].as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(w.tensors[1].as_f32().unwrap(), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn size_mismatch_rejected() {
        let spec = spec_with(vec![ParamSpec {
            name: "a".into(),
            dtype: DType::F32,
            dims: vec![4],
        }]);
        let dir = std::env::temp_dir().join("avery_loader_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        std::fs::write(&path, [0u8; 8]).unwrap();
        assert!(load_weight_tensors(&spec, &path).is_err());
    }
}
