//! The PJRT engine thread and its cloneable [`Engine`] handle.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::manifest::Manifest;
use crate::tensor::Tensor;

use super::loader::load_weight_tensors;

/// How weights reach the device each call — the §Perf lever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Re-marshal weight literals on every execute (naive baseline).
    LiteralsEachCall,
    /// Upload weights once per (artifact, set) as device buffers; each call
    /// uploads only the runtime inputs (steady-state mode).
    PreuploadedBuffers,
}

/// Wall-clock execution statistics per artifact (perf pass instrumentation).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

enum Request {
    Execute {
        artifact: String,
        set: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    Preload {
        artifact: String,
        set: String,
        reply: Sender<Result<()>>,
    },
    Stats {
        reply: Sender<BTreeMap<String, ExecStats>>,
    },
    SetMode(ExecMode),
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct Engine {
    tx: Sender<Request>,
    // Keep the join handle so drop of the *last* Engine shuts the thread down.
    _shared: Arc<EngineShared>,
}

struct EngineShared {
    tx: Sender<Request>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for EngineShared {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl Engine {
    /// Spawn an engine thread backed by the closed-form synthetic model
    /// (`runtime::synth`) — no artifacts, no PJRT.  Serves the same
    /// artifact-name surface as the real engine so missions, the cloud
    /// pool and the fleet scheduler run unmodified; see DESIGN.md
    /// "Scenario library & artifact-free sim path".
    pub fn synthetic() -> Self {
        let (tx, rx) = channel::<Request>();
        let join = std::thread::Builder::new()
            .name("avery-synth".into())
            .spawn(move || synth_worker(rx))
            .expect("spawning synthetic engine thread");
        let shared = Arc::new(EngineShared { tx: tx.clone(), join: Mutex::new(Some(join)) });
        Engine { tx, _shared: shared }
    }

    /// Spawn the engine thread over a manifest. Artifacts compile lazily.
    pub fn start(manifest: Manifest, mode: ExecMode) -> Result<Self> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("avery-pjrt".into())
            .spawn(move || worker(manifest, mode, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx.recv().context("engine thread died during init")??;
        let shared = Arc::new(EngineShared { tx: tx.clone(), join: Mutex::new(Some(join)) });
        Ok(Engine { tx, _shared: shared })
    }

    /// Execute one artifact synchronously with the given weight set.
    pub fn execute(&self, artifact: &str, set: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute {
                artifact: artifact.to_string(),
                set: set.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Compile an artifact and upload its weights ahead of time.
    pub fn preload(&self, artifact: &str, set: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Preload { artifact: artifact.to_string(), set: set.to_string(), reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Per-artifact wall-clock stats (perf pass).
    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        let (reply, rx) = channel();
        if self.tx.send(Request::Stats { reply }).is_err() {
            return BTreeMap::new();
        }
        rx.recv().unwrap_or_default()
    }

    /// Switch weight-delivery mode (affects artifacts loaded afterwards).
    pub fn set_mode(&self, mode: ExecMode) {
        let _ = self.tx.send(Request::SetMode(mode));
    }
}

/// Request loop of the synthetic engine thread: every execute is answered
/// by the deterministic closed-form model; preloads are no-ops.
fn synth_worker(rx: std::sync::mpsc::Receiver<Request>) {
    let mut stats: BTreeMap<String, ExecStats> = BTreeMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::SetMode(_) => {}
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Preload { reply, .. } => {
                let _ = reply.send(Ok(()));
            }
            Request::Execute { artifact, set, inputs, reply } => {
                let t0 = Instant::now();
                let r = super::synth::execute_synthetic(&artifact, &set, &inputs);
                let st = stats.entry(artifact).or_default();
                st.calls += 1;
                st.total_secs += t0.elapsed().as_secs_f64();
                let _ = reply.send(r);
            }
        }
    }
}

/// Engine-thread-local state for one compiled artifact.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    /// set name -> weight literals (LiteralsEachCall mode).
    literals: BTreeMap<String, Vec<xla::Literal>>,
    /// set name -> pre-uploaded device buffers (PreuploadedBuffers mode).
    buffers: BTreeMap<String, Vec<xla::PjRtBuffer>>,
}

fn worker(
    manifest: Manifest,
    mode: ExecMode,
    rx: std::sync::mpsc::Receiver<Request>,
    ready: Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    let mut mode = mode;
    let mut cache: BTreeMap<String, Loaded> = BTreeMap::new();
    let mut stats: BTreeMap<String, ExecStats> = BTreeMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::SetMode(m) => mode = m,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Preload { artifact, set, reply } => {
                let r = ensure_loaded(&client, &manifest, &mut cache, &mut stats, &artifact, &set, mode)
                    .map(|_| ());
                let _ = reply.send(r);
            }
            Request::Execute { artifact, set, inputs, reply } => {
                let r = (|| -> Result<Vec<Tensor>> {
                    ensure_loaded(&client, &manifest, &mut cache, &mut stats, &artifact, &set, mode)?;
                    let loaded = cache.get(&artifact).unwrap();
                    let t0 = Instant::now();
                    let outs = run_one(&client, loaded, &set, &inputs, mode)?;
                    let st = stats.entry(artifact.clone()).or_default();
                    st.calls += 1;
                    st.total_secs += t0.elapsed().as_secs_f64();
                    Ok(outs)
                })();
                let _ = reply.send(r);
            }
        }
    }
}

fn ensure_loaded(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &mut BTreeMap<String, Loaded>,
    stats: &mut BTreeMap<String, ExecStats>,
    artifact: &str,
    set: &str,
    mode: ExecMode,
) -> Result<()> {
    if !cache.contains_key(artifact) {
        let spec = manifest.artifact(artifact)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.hlo.to_str().context("hlo path utf8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", spec.hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {artifact}: {e}"))?;
        stats.entry(artifact.to_string()).or_default().compile_secs +=
            t0.elapsed().as_secs_f64();
        cache.insert(
            artifact.to_string(),
            Loaded { exe, literals: BTreeMap::new(), buffers: BTreeMap::new() },
        );
    }
    // Load + (optionally) upload the requested weight set.
    let spec = manifest.artifact(artifact)?;
    let loaded = cache.get_mut(artifact).unwrap();
    if !loaded.literals.contains_key(set) {
        let path = spec
            .weights
            .get(set)
            .with_context(|| format!("artifact {artifact} has no weight set `{set}`"))?;
        let wf = load_weight_tensors(spec, path)?;
        let lits: Vec<xla::Literal> =
            wf.tensors.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        loaded.literals.insert(set.to_string(), lits);
    }
    if mode == ExecMode::PreuploadedBuffers && !loaded.buffers.contains_key(set) {
        let lits = loaded.literals.get(set).unwrap();
        let bufs: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| {
                let b = client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("uploading weights for {artifact}: {e}"))?;
                // Force the async host->device transfer to complete before the
                // buffer is used: the crate exposes no GetReadyFuture, and
                // in-flight transfers racing later compile/execute calls
                // crash inside XLA (ShapeUtil CHECK). One-time cost per
                // (artifact, set).
                b.to_literal_sync()
                    .map_err(|e| anyhow!("syncing weight upload for {artifact}: {e}"))?;
                Ok(b)
            })
            .collect::<Result<_>>()?;
        loaded.buffers.insert(set.to_string(), bufs);
    }
    Ok(())
}

fn run_one(
    client: &xla::PjRtClient,
    loaded: &Loaded,
    set: &str,
    inputs: &[Tensor],
    mode: ExecMode,
) -> Result<Vec<Tensor>> {
    let result = match mode {
        ExecMode::LiteralsEachCall => {
            let mut args: Vec<xla::Literal> = Vec::new();
            for l in loaded.literals.get(set).into_iter().flatten() {
                // Literal has no cheap clone; convert via reshape to same dims.
                let shape = l.array_shape()?;
                args.push(l.reshape(shape.dims())?);
            }
            for t in inputs {
                args.push(t.to_literal()?);
            }
            loaded.exe.execute::<xla::Literal>(&args)?
        }
        ExecMode::PreuploadedBuffers => {
            let weight_bufs = loaded
                .buffers
                .get(set)
                .with_context(|| format!("weight set `{set}` not uploaded"))?;
            let mut args: Vec<&xla::PjRtBuffer> = weight_bufs.iter().collect();
            // The H2D transfer behind buffer_from_host_literal is async and
            // captures a LiteralSlice into OUR literal; neither execute_b
            // nor buffer drop awaits it (the vendored literal-path `execute`
            // does, which is why LiteralsEachCall is unconditionally safe).
            // Dropping the literal while the copy lambda is pending reads a
            // dangling Shape and aborts inside ShapeUtil. Force readiness of
            // every input buffer before releasing its source literal —
            // inputs are small (<= 48 KB), so the extra sync is noise next
            // to the 5 MB weight re-marshal this mode avoids.
            let input_lits: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let input_bufs: Vec<xla::PjRtBuffer> = input_lits
                .iter()
                .map(|lit| {
                    let b = client
                        .buffer_from_host_literal(None, lit)
                        .map_err(|e| anyhow!("uploading input: {e}"))?;
                    b.to_literal_sync()
                        .map_err(|e| anyhow!("syncing input upload: {e}"))?;
                    Ok(b)
                })
                .collect::<Result<Vec<_>>>()?;
            for b in &input_bufs {
                args.push(b);
            }
            let out = loaded.exe.execute_b::<&xla::PjRtBuffer>(&args)?;
            drop(input_bufs);
            drop(input_lits);
            out
        }
    };
    // return_tuple=True => single tuple output literal.
    let lit = result[0][0].to_literal_sync()?;
    let parts = lit.to_tuple()?;
    let mut outs = Vec::with_capacity(parts.len());
    for p in parts {
        let shape = p.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        outs.push(Tensor::from_literal(&p, dims)?);
    }
    Ok(outs)
}
