//! Execution backends behind the cloneable [`Engine`] handle.
//!
//! Two backends serve the same artifact-name surface:
//!
//! * **Inline synthetic** ([`Engine::synthetic`]) — the closed-form model in
//!   [`super::synth`] is pure and stateless, so it executes **in the
//!   caller's thread**: no spawn, no channel round-trip, no per-call
//!   allocation for the request envelope.  Per-artifact [`ExecStats`] live
//!   in dense atomic slots (see [`super::artifact`]), so clones of one
//!   inline engine execute truly in parallel from any number of threads —
//!   this is what lets [`crate::cloud::CloudPool`] workers and the `--jobs`
//!   mission fan-out scale with cores instead of serializing behind one
//!   engine thread.
//! * **Threaded** ([`Engine::start`] for PJRT, [`Engine::synthetic_threaded`]
//!   for the queueing-model synthetic) — XLA handles (`PjRtClient`,
//!   `Literal`) are `Rc`-based and `!Send`, so all PJRT state stays on a
//!   dedicated engine thread reached over an mpsc request channel.  Request
//!   envelopes carry `Cow<'static, str>` names: the closed artifact/set
//!   namespace is interned, so the steady-state path sends no owned
//!   `String`s either.
//!
//! `EdgePipeline`, `CloudServer`/`CloudPool`, missions and transports are
//! backend-agnostic — they only see [`Engine`].

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::manifest::Manifest;
use crate::tensor::Tensor;

use super::artifact::{intern_artifact, intern_set, stat_slot, stat_slot_name, N_STAT_SLOTS};
use super::loader::load_weight_tensors;

/// How weights reach the device each call — the §Perf lever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Re-marshal weight literals on every execute (naive baseline).
    LiteralsEachCall,
    /// Upload weights once per (artifact, set) as device buffers; each call
    /// uploads only the runtime inputs (steady-state mode).
    PreuploadedBuffers,
}

/// Wall-clock execution statistics per artifact (perf pass instrumentation).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Borrow a stats entry without allocating on the hot path (the name is
/// cloned only on an artifact's first call).
fn stats_mut<'a>(stats: &'a mut BTreeMap<String, ExecStats>, name: &str) -> &'a mut ExecStats {
    if !stats.contains_key(name) {
        stats.insert(name.to_string(), ExecStats::default());
    }
    stats.get_mut(name).unwrap()
}

enum Request {
    Execute {
        artifact: Cow<'static, str>,
        set: Cow<'static, str>,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    /// A micro-batch of compatible requests (same artifact + set) crossing
    /// the channel as ONE envelope — the threaded backend's share of the
    /// serving-layer batching win: one round-trip per batch instead of one
    /// per request (see DESIGN.md "Cloud serving layer").
    ExecuteBatch {
        artifact: Cow<'static, str>,
        set: Cow<'static, str>,
        batches: Vec<Vec<Tensor>>,
        reply: Sender<Result<Vec<Vec<Tensor>>>>,
    },
    Preload {
        artifact: Cow<'static, str>,
        set: Cow<'static, str>,
        reply: Sender<Result<()>>,
    },
    Stats {
        reply: Sender<BTreeMap<String, ExecStats>>,
    },
    SetMode(ExecMode),
    Shutdown,
}

/// Intern a request field: the closed artifact/set namespace borrows, an
/// unknown name (cold path) clones.
fn interned(name: &str, table: fn(&str) -> Option<&'static str>) -> Cow<'static, str> {
    match table(name) {
        Some(s) => Cow::Borrowed(s),
        None => Cow::Owned(name.to_string()),
    }
}

/// Cloneable handle over one execution backend.
#[derive(Clone)]
pub struct Engine {
    backend: Backend,
}

#[derive(Clone)]
enum Backend {
    /// Caller-thread synthetic execution over shared atomic stats.
    Inline(Arc<InlineSynth>),
    /// Dedicated engine thread reached over an mpsc channel.
    Threaded(ThreadedHandle),
}

/// Shared state of the inline synthetic backend: only the statistics —
/// execution itself is pure.
struct InlineSynth {
    calls: [AtomicU64; N_STAT_SLOTS],
    nanos: [AtomicU64; N_STAT_SLOTS],
    /// Overflow for names outside the dense slot table (unknown artifacts,
    /// splits beyond the static range) — never hit on the packet hot path.
    other: Mutex<BTreeMap<String, ExecStats>>,
}

impl InlineSynth {
    fn new() -> Self {
        Self {
            calls: std::array::from_fn(|_| AtomicU64::new(0)),
            nanos: std::array::from_fn(|_| AtomicU64::new(0)),
            other: Mutex::new(BTreeMap::new()),
        }
    }

    fn execute(&self, artifact: &str, set: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let r = super::synth::execute_synthetic(artifact, set, inputs);
        let dt = t0.elapsed().as_nanos() as u64;
        match stat_slot(artifact) {
            Some(slot) => {
                self.calls[slot].fetch_add(1, Ordering::Relaxed);
                self.nanos[slot].fetch_add(dt, Ordering::Relaxed);
            }
            None => {
                let mut other = self.other.lock().unwrap();
                let st = stats_mut(&mut other, artifact);
                st.calls += 1;
                st.total_secs += dt as f64 / 1e9;
            }
        }
        r
    }

    /// Batched inline execution: the closed-form kernel loops over the
    /// batch with the artifact name resolved once and ONE stats update for
    /// the whole batch (single `Instant::now` pair + one atomic add per
    /// counter instead of per request).
    fn execute_batch(
        &self,
        artifact: &str,
        set: &str,
        batches: &[&[Tensor]],
    ) -> Result<Vec<Vec<Tensor>>> {
        let t0 = Instant::now();
        let r = super::synth::execute_synthetic_batch(artifact, set, batches);
        let dt = t0.elapsed().as_nanos() as u64;
        let n = batches.len() as u64;
        match stat_slot(artifact) {
            Some(slot) => {
                self.calls[slot].fetch_add(n, Ordering::Relaxed);
                self.nanos[slot].fetch_add(dt, Ordering::Relaxed);
            }
            None => {
                let mut other = self.other.lock().unwrap();
                let st = stats_mut(&mut other, artifact);
                st.calls += n;
                st.total_secs += dt as f64 / 1e9;
            }
        }
        r
    }

    fn snapshot(&self) -> BTreeMap<String, ExecStats> {
        let mut map = self.other.lock().unwrap().clone();
        for slot in 0..N_STAT_SLOTS {
            let calls = self.calls[slot].load(Ordering::Relaxed);
            if calls == 0 {
                continue;
            }
            let total_secs = self.nanos[slot].load(Ordering::Relaxed) as f64 / 1e9;
            map.insert(
                stat_slot_name(slot).to_string(),
                ExecStats { calls, total_secs, compile_secs: 0.0 },
            );
        }
        map
    }
}

#[derive(Clone)]
struct ThreadedHandle {
    tx: Sender<Request>,
    // Keep the join handle so drop of the *last* handle shuts the thread down.
    _shared: Arc<EngineShared>,
}

struct EngineShared {
    tx: Sender<Request>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for EngineShared {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl ThreadedHandle {
    fn spawn(
        name: &str,
        worker: impl FnOnce(std::sync::mpsc::Receiver<Request>) + Send + 'static,
    ) -> Result<Self> {
        let (tx, rx) = channel::<Request>();
        let join = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || worker(rx))
            .with_context(|| format!("spawning {name} thread"))?;
        let shared = Arc::new(EngineShared { tx: tx.clone(), join: Mutex::new(Some(join)) });
        Ok(Self { tx, _shared: shared })
    }

    fn execute_owned(
        &self,
        artifact: &str,
        set: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute {
                artifact: interned(artifact, intern_artifact),
                set: interned(set, intern_set),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    fn execute_batch_owned(
        &self,
        artifact: &str,
        set: &str,
        batches: Vec<Vec<Tensor>>,
    ) -> Result<Vec<Vec<Tensor>>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::ExecuteBatch {
                artifact: interned(artifact, intern_artifact),
                set: interned(set, intern_set),
                batches,
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    fn preload(&self, artifact: &str, set: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Preload {
                artifact: interned(artifact, intern_artifact),
                set: interned(set, intern_set),
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    fn stats(&self) -> BTreeMap<String, ExecStats> {
        let (reply, rx) = channel();
        if self.tx.send(Request::Stats { reply }).is_err() {
            return BTreeMap::new();
        }
        rx.recv().unwrap_or_default()
    }
}

impl Engine {
    /// The inline synthetic backend — no artifacts, no PJRT, no engine
    /// thread: every execute runs the closed-form model
    /// (`runtime::synth`) in the caller's thread.  Serves the same
    /// artifact-name surface as the real engine so missions, the cloud
    /// pool and the fleet scheduler run unmodified; see DESIGN.md
    /// "Execution backends & parallel runner".
    pub fn synthetic() -> Self {
        Engine { backend: Backend::Inline(Arc::new(InlineSynth::new())) }
    }

    /// The synthetic model behind a dedicated engine thread — the
    /// pre-backend-split dispatch shape, kept for inline/threaded parity
    /// tests and as an explicit single-consumer queueing model.
    pub fn synthetic_threaded() -> Self {
        let handle = ThreadedHandle::spawn("avery-synth", synth_worker)
            .expect("spawning synthetic engine thread");
        Engine { backend: Backend::Threaded(handle) }
    }

    /// Spawn the PJRT engine thread over a manifest. Artifacts compile
    /// lazily.
    pub fn start(manifest: Manifest, mode: ExecMode) -> Result<Self> {
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = ThreadedHandle::spawn("avery-pjrt", move |rx| {
            worker(manifest, mode, rx, ready_tx)
        })?;
        ready_rx.recv().context("engine thread died during init")??;
        Ok(Engine { backend: Backend::Threaded(handle) })
    }

    /// True when executes run inline in the caller's thread (no channel
    /// round-trip) — the property [`crate::cloud::CloudPool::process_sync`]
    /// exploits for its direct-call fast path.
    pub fn is_inline(&self) -> bool {
        matches!(self.backend, Backend::Inline(_))
    }

    /// Execute one artifact synchronously with the given weight set.
    /// Inputs are borrowed: the inline backend reads them in place; the
    /// threaded backend clones them into its request envelope.  Call sites
    /// that own their inputs anyway should use [`Engine::execute_owned`],
    /// which moves them into the envelope instead.
    pub fn execute(&self, artifact: &str, set: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match &self.backend {
            Backend::Inline(s) => s.execute(artifact, set, inputs),
            Backend::Threaded(t) => t.execute_owned(artifact, set, inputs.to_vec()),
        }
    }

    /// [`Engine::execute`] for call sites that own their inputs: the inline
    /// backend still only borrows, the threaded backend moves the vector
    /// into its request envelope — no per-call tensor clone on either path.
    pub fn execute_owned(
        &self,
        artifact: &str,
        set: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        match &self.backend {
            Backend::Inline(s) => s.execute(artifact, set, &inputs),
            Backend::Threaded(t) => t.execute_owned(artifact, set, inputs),
        }
    }

    /// Execute one artifact over a micro-batch of input sets (all against
    /// the same weight set).  Results are element-for-element identical to
    /// calling [`Engine::execute`] once per element — batching only changes
    /// the dispatch cost: the inline backend loops the closed-form kernel
    /// with a single stats update, the threaded backend crosses its request
    /// channel once per batch instead of once per request.  An empty batch
    /// is a no-op; any failing element fails the whole batch.
    pub fn execute_batch(
        &self,
        artifact: &str,
        set: &str,
        batches: &[&[Tensor]],
    ) -> Result<Vec<Vec<Tensor>>> {
        if batches.is_empty() {
            return Ok(Vec::new());
        }
        match &self.backend {
            Backend::Inline(s) => s.execute_batch(artifact, set, batches),
            Backend::Threaded(t) => t.execute_batch_owned(
                artifact,
                set,
                batches.iter().map(|b| b.to_vec()).collect(),
            ),
        }
    }

    /// [`Engine::execute_batch`] for call sites that own their inputs: the
    /// threaded backend moves the batch into its request envelope with no
    /// per-tensor clone (the serving-layer micro-batcher's hot path).
    pub fn execute_batch_owned(
        &self,
        artifact: &str,
        set: &str,
        batches: Vec<Vec<Tensor>>,
    ) -> Result<Vec<Vec<Tensor>>> {
        if batches.is_empty() {
            return Ok(Vec::new());
        }
        match &self.backend {
            Backend::Inline(s) => {
                let refs: Vec<&[Tensor]> = batches.iter().map(|b| b.as_slice()).collect();
                s.execute_batch(artifact, set, &refs)
            }
            Backend::Threaded(t) => t.execute_batch_owned(artifact, set, batches),
        }
    }

    /// Compile an artifact and upload its weights ahead of time (no-op for
    /// the synthetic backends — they have nothing to warm).
    pub fn preload(&self, artifact: &str, set: &str) -> Result<()> {
        match &self.backend {
            Backend::Inline(_) => Ok(()),
            Backend::Threaded(t) => t.preload(artifact, set),
        }
    }

    /// Per-artifact wall-clock stats (perf pass).
    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        match &self.backend {
            Backend::Inline(s) => s.snapshot(),
            Backend::Threaded(t) => t.stats(),
        }
    }

    /// Switch weight-delivery mode (affects artifacts loaded afterwards;
    /// meaningless for the synthetic backends).
    pub fn set_mode(&self, mode: ExecMode) {
        if let Backend::Threaded(t) = &self.backend {
            let _ = t.tx.send(Request::SetMode(mode));
        }
    }
}

/// Request loop of the threaded synthetic engine: every execute is answered
/// by the deterministic closed-form model; preloads are no-ops.
fn synth_worker(rx: std::sync::mpsc::Receiver<Request>) {
    let mut stats: BTreeMap<String, ExecStats> = BTreeMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::SetMode(_) => {}
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Preload { reply, .. } => {
                let _ = reply.send(Ok(()));
            }
            Request::Execute { artifact, set, inputs, reply } => {
                let t0 = Instant::now();
                let r = super::synth::execute_synthetic(&artifact, &set, &inputs);
                let st = stats_mut(&mut stats, &artifact);
                st.calls += 1;
                st.total_secs += t0.elapsed().as_secs_f64();
                let _ = reply.send(r);
            }
            Request::ExecuteBatch { artifact, set, batches, reply } => {
                let refs: Vec<&[Tensor]> = batches.iter().map(|b| b.as_slice()).collect();
                let t0 = Instant::now();
                let r = super::synth::execute_synthetic_batch(&artifact, &set, &refs);
                let st = stats_mut(&mut stats, &artifact);
                st.calls += batches.len() as u64;
                st.total_secs += t0.elapsed().as_secs_f64();
                let _ = reply.send(r);
            }
        }
    }
}

/// Engine-thread-local state for one compiled artifact.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    /// set name -> weight literals (LiteralsEachCall mode).
    literals: BTreeMap<String, Vec<xla::Literal>>,
    /// set name -> pre-uploaded device buffers (PreuploadedBuffers mode).
    buffers: BTreeMap<String, Vec<xla::PjRtBuffer>>,
}

fn worker(
    manifest: Manifest,
    mode: ExecMode,
    rx: std::sync::mpsc::Receiver<Request>,
    ready: Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    let mut mode = mode;
    let mut cache: BTreeMap<String, Loaded> = BTreeMap::new();
    let mut stats: BTreeMap<String, ExecStats> = BTreeMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::SetMode(m) => mode = m,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Preload { artifact, set, reply } => {
                let r =
                    ensure_loaded(&client, &manifest, &mut cache, &mut stats, &artifact, &set, mode)
                        .map(|_| ());
                let _ = reply.send(r);
            }
            Request::Execute { artifact, set, inputs, reply } => {
                let r = (|| -> Result<Vec<Tensor>> {
                    ensure_loaded(
                        &client, &manifest, &mut cache, &mut stats, &artifact, &set, mode,
                    )?;
                    let loaded = cache.get(artifact.as_ref()).unwrap();
                    let t0 = Instant::now();
                    let outs = run_one(&client, loaded, &set, &inputs, mode)?;
                    let st = stats_mut(&mut stats, &artifact);
                    st.calls += 1;
                    st.total_secs += t0.elapsed().as_secs_f64();
                    Ok(outs)
                })();
                let _ = reply.send(r);
            }
            Request::ExecuteBatch { artifact, set, batches, reply } => {
                // One compile/weight-load check and one stats update for the
                // whole batch; the executable itself runs per element (the
                // AOT artifacts are compiled for batch-1 shapes).
                let r = (|| -> Result<Vec<Vec<Tensor>>> {
                    ensure_loaded(
                        &client, &manifest, &mut cache, &mut stats, &artifact, &set, mode,
                    )?;
                    let loaded = cache.get(artifact.as_ref()).unwrap();
                    let t0 = Instant::now();
                    let outs = batches
                        .iter()
                        .map(|inputs| run_one(&client, loaded, &set, inputs, mode))
                        .collect::<Result<Vec<_>>>()?;
                    let st = stats_mut(&mut stats, &artifact);
                    st.calls += batches.len() as u64;
                    st.total_secs += t0.elapsed().as_secs_f64();
                    Ok(outs)
                })();
                let _ = reply.send(r);
            }
        }
    }
}

fn ensure_loaded(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &mut BTreeMap<String, Loaded>,
    stats: &mut BTreeMap<String, ExecStats>,
    artifact: &str,
    set: &str,
    mode: ExecMode,
) -> Result<()> {
    if !cache.contains_key(artifact) {
        let spec = manifest.artifact(artifact)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.hlo.to_str().context("hlo path utf8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", spec.hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {artifact}: {e}"))?;
        stats_mut(stats, artifact).compile_secs += t0.elapsed().as_secs_f64();
        cache.insert(
            artifact.to_string(),
            Loaded { exe, literals: BTreeMap::new(), buffers: BTreeMap::new() },
        );
    }
    // Load + (optionally) upload the requested weight set.
    let spec = manifest.artifact(artifact)?;
    let loaded = cache.get_mut(artifact).unwrap();
    if !loaded.literals.contains_key(set) {
        let path = spec
            .weights
            .get(set)
            .with_context(|| format!("artifact {artifact} has no weight set `{set}`"))?;
        let wf = load_weight_tensors(spec, path)?;
        let lits: Vec<xla::Literal> =
            wf.tensors.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        loaded.literals.insert(set.to_string(), lits);
    }
    if mode == ExecMode::PreuploadedBuffers && !loaded.buffers.contains_key(set) {
        let lits = loaded.literals.get(set).unwrap();
        let bufs: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| {
                let b = client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("uploading weights for {artifact}: {e}"))?;
                // Force the async host->device transfer to complete before the
                // buffer is used: the crate exposes no GetReadyFuture, and
                // in-flight transfers racing later compile/execute calls
                // crash inside XLA (ShapeUtil CHECK). One-time cost per
                // (artifact, set).
                b.to_literal_sync()
                    .map_err(|e| anyhow!("syncing weight upload for {artifact}: {e}"))?;
                Ok(b)
            })
            .collect::<Result<_>>()?;
        loaded.buffers.insert(set.to_string(), bufs);
    }
    Ok(())
}

fn run_one(
    client: &xla::PjRtClient,
    loaded: &Loaded,
    set: &str,
    inputs: &[Tensor],
    mode: ExecMode,
) -> Result<Vec<Tensor>> {
    let result = match mode {
        ExecMode::LiteralsEachCall => {
            let mut args: Vec<xla::Literal> = Vec::new();
            for l in loaded.literals.get(set).into_iter().flatten() {
                // Literal has no cheap clone; convert via reshape to same dims.
                let shape = l.array_shape()?;
                args.push(l.reshape(shape.dims())?);
            }
            for t in inputs {
                args.push(t.to_literal()?);
            }
            loaded.exe.execute::<xla::Literal>(&args)?
        }
        ExecMode::PreuploadedBuffers => {
            let weight_bufs = loaded
                .buffers
                .get(set)
                .with_context(|| format!("weight set `{set}` not uploaded"))?;
            let mut args: Vec<&xla::PjRtBuffer> = weight_bufs.iter().collect();
            // The H2D transfer behind buffer_from_host_literal is async and
            // captures a LiteralSlice into OUR literal; neither execute_b
            // nor buffer drop awaits it (the vendored literal-path `execute`
            // does, which is why LiteralsEachCall is unconditionally safe).
            // Dropping the literal while the copy lambda is pending reads a
            // dangling Shape and aborts inside ShapeUtil. Force readiness of
            // every input buffer before releasing its source literal —
            // inputs are small (<= 48 KB), so the extra sync is noise next
            // to the 5 MB weight re-marshal this mode avoids.
            let input_lits: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let input_bufs: Vec<xla::PjRtBuffer> = input_lits
                .iter()
                .map(|lit| {
                    let b = client
                        .buffer_from_host_literal(None, lit)
                        .map_err(|e| anyhow!("uploading input: {e}"))?;
                    b.to_literal_sync()
                        .map_err(|e| anyhow!("syncing input upload: {e}"))?;
                    Ok(b)
                })
                .collect::<Result<Vec<_>>>()?;
            for b in &input_bufs {
                args.push(b);
            }
            let out = loaded.exe.execute_b::<&xla::PjRtBuffer>(&args)?;
            drop(input_bufs);
            drop(input_lits);
            out
        }
    };
    // return_tuple=True => single tuple output literal.
    let lit = result[0][0].to_literal_sync()?;
    let parts = lit.to_tuple()?;
    let mut outs = Vec::with_capacity(parts.len());
    for p in parts {
        let shape = p.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        outs.push(Tensor::from_literal(&p, dims)?);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tokenize;

    fn scene() -> Tensor {
        let img = 4;
        let mut data = vec![0.0f32; img * img * 3];
        for i in 0..img * img / 2 {
            data[i * 3] = 1.0;
        }
        Tensor::f32(vec![img, img, 3], data).unwrap()
    }

    #[test]
    fn inline_backend_executes_and_counts_stats() {
        let e = Engine::synthetic();
        assert!(e.is_inline());
        let outs = e.execute("head_sp1_balanced", "shared", std::slice::from_ref(&scene()));
        assert_eq!(outs.unwrap().len(), 3);
        e.preload("head_sp1_balanced", "shared").unwrap();
        let stats = e.stats();
        let st = stats.get("head_sp1_balanced").expect("stats slot recorded");
        assert_eq!(st.calls, 1);
        assert!(st.total_secs >= 0.0);
        // Errors (unknown artifacts) are still counted, via the overflow map.
        assert!(e.execute("bogus", "shared", &[]).is_err());
        assert_eq!(e.stats().get("bogus").map(|s| s.calls), Some(1));
    }

    #[test]
    fn inline_stats_are_shared_across_clones_and_threads() {
        let e = Engine::synthetic();
        let img = scene();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let eng = e.clone();
                let img = &img;
                s.spawn(move || {
                    for _ in 0..8 {
                        eng.execute("context_edge", "shared", std::slice::from_ref(img)).unwrap();
                    }
                });
            }
        });
        assert_eq!(e.stats().get("context_edge").map(|s| s.calls), Some(32));
    }

    #[test]
    fn execute_batch_matches_sequential_and_counts_once_per_element() {
        let img = scene();
        for engine in [Engine::synthetic(), Engine::synthetic_threaded()] {
            let single = engine
                .execute("head_sp1_balanced", "shared", std::slice::from_ref(&img))
                .unwrap();
            let batch = engine
                .execute_batch(
                    "head_sp1_balanced",
                    "shared",
                    &[std::slice::from_ref(&img), std::slice::from_ref(&img)],
                )
                .unwrap();
            assert_eq!(batch.len(), 2);
            assert_eq!(batch[0], single);
            assert_eq!(batch[1], single);
            // 1 single + 2 batched elements = 3 calls.
            assert_eq!(engine.stats().get("head_sp1_balanced").map(|s| s.calls), Some(3));
            // Empty batches are no-ops.
            assert!(engine.execute_batch("head_sp1_balanced", "shared", &[]).unwrap().is_empty());
            assert!(engine.execute_batch_owned("bogus", "shared", vec![vec![]]).is_err());
        }
    }

    #[test]
    fn threaded_synthetic_matches_inline() {
        let inline = Engine::synthetic();
        let threaded = Engine::synthetic_threaded();
        assert!(!threaded.is_inline());
        let img = scene();
        let a = inline.execute("head_sp2_high_accuracy", "shared", std::slice::from_ref(&img));
        let b = threaded.execute("head_sp2_high_accuracy", "shared", std::slice::from_ref(&img));
        assert_eq!(a.unwrap(), b.unwrap());
        let pids = Tensor::i32(vec![16], tokenize("highlight the stranded people")).unwrap();
        let head = inline
            .execute("head_sp2_high_accuracy", "shared", std::slice::from_ref(&img))
            .unwrap();
        let tin = [head[0].clone(), head[1].clone(), pids];
        let ta = inline.execute("tail_sp2_high_accuracy", "ft", &tin).unwrap();
        let tb = threaded.execute("tail_sp2_high_accuracy", "ft", &tin).unwrap();
        assert_eq!(ta, tb);
    }
}
