//! Small self-contained utilities: a deterministic PRNG (the offline crate
//! set has no `rand`), line-record parsing helpers for the artifact metadata,
//! and a tiny stats toolkit used by telemetry and the bench harness.

/// xorshift64* — deterministic, seedable, good enough for workload generation
/// and property-test case generation. Never used for anything cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1).wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// CRC-32 lookup table (IEEE 802.3, reflected polynomial 0xEDB88320),
/// generated at compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = (c >> 1) ^ (0xEDB8_8320 & (c & 1).wrapping_neg());
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE 802.3) — table-driven and self-contained, so the per-packet
/// wire format has no external-crate dependency on its hot path.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Streaming CRC-32 over multiple sections (same polynomial and result as
/// [`crc32`]) — lets the cloud serving layer derive its content-addressed
/// cache key from a packet's payload fields without materializing one
/// contiguous buffer per request.
#[derive(Clone, Copy, Debug)]
pub struct Crc32(u32);

impl Crc32 {
    pub fn new() -> Self {
        Self(!0)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 >> 8) ^ CRC32_TABLE[((self.0 ^ b as u32) & 0xFF) as usize];
        }
    }

    pub fn finish(self) -> u32 {
        !self.0
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-1a 32-bit — MUST stay in exact sync with python/compile/data.py.
pub fn fnv1a32(s: &str) -> u32 {
    let mut h: u32 = 0x811C9DC5;
    for b in s.as_bytes() {
        h ^= *b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Parse "a,b,c" into dims; "scalar" -> [].
pub fn parse_dims(s: &str) -> Vec<usize> {
    if s == "scalar" {
        return vec![];
    }
    s.split(',').filter(|t| !t.is_empty()).map(|t| t.parse().unwrap_or(0)).collect()
}

/// Summary statistics over a sample of f64s.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Stats {
    pub fn from(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| sorted[(((n - 1) as f64) * p).round() as usize];
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }
}

/// An exponentially-weighted moving average (bandwidth estimator helper).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.range(3.0, 9.0);
            assert!((3.0..9.0).contains(&x));
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn crc32_check_value() {
        // The CRC-32 "check" input from the catalogue of parametrised CRCs.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_streaming_matches_one_shot() {
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"");
        c.update(b"56789");
        assert_eq!(c.finish(), crc32(b"123456789"));
        assert_eq!(Crc32::default().finish(), 0);
    }

    #[test]
    fn fnv_matches_python_reference() {
        // Golden values from python: fnv1a32("flood") etc.
        assert_eq!(fnv1a32(""), 0x811C9DC5);
        assert_eq!(fnv1a32("a"), 0xE40C292C);
    }

    #[test]
    fn stats_basic() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        e.update(10.0);
        for _ in 0..64 {
            e.update(20.0);
        }
        assert!((e.get().unwrap() - 20.0).abs() < 1e-3);
    }

    #[test]
    fn parse_dims_ok() {
        assert_eq!(parse_dims("64,128"), vec![64, 128]);
        assert!(parse_dims("scalar").is_empty());
    }

    #[test]
    fn normal_mean_near_zero() {
        let mut r = Rng::new(11);
        let m: f64 = (0..20_000).map(|_| r.normal()).sum::<f64>() / 20_000.0;
        assert!(m.abs() < 0.05, "mean {m}");
    }
}
