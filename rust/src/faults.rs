//! Deterministic fault injection — the chaos layer (DESIGN.md "Chaos &
//! recovery").
//!
//! A [`FaultPlan`] is a validated, time-ordered schedule of infrastructure
//! failures over *virtual* mission time: cells crash and recover, workers
//! stall, executions fail at a rate, the wire corrupts frames, sessions
//! drop.  The plan is data (compiled from `[[fault]]` manifest sections or
//! built programmatically) and the [`FaultInjector`] is its runtime: every
//! probabilistic draw comes from one seeded xorshift stream consumed in
//! request order, so the serial virtual-time fleet loop replays the exact
//! same fault sequence for a fixed seed — chaos runs are byte-deterministic
//! (pinned by `rust/tests/chaos.rs`).
//!
//! The injector answers point-in-time queries against a request's virtual
//! capture time.  The fleet event loop steps agents in clock order, so the
//! request stream's times are non-decreasing and window membership is a
//! pure function of the event-ordered stream — no wall clock anywhere.

use anyhow::{bail, Result};

use crate::util::Rng;

/// The fault taxonomy — one discriminant per injectable failure mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// A serving cell is unreachable for a window (connection refused).
    CellCrash,
    /// A cell's workers stall: requests still complete but each one is
    /// charged extra virtual latency while the window is open.
    WorkerStall,
    /// Executions at a cell fail with probability `rate` inside the window.
    ExecError,
    /// The edge–cloud wire corrupts frames with probability `rate` inside
    /// the window (cell-agnostic — the link, not a cell, is at fault).
    WireCorrupt,
    /// One session teardown: the first request at or after `at` is dropped.
    SessionDrop,
}

impl FaultKind {
    pub const ALL: [FaultKind; 5] = [
        FaultKind::CellCrash,
        FaultKind::WorkerStall,
        FaultKind::ExecError,
        FaultKind::WireCorrupt,
        FaultKind::SessionDrop,
    ];

    /// Stable manifest/report name (the `[[fault]] kind = "..."` key).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::CellCrash => "cell-crash",
            FaultKind::WorkerStall => "worker-stall",
            FaultKind::ExecError => "exec-error",
            FaultKind::WireCorrupt => "wire-corrupt",
            FaultKind::SessionDrop => "session-drop",
        }
    }

    pub fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Dense index for per-kind counter arrays.
    pub fn index(self) -> usize {
        match self {
            FaultKind::CellCrash => 0,
            FaultKind::WorkerStall => 1,
            FaultKind::ExecError => 2,
            FaultKind::WireCorrupt => 3,
            FaultKind::SessionDrop => 4,
        }
    }
}

/// One scheduled fault, in absolute virtual seconds.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// Cell `cell` refuses every request in `[at, at + recover_after)`.
    CellCrash { cell: usize, at: f64, recover_after: f64 },
    /// Requests served by `cell` in `[at, at + duration)` are each charged
    /// `stall_secs` extra virtual latency.
    WorkerStall { cell: usize, at: f64, duration: f64, stall_secs: f64 },
    /// Executions at `cell` in `[at, at + duration)` fail with probability
    /// `rate` (one seeded draw per request).
    ExecError { cell: usize, at: f64, duration: f64, rate: f64 },
    /// Any request in `[at, at + duration)` is corrupted on the wire with
    /// probability `rate`.
    WireCorrupt { at: f64, duration: f64, rate: f64 },
    /// The first request at or after `at` is dropped (one-shot).
    SessionDrop { at: f64 },
}

impl FaultEvent {
    pub fn kind(&self) -> FaultKind {
        match self {
            FaultEvent::CellCrash { .. } => FaultKind::CellCrash,
            FaultEvent::WorkerStall { .. } => FaultKind::WorkerStall,
            FaultEvent::ExecError { .. } => FaultKind::ExecError,
            FaultEvent::WireCorrupt { .. } => FaultKind::WireCorrupt,
            FaultEvent::SessionDrop { .. } => FaultKind::SessionDrop,
        }
    }

    /// Start of the event's window.
    pub fn at(&self) -> f64 {
        match *self {
            FaultEvent::CellCrash { at, .. }
            | FaultEvent::WorkerStall { at, .. }
            | FaultEvent::ExecError { at, .. }
            | FaultEvent::WireCorrupt { at, .. }
            | FaultEvent::SessionDrop { at } => at,
        }
    }

    /// `[start, end)` window (a [`FaultKind::SessionDrop`] is a point).
    pub fn window(&self) -> (f64, f64) {
        match *self {
            FaultEvent::CellCrash { at, recover_after, .. } => (at, at + recover_after),
            FaultEvent::WorkerStall { at, duration, .. }
            | FaultEvent::ExecError { at, duration, .. }
            | FaultEvent::WireCorrupt { at, duration, .. } => (at, at + duration),
            FaultEvent::SessionDrop { at } => (at, at),
        }
    }

    /// The cell this event targets (None for link-level faults).
    pub fn cell(&self) -> Option<usize> {
        match *self {
            FaultEvent::CellCrash { cell, .. }
            | FaultEvent::WorkerStall { cell, .. }
            | FaultEvent::ExecError { cell, .. } => Some(cell),
            FaultEvent::WireCorrupt { .. } | FaultEvent::SessionDrop { .. } => None,
        }
    }
}

/// A fraction-based fault specification — what `[[fault]]` manifest
/// sections lower to.  Temporal fields (`at`, `duration`) are fractions of
/// the mission duration, bound to absolute seconds by [`FaultSpec::bind`]
/// exactly like the intent schedule's fractions; `stall_secs` is already
/// absolute (a latency, not a window).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub cell: usize,
    /// Window start as a fraction of mission duration, in `[0, 1]`.
    pub at: f64,
    /// Window length as a fraction of mission duration (`recover_after`
    /// for a [`FaultKind::CellCrash`]).
    pub duration: f64,
    /// Failure probability per request for rate faults, in `[0, 1]`.
    pub rate: f64,
    /// Extra virtual seconds per request for a [`FaultKind::WorkerStall`].
    pub stall_secs: f64,
}

impl FaultSpec {
    pub fn bind(&self, duration_secs: f64) -> FaultEvent {
        let at = self.at * duration_secs;
        let dur = self.duration * duration_secs;
        match self.kind {
            FaultKind::CellCrash => {
                FaultEvent::CellCrash { cell: self.cell, at, recover_after: dur }
            }
            FaultKind::WorkerStall => FaultEvent::WorkerStall {
                cell: self.cell,
                at,
                duration: dur,
                stall_secs: self.stall_secs,
            },
            FaultKind::ExecError => {
                FaultEvent::ExecError { cell: self.cell, at, duration: dur, rate: self.rate }
            }
            FaultKind::WireCorrupt => {
                FaultEvent::WireCorrupt { at, duration: dur, rate: self.rate }
            }
            FaultKind::SessionDrop => FaultEvent::SessionDrop { at },
        }
    }
}

/// Bind a spec list against a mission duration (the scenario instantiation
/// step for faults).
pub fn bind_specs(specs: &[FaultSpec], duration_secs: f64) -> Vec<FaultEvent> {
    specs.iter().map(|s| s.bind(duration_secs)).collect()
}

/// A validated, time-ordered fault schedule plus the seed its injector's
/// probabilistic draws run on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self { events: Vec::new(), seed }
    }

    /// Build and validate in one step.
    pub fn with_events(seed: u64, events: Vec<FaultEvent>) -> Result<Self> {
        let plan = Self { events, seed };
        plan.validate()?;
        Ok(plan)
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Largest cell index any event targets (sizing check for clusters).
    pub fn max_cell(&self) -> Option<usize> {
        self.events.iter().filter_map(|e| e.cell()).max()
    }

    /// Structural validation, mirroring the scenario compiler's rules so a
    /// programmatic plan cannot express what a manifest cannot: finite
    /// non-negative times, rates in `[0, 1]`, events ordered by start time,
    /// and no overlapping crash windows on the same cell (an overlapped
    /// crash has no well-defined recovery point).
    pub fn validate(&self) -> Result<()> {
        let mut prev_at = f64::NEG_INFINITY;
        for (i, ev) in self.events.iter().enumerate() {
            let (start, end) = ev.window();
            if !start.is_finite() || start < 0.0 || !end.is_finite() || end < start {
                bail!("fault[{i}]: window [{start}, {end}) is not a finite forward range");
            }
            if start < prev_at {
                bail!("fault[{i}]: events must be ordered by start time ({start} < {prev_at})");
            }
            prev_at = start;
            match *ev {
                FaultEvent::ExecError { rate, .. } | FaultEvent::WireCorrupt { rate, .. } => {
                    if !(0.0..=1.0).contains(&rate) {
                        bail!("fault[{i}]: rate {rate} outside [0, 1]");
                    }
                }
                FaultEvent::WorkerStall { stall_secs, .. } => {
                    if !stall_secs.is_finite() || stall_secs < 0.0 {
                        bail!("fault[{i}]: stall of {stall_secs}s is not a finite non-negative latency");
                    }
                }
                _ => {}
            }
            if let FaultEvent::CellCrash { cell, .. } = *ev {
                for (j, other) in self.events[..i].iter().enumerate() {
                    if let FaultEvent::CellCrash { cell: oc, .. } = *other {
                        let (os, oe) = other.window();
                        if oc == cell && start < oe && os < end {
                            bail!("fault[{i}]: crash window overlaps fault[{j}] on cell {cell}");
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Per-kind injection counters (index via [`FaultKind::index`]).
pub type FaultCounts = [u64; 5];

/// The plan's runtime: point-in-time fault queries with seeded per-request
/// draws and per-kind injection counters.  Methods take `&mut self` — the
/// caller serializes access (the cluster holds the injector inside its
/// chaos mutex; the fleet loop is serial anyway), which is exactly what
/// keeps the draw stream deterministic.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Rng,
    /// Consumed flags, one per SessionDrop event in plan order.
    drops_taken: Vec<bool>,
    counts: FaultCounts,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        let drops = plan
            .events
            .iter()
            .filter(|e| matches!(e, FaultEvent::SessionDrop { .. }))
            .count();
        Self {
            rng: Rng::new(plan.seed ^ 0xFA_17),
            drops_taken: vec![false; drops],
            counts: [0; 5],
            plan,
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Injections recorded so far, per kind.
    pub fn counts(&self) -> FaultCounts {
        self.counts
    }

    pub fn record(&mut self, kind: FaultKind) {
        self.counts[kind.index()] += 1;
    }

    /// Is `cell` inside an open crash window at `t`?  Pure query — the
    /// caller records the injection only when a request actually hits it.
    pub fn crash_active(&self, cell: usize, t: f64) -> bool {
        self.plan.events.iter().any(|e| match *e {
            FaultEvent::CellCrash { cell: c, .. } => {
                let (s, end) = e.window();
                c == cell && t >= s && t < end
            }
            _ => false,
        })
    }

    /// Total stall latency open at `cell` for a request at `t` (0.0 when
    /// no stall window covers it).  Records the injection when non-zero.
    pub fn stall_secs(&mut self, cell: usize, t: f64) -> f64 {
        let total: f64 = self
            .plan
            .events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::WorkerStall { cell: c, stall_secs, .. } if c == cell => {
                    let (s, end) = e.window();
                    (t >= s && t < end).then_some(stall_secs)
                }
                _ => None,
            })
            .sum();
        if total > 0.0 {
            self.record(FaultKind::WorkerStall);
        }
        total
    }

    /// One seeded draw against every exec-error window open at (`cell`,
    /// `t`); true = this request's execution fails.  Draws are consumed
    /// only inside a window, so runs without rate faults burn no rng state.
    pub fn draw_exec_error(&mut self, cell: usize, t: f64) -> bool {
        for e in &self.plan.events {
            if let FaultEvent::ExecError { cell: c, rate, .. } = *e {
                let (s, end) = e.window();
                if c == cell && t >= s && t < end && self.rng.f64() < rate {
                    self.counts[FaultKind::ExecError.index()] += 1;
                    return true;
                }
            }
        }
        false
    }

    /// One seeded draw against every wire-corruption window open at `t`.
    pub fn draw_wire_corrupt(&mut self, t: f64) -> bool {
        for e in &self.plan.events {
            if let FaultEvent::WireCorrupt { rate, .. } = *e {
                let (s, end) = e.window();
                if t >= s && t < end && self.rng.f64() < rate {
                    self.counts[FaultKind::WireCorrupt.index()] += 1;
                    return true;
                }
            }
        }
        false
    }

    /// Consume the next un-taken session drop due at or before `t`
    /// (one-shot per event); true = this request is dropped.
    pub fn take_session_drop(&mut self, t: f64) -> bool {
        let mut di = 0;
        for e in &self.plan.events {
            if let FaultEvent::SessionDrop { at } = *e {
                if !self.drops_taken[di] && t >= at {
                    self.drops_taken[di] = true;
                    self.counts[FaultKind::SessionDrop.index()] += 1;
                    return true;
                }
                di += 1;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn crash(cell: usize, at: f64, dur: f64) -> FaultEvent {
        FaultEvent::CellCrash { cell, at, recover_after: dur }
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in FaultKind::ALL {
            assert_eq!(FaultKind::parse(k.name()), Some(k));
        }
        assert_eq!(FaultKind::parse("segfault"), None);
        // Dense indices cover 0..5 exactly once.
        let mut seen = [false; 5];
        for k in FaultKind::ALL {
            assert!(!seen[k.index()]);
            seen[k.index()] = true;
        }
    }

    #[test]
    fn validation_rejects_disorder_overlap_and_bad_rates() {
        // Ordered, disjoint: fine.
        FaultPlan::with_events(1, vec![crash(0, 10.0, 5.0), crash(0, 20.0, 5.0)]).unwrap();
        // Same window, different cells: fine.
        FaultPlan::with_events(1, vec![crash(0, 10.0, 5.0), crash(1, 10.0, 5.0)]).unwrap();
        // Out of order.
        let e = FaultPlan::with_events(1, vec![crash(0, 20.0, 5.0), crash(1, 10.0, 5.0)])
            .unwrap_err()
            .to_string();
        assert!(e.contains("ordered"), "{e}");
        // Overlapping crash on the same cell.
        let e = FaultPlan::with_events(1, vec![crash(0, 10.0, 15.0), crash(0, 20.0, 5.0)])
            .unwrap_err()
            .to_string();
        assert!(e.contains("overlaps"), "{e}");
        // Rate outside [0, 1].
        let e = FaultPlan::with_events(
            1,
            vec![FaultEvent::ExecError { cell: 0, at: 0.0, duration: 1.0, rate: 1.5 }],
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("rate"), "{e}");
        // Negative / non-finite times.
        assert!(FaultPlan::with_events(1, vec![crash(0, -1.0, 5.0)]).is_err());
        assert!(FaultPlan::with_events(1, vec![crash(0, f64::NAN, 5.0)]).is_err());
        assert!(
            FaultPlan::with_events(1, vec![crash(0, 1.0, f64::INFINITY)]).is_err(),
            "open-ended crash has no recovery point"
        );
    }

    #[test]
    fn spec_binding_scales_fractions() {
        let spec = FaultSpec {
            kind: FaultKind::CellCrash,
            cell: 2,
            at: 0.25,
            duration: 0.5,
            rate: 0.0,
            stall_secs: 0.0,
        };
        assert_eq!(
            spec.bind(400.0),
            FaultEvent::CellCrash { cell: 2, at: 100.0, recover_after: 200.0 }
        );
        let wire = FaultSpec {
            kind: FaultKind::WireCorrupt,
            cell: 0,
            at: 0.5,
            duration: 0.1,
            rate: 0.3,
            stall_secs: 0.0,
        };
        assert_eq!(wire.bind(100.0), FaultEvent::WireCorrupt { at: 50.0, duration: 10.0, rate: 0.3 });
    }

    #[test]
    fn injector_windows_and_one_shots() {
        let plan = FaultPlan::with_events(
            9,
            vec![
                crash(1, 10.0, 5.0),
                FaultEvent::WorkerStall { cell: 0, at: 12.0, duration: 4.0, stall_secs: 0.25 },
                FaultEvent::SessionDrop { at: 30.0 },
            ],
        )
        .unwrap();
        let mut inj = FaultInjector::new(plan);
        assert!(!inj.crash_active(1, 9.9));
        assert!(inj.crash_active(1, 10.0));
        assert!(inj.crash_active(1, 14.9));
        assert!(!inj.crash_active(1, 15.0), "window is half-open");
        assert!(!inj.crash_active(0, 12.0), "other cells unaffected");
        assert_eq!(inj.stall_secs(0, 13.0), 0.25);
        assert_eq!(inj.stall_secs(0, 20.0), 0.0);
        assert_eq!(inj.stall_secs(1, 13.0), 0.0);
        // The drop fires exactly once, at the first request past its time.
        assert!(!inj.take_session_drop(29.0));
        assert!(inj.take_session_drop(31.0));
        assert!(!inj.take_session_drop(32.0));
        let c = inj.counts();
        assert_eq!(c[FaultKind::WorkerStall.index()], 1);
        assert_eq!(c[FaultKind::SessionDrop.index()], 1);
    }

    #[test]
    fn rate_draws_are_seed_deterministic() {
        let plan = FaultPlan::with_events(
            42,
            vec![FaultEvent::ExecError { cell: 0, at: 0.0, duration: 100.0, rate: 0.5 }],
        )
        .unwrap();
        let seq = |mut inj: FaultInjector| -> Vec<bool> {
            (0..64).map(|i| inj.draw_exec_error(0, i as f64)).collect()
        };
        let a = seq(FaultInjector::new(plan.clone()));
        let b = seq(FaultInjector::new(plan.clone()));
        assert_eq!(a, b, "same seed, same draw stream");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "rate 0.5 mixes outcomes");
        let other = FaultPlan { seed: 43, ..plan };
        let c = seq(FaultInjector::new(other));
        assert_ne!(a, c, "different seed, different stream");
        // Outside the window: no draw consumed, never fires.
        let plan2 = FaultPlan::with_events(
            42,
            vec![FaultEvent::ExecError { cell: 0, at: 50.0, duration: 1.0, rate: 1.0 }],
        )
        .unwrap();
        let mut inj = FaultInjector::new(plan2);
        assert!(!inj.draw_exec_error(0, 10.0));
        assert!(inj.draw_exec_error(0, 50.5), "rate 1.0 always fires in-window");
    }
}
