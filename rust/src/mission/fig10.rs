//! Figure 10 — the trade-off scatter: average accuracy vs average throughput
//! for the three static tiers and AVERY ("Prioritize Accuracy", Original
//! model), plus the "Prioritize Throughput" operating point quoted in the
//! text (1.85 PPS).

use anyhow::Result;

use crate::coordinator::MissionGoal;
use crate::telemetry::{f, pct, Csv, Table};

use super::fig9::{run_fig9, Fig9Options};
use super::Env;

pub fn run_fig10(env: &Env, opts: &Fig9Options) -> Result<()> {
    let runs = run_fig9(env, opts)?;
    let mut table = Table::new(
        "Figure 10 — Avg Accuracy vs Avg Throughput (Original model)",
        &["Config", "Avg PPS", "Avg IoU (orig)"],
    );
    let mut csv = Csv::create(
        &env.out_dir.join("fig10_tradeoff.csv"),
        &["config", "avg_pps", "avg_iou_orig"],
    )?;
    for run in &runs {
        let s = &run.summary;
        table.row(&[s.policy.clone(), f(s.avg_pps, 3), pct(s.avg_iou_orig)]);
        csv.row(&[s.policy.clone(), f(s.avg_pps, 4), f(s.avg_iou_orig, 6)])?;
    }

    // The throughput-mode operating point (paper text: 1.85 PPS).
    let tp = run_fig9(
        env,
        &Fig9Options { goal: MissionGoal::PrioritizeThroughput, ..opts.clone() },
    )?;
    let s = &tp[0].summary;
    table.row(&[
        "AVERY (Prioritize Throughput)".to_string(),
        f(s.avg_pps, 3),
        pct(s.avg_iou_orig),
    ]);
    csv.row(&["avery_throughput".to_string(), f(s.avg_pps, 4), f(s.avg_iou_orig, 6)])?;
    table.print();
    println!("paper: AVERY 0.74 PPS (accuracy mode), 1.85 PPS (throughput mode)");
    println!("csv: {}", csv.path.display());
    Ok(())
}
