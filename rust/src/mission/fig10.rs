//! Figure 10 — the trade-off scatter: average accuracy vs average throughput
//! for the three static tiers and AVERY ("Prioritize Accuracy", Original
//! model), plus the "Prioritize Throughput" operating point quoted in the
//! text (1.85 PPS).

use anyhow::Result;

use crate::coordinator::MissionGoal;
use crate::report::{Report, ReportTable, Series};
use crate::telemetry::{f, pct};

use super::fig9::run_fig9;
use super::{Env, Mission, RunOptions};

/// `avery fig10` — accuracy/throughput trade-off scatter (runs fig9 in
/// both goals and absorbs those sub-reports).
pub struct Fig10Mission;

impl Mission for Fig10Mission {
    fn name(&self) -> &'static str {
        "fig10"
    }

    fn summary(&self) -> &'static str {
        "Fig 10 — accuracy/throughput trade-off scatter"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        run_fig10(env, opts)
    }
}

pub fn run_fig10(env: &Env, opts: &RunOptions) -> Result<Report> {
    let title = "Figure 10 — Avg Accuracy vs Avg Throughput (Original model)";
    let mut report = Report::new("fig10", title);

    let (runs, sub) = run_fig9(env, opts)?;
    report.absorb(sub);

    let mut table = ReportTable::new("tradeoff", title, &["Config", "Avg PPS", "Avg IoU (orig)"]);
    let mut csv = Series::new("fig10_tradeoff", &["config", "avg_pps", "avg_iou_orig"]);
    for run in &runs {
        let s = &run.summary;
        table.row(&[s.policy.clone(), f(s.avg_pps, 3), pct(s.avg_iou_orig)]);
        csv.row(&[s.policy.clone(), f(s.avg_pps, 4), f(s.avg_iou_orig, 6)]);
    }

    // The throughput-mode operating point (paper text: 1.85 PPS).  Its fig9
    // sub-report overwrites the accuracy-mode fig9 CSVs exactly as the
    // sequential drivers did.
    let (tp, sub_tp) = run_fig9(
        env,
        &RunOptions { goal: Some(MissionGoal::PrioritizeThroughput), ..opts.clone() },
    )?;
    report.absorb(sub_tp);
    let s = &tp[0].summary;
    table.row(&[
        "AVERY (Prioritize Throughput)".to_string(),
        f(s.avg_pps, 3),
        pct(s.avg_iou_orig),
    ]);
    csv.row(&["avery_throughput".to_string(), f(s.avg_pps, 4), f(s.avg_iou_orig, 6)]);

    report.push_scalar("avery_throughput_mode_pps", s.avg_pps);
    report.push_scalar("avery_throughput_mode_iou_orig", s.avg_iou_orig);
    report.push_table(table);
    report.push_series(csv);
    report.push_note("paper: AVERY 0.74 PPS (accuracy mode), 1.85 PPS (throughput mode)");
    Ok(report)
}
