//! Table 3 — the System LUT: per-tier compression ratio, Average IoU for
//! the Original and Fine-tuned models, and payload size.  Accuracy is
//! re-measured here through the *runtime* path (PJRT artifacts + int8 wire
//! quantization), independently of the python-side profiling that produced
//! lut.txt; the two must agree (that agreement is itself a parity check,
//! reported in the last two columns).

use anyhow::Result;

use crate::baselines::eval_split_path;
use crate::coordinator::TierId;
use crate::report::{Report, ReportTable, Series};
use crate::telemetry::{f, pct};

use super::{Env, Mission, RunOptions};

/// `avery table3` — regenerate the System LUT through the runtime path.
pub struct Table3Mission;

impl Mission for Table3Mission {
    fn name(&self) -> &'static str {
        "table3"
    }

    fn summary(&self) -> &'static str {
        "Table 3 — System LUT (per-tier accuracy/payload through the runtime)"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, _opts: &RunOptions) -> Result<Report> {
        run_table3(env)
    }
}

pub fn run_table3(env: &Env) -> Result<Report> {
    let title = "Table 3 — AVERY System Lookup Table (measured through the rust runtime)";
    let mut report = Report::new("table3", title);
    let mut table = ReportTable::new(
        "lut",
        title,
        &["Tier", "Ratio r", "IoU orig", "IoU ft", "Wire MB", "LUT orig", "LUT ft"],
    );
    let mut csv = Series::new(
        "table3_lut",
        &["tier", "ratio", "iou_orig", "iou_ft", "wire_mb", "lut_orig", "lut_ft"],
    );
    for tier in TierId::ALL {
        let e = *env.lut.entry(tier);
        let (acc_o, _) =
            eval_split_path(&env.engine, &env.generic_val, &env.lut, &env.device, 1, tier)?;
        let (acc_f, _) =
            eval_split_path(&env.engine, &env.flood_val, &env.lut, &env.device, 1, tier)?;
        table.row(&[
            tier.display().to_string(),
            f(e.ratio, 2),
            pct(acc_o),
            pct(acc_f),
            f(e.wire_bytes / 1e6, 2),
            pct(e.acc_orig),
            pct(e.acc_ft),
        ]);
        csv.row(&[
            tier.name().to_string(),
            f(e.ratio, 2),
            f(acc_o, 6),
            f(acc_f, 6),
            f(e.wire_bytes / 1e6, 2),
            f(e.acc_orig, 6),
            f(e.acc_ft, 6),
        ]);
        report.push_scalar(&format!("iou_orig_{}", tier.name()), acc_o);
        report.push_scalar(&format!("iou_ft_{}", tier.name()), acc_f);
    }
    report.push_table(table);
    report.push_series(csv);
    report.push_note(
        "paper Table 3: 84.42/81.12 @0.25, 82.89/79.20 @0.10, 80.67/78.48 @0.05 (%)",
    );
    Ok(report)
}
