//! Table 3 — the System LUT: per-tier compression ratio, Average IoU for
//! the Original and Fine-tuned models, and payload size.  Accuracy is
//! re-measured here through the *runtime* path (PJRT artifacts + int8 wire
//! quantization), independently of the python-side profiling that produced
//! lut.txt; the two must agree (that agreement is itself a parity check,
//! reported in the last two columns).

use anyhow::Result;

use crate::baselines::eval_split_path;
use crate::coordinator::TierId;
use crate::telemetry::{f, pct, Csv, Table};

use super::Env;

pub fn run_table3(env: &Env) -> Result<()> {
    let mut table = Table::new(
        "Table 3 — AVERY System Lookup Table (measured through the rust runtime)",
        &["Tier", "Ratio r", "IoU orig", "IoU ft", "Wire MB", "LUT orig", "LUT ft"],
    );
    let mut csv = Csv::create(
        &env.out_dir.join("table3_lut.csv"),
        &["tier", "ratio", "iou_orig", "iou_ft", "wire_mb", "lut_orig", "lut_ft"],
    )?;
    for tier in TierId::ALL {
        let e = *env.lut.entry(tier);
        let (acc_o, _) =
            eval_split_path(&env.engine, &env.generic_val, &env.lut, &env.device, 1, tier)?;
        let (acc_f, _) =
            eval_split_path(&env.engine, &env.flood_val, &env.lut, &env.device, 1, tier)?;
        table.row(&[
            tier.display().to_string(),
            f(e.ratio, 2),
            pct(acc_o),
            pct(acc_f),
            f(e.wire_bytes / 1e6, 2),
            pct(e.acc_orig),
            pct(e.acc_ft),
        ]);
        csv.row(&[
            tier.name().to_string(),
            f(e.ratio, 2),
            f(acc_o, 6),
            f(acc_f, 6),
            f(e.wire_bytes / 1e6, 2),
            f(e.acc_orig, 6),
            f(e.acc_ft, 6),
        ])?;
    }
    table.print();
    println!("paper Table 3: 84.42/81.12 @0.25, 82.89/79.20 @0.10, 80.67/78.48 @0.05 (%)");
    println!("csv: {}", csv.path.display());
    Ok(())
}
