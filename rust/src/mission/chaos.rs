//! `avery run chaos` — drive a canonical fault-schedule matrix through the
//! full fleet mission and gate every schedule on the chaos layer's two
//! structural invariants:
//!
//! * **conservation** — every sampled capture resolves to exactly one
//!   terminal outcome: `executed + shed_lost + degraded + abandoned ==
//!   captures`.  A violation means a request was double-counted or lost in
//!   the resilience path, so the mission fails hard rather than reporting a
//!   soft gate.
//! * **determinism** — the same `(schedule, seed)` replays to an identical
//!   counter fingerprint.  Every probabilistic fault draw comes from one
//!   seeded stream consumed in request order (`faults::FaultInjector`), so
//!   a mismatch means wall-clock or scheduling state leaked into the
//!   virtual timeline.
//!
//! Each schedule runs at a fixed internal duration so `--duration` (meant
//! for single-mission runs) cannot turn the matrix into an hours-long
//! sweep, mirroring `avery run matrix`.  Availability per schedule is
//! reported (and floor-gated by CI via `benches/chaos.rs`), not gated
//! here: it is a measurement, while conservation is an invariant.

use anyhow::{bail, Result};

use crate::faults::{FaultKind, FaultSpec};
use crate::report::{Report, ReportTable, Series};
use crate::streams::fleet::FleetRun;
use crate::telemetry::f;

use super::{run_fleet, Env, Mission, RunOptions};

/// Fixed per-schedule mission length (virtual seconds).
const CHAOS_SCHEDULE_SECS: f64 = 240.0;

/// `avery run chaos` — invariant-gated sweep over fault schedules.
pub struct ChaosMission;

impl Mission for ChaosMission {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn summary(&self) -> &'static str {
        "chaos matrix: canonical fault schedules under conservation + determinism gates"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        run_chaos(env, opts)
    }
}

fn spec(
    kind: FaultKind,
    cell: usize,
    at: f64,
    duration: f64,
    rate: f64,
    stall_secs: f64,
) -> FaultSpec {
    FaultSpec { kind, cell, at, duration, rate, stall_secs }
}

/// The canonical schedule matrix: one row per fault kind (plus a fault-free
/// baseline and a mixed storm), all fraction-based so they bind to the
/// fixed internal duration.
fn schedules() -> Vec<(&'static str, Vec<FaultSpec>)> {
    vec![
        ("none", Vec::new()),
        ("cell-crash", vec![spec(FaultKind::CellCrash, 0, 0.25, 0.25, 0.0, 0.0)]),
        ("worker-stall", vec![spec(FaultKind::WorkerStall, 0, 0.30, 0.30, 0.0, 0.4)]),
        ("exec-error", vec![spec(FaultKind::ExecError, 0, 0.20, 0.50, 0.25, 0.0)]),
        ("wire-corrupt", vec![spec(FaultKind::WireCorrupt, 0, 0.20, 0.50, 0.20, 0.0)]),
        ("session-drop", vec![spec(FaultKind::SessionDrop, 0, 0.50, 0.0, 0.0, 0.0)]),
        (
            "mixed",
            vec![
                spec(FaultKind::CellCrash, 0, 0.20, 0.20, 0.0, 0.0),
                spec(FaultKind::ExecError, 1, 0.50, 0.30, 0.30, 0.0),
                spec(FaultKind::SessionDrop, 0, 0.80, 0.0, 0.0, 0.0),
            ],
        ),
    ]
}

/// Counter fingerprint for the determinism gate: every field is a pure
/// function of the event-ordered virtual timeline, so two same-seed runs
/// must match byte-for-byte once formatted.
fn fingerprint(run: &FleetRun) -> String {
    format!(
        "{}|{}|{}|{}|{}|{}|{}|{:.9}|{:.9}|{:.9}|{:.6}|{:.9}",
        run.delivered_total,
        run.executed_total,
        run.captures_total,
        run.retries_total,
        run.shed_lost_total,
        run.degraded_total,
        run.abandoned_total,
        run.degraded_secs_total,
        run.retry_wait_secs_total,
        run.avg_iou,
        run.total_energy_j,
        run.lat_insight.p99(),
    )
}

/// One schedule's outcomes.
struct ChaosRow {
    name: &'static str,
    faults: usize,
    captures: u64,
    executed: u64,
    retries: u64,
    shed_lost: u64,
    degraded: u64,
    abandoned: u64,
    degraded_secs: f64,
    retry_wait_secs: f64,
    availability: f64,
}

/// Run the schedule matrix and build the gated report.  Conservation or
/// determinism violations fail the mission (they are invariants of the
/// chaos layer, not measurements of it).
pub fn run_chaos(env: &Env, opts: &RunOptions) -> Result<Report> {
    let mut rows = Vec::new();
    for (name, schedule) in schedules() {
        // The sweep pins its own duration and a coarse execute cadence;
        // cluster shape passes through but is floored at two cells so
        // cell-targeted faults always have a failover destination.
        let child = RunOptions {
            duration_secs: CHAOS_SCHEDULE_SECS,
            exec_every: opts.exec_every.max(25),
            seed: opts.seed,
            uavs: opts.uavs,
            workers: opts.workers,
            cells: Some(opts.cells.unwrap_or(2).max(2)),
            replicas: opts.replicas,
            hop_latency: opts.hop_latency,
            spill_max: opts.spill_max,
            retry_budget: opts.retry_budget,
            retry_backoff: opts.retry_backoff,
            retry_deadline: opts.retry_deadline,
            degrade: opts.degrade,
            probe_backoff: opts.probe_backoff,
            shards: opts.shards,
            fault_specs: schedule.clone(),
            ..RunOptions::default()
        };
        let (run, _) = run_fleet(env, &child)?;

        let resolved = run.executed_total
            + run.shed_lost_total
            + run.degraded_total
            + run.abandoned_total;
        if resolved != run.captures_total {
            bail!(
                "chaos schedule `{name}`: conservation violated — \
                 executed {} + shed {} + degraded {} + abandoned {} = {} != {} captures",
                run.executed_total,
                run.shed_lost_total,
                run.degraded_total,
                run.abandoned_total,
                resolved,
                run.captures_total
            );
        }

        // Determinism gate: replay the identical (schedule, seed) and
        // compare counter fingerprints.
        let (replay, _) = run_fleet(env, &child)?;
        let (a, b) = (fingerprint(&run), fingerprint(&replay));
        if a != b {
            bail!(
                "chaos schedule `{name}`: same-seed replay diverged\n first: {a}\nreplay: {b}"
            );
        }

        let captures = run.captures_total.max(1);
        rows.push(ChaosRow {
            name,
            faults: schedule.len(),
            captures: run.captures_total,
            executed: run.executed_total,
            retries: run.retries_total,
            shed_lost: run.shed_lost_total,
            degraded: run.degraded_total,
            abandoned: run.abandoned_total,
            degraded_secs: run.degraded_secs_total,
            retry_wait_secs: run.retry_wait_secs_total,
            availability: (run.executed_total + run.degraded_total) as f64 / captures as f64,
        });
    }

    let min_availability = rows
        .iter()
        .filter(|r| r.faults > 0)
        .map(|r| r.availability)
        .fold(f64::INFINITY, f64::min);
    let title = format!(
        "Chaos matrix — {} schedules conserved + deterministic (seed {}, min availability {:.3})",
        rows.len(),
        opts.seed,
        min_availability
    );
    let mut report = Report::new("chaos", &title);

    let mut table = ReportTable::new(
        "chaos_gates",
        &title,
        &[
            "Schedule", "Faults", "Captures", "Served", "Retries", "Degraded", "Shed",
            "Abandoned", "Availability",
        ],
    );
    let mut sm = Series::new(
        "chaos_matrix",
        &[
            "schedule", "seed", "duration_s", "faults", "captures", "executed", "retries",
            "shed_lost", "degraded", "abandoned", "degraded_secs", "retry_wait_secs",
            "availability",
        ],
    );
    for r in &rows {
        table.row(&[
            r.name.to_string(),
            r.faults.to_string(),
            r.captures.to_string(),
            r.executed.to_string(),
            r.retries.to_string(),
            r.degraded.to_string(),
            r.shed_lost.to_string(),
            r.abandoned.to_string(),
            f(r.availability, 3),
        ]);
        sm.row(&[
            r.name.to_string(),
            opts.seed.to_string(),
            f(CHAOS_SCHEDULE_SECS, 0),
            r.faults.to_string(),
            r.captures.to_string(),
            r.executed.to_string(),
            r.retries.to_string(),
            r.shed_lost.to_string(),
            r.degraded.to_string(),
            r.abandoned.to_string(),
            f(r.degraded_secs, 4),
            f(r.retry_wait_secs, 4),
            f(r.availability, 6),
        ]);
    }
    report.push_table(table);
    report.push_series(sm);

    report.push_scalar("schedules_run", rows.len() as f64);
    report.push_scalar("min_availability", min_availability);
    report.push_scalar(
        "captures_total",
        rows.iter().map(|r| r.captures as f64).sum::<f64>(),
    );
    report.push_scalar(
        "retries_total",
        rows.iter().map(|r| r.retries as f64).sum::<f64>(),
    );
    report.push_scalar(
        "degraded_total",
        rows.iter().map(|r| r.degraded as f64).sum::<f64>(),
    );
    report.push_note(format!(
        "gates: request conservation (served + shed + degraded + abandoned == captures) \
         and same-seed replay determinism; each schedule ran {CHAOS_SCHEDULE_SECS:.0} \
         virtual seconds twice over a {}-cell cluster",
        opts.cells.unwrap_or(2).max(2)
    ));
    Ok(report)
}
