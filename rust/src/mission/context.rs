//! §5.2.2 dual-stream characterization + the §4.3 triage workflow: run the
//! Context stream at its compute-bound rate, score the text-level presence
//! answers, and demonstrate the context->insight escalation on one scene.

use anyhow::Result;

use crate::cloud::CloudServer;
use crate::coordinator::{classify_intent, IntentLevel, TierId};
use crate::edge::EdgePipeline;
use crate::eval::mask_iou;
use crate::report::{Report, ReportTable};
use crate::streams::fleet::CONTEXT_PROMPTS;
use crate::streams::run_context_mission;
use crate::telemetry::{f, pct};

use super::{Env, Mission, RunOptions};

/// `avery streams` — the dual-stream characterization + triage demo.
pub struct StreamsMission;

impl Mission for StreamsMission {
    fn name(&self) -> &'static str {
        "streams"
    }

    fn summary(&self) -> &'static str {
        "§5.2.2 dual-stream characterization + §4.3 triage demo"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, _opts: &RunOptions) -> Result<Report> {
        run_streams(env)
    }
}

pub fn run_streams(env: &Env) -> Result<Report> {
    let run = run_context_mission(
        &env.engine,
        &env.datasets(),
        &env.lut,
        &env.device,
        60.0,
        &CONTEXT_PROMPTS,
    )?;
    let title = "Dual-stream characterization (§5.2.2)";
    let mut report = Report::new("streams", title);
    let mut table = ReportTable::new("dual_stream", title, &["Metric", "Paper", "Measured"]);
    table.row(&[
        "Context on-device latency (s)".to_string(),
        "-".to_string(),
        f(run.edge_latency_s, 4),
    ]);
    table.row(&[
        "Insight head on-device latency (s)".to_string(),
        "0.2318".to_string(),
        f(run.insight_edge_latency_s, 4),
    ]);
    table.row(&["Context speedup".to_string(), "6.4x".to_string(), format!("{:.1}x", run.speedup)]);
    table.row(&[
        "Context achieved PPS (60 s window)".to_string(),
        "real-time".to_string(),
        f(run.achieved_pps, 2),
    ]);
    table.row(&[
        "Context presence accuracy".to_string(),
        "-".to_string(),
        pct(run.presence_accuracy),
    ]);
    report.push_table(table);
    report.push_scalar("context_edge_latency_s", run.edge_latency_s);
    report.push_scalar("context_speedup", run.speedup);
    report.push_scalar("context_achieved_pps", run.achieved_pps);
    report.push_scalar("context_presence_accuracy", run.presence_accuracy);

    // ---- Triage escalation demo (paper §4.3 workflow). ----
    report.push_note("\nTriage workflow demo (§4.3):");
    let scene = &env.flood_val.scenes[0];
    let mut edge = EdgePipeline::new(env.engine.clone(), env.device.clone(), env.lut.clone());
    let server = CloudServer::new(env.engine.clone());

    let ctx_prompt = "are there any living beings on the rooftops";
    let ctx_intent = classify_intent(ctx_prompt);
    assert_eq!(ctx_intent.level, IntentLevel::Context);
    let (pkt, _) = edge.capture_context(scene, 0.0)?;
    let resp = server.process(&pkt, &ctx_intent.token_ids, "ft")?;
    report.push_note(format!("  operator> {ctx_prompt}"));
    report.push_note(format!("  avery  > {}", resp.text_answer(&["person", "vehicle"])));

    let ins_prompt = "highlight the people stranded by the flood";
    let ins_intent = classify_intent(ins_prompt);
    assert_eq!(ins_intent.level, IntentLevel::Insight);
    let (pkt, _) = edge.capture_insight(scene, 1, TierId::HighAccuracy, 1.0)?;
    let resp = server.process(&pkt, &ins_intent.token_ids, "ft")?;
    let logits = resp.mask_logits.as_ref().unwrap();
    let class = ins_intent.target_class.unwrap_or(0);
    let s = mask_iou(logits.as_f32()?, &scene.masks[class], 0.0);
    let iou = if s.union > 0.0 { s.intersection / s.union } else { 1.0 };
    report.push_note(format!("  operator> {ins_prompt}"));
    report.push_note(format!(
        "  avery  > [segmentation mask, {} px, IoU vs GT {:.3}]",
        logits.as_f32()?.iter().filter(|&&v| v > 0.0).count(),
        iou
    ));
    report.push_scalar("triage_insight_iou", iou);
    Ok(report)
}
