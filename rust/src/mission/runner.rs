//! The parallel mission runner — `avery all --jobs N` and the simkernel
//! bench fan missions out over scoped worker threads (DESIGN.md "Execution
//! backends & parallel runner").
//!
//! Design constraints, in order:
//!
//! 1. **Output bytes cannot change.**  Workers only *compute* reports;
//!    the caller renders them (stdout tables / JSON / CSV files) serially,
//!    in the caller's mission order.  Reports are wall-clock- and path-free
//!    (see `crate::report`), so a mission's report is identical no matter
//!    which worker ran it or when.
//! 2. **No shared engine bottleneck on the synthetic path.**  Synthetic
//!    workers each build their own [`Env`] (cheap: no I/O), so parallel
//!    missions never serialize behind one engine thread.  The artifacts
//!    path instead builds ONE `Env` up front and shares it — `Env::load`
//!    is expensive (PJRT engine, lazy artifact compilation, device weight
//!    uploads) and duplicating it per worker would multiply compile time
//!    and device memory; the engine handle is thread-safe, and PJRT
//!    execution serializes at its dedicated thread regardless.
//! 3. **Balanced schedule.**  Workers pull mission indices from a shared
//!    atomic cursor over a heaviest-first ordering (composed missions like
//!    fig10/headline re-run fig9 internally and dominate wall time), so
//!    the longest mission starts first and the others pack around it.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::report::Report;
use crate::runtime::ExecMode;

use super::{Env, Mission, RunOptions};

/// How a runner worker builds its [`Env`] — resolved once by the caller so
/// parallel workers neither race artifact discovery nor repeat the
/// synthetic-fallback notice.
#[derive(Clone, Debug)]
pub enum EnvSpec {
    /// Load the PJRT artifacts from `dir`.
    Artifacts { dir: PathBuf, mode: ExecMode },
    /// The artifact-free inline synthetic environment.
    Synthetic,
}

impl EnvSpec {
    /// The one place artifact discovery becomes an environment choice
    /// (shared by the CLI and `Env::load_or_synthetic`): an *explicitly
    /// named* artifacts dir that cannot be found is an error (the caller
    /// asked for it); discovery failure falls through to the synthetic
    /// path with a one-time notice.
    pub fn resolve(explicit_artifacts: Option<&str>, mode: ExecMode) -> Result<Self> {
        if explicit_artifacts.is_some() {
            let dir = crate::find_artifacts(explicit_artifacts)?;
            return Ok(EnvSpec::Artifacts { dir, mode });
        }
        match crate::find_artifacts(None) {
            Ok(dir) => Ok(EnvSpec::Artifacts { dir, mode }),
            Err(_) => {
                eprintln!(
                    "artifacts/ not found — running the synthetic closed-form engine \
                     (control plane exact, numerics simulated; `make artifacts` for \
                     the real model)"
                );
                Ok(EnvSpec::Synthetic)
            }
        }
    }

    pub fn build(&self, out_dir: &Path) -> Result<Env> {
        match self {
            EnvSpec::Artifacts { dir, mode } => Env::load(dir, out_dir, *mode),
            EnvSpec::Synthetic => Env::synthetic(out_dir),
        }
    }
}

/// Static wall-time ordering for the LPT-style schedule: lower rank =
/// scheduled earlier.  Only a heuristic — correctness never depends on it.
fn cost_rank(name: &str) -> usize {
    match name {
        "fig10" => 0,    // fig9 + trade-off sweep
        "headline" => 1, // fig9 + baselines
        "fig9" => 2,
        "fleet" => 3,
        "scenario" => 4,
        "streams" => 5,
        "fig8" => 6,
        "fig7" => 7,
        _ => 8,
    }
}

/// Run every mission against `opts`, `jobs` at a time, and return the
/// reports **in input order** (the caller renders them serially, so stdout,
/// JSON and CSV bytes match a `jobs = 1` run exactly).  Synthetic workers
/// build their own environment; the artifacts environment is built once,
/// up front, and shared — and if that build fails, every mission fails
/// immediately instead of retrying the expensive load per mission.
pub fn run_collect(
    missions: &[Box<dyn Mission>],
    spec: &EnvSpec,
    out_dir: &Path,
    opts: &RunOptions,
    jobs: usize,
) -> Vec<Result<Report>> {
    let n = missions.len();
    if n == 0 {
        return Vec::new();
    }
    let shared_env: Option<Env> = match spec {
        EnvSpec::Synthetic => None,
        EnvSpec::Artifacts { .. } => match spec.build(out_dir) {
            Ok(e) => Some(e),
            Err(e) => {
                // anyhow::Error is not Clone; replicate the rendered chain.
                let msg = format!("{e:#}");
                return (0..n).map(|_| Err(anyhow!("building environment: {msg}"))).collect();
            }
        },
    };
    let jobs = jobs.clamp(1, n);
    // Serial runs keep registry order end to end; parallel runs schedule
    // heaviest-first (results are still returned in input order).
    let mut order: Vec<usize> = (0..n).collect();
    if jobs > 1 {
        order.sort_by_key(|&i| (cost_rank(missions[i].name()), i));
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<Report>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| {
                let mut own_env: Option<Env> = None;
                loop {
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= n {
                        break;
                    }
                    let i = order[k];
                    let r = match &shared_env {
                        Some(e) => missions[i].run(e, opts),
                        None => {
                            if own_env.is_none() {
                                // Synthetic build: cheap (create_dir_all
                                // only), so a rare failure is retried.
                                match spec.build(out_dir) {
                                    Ok(e) => own_env = Some(e),
                                    Err(e) => {
                                        *slots[i].lock().unwrap() = Some(Err(e));
                                        continue;
                                    }
                                }
                            }
                            missions[i].run(own_env.as_ref().unwrap(), opts)
                        }
                    };
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| Err(anyhow!("mission was never scheduled")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mission::registry;
    use crate::report::to_json;

    #[test]
    fn cost_rank_orders_composed_missions_first() {
        assert!(cost_rank("fig10") < cost_rank("fig9"));
        assert!(cost_rank("headline") < cost_rank("table3"));
        assert_eq!(cost_rank("unknown"), 8);
    }

    #[test]
    fn empty_mission_list_is_a_noop() {
        let r = run_collect(
            &[],
            &EnvSpec::Synthetic,
            Path::new("target/test-out/runner-empty"),
            &RunOptions::default(),
            4,
        );
        assert!(r.is_empty());
    }

    #[test]
    fn parallel_reports_match_serial_for_one_mission_pair() {
        // Full 8-mission parity lives in tests/mission_api.rs; this quick
        // in-crate check covers the runner plumbing with two light missions.
        let missions: Vec<Box<dyn Mission>> = registry()
            .into_iter()
            .filter(|m| matches!(m.name(), "table3" | "fig7"))
            .collect();
        let opts = RunOptions { duration_secs: 60.0, exec_every: 10, ..RunOptions::default() };
        let serial = run_collect(
            &missions,
            &EnvSpec::Synthetic,
            Path::new("target/test-out/runner-serial"),
            &opts,
            1,
        );
        let parallel = run_collect(
            &missions,
            &EnvSpec::Synthetic,
            Path::new("target/test-out/runner-parallel"),
            &opts,
            2,
        );
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(
                to_json(a.as_ref().unwrap()),
                to_json(b.as_ref().unwrap()),
                "parallel run diverged"
            );
        }
    }
}
