//! Headline claims (abstract / §5):
//!   H1 — 93.98% lower energy than full-edge execution of the Insight path.
//!   H2 — 11.2% higher accuracy than raw image compression at matched payload.
//!   H3 — within 0.75% of the static High-Accuracy baseline under dynamics.
//!   H4 — Context stream 6.4x faster on-device than the Insight head.

use anyhow::Result;

use crate::baselines::{eval_raw_compression, eval_split_path, matched_side};
use crate::coordinator::TierId;
use crate::report::{Report, ReportTable};
use crate::telemetry::{f, pct};

use super::fig9::run_fig9;
use super::{Env, Mission, RunOptions};

/// `avery headline` — the abstract's H1..H4 claims.  Needs artifacts: the
/// H2 raw-compression baseline runs the `full_pipeline` artifact, which
/// the synthetic closed-form engine does not serve.
pub struct HeadlineMission;

impl Mission for HeadlineMission {
    fn name(&self) -> &'static str {
        "headline"
    }

    fn summary(&self) -> &'static str {
        "headline claims H1..H4 (abstract vs reproduction)"
    }

    fn needs_artifacts(&self) -> bool {
        true
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        run_headline(env, opts)
    }
}

pub fn run_headline(env: &Env, opts: &RunOptions) -> Result<Report> {
    let title = "Headline claims — paper vs this reproduction";
    let mut report = Report::new("headline", title);
    let mut table = ReportTable::new("claims", title, &["Claim", "Paper", "Measured"]);

    // H1: energy saving of split@1 vs full edge (device model).
    let sp1 = env.device.insight_edge(1);
    let full = env.device.full_edge();
    let h1 = 1.0 - sp1.energy_j / full.energy_j;
    table.row(&[
        "H1 energy saving vs full edge".to_string(),
        "93.98%".to_string(),
        pct(h1),
    ]);

    // H2: split@1 + learned bottleneck vs raw image compression at matched
    // payload, High-Accuracy tier, both corpora pooled.
    let tier = TierId::HighAccuracy;
    let (split_g, _) =
        eval_split_path(&env.engine, &env.generic_val, &env.lut, &env.device, 1, tier)?;
    let (split_f, _) =
        eval_split_path(&env.engine, &env.flood_val, &env.lut, &env.device, 1, tier)?;
    let (raw_g, _) = eval_raw_compression(&env.engine, &env.generic_val, &env.lut, tier)?;
    let (raw_f, _) = eval_raw_compression(&env.engine, &env.flood_val, &env.lut, tier)?;
    let split_acc = 0.5 * (split_g + split_f);
    let raw_acc = 0.5 * (raw_g + raw_f);
    let h2 = split_acc - raw_acc;
    table.row(&[
        format!(
            "H2 accuracy vs raw compression (side {}px)",
            matched_side(&env.lut, tier)
        ),
        "+11.2%".to_string(),
        format!("{:+.2}% ({} vs {})", h2 * 100.0, pct(split_acc), pct(raw_acc)),
    ]);

    // H3 + throughput + H4 come from the dynamic run and the device model.
    let (runs, sub) = run_fig9(env, opts)?;
    report.absorb(sub);
    let avery = &runs[0].summary;
    let ha = &runs[1].summary;
    let h3 = (ha.avg_iou - avery.avg_iou).abs();
    table.row(&[
        "H3 gap to static High-Accuracy".to_string(),
        "<= 0.75%".to_string(),
        pct(h3),
    ]);
    table.row(&[
        "   AVERY sustained PPS (accuracy mode)".to_string(),
        "0.74".to_string(),
        f(avery.avg_pps, 3),
    ]);

    let h4 = env.device.insight_edge(1).latency_s / env.device.context_edge().latency_s;
    table.row(&[
        "H4 context speedup over insight head".to_string(),
        "6.4x".to_string(),
        format!("{h4:.1}x"),
    ]);

    report.push_scalar("h1_energy_saving", h1);
    report.push_scalar("h2_accuracy_gain", h2);
    report.push_scalar("h3_gap_to_static_ha", h3);
    report.push_scalar("h4_context_speedup", h4);
    report.push_table(table);
    Ok(report)
}
