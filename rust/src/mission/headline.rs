//! Headline claims (abstract / §5):
//!   H1 — 93.98% lower energy than full-edge execution of the Insight path.
//!   H2 — 11.2% higher accuracy than raw image compression at matched payload.
//!   H3 — within 0.75% of the static High-Accuracy baseline under dynamics.
//!   H4 — Context stream 6.4x faster on-device than the Insight head.

use anyhow::Result;

use crate::baselines::{eval_raw_compression, eval_split_path, matched_side};
use crate::coordinator::TierId;
use crate::telemetry::{f, pct, Table};

use super::fig9::{run_fig9, Fig9Options};
use super::Env;

pub fn run_headline(env: &Env, fig9_opts: &Fig9Options) -> Result<()> {
    let mut table = Table::new(
        "Headline claims — paper vs this reproduction",
        &["Claim", "Paper", "Measured"],
    );

    // H1: energy saving of split@1 vs full edge (device model).
    let sp1 = env.device.insight_edge(1);
    let full = env.device.full_edge();
    let h1 = 1.0 - sp1.energy_j / full.energy_j;
    table.row(&[
        "H1 energy saving vs full edge".to_string(),
        "93.98%".to_string(),
        pct(h1),
    ]);

    // H2: split@1 + learned bottleneck vs raw image compression at matched
    // payload, High-Accuracy tier, both corpora pooled.
    let tier = TierId::HighAccuracy;
    let (split_g, acc_sg) =
        eval_split_path(&env.engine, &env.generic_val, &env.lut, &env.device, 1, tier)?;
    let (split_f, acc_sf) =
        eval_split_path(&env.engine, &env.flood_val, &env.lut, &env.device, 1, tier)?;
    let (raw_g, acc_rg) = eval_raw_compression(&env.engine, &env.generic_val, &env.lut, tier)?;
    let (raw_f, acc_rf) = eval_raw_compression(&env.engine, &env.flood_val, &env.lut, tier)?;
    let split_acc = 0.5 * (split_g + split_f);
    let raw_acc = 0.5 * (raw_g + raw_f);
    let h2 = split_acc - raw_acc;
    table.row(&[
        format!(
            "H2 accuracy vs raw compression (side {}px)",
            matched_side(&env.lut, tier)
        ),
        "+11.2%".to_string(),
        format!("{:+.2}% ({} vs {})", h2 * 100.0, pct(split_acc), pct(raw_acc)),
    ]);
    let _ = (acc_sg, acc_sf, acc_rg, acc_rf);

    // H3 + throughput + H4 come from the dynamic run and the device model.
    let runs = run_fig9(env, fig9_opts)?;
    let avery = &runs[0].summary;
    let ha = &runs[1].summary;
    let h3 = (ha.avg_iou - avery.avg_iou).abs();
    table.row(&[
        "H3 gap to static High-Accuracy".to_string(),
        "<= 0.75%".to_string(),
        pct(h3),
    ]);
    table.row(&[
        "   AVERY sustained PPS (accuracy mode)".to_string(),
        "0.74".to_string(),
        f(avery.avg_pps, 3),
    ]);

    let h4 = env.device.insight_edge(1).latency_s / env.device.context_edge().latency_s;
    table.row(&[
        "H4 context speedup over insight head".to_string(),
        "6.4x".to_string(),
        format!("{h4:.1}x"),
    ]);

    table.print();
    Ok(())
}
