//! `avery scenario` / `avery run scenario` — run one named scenario from
//! the library end to end: scenario trace + link knobs + fleet composition
//! + intent schedule, over the contended uplink, emitting per-scenario CSV
//! telemetry.
//!
//! The driver is deliberately wall-clock-free: every report cell is a
//! virtual quantity, so two runs with the same `(name, seed, duration)`
//! produce byte-identical summary CSVs *and* byte-identical JSON reports
//! (pinned by `rust/tests/scenario.rs` and `rust/tests/mission_api.rs`).
//! Serving goes through the concurrent [`CloudCluster`] (K cells of
//! worker pools, exactly like `avery fleet`; one pool at the default
//! `--cells 1`) — real PJRT when artifacts are loaded, the synthetic
//! closed-form model otherwise; either way responses are pure functions
//! of the request, so pool scheduling cannot perturb the virtual-time
//! results.

use std::path::Path;

use anyhow::{Context, Result};

use crate::cloud::CloudCluster;
use crate::coordinator::IntentLevel;
use crate::netsim::{BandwidthTrace, SharedLink};
use crate::report::{Report, ReportTable, Series};
use crate::scenario::compile::compile_file;
use crate::scenario::{build, summarize_trace, Scenario};
use crate::streams::fleet::{run_fleet_mission, FleetConfig, FleetRun};
use crate::streams::shard::run_fleet_mission_sharded;
use crate::streams::{MissionConfig, UavRole};
use crate::telemetry::{f, pct};

use super::{Env, Mission, RunOptions};

/// Scenario the mission falls back to when neither `--name` nor
/// `--scenario` selects one.
pub const DEFAULT_SCENARIO: &str = "urban-flood";

/// `avery scenario` — one named disaster/network regime end to end.
pub struct ScenarioMission;

impl Mission for ScenarioMission {
    fn name(&self) -> &'static str {
        "scenario"
    }

    fn summary(&self) -> &'static str {
        "scenario library: named disaster/network regimes (artifact-free capable)"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        Ok(run_scenario(env, opts)?.1)
    }
}

/// Run one scenario and build its report; the raw [`FleetRun`] comes back
/// alongside for programmatic consumers.  With `--manifest PATH` the
/// scenario comes from the compiler (`scenario::compile`); otherwise it is
/// `opts.name`, falling back to `opts.scenario`, then [`DEFAULT_SCENARIO`].
/// Fleet size/workers/goal default to the scenario's own unless overridden.
pub fn run_scenario(env: &Env, opts: &RunOptions) -> Result<(FleetRun, Report)> {
    let sc = match &opts.manifest {
        Some(path) => compile_file(Path::new(path))
            .with_context(|| format!("compiling scenario manifest {path}"))?
            .instantiate(opts.seed, opts.duration_secs),
        None => {
            let name = opts
                .name
                .clone()
                .or_else(|| opts.scenario.clone())
                .unwrap_or_else(|| DEFAULT_SCENARIO.to_string());
            build(&name, opts.seed, opts.duration_secs)?
        }
    };
    run_compiled_scenario(env, opts, &sc)
}

/// Drive one fully-resolved [`Scenario`] end to end — the shared back half
/// of `run_scenario` and the matrix mission (which instantiates compiled
/// scenarios directly, bypassing name/manifest resolution).
pub fn run_compiled_scenario(
    env: &Env,
    opts: &RunOptions,
    sc: &Scenario,
) -> Result<(FleetRun, Report)> {
    let n_uavs = opts.uavs.unwrap_or(sc.fleet.n_uavs).max(1);
    let workers = opts.workers.unwrap_or(sc.fleet.workers).max(1);
    let goal = opts.goal.unwrap_or(sc.goal);

    let trace = BandwidthTrace::generate(&sc.trace);
    let tsum = summarize_trace(&sc.trace, &trace);
    // `--shards` beats the manifest's `[fleet] shards`; both unset keeps
    // the legacy single-threaded event loop byte for byte.
    let shards = opts.shards.or(sc.fleet.shards);

    // Timing charges the amortized tail per *effective* batch bound —
    // capped by fleet size, since batches can only fill from concurrent
    // UAVs (see `run_fleet`).
    let serving = opts.serving();
    let effective_batch = serving.batch_max.min(n_uavs);
    // Cloud cluster: K cells of `workers` workers each; the default K=1
    // delegates to a single pool, byte-identical to the pre-cluster path.
    let mut cluster_cfg = opts.cluster();
    // Chaos layer: a scenario's `[[fault]]` sections arrive pre-bound to
    // mission seconds; union them with any `--fault-plan` specs, then arm
    // the cluster injector + health machine.  Unarmed (the default for
    // every built-in scenario), `faults` stays `None` and the report is
    // byte-identical to the pre-chaos path.
    let mut fault_events = sc.faults.clone();
    fault_events
        .extend(crate::faults::bind_specs(&opts.load_fault_specs()?, opts.duration_secs));
    fault_events.sort_by(|a, b| a.at().partial_cmp(&b.at()).expect("finite fault times"));
    let chaos_armed = !fault_events.is_empty();
    if chaos_armed {
        cluster_cfg.faults =
            Some(crate::faults::FaultPlan::with_events(opts.seed, fault_events)?);
        cluster_cfg.health = opts.health();
    }
    let (retry_budget, retry_backoff_secs, retry_deadline_secs, degrade) =
        opts.resilience(chaos_armed);
    let fleet_cfg = FleetConfig {
        n_uavs,
        mission: MissionConfig {
            duration_secs: opts.duration_secs,
            goal,
            exec_every: opts.exec_every,
            seed: opts.seed,
            hysteresis: sc.hysteresis,
            min_dwell: sc.min_dwell,
            batch_max: effective_batch,
            retry_budget,
            retry_backoff_secs,
            retry_deadline_secs,
            degrade,
            ..MissionConfig::default()
        },
        context_every: sc.fleet.context_every,
        stagger_secs: sc.fleet.stagger_secs,
        // Utilization denominator: total workers across all cells.
        workers: workers * cluster_cfg.cells,
        schedule: sc.schedule.clone(),
    };

    let (run, cluster_stats, chaos_stats, sharded_injected) = match shards {
        Some(t) => {
            let sharded = run_fleet_mission_sharded(
                &env.engine,
                &env.datasets(),
                &env.lut,
                &env.device,
                &trace,
                &sc.link,
                &fleet_cfg,
                &cluster_cfg,
                workers,
                t,
            )?;
            (sharded.run, sharded.cluster_stats, None, sharded.injected)
        }
        None => {
            let mut link = SharedLink::new(trace, sc.link.clone(), n_uavs);
            let cluster = CloudCluster::with_config(
                vec![env.engine.clone(); workers],
                cluster_cfg.clone(),
            );
            let run = run_fleet_mission(
                &env.engine,
                &env.datasets(),
                &env.lut,
                &env.device,
                &mut link,
                &fleet_cfg,
                &cluster,
            )?;
            let chaos = cluster.chaos_stats();
            (run, cluster.stats(), chaos, None)
        }
    };

    let title = format!(
        "Scenario `{}` — {} UAVs, {:.0} min, {:?} | {}",
        sc.name,
        n_uavs,
        opts.duration_secs / 60.0,
        goal,
        sc.summary
    );
    let mut report = Report::new("scenario", &title);

    // ---- CSV series (all virtual-time quantities: byte-stable per seed).
    let stem = format!("scenario_{}", sc.name);
    let mut sm = Series::new(
        &format!("{stem}_summary"),
        &[
            "scenario", "seed", "duration_s", "uavs", "workers", "goal", "delivered",
            "executed", "aggregate_pps", "jain_pps", "avg_iou", "tier_switches",
            "intent_switches", "infeasible_s", "total_energy_j", "trace_mean_mbps",
            "trace_min_mbps", "trace_max_mbps", "trace_outage_s", "trace_regimes",
            "ctx_p50_s", "ctx_p90_s", "ctx_p99_s", "ins_p50_s", "ins_p90_s", "ins_p99_s",
        ],
    );
    sm.row(&[
        sc.name.to_string(),
        opts.seed.to_string(),
        f(opts.duration_secs, 0),
        n_uavs.to_string(),
        workers.to_string(),
        format!("{goal:?}"),
        run.delivered_total.to_string(),
        run.executed_total.to_string(),
        f(run.aggregate_pps, 4),
        f(run.jain_pps, 4),
        f(run.avg_iou, 6),
        run.switches_total.to_string(),
        run.intent_switches_total.to_string(),
        run.infeasible_total.to_string(),
        f(run.total_energy_j, 1),
        f(tsum.mean_mbps, 4),
        f(tsum.min_mbps, 4),
        f(tsum.max_mbps, 4),
        f(tsum.outage_secs, 0),
        tsum.regimes.to_string(),
        f(run.lat_context.p50(), 6),
        f(run.lat_context.p90(), 6),
        f(run.lat_context.p99(), 6),
        f(run.lat_insight.p50(), 6),
        f(run.lat_insight.p90(), 6),
        f(run.lat_insight.p99(), 6),
    ]);
    report.push_series(sm);

    let mut pu = Series::new(
        &format!("{stem}_per_uav"),
        &[
            "uav", "launch_role", "start_t", "seed", "delivered", "executed", "avg_pps",
            "avg_iou", "energy_j", "ha_secs", "bal_secs", "ht_secs", "tier_switches",
            "intent_switches", "infeasible_s", "context_acc",
        ],
    );
    for o in &run.per_uav {
        let s = &o.summary;
        pu.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            f(o.start_t, 1),
            o.seed.to_string(),
            s.delivered.to_string(),
            s.executed.to_string(),
            f(s.avg_pps, 4),
            f(s.avg_iou, 6),
            f(s.total_energy_j, 2),
            f(s.tier_secs[0], 1),
            f(s.tier_secs[1], 1),
            f(s.tier_secs[2], 1),
            s.switches.to_string(),
            s.intent_switches.to_string(),
            s.infeasible_epochs.to_string(),
            f(o.context_accuracy, 4),
        ]);
    }
    report.push_series(pu);

    let mut ep = Series::new(
        &format!("{stem}_epochs"),
        &["uav", "t", "share_true_mbps", "bandwidth_est_mbps", "tier", "stream"],
    );
    for (uav, e) in &run.epochs {
        ep.row(&[
            uav.to_string(),
            f(e.t, 1),
            f(e.bandwidth_true_mbps, 4),
            f(e.bandwidth_est_mbps, 4),
            e.tier.map(|t| t.index() as i64).unwrap_or(-1).to_string(),
            match e.level {
                IntentLevel::Insight => "insight".to_string(),
                IntentLevel::Context => "context".to_string(),
            },
        ]);
    }
    report.push_series(ep);

    // ---- Terminal table ----
    let mut table = ReportTable::new(
        "per_uav",
        &title,
        &[
            "UAV", "Launch", "Start", "Delivered", "Avg PPS", "Avg IoU / Ctx Acc",
            "HA/BAL/HT (s)", "Tier sw", "Intent sw", "Infeasible s",
        ],
    );
    for o in &run.per_uav {
        let s = &o.summary;
        let quality = match o.role {
            UavRole::Insight => pct(s.avg_iou),
            UavRole::Context => format!("{} ctx", pct(o.context_accuracy)),
        };
        table.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            f(o.start_t, 0),
            s.delivered.to_string(),
            f(s.avg_pps, 3),
            quality,
            format!("{:.0}/{:.0}/{:.0}", s.tier_secs[0], s.tier_secs[1], s.tier_secs[2]),
            s.switches.to_string(),
            s.intent_switches.to_string(),
            s.infeasible_epochs.to_string(),
        ]);
    }
    report.push_table(table);

    report.push_scalar("uavs", n_uavs as f64);
    report.push_scalar("workers", workers as f64);
    report.push_scalar("delivered", run.delivered_total as f64);
    report.push_scalar("executed", run.executed_total as f64);
    report.push_scalar("aggregate_pps", run.aggregate_pps);
    report.push_scalar("jain_pps", run.jain_pps);
    report.push_scalar("avg_iou", run.avg_iou);
    report.push_scalar("tier_switches", run.switches_total as f64);
    report.push_scalar("intent_switches", run.intent_switches_total as f64);
    report.push_scalar("infeasible_s", run.infeasible_total as f64);
    report.push_scalar("total_energy_j", run.total_energy_j);
    report.push_scalar("trace_mean_mbps", tsum.mean_mbps);
    report.push_scalar("trace_outage_s", tsum.outage_secs);
    report.push_scalar("trace_regimes", tsum.regimes as f64);

    // Tail percentiles per stream class: virtual-time histograms, so these
    // stay byte-stable per `(name, seed, duration)` like every other cell.
    super::push_latency_telemetry(
        &mut report,
        "Per-class request latency (virtual seconds)",
        &run.lat_context,
        &run.lat_insight,
    );

    // Serving-layer telemetry, only when a serving feature is enabled —
    // default scenario reports stay byte-identical to the pre-layer ones
    // (pinned by the mission-api golden JSON test).
    if serving.enabled() {
        super::push_serving_telemetry(
            &mut report,
            &format!("{stem}_serving"),
            "launch_role",
            &run.per_uav,
            &serving,
            effective_batch,
            &cluster_stats.total,
        );
    }
    // Cluster telemetry likewise only exists past K=1.
    if cluster_cfg.multi_cell() {
        super::push_cluster_telemetry(
            &mut report,
            &format!("{stem}_cluster"),
            &run,
            &cluster_cfg,
            &cluster_stats,
        );
    }
    // Chaos telemetry only exists when a fault schedule was armed.  On the
    // sharded path injector counts come from the per-agent injectors and
    // there is no cluster-level health machine (`cs` stays None).
    if chaos_armed {
        let injected = chaos_stats
            .as_ref()
            .map(|s| s.injected)
            .or(sharded_injected)
            .unwrap_or([0; 5]);
        super::push_chaos_telemetry(
            &mut report,
            &format!("{stem}_chaos"),
            &run,
            &injected,
            chaos_stats.as_ref(),
        );
    }

    report.push_note(format!(
        "trace: mean {:.1} Mbps in [{:.2}, {:.1}], {} regimes, {:.0} s outage",
        tsum.mean_mbps, tsum.min_mbps, tsum.max_mbps, tsum.regimes, tsum.outage_secs
    ));
    report.push_note(format!(
        "fleet: {:.2} PPS aggregate, Jain {:.3}, avg IoU {}, {} tier switches, \
         {} intent switches, {} infeasible s",
        run.aggregate_pps,
        run.jain_pps,
        pct(run.avg_iou),
        run.switches_total,
        run.intent_switches_total,
        run.infeasible_total
    ));
    Ok((run, report))
}
