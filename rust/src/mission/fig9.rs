//! Figure 9 — the 20-minute dynamic evaluation in "Prioritize Accuracy"
//! mode: (a) bandwidth trace, (b) runtime tier switching, (c) accuracy for
//! Original and Fine-tuned models, (d) throughput of AVERY vs the three
//! static-tier baselines — all over the same scripted trace.

use anyhow::Result;

use crate::coordinator::{MissionGoal, TierId};
use crate::netsim::{BandwidthTrace, Link, LinkConfig, TraceConfig};
use crate::streams::{run_insight_mission, InsightRun, MissionConfig, Policy};
use crate::telemetry::{f, pct, Csv, Table};

use super::Env;

#[derive(Clone, Debug)]
pub struct Fig9Options {
    pub duration_secs: f64,
    pub goal: MissionGoal,
    /// Execute HLO on every Nth packet (1 = all; raise to speed up).
    pub exec_every: usize,
    /// Hysteresis ablation: also run AVERY with this margin and report the
    /// switch-count delta.
    pub ablate_hysteresis: Option<f64>,
    pub seed: u64,
    /// Run the dynamic comparison over a scenario-library trace + link
    /// instead of the paper's script (`--scenario NAME`).
    pub scenario: Option<String>,
}

impl Default for Fig9Options {
    fn default() -> Self {
        Self {
            duration_secs: 1200.0,
            goal: MissionGoal::PrioritizeAccuracy,
            exec_every: 1,
            ablate_hysteresis: None,
            seed: 7,
            scenario: None,
        }
    }
}

pub fn run_fig9(env: &Env, opts: &Fig9Options) -> Result<Vec<InsightRun>> {
    // Either the paper's 20-minute script or a scenario-library regime
    // (trace, link knobs and controller hysteresis/dwell; intent schedules
    // are a fleet/scenario-driver concern — this comparison keeps the
    // standing Insight intent fixed so the static-tier baselines stay
    // comparable).
    let (trace_cfg, link_cfg, hysteresis, min_dwell) = match &opts.scenario {
        Some(name) => {
            let sc = crate::scenario::build(name, opts.seed, opts.duration_secs)?;
            println!("fig9 over scenario `{}`: {}", sc.name, sc.summary);
            (sc.trace, sc.link, sc.hysteresis, sc.min_dwell)
        }
        None => (
            TraceConfig::paper_20min(opts.seed).scaled_to(opts.duration_secs),
            LinkConfig { seed: opts.seed, ..LinkConfig::default() },
            0.0,
            0,
        ),
    };
    let trace = BandwidthTrace::generate(&trace_cfg);

    let mission = MissionConfig {
        duration_secs: opts.duration_secs,
        goal: opts.goal,
        exec_every: opts.exec_every,
        seed: opts.seed,
        hysteresis,
        min_dwell,
        ..MissionConfig::default()
    };

    let policies = [
        Policy::Avery,
        Policy::Static(TierId::HighAccuracy),
        Policy::Static(TierId::Balanced),
        Policy::Static(TierId::HighThroughput),
    ];
    let mut runs = Vec::new();
    for policy in policies {
        // Fresh link per run: every policy sees the same trace.
        let mut link = Link::new(trace.clone(), link_cfg.clone());
        let run = run_insight_mission(
            &env.engine,
            &env.datasets(),
            &env.lut,
            &env.device,
            &mut link,
            &mission,
            policy,
        )?;
        runs.push(run);
    }

    // ---- CSVs ----
    // (a)+(b): per-second bandwidth + AVERY tier timeline.
    let mut tl = Csv::create(
        &env.out_dir.join("fig9_timeline.csv"),
        &["t", "bandwidth_true_mbps", "bandwidth_est_mbps", "avery_tier"],
    )?;
    for e in &runs[0].epochs {
        tl.row(&[
            f(e.t, 1),
            f(e.bandwidth_true_mbps, 4),
            f(e.bandwidth_est_mbps, 4),
            e.tier.map(|t| t.index() as i64).unwrap_or(-1).to_string(),
        ])?;
    }
    // (c)+(d): per-policy packets.
    let mut pk = Csv::create(
        &env.out_dir.join("fig9_packets.csv"),
        &["policy", "t_send", "t_deliver", "tier", "corpus", "iou"],
    )?;
    for run in &runs {
        for p in &run.packets {
            pk.row(&[
                run.summary.policy.clone(),
                f(p.t_send, 2),
                f(p.t_deliver, 2),
                p.tier.name().to_string(),
                format!("{:?}", p.corpus),
                p.iou.map(|v| format!("{v:.6}")).unwrap_or_default(),
            ])?;
        }
    }

    // ---- Summary table (the Fig 9 c/d aggregates). ----
    let mut table = Table::new(
        &format!(
            "Figure 9 — {:.0}-minute dynamic run, {:?} (AVERY vs static tiers)",
            opts.duration_secs / 60.0,
            opts.goal
        ),
        &[
            "Policy", "Delivered", "Avg PPS", "Avg IoU", "IoU orig", "IoU ft",
            "Energy (J)", "Switches", "Infeasible s",
        ],
    );
    for run in &runs {
        let s = &run.summary;
        table.row(&[
            s.policy.clone(),
            s.delivered.to_string(),
            f(s.avg_pps, 3),
            pct(s.avg_iou),
            pct(s.avg_iou_orig),
            pct(s.avg_iou_ft),
            f(s.total_energy_j, 0),
            s.switches.to_string(),
            s.infeasible_epochs.to_string(),
        ]);
    }
    table.print();

    let avery = &runs[0].summary;
    let ha = &runs[1].summary;
    let gap = ha.avg_iou - avery.avg_iou;
    println!(
        "AVERY avg IoU within {:.2}% of static High-Accuracy ({} vs {}), paper: within 0.75%",
        gap.abs() * 100.0,
        pct(avery.avg_iou),
        pct(ha.avg_iou)
    );
    println!(
        "AVERY sustained {:.2} PPS vs High-Accuracy {:.2} PPS (paper: 0.74 vs HA collapse)",
        avery.avg_pps, ha.avg_pps
    );
    println!(
        "AVERY tier residency (s): HA {:.0} / BAL {:.0} / HT {:.0}; switches {}",
        avery.tier_secs[0], avery.tier_secs[1], avery.tier_secs[2], avery.switches
    );

    // Optional hysteresis ablation.
    if let Some(h) = opts.ablate_hysteresis {
        let mut link = Link::new(trace.clone(), link_cfg.clone());
        let run = run_insight_mission(
            &env.engine,
            &env.datasets(),
            &env.lut,
            &env.device,
            &mut link,
            &MissionConfig { hysteresis: h, ..mission.clone() },
            Policy::Avery,
        )?;
        println!(
            "ablation: hysteresis {h:.2} -> {} switches (vs {}), avg IoU {} (vs {})",
            run.summary.switches,
            avery.switches,
            pct(run.summary.avg_iou),
            pct(avery.avg_iou)
        );
    }

    println!("csv: {} / {}", tl.path.display(), pk.path.display());
    Ok(runs)
}
