//! Figure 9 — the 20-minute dynamic evaluation in "Prioritize Accuracy"
//! mode: (a) bandwidth trace, (b) runtime tier switching, (c) accuracy for
//! Original and Fine-tuned models, (d) throughput of AVERY vs the three
//! static-tier baselines — all over the same scripted trace.

use anyhow::Result;

use crate::coordinator::{MissionGoal, TierId};
use crate::netsim::{BandwidthTrace, Link, LinkConfig, TraceConfig};
use crate::report::{Report, ReportTable, Series};
use crate::streams::{run_insight_mission, InsightRun, MissionConfig, Policy};
use crate::telemetry::{f, pct};

use super::{Env, Mission, RunOptions};

/// `avery fig9` — the dynamic AVERY-vs-static-tiers comparison.
pub struct Fig9Mission;

impl Mission for Fig9Mission {
    fn name(&self) -> &'static str {
        "fig9"
    }

    fn summary(&self) -> &'static str {
        "Fig 9 — 20-min dynamic run, AVERY vs static tiers"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        Ok(run_fig9(env, opts)?.1)
    }
}

/// Run the dynamic comparison and build its report.  The raw
/// [`InsightRun`]s come back alongside so composed missions (fig10,
/// headline) and programmatic callers can consume the full telemetry.
pub fn run_fig9(env: &Env, opts: &RunOptions) -> Result<(Vec<InsightRun>, Report)> {
    // Either the paper's 20-minute script or a scenario-library regime
    // (trace, link knobs and controller hysteresis/dwell; intent schedules
    // are a fleet/scenario-driver concern — this comparison keeps the
    // standing Insight intent fixed so the static-tier baselines stay
    // comparable).  Under a scenario the regime's own goal applies unless
    // the caller set one explicitly.
    let (trace_cfg, link_cfg, hysteresis, min_dwell, scenario_goal) = match &opts.scenario {
        Some(name) => {
            let sc = crate::scenario::build(name, opts.seed, opts.duration_secs)?;
            eprintln!("fig9 over scenario `{}`: {}", sc.name, sc.summary);
            (sc.trace, sc.link, sc.hysteresis, sc.min_dwell, Some(sc.goal))
        }
        None => (
            TraceConfig::paper_20min(opts.seed).scaled_to(opts.duration_secs),
            LinkConfig { seed: opts.seed, ..LinkConfig::default() },
            0.0,
            0,
            None,
        ),
    };
    let goal = opts.goal.or(scenario_goal).unwrap_or(MissionGoal::PrioritizeAccuracy);
    let trace = BandwidthTrace::generate(&trace_cfg);

    let mission = MissionConfig {
        duration_secs: opts.duration_secs,
        goal,
        exec_every: opts.exec_every,
        seed: opts.seed,
        hysteresis,
        min_dwell,
        ..MissionConfig::default()
    };

    let policies = [
        Policy::Avery,
        Policy::Static(TierId::HighAccuracy),
        Policy::Static(TierId::Balanced),
        Policy::Static(TierId::HighThroughput),
    ];
    let mut runs = Vec::new();
    for policy in policies {
        // Fresh link per run: every policy sees the same trace.
        let mut link = Link::new(trace.clone(), link_cfg.clone());
        let run = run_insight_mission(
            &env.engine,
            &env.datasets(),
            &env.lut,
            &env.device,
            &mut link,
            &mission,
            policy,
        )?;
        runs.push(run);
    }

    let title = format!(
        "Figure 9 — {:.0}-minute dynamic run, {:?} (AVERY vs static tiers)",
        opts.duration_secs / 60.0,
        goal
    );
    let mut report = Report::new("fig9", &title);

    // (a)+(b): per-second bandwidth + AVERY tier timeline.
    let mut tl = Series::new(
        "fig9_timeline",
        &["t", "bandwidth_true_mbps", "bandwidth_est_mbps", "avery_tier"],
    );
    for e in &runs[0].epochs {
        tl.row(&[
            f(e.t, 1),
            f(e.bandwidth_true_mbps, 4),
            f(e.bandwidth_est_mbps, 4),
            e.tier.map(|t| t.index() as i64).unwrap_or(-1).to_string(),
        ]);
    }
    report.push_series(tl);

    // (c)+(d): per-policy packets.
    let mut pk = Series::new(
        "fig9_packets",
        &["policy", "t_send", "t_deliver", "tier", "corpus", "iou"],
    );
    for run in &runs {
        for p in &run.packets {
            pk.row(&[
                run.summary.policy.clone(),
                f(p.t_send, 2),
                f(p.t_deliver, 2),
                p.tier.name().to_string(),
                format!("{:?}", p.corpus),
                p.iou.map(|v| format!("{v:.6}")).unwrap_or_default(),
            ]);
        }
    }
    report.push_series(pk);

    // ---- Summary table (the Fig 9 c/d aggregates). ----
    let mut table = ReportTable::new(
        "dynamic_run",
        &title,
        &[
            "Policy", "Delivered", "Avg PPS", "Avg IoU", "IoU orig", "IoU ft",
            "Energy (J)", "Switches", "Infeasible s",
        ],
    );
    for run in &runs {
        let s = &run.summary;
        table.row(&[
            s.policy.clone(),
            s.delivered.to_string(),
            f(s.avg_pps, 3),
            pct(s.avg_iou),
            pct(s.avg_iou_orig),
            pct(s.avg_iou_ft),
            f(s.total_energy_j, 0),
            s.switches.to_string(),
            s.infeasible_epochs.to_string(),
        ]);
    }
    report.push_table(table);

    let avery = &runs[0].summary;
    let ha = &runs[1].summary;
    let gap = ha.avg_iou - avery.avg_iou;
    report.push_scalar("avery_avg_pps", avery.avg_pps);
    report.push_scalar("avery_avg_iou", avery.avg_iou);
    report.push_scalar("avery_switches", avery.switches as f64);
    report.push_scalar("static_ha_avg_pps", ha.avg_pps);
    report.push_scalar("static_ha_avg_iou", ha.avg_iou);
    report.push_scalar("iou_gap_vs_static_ha", gap.abs());
    report.push_note(format!(
        "AVERY avg IoU within {:.2}% of static High-Accuracy ({} vs {}), paper: within 0.75%",
        gap.abs() * 100.0,
        pct(avery.avg_iou),
        pct(ha.avg_iou)
    ));
    report.push_note(format!(
        "AVERY sustained {:.2} PPS vs High-Accuracy {:.2} PPS (paper: 0.74 vs HA collapse)",
        avery.avg_pps, ha.avg_pps
    ));
    report.push_note(format!(
        "AVERY tier residency (s): HA {:.0} / BAL {:.0} / HT {:.0}; switches {}",
        avery.tier_secs[0], avery.tier_secs[1], avery.tier_secs[2], avery.switches
    ));

    // Optional hysteresis ablation.
    if let Some(h) = opts.ablate_hysteresis {
        let mut link = Link::new(trace.clone(), link_cfg.clone());
        let run = run_insight_mission(
            &env.engine,
            &env.datasets(),
            &env.lut,
            &env.device,
            &mut link,
            &MissionConfig { hysteresis: h, ..mission.clone() },
            Policy::Avery,
        )?;
        report.push_scalar("ablation_hysteresis_switches", run.summary.switches as f64);
        report.push_scalar("ablation_hysteresis_avg_iou", run.summary.avg_iou);
        report.push_note(format!(
            "ablation: hysteresis {h:.2} -> {} switches (vs {}), avg IoU {} (vs {})",
            run.summary.switches,
            avery.switches,
            pct(run.summary.avg_iou),
            pct(avery.avg_iou)
        ));
    }

    Ok((runs, report))
}
