//! `avery run matrix` — compile a seeded subset of the generated scenario
//! matrix (`scenario::generate`) and run every member end to end, gating
//! each on the golden-trace invariants from the scenario regression suite:
//!
//! * **clamp** — every generated bandwidth sample stays inside its phase's
//!   legal band (the outage floor for `Outage` phases, the configured
//!   `[min, max]` clamp otherwise);
//! * **anti-flap** — the controller, driven exactly like the mission's
//!   Sense stage (EWMA α = 0.4, one observation per epoch) with the
//!   scenario's hysteresis + dwell, never voluntarily flaps A→B→A on
//!   consecutive epochs (only forced evictions of an infeasible B);
//! * **run** — the full fleet mission delivers at least one packet and its
//!   Jain fairness index lands in (0, 1].
//!
//! Every scenario runs at a fixed internal duration so `--duration`
//! (meant for single-mission runs) cannot turn a 16-point smoke into an
//! hours-long sweep; `--matrix-count N` picks the sample size.  The
//! report is wall-clock-free and byte-deterministic per seed, like every
//! other mission (pinned by the `avery all --jobs` parity test).

use anyhow::{Context, Result};

use crate::coordinator::{
    classify_intent, ControllerDecision, Lut, MissionGoal, RuntimeState, SplitController,
    TierId,
};
use crate::netsim::{BandwidthEstimator, BandwidthTrace, PhaseKind, OUTAGE_FLOOR_MBPS};
use crate::report::{Report, ReportTable, Series};
use crate::scenario::compile::compile_str;
use crate::scenario::{generate, Scenario};
use crate::telemetry::f;

use super::{run_compiled_scenario, Env, Mission, RunOptions};

/// Scenarios run per matrix mission when `--matrix-count` is unset.
pub const DEFAULT_MATRIX_COUNT: usize = 16;

/// Fixed per-scenario mission length (virtual seconds).
const MATRIX_SCENARIO_SECS: f64 = 120.0;

/// `avery run matrix` — invariant-gated sweep over generated scenarios.
pub struct MatrixMission;

impl Mission for MatrixMission {
    fn name(&self) -> &'static str {
        "matrix"
    }

    fn summary(&self) -> &'static str {
        "generated scenario matrix: compile + run a seeded subset under invariant gates"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        run_matrix(env, opts)
    }
}

/// One scenario's gate outcomes.
struct GateRow {
    name: String,
    uavs: usize,
    delivered: u64,
    jain: f64,
    /// Insight-class p50/p90/p99 virtual request latency (seconds).
    ins_p: [f64; 3],
    clamp_ok: bool,
    antiflap_ok: bool,
    run_ok: bool,
}

impl GateRow {
    fn pass(&self) -> bool {
        self.clamp_ok && self.antiflap_ok && self.run_ok
    }
}

/// Compile and run the seeded matrix subset; report per-scenario gates.
pub fn run_matrix(env: &Env, opts: &RunOptions) -> Result<Report> {
    let count = opts.matrix_count.unwrap_or(DEFAULT_MATRIX_COUNT).max(1);
    let sample = generate::sample(opts.seed, count);

    // The sweep pins its own per-scenario duration and a coarse execute
    // cadence; everything else (fleet shape, goal, controller knobs) comes
    // from each compiled scenario.
    let child = RunOptions {
        duration_secs: MATRIX_SCENARIO_SECS,
        exec_every: opts.exec_every.max(25),
        seed: opts.seed,
        // Cluster shape passes through so a clustered matrix sweep gates
        // the same serving topology the fleet would run (defaults: K=1).
        cells: opts.cells,
        replicas: opts.replicas,
        hop_latency: opts.hop_latency,
        spill_max: opts.spill_max,
        shards: opts.shards,
        ..RunOptions::default()
    };

    let mut rows = Vec::with_capacity(sample.len());
    for m in &sample {
        let sc = compile_str(&m.text)
            .with_context(|| format!("generated manifest `{}` failed to compile", m.name))?
            .instantiate(opts.seed, MATRIX_SCENARIO_SECS);
        let trace = BandwidthTrace::generate(&sc.trace);
        let clamp_ok = clamp_gate(&sc, &trace);
        let antiflap_ok = antiflap_gate(&sc, &trace);
        let (run, _) = run_compiled_scenario(env, &child, &sc)?;
        let run_ok =
            run.delivered_total > 0 && run.jain_pps > 0.0 && run.jain_pps <= 1.0 + 1e-12;
        rows.push(GateRow {
            name: sc.name.clone(),
            uavs: sc.fleet.n_uavs,
            delivered: run.delivered_total,
            jain: run.jain_pps,
            ins_p: [run.lat_insight.p50(), run.lat_insight.p90(), run.lat_insight.p99()],
            clamp_ok,
            antiflap_ok,
            run_ok,
        });
    }

    let passed = rows.iter().filter(|r| r.pass()).count();
    let failed = rows.len() - passed;
    let title = format!(
        "Scenario matrix — {}/{} gated scenarios passed ({} sampled of {}, seed {})",
        passed,
        rows.len(),
        rows.len(),
        generate::MATRIX_SIZE,
        opts.seed
    );
    let mut report = Report::new("matrix", &title);

    let mut table = ReportTable::new(
        "matrix_gates",
        &title,
        &["Scenario", "UAVs", "Delivered", "Jain", "Clamp", "Anti-flap", "Run", "Pass"],
    );
    let mut sm = Series::new(
        "matrix_summary",
        &[
            "scenario", "seed", "duration_s", "uavs", "delivered", "jain_pps", "ins_p50_s",
            "ins_p90_s", "ins_p99_s", "clamp_ok", "antiflap_ok", "run_ok", "pass",
        ],
    );
    let ok = |b: bool| if b { "ok" } else { "FAIL" }.to_string();
    for r in &rows {
        table.row(&[
            r.name.clone(),
            r.uavs.to_string(),
            r.delivered.to_string(),
            f(r.jain, 3),
            ok(r.clamp_ok),
            ok(r.antiflap_ok),
            ok(r.run_ok),
            ok(r.pass()),
        ]);
        sm.row(&[
            r.name.clone(),
            opts.seed.to_string(),
            f(MATRIX_SCENARIO_SECS, 0),
            r.uavs.to_string(),
            r.delivered.to_string(),
            f(r.jain, 4),
            f(r.ins_p[0], 6),
            f(r.ins_p[1], 6),
            f(r.ins_p[2], 6),
            (r.clamp_ok as u8).to_string(),
            (r.antiflap_ok as u8).to_string(),
            (r.run_ok as u8).to_string(),
            (r.pass() as u8).to_string(),
        ]);
    }
    report.push_table(table);
    report.push_series(sm);

    report.push_scalar("scenarios_run", rows.len() as f64);
    report.push_scalar("passed", passed as f64);
    report.push_scalar("failed", failed as f64);
    report.push_scalar("matrix_count", count as f64);
    report.push_scalar("corpus_size", generate::MATRIX_SIZE as f64);
    report.push_note(format!(
        "gates: clamp band, controller anti-flap, delivery + Jain in (0, 1]; \
         each scenario ran {MATRIX_SCENARIO_SECS:.0} virtual seconds"
    ));
    if failed > 0 {
        let names: Vec<&str> =
            rows.iter().filter(|r| !r.pass()).map(|r| r.name.as_str()).collect();
        report.push_note(format!("FAILED: {}", names.join(", ")));
    }
    Ok(report)
}

/// Every sample stays inside the band of the phase that produced it
/// (walked with the generator's own per-phase rounding).
fn clamp_gate(sc: &Scenario, trace: &BandwidthTrace) -> bool {
    let cfg = &sc.trace;
    let mut idx = 0usize;
    for p in &cfg.phases {
        let n = (p.secs / cfg.dt).round() as usize;
        let lo = match p.kind {
            PhaseKind::Outage => OUTAGE_FLOOR_MBPS,
            _ => cfg.min_mbps,
        };
        for i in idx..(idx + n).min(trace.samples_mbps.len()) {
            let b = trace.samples_mbps[i];
            if !(lo - 1e-9..=cfg.max_mbps + 1e-9).contains(&b) {
                return false;
            }
        }
        idx += n;
    }
    idx == trace.samples_mbps.len()
}

/// Drive the controller over the trace exactly like the mission's Sense
/// stage and reject any voluntary A→B→A flap on consecutive epochs.
fn antiflap_gate(sc: &Scenario, trace: &BandwidthTrace) -> bool {
    let lut = Lut::paper();
    let mut c = SplitController::new(Lut::paper(), 0.5, 6.0);
    c.hysteresis = sc.hysteresis;
    c.min_dwell_decisions = sc.min_dwell;
    let mut est = BandwidthEstimator::new(0.4);
    let intent = classify_intent("highlight the stranded people");
    let mut timeline: Vec<(f64, Option<TierId>)> = Vec::new();
    let mut t = 0.0;
    while t < trace.duration_secs() {
        let e = est.observe(trace.at(t));
        let state = RuntimeState {
            bandwidth_mbps: e,
            power_mode: "MODE_30W_ALL",
            intent: intent.clone(),
        };
        let d = match c.select_configuration(&state, MissionGoal::PrioritizeAccuracy) {
            Ok(ControllerDecision::Insight { tier, .. }) => Some(tier),
            Ok(ControllerDecision::Context { .. }) => None,
            Err(_) => None,
        };
        timeline.push((e, d));
        t += 1.0;
    }
    // With dwell active, A→B→A is legal only as a forced eviction: B went
    // infeasible at the third epoch's estimate.
    sc.min_dwell == 0
        || timeline.windows(3).all(|w| {
            let ((_, a), (_, b), (e2, c2)) = (w[0], w[1], w[2]);
            match (a, b, c2) {
                (Some(a), Some(b), Some(c2)) if a != b && c2 == a => {
                    lut.entry(b).max_pps(e2) < 0.5
                }
                _ => true,
            }
        })
}
