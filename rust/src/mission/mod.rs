//! The Mission API: every table/figure of the paper's evaluation — plus
//! the fleet and scenario missions that go beyond it — behind one uniform
//! contract (see DESIGN.md "Mission API").
//!
//! A [`Mission`] names itself, declares whether it needs the PJRT
//! artifacts, and runs against a shared [`Env`] + [`RunOptions`] to a
//! structured [`Report`] (scalars, tables, CSV series, notes) that the
//! caller renders through the sinks in [`crate::report`].  The
//! [`registry`] enumerates all eleven missions in the canonical `avery
//! all` order; `avery run <name>`, the legacy subcommands, the benches and
//! the integration tests all resolve missions through it.

mod chaos;
mod context;
mod fig10;
mod fig7;
mod fig8;
mod fig9;
mod fleet;
mod headline;
mod matrix;
mod runner;
mod scenario;
mod table3;

pub use chaos::{run_chaos, ChaosMission};
pub use context::{run_streams, StreamsMission};
pub use runner::{run_collect, EnvSpec};
pub use fig10::{run_fig10, Fig10Mission};
pub use fig7::{run_fig7, Fig7Mission};
pub use fig8::{run_fig8, Fig8Mission};
pub use fig9::{run_fig9, Fig9Mission};
pub use fleet::{run_fleet, FleetMission};
pub use headline::{run_headline, HeadlineMission};
pub use matrix::{run_matrix, MatrixMission};
pub use scenario::{run_compiled_scenario, run_scenario, ScenarioMission};
pub use table3::{run_table3, Table3Mission};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cloud::{ClusterConfig, ClusterStats, PoolStats, ServingConfig};
use crate::config::RunConfig;
use crate::coordinator::{Lut, MissionGoal};
use crate::dataset::{Corpus, Dataset};
use crate::energy::DeviceModel;
use crate::manifest::Manifest;
use crate::report::{latency_table, Report, Series};
use crate::runtime::{Engine, ExecMode};
use crate::streams::fleet::{FleetRun, UavOutcome};
use crate::telemetry::{f, LatencyHistogram};

/// Default fleet size when neither the CLI nor a scenario specifies one.
pub const DEFAULT_UAVS: usize = 4;
/// Default cloud-pool worker count.
pub const DEFAULT_WORKERS: usize = 2;

/// One mission behind the uniform API: a named, registry-enumerable driver
/// from `(Env, RunOptions)` to a structured [`Report`].
///
/// `Send + Sync` because the parallel runner ([`run_collect`]) fans
/// registry missions out over scoped worker threads — drivers hold no
/// shared mutable state (everything mission-local hangs off the `Env`
/// and the options they are passed).
pub trait Mission: Send + Sync {
    /// Registry name — also the CLI subcommand (`avery run <name>` and the
    /// legacy `avery <name>` alias).
    fn name(&self) -> &'static str;
    /// One-line description for `avery list`.
    fn summary(&self) -> &'static str;
    /// True when the mission touches artifact-only paths (e.g. the
    /// `full_pipeline` baseline) and cannot fall back to the synthetic
    /// closed-form engine.
    fn needs_artifacts(&self) -> bool;
    /// Run against a loaded environment; pure of rendering — all output
    /// goes through the returned report's sinks.
    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report>;
}

/// Every registered mission, in the canonical `avery all` order.
pub fn registry() -> Vec<Box<dyn Mission>> {
    vec![
        Box::new(Table3Mission),
        Box::new(Fig7Mission),
        Box::new(Fig8Mission),
        Box::new(Fig9Mission),
        Box::new(Fig10Mission),
        Box::new(HeadlineMission),
        Box::new(StreamsMission),
        Box::new(FleetMission),
        Box::new(ScenarioMission),
        Box::new(MatrixMission),
        Box::new(ChaosMission),
    ]
}

/// Resolve one mission by registry name.
pub fn find(name: &str) -> Option<Box<dyn Mission>> {
    registry().into_iter().find(|m| m.name() == name)
}

/// Consolidated options for every mission (the union of what the old
/// per-driver option structs carried).  `None` means "the mission's —
/// or the scenario regime's — default", which is how the scenario-goal
/// override works uniformly: a mission resolves
/// `opts.goal.or(scenario_goal).unwrap_or(default)` instead of the CLI
/// plumbing `*_explicit` flags around.
#[derive(Clone, Debug)]
pub struct RunOptions {
    /// Mission length in virtual seconds.
    pub duration_secs: f64,
    /// Mission goal; `None` = PrioritizeAccuracy, or the scenario's goal
    /// when running under a scenario regime.
    pub goal: Option<MissionGoal>,
    /// Execute HLO on every Nth delivered packet (1 = all).
    pub exec_every: usize,
    /// Trace/workload seed.
    pub seed: u64,
    /// fig9 hysteresis ablation margin (`--hysteresis H`).
    pub ablate_hysteresis: Option<f64>,
    /// Fleet size; `None` = [`DEFAULT_UAVS`] (fleet) or the scenario's.
    pub uavs: Option<usize>,
    /// Cloud workers; `None` = [`DEFAULT_WORKERS`] (fleet) or the scenario's.
    pub workers: Option<usize>,
    /// Scenario regime overlay for fig9/fig10/headline/fleet
    /// (`--scenario NAME`): trace, link knobs, schedule and default goal
    /// come from the scenario library.
    pub scenario: Option<String>,
    /// Scenario to run for the `scenario` mission (`--name NAME`; falls
    /// back to `scenario`, then "urban-flood").
    pub name: Option<String>,
    /// Scenario manifest path for the `scenario` mission
    /// (`--manifest PATH`): compiled through `scenario::compile` and run
    /// in place of a registered name.
    pub manifest: Option<String>,
    /// Matrix mission sample size (`--matrix-count N`); `None` = the
    /// mission's default subset.
    pub matrix_count: Option<usize>,
    /// Cloud serving layer (`--batch-max N`): micro-batch bound; `None` =
    /// 1 (unbatched — byte-identical to the pre-serving-layer pool).
    pub batch_max: Option<usize>,
    /// Cloud serving layer (`--cache-entries N`): response-cache capacity;
    /// `None` = 0 (cache off).
    pub cache_entries: Option<usize>,
    /// Cloud serving layer (`--cache-ttl SECS`): cache TTL in virtual
    /// seconds; `None` = never expire.
    pub cache_ttl: Option<f64>,
    /// Cloud serving layer (`--queue-depth N`): in-flight request bound;
    /// `None` = 0 (unbounded).
    pub queue_depth: Option<usize>,
    /// Deadline budget for Context-class requests in virtual seconds
    /// (`--deadline-context SECS`); `None` = infinite (no deadline).
    pub deadline_context: Option<f64>,
    /// Deadline budget for Insight-class requests (`--deadline-insight
    /// SECS`); `None` = infinite.
    pub deadline_insight: Option<f64>,
    /// Drain the micro-batch queue earliest-deadline-first (`--edf`);
    /// false = FIFO (the default, byte-identical to prior outputs).
    pub edf: bool,
    /// Shed the queued request predicted to miss its deadline instead of
    /// the newest arrival (`--deadline-shed`); false = depth-based shed.
    pub deadline_shed: bool,
    /// Cloud cluster (`--cells K`): serving cells behind the
    /// consistent-hash router; `None` = 1 (single pool — the cluster
    /// delegates and output is byte-identical to the pre-cluster path).
    pub cells: Option<usize>,
    /// Cloud cluster (`--replicas R`): response-cache replication factor;
    /// `None` = 1 (home cell only, no sibling probes).
    pub replicas: Option<usize>,
    /// Cloud cluster (`--hop-latency SECS`): modeled inter-cell latency
    /// charged per ring hop; `None` = `cloud::DEFAULT_HOP_LATENCY_SECS`.
    pub hop_latency: Option<f64>,
    /// Cloud cluster (`--spill-max H`): max ring hops past the home cell
    /// before a typed shed; `None` = 1.
    pub spill_max: Option<u32>,
    /// Chaos layer (`--fault-plan PATH`): standalone `[[fault]]` manifest
    /// compiled into a fraction-based schedule; `None` = no injected
    /// faults unless a scenario manifest declares them or `fault_specs`
    /// is set programmatically.
    pub fault_plan: Option<String>,
    /// Programmatic fault schedule (benches/tests inject here without a
    /// manifest file); unioned after any `fault_plan` specs.
    pub fault_specs: Vec<crate::faults::FaultSpec>,
    /// Agent resilience (`--retry-budget N`); `None` = 0, or
    /// [`CHAOS_DEFAULT_RETRY_BUDGET`] once the chaos layer is armed.
    pub retry_budget: Option<u32>,
    /// Agent resilience (`--retry-backoff SECS`); `None` = 0.05.
    pub retry_backoff: Option<f64>,
    /// Agent resilience (`--retry-deadline SECS`); `None` = infinite.
    pub retry_deadline: Option<f64>,
    /// Agent resilience (`--degrade`); `None` = off, or on once the chaos
    /// layer is armed.
    pub degrade: Option<bool>,
    /// Cell health (`--probe-backoff SECS`): first re-probe backoff;
    /// `None` = the health-machine default.
    pub probe_backoff: Option<f64>,
    /// Megafleet core (`--shards T`): run the fleet on the sharded
    /// epoch-quantized scheduler with T worker shards.  `None` = the
    /// unsharded event loop (byte-identical to pre-shard output); any
    /// `Some(T)` takes the epoch-quantized path, whose output is
    /// identical for every T (see DESIGN.md "Megafleet core").
    pub shards: Option<usize>,
}

/// Retry budget the resilience layer defaults to once faults are armed
/// and the user left `--retry-budget` unset.
pub const CHAOS_DEFAULT_RETRY_BUDGET: u32 = 2;

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            duration_secs: 1200.0,
            goal: None,
            exec_every: 1,
            seed: 7,
            ablate_hysteresis: None,
            uavs: None,
            workers: None,
            scenario: None,
            name: None,
            manifest: None,
            matrix_count: None,
            batch_max: None,
            cache_entries: None,
            cache_ttl: None,
            queue_depth: None,
            deadline_context: None,
            deadline_insight: None,
            edf: false,
            deadline_shed: false,
            cells: None,
            replicas: None,
            hop_latency: None,
            spill_max: None,
            fault_plan: None,
            fault_specs: Vec::new(),
            retry_budget: None,
            retry_backoff: None,
            retry_deadline: None,
            degrade: None,
            probe_backoff: None,
            shards: None,
        }
    }
}

impl RunOptions {
    /// The single place a [`RunConfig`] becomes mission options.
    pub fn from_config(cfg: &RunConfig) -> Self {
        Self {
            duration_secs: cfg.duration_secs,
            goal: cfg.goal,
            exec_every: cfg.exec_every,
            seed: cfg.seed,
            ablate_hysteresis: cfg.hysteresis,
            uavs: cfg.uavs,
            workers: cfg.workers,
            scenario: cfg.scenario.clone(),
            name: cfg.name.clone(),
            manifest: cfg.manifest.clone(),
            matrix_count: cfg.matrix_count,
            batch_max: cfg.batch_max,
            cache_entries: cfg.cache_entries,
            cache_ttl: cfg.cache_ttl,
            queue_depth: cfg.queue_depth,
            deadline_context: cfg.deadline_context,
            deadline_insight: cfg.deadline_insight,
            edf: cfg.edf,
            deadline_shed: cfg.deadline_shed,
            cells: cfg.cells,
            replicas: cfg.replicas,
            hop_latency: cfg.hop_latency,
            spill_max: cfg.spill_max,
            fault_plan: cfg.fault_plan.clone(),
            fault_specs: Vec::new(),
            retry_budget: cfg.retry_budget,
            retry_backoff: cfg.retry_backoff,
            retry_deadline: cfg.retry_deadline,
            degrade: cfg.degrade,
            probe_backoff: cfg.probe_backoff,
            shards: cfg.shards,
        }
    }

    /// The cloud serving configuration these options select — defaults
    /// reproduce the pre-serving-layer pool byte-for-byte (no batching,
    /// no cache, unbounded queue; see `cloud::ServingConfig`).
    pub fn serving(&self) -> crate::cloud::ServingConfig {
        crate::cloud::ServingConfig {
            batch_max: self.batch_max.unwrap_or(1).max(1),
            cache_entries: self.cache_entries.unwrap_or(0),
            cache_ttl_secs: self.cache_ttl.unwrap_or(f64::INFINITY),
            queue_depth: self.queue_depth.unwrap_or(0),
            admission: crate::cloud::AdmissionPolicy::Shed,
            deadline_context_secs: self.deadline_context.unwrap_or(f64::INFINITY),
            deadline_insight_secs: self.deadline_insight.unwrap_or(f64::INFINITY),
            edf: self.edf,
            deadline_shed: self.deadline_shed,
        }
    }

    /// The cloud cluster configuration these options select — defaults
    /// (one cell, one replica) make [`crate::cloud::CloudCluster`] delegate
    /// straight to its single pool, byte-identical to the pre-cluster path.
    pub fn cluster(&self) -> crate::cloud::ClusterConfig {
        crate::cloud::ClusterConfig {
            cells: self.cells.unwrap_or(1).max(1),
            replicas: self.replicas.unwrap_or(1).max(1),
            hop_latency_secs: self
                .hop_latency
                .unwrap_or(crate::cloud::DEFAULT_HOP_LATENCY_SECS),
            spill_max: self.spill_max.unwrap_or(1),
            serving: self.serving(),
            // Chaos arming happens at the mission drivers (they union
            // scenario + CLI fault specs first); options alone never arm.
            faults: None,
            health: crate::cloud::HealthConfig::default(),
        }
    }

    /// Resolve the fraction-based fault schedule these options select:
    /// the `--fault-plan` manifest's specs (if any) followed by any
    /// programmatic `fault_specs`.  Empty = chaos layer disarmed.
    pub fn load_fault_specs(&self) -> Result<Vec<crate::faults::FaultSpec>> {
        let mut specs = match &self.fault_plan {
            None => Vec::new(),
            Some(path) => {
                crate::scenario::compile::compile_fault_plan_file(Path::new(path))
                    .with_context(|| format!("compiling fault plan {path}"))?
            }
        };
        specs.extend(self.fault_specs.iter().cloned());
        Ok(specs)
    }

    /// The cell-health recovery configuration these options select.
    pub fn health(&self) -> crate::cloud::HealthConfig {
        let mut h = crate::cloud::HealthConfig::default();
        if let Some(b) = self.probe_backoff {
            h.backoff_base_secs = b;
        }
        h
    }

    /// Effective agent-resilience knobs: `(retry_budget, retry_backoff,
    /// retry_deadline, degrade)`.  With the chaos layer armed, unset
    /// budget/degrade default on (a fault plan with no recovery path
    /// would only measure losses); disarmed, everything defaults off so
    /// flag-free runs stay byte-identical.
    pub fn resilience(&self, chaos_armed: bool) -> (u32, f64, f64, bool) {
        let budget = self
            .retry_budget
            .unwrap_or(if chaos_armed { CHAOS_DEFAULT_RETRY_BUDGET } else { 0 });
        let backoff = self.retry_backoff.unwrap_or(0.05);
        let deadline = self.retry_deadline.unwrap_or(f64::INFINITY);
        let degrade = self.degrade.unwrap_or(chaos_armed);
        (budget, backoff, deadline, degrade)
    }
}

/// Append the serving-layer telemetry shared by the fleet and scenario
/// reports: a per-UAV `<series_name>` CSV series plus the cache/admission
/// scalars and a summary note.  Callers invoke this ONLY when a serving
/// feature is enabled, so off-mode reports stay byte-identical to the
/// pre-serving-layer ones.  Every surfaced counter is a deterministic
/// count of the event-ordered request stream (never wall-clock).
pub(crate) fn push_serving_telemetry(
    report: &mut Report,
    series_name: &str,
    role_header: &str,
    per_uav: &[UavOutcome],
    serving: &ServingConfig,
    effective_batch: usize,
    ps: &PoolStats,
) {
    let mut sv =
        Series::new(series_name, &["uav", role_header, "executed", "cache_hits", "hit_rate"]);
    for o in per_uav {
        let s = &o.summary;
        sv.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            s.executed.to_string(),
            s.cache_hits.to_string(),
            f(s.cache_hits as f64 / s.executed.max(1) as f64, 4),
        ]);
    }
    report.push_series(sv);
    report.push_scalar("batch_max", serving.batch_max as f64);
    // What the timing model actually charged: the flag capped by fleet
    // size (batches can only fill from concurrent UAVs).
    report.push_scalar("batch_max_effective", effective_batch as f64);
    report.push_scalar("cache_entries", serving.cache_entries as f64);
    report.push_scalar("cache_hits", ps.cache_hits as f64);
    report.push_scalar("cache_misses", ps.cache_misses as f64);
    report.push_scalar("cache_evictions", ps.cache_evictions as f64);
    report.push_scalar("cache_expirations", ps.cache_expirations as f64);
    report.push_scalar("cache_hit_rate", ps.cache_hit_rate());
    report.push_scalar("shed", ps.shed as f64);
    report.push_scalar("shed_context", ps.shed_context as f64);
    report.push_scalar("shed_insight", ps.shed_insight as f64);
    report.push_note(format!(
        "serving: batch_max {}, cache {}/{} hits ({} entries, {} evictions, {} expired), \
         {} shed",
        serving.batch_max,
        ps.cache_hits,
        ps.cache_hits + ps.cache_misses,
        serving.cache_entries,
        ps.cache_evictions,
        ps.cache_expirations,
        ps.shed
    ));
}

/// Append the cluster-layer telemetry shared by the fleet and scenario
/// reports: per-cell and spill-hop CSV series, per-UAV cells-hit rows, and
/// the routing/spill/replication scalars.  Callers invoke this ONLY when
/// the cluster is multi-cell, so single-pool runs stay byte-identical to
/// the pre-cluster reports.  Everything surfaced is a deterministic count
/// of the event-ordered request stream (never wall-clock).
pub(crate) fn push_cluster_telemetry(
    report: &mut Report,
    series_prefix: &str,
    run: &FleetRun,
    cluster: &ClusterConfig,
    st: &ClusterStats,
) {
    let mut cells = Series::new(
        &format!("{series_prefix}_cells"),
        &["cell", "completed", "batches", "cache_hits", "cache_misses", "remote_hits", "shed"],
    );
    for (i, ps) in st.per_cell.iter().enumerate() {
        cells.row(&[
            i.to_string(),
            ps.completed.to_string(),
            ps.batches.to_string(),
            ps.cache_hits.to_string(),
            ps.cache_misses.to_string(),
            st.remote_hits[i].to_string(),
            ps.shed.to_string(),
        ]);
    }
    report.push_series(cells);

    let mut hops = Series::new(&format!("{series_prefix}_spill_hops"), &["hop", "served"]);
    for (h, n) in st.served_at_hop.iter().enumerate() {
        hops.row(&[h.to_string(), n.to_string()]);
    }
    report.push_series(hops);

    let mut uc = Series::new(
        &format!("{series_prefix}_uav_cells"),
        &["uav", "role", "spill_hops", "remote_hits", "cells_hit"],
    );
    for o in &run.per_uav {
        let s = &o.summary;
        uc.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            s.spill_hops.to_string(),
            s.remote_hits.to_string(),
            s.cells_mask.count_ones().to_string(),
        ]);
    }
    report.push_series(uc);

    report.push_scalar("cells", st.cells as f64);
    report.push_scalar("replicas", cluster.replicas as f64);
    report.push_scalar("spill_max", cluster.spill_max as f64);
    report.push_scalar("hop_latency_s", cluster.hop_latency_secs);
    report.push_scalar("spilled", st.spilled() as f64);
    report.push_scalar("spill_hops", run.spill_hops_total as f64);
    report.push_scalar("remote_hits", st.remote_hits_total() as f64);
    report.push_scalar("cluster_shed", st.shed as f64);
    report.push_scalar("cells_hit", run.cells_hit as f64);
    report.push_note(format!(
        "cluster: {} cells, {} replicas, {} served after spill, {} remote cache hits, \
         {} shed past {} max hops",
        st.cells,
        cluster.replicas,
        st.spilled(),
        st.remote_hits_total(),
        st.shed,
        cluster.spill_max
    ));
}

/// Append the chaos-layer telemetry shared by the fleet, scenario and
/// chaos reports: per-fault-kind injection counts, the resilience
/// counters and conservation/availability scalars, and — when the cluster
/// health machine ran — MTTR/time-to-detect percentiles plus the per-cell
/// health timeline.  Callers invoke this ONLY when the chaos layer is
/// armed, so fault-free reports stay byte-identical to the pre-chaos
/// ones.  Everything surfaced is a deterministic function of the
/// event-ordered virtual timeline (never wall-clock).
pub(crate) fn push_chaos_telemetry(
    report: &mut Report,
    series_prefix: &str,
    run: &FleetRun,
    injected: &crate::faults::FaultCounts,
    chaos: Option<&crate::cloud::ChaosStats>,
) {
    use crate::faults::FaultKind;

    let mut fs = Series::new(&format!("{series_prefix}_faults"), &["kind", "injected"]);
    for kind in FaultKind::ALL {
        fs.row(&[kind.name().to_string(), injected[kind.index()].to_string()]);
    }
    report.push_series(fs);

    let captures = run.captures_total.max(1);
    // Availability counts every request that got *an* answer — a cloud
    // serve or an edge-degraded one; sheds and abandonments are the
    // unavailable tail.
    let answered = run.executed_total + run.degraded_total;
    report.push_scalar("captures", run.captures_total as f64);
    report.push_scalar("retries", run.retries_total as f64);
    report.push_scalar("shed_lost", run.shed_lost_total as f64);
    report.push_scalar("degraded", run.degraded_total as f64);
    report.push_scalar("abandoned", run.abandoned_total as f64);
    report.push_scalar("degraded_secs", run.degraded_secs_total);
    report.push_scalar("retry_wait_secs", run.retry_wait_secs_total);
    report.push_scalar("availability", answered as f64 / captures as f64);
    report.push_scalar(
        "faults_injected",
        injected.iter().map(|&n| n as f64).sum::<f64>(),
    );

    if let Some(cs) = chaos {
        report.push_latency_scalars("mttr", &cs.mttr);
        report.push_latency_scalars("ttd", &cs.ttd);
        report.push_scalar("downtime_secs", cs.downtime_secs);
        report.push_scalar("recoveries", cs.recoveries as f64);
        report.push_scalar("cells_down_now", cs.down_now as f64);
        let mut hs =
            Series::new(&format!("{series_prefix}_health"), &["t", "cell", "state"]);
        for (t, cell, state) in &cs.timeline {
            hs.row(&[f(*t, 3), cell.to_string(), state.name().to_string()]);
        }
        report.push_series(hs);
    }

    report.push_note(format!(
        "chaos: {} faults injected, {} retries, {} degraded to edge, {} shed, \
         {} abandoned ({} captures)",
        injected.iter().sum::<u64>(),
        run.retries_total,
        run.degraded_total,
        run.shed_lost_total,
        run.abandoned_total,
        run.captures_total
    ));
}

/// Append per-class virtual-latency percentiles shared by the fleet and
/// scenario reports: `ctx_*`/`ins_*` scalars plus a rendered table.  Pushed
/// unconditionally — unlike the serving telemetry, the scalars are
/// schema-stable zeros when nothing recorded latency, tables are not pinned
/// by the golden series tests, and the histograms themselves are pure
/// functions of the event-ordered virtual timeline (never wall-clock), so
/// default-flag outputs stay deterministic.
pub(crate) fn push_latency_telemetry(
    report: &mut Report,
    title: &str,
    ctx: &LatencyHistogram,
    ins: &LatencyHistogram,
) {
    report.push_latency_scalars("ctx", ctx);
    report.push_latency_scalars("ins", ins);
    report.push_table(latency_table("latency", title, &[("Context", ctx), ("Insight", ins)]));
}

/// Shared environment every mission needs.
pub struct Env {
    pub engine: Engine,
    pub manifest_meta: ManifestMeta,
    pub lut: Lut,
    pub device: DeviceModel,
    pub generic_val: Dataset,
    pub flood_val: Dataset,
    pub out_dir: PathBuf,
}

/// The manifest fields missions need after the Engine has consumed it.
#[derive(Clone, Copy, Debug)]
pub struct ManifestMeta {
    pub img: usize,
    pub depth: usize,
}

impl Env {
    /// Load artifacts, datasets and LUT; spawn the engine.
    pub fn load(artifacts_dir: &Path, out_dir: &Path, mode: ExecMode) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let meta = ManifestMeta { img: manifest.img, depth: manifest.depth };
        let lut = Lut::load(artifacts_dir)?;
        let device = DeviceModel::jetson_mode_30w(meta.depth);
        let generic_val =
            Dataset::load(&artifacts_dir.join("data/generic_val.bin"), Corpus::Generic)?;
        let flood_val =
            Dataset::load(&artifacts_dir.join("data/flood_val.bin"), Corpus::Flood)?;
        let engine = Engine::start(manifest, mode)?;
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("creating output dir {}", out_dir.display()))?;
        Ok(Self {
            engine,
            manifest_meta: meta,
            lut,
            device,
            generic_val,
            flood_val,
            out_dir: out_dir.to_path_buf(),
        })
    }

    pub fn datasets(&self) -> Vec<&Dataset> {
        vec![&self.generic_val, &self.flood_val]
    }

    /// Build an artifact-free environment over the synthetic closed-form
    /// engine (`runtime::synth`): synthetic corpora whose scenes encode
    /// their GT masks, the paper's Table 3 LUT, and the calibrated device
    /// model.  Timing, the controller and the schedulers are *identical* to
    /// the artifact-backed environment — only the numerics are simulated.
    pub fn synthetic(out_dir: &Path) -> Result<Self> {
        let img = 16;
        let depth = 8;
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("creating output dir {}", out_dir.display()))?;
        Ok(Self {
            engine: Engine::synthetic(),
            manifest_meta: ManifestMeta { img, depth },
            lut: crate::coordinator::Lut::paper(),
            device: DeviceModel::jetson_mode_30w(depth),
            generic_val: Dataset::synthetic(Corpus::Generic, 24, img, 0xA5E17),
            flood_val: Dataset::synthetic(Corpus::Flood, 24, img, 0xF10D0),
            out_dir: out_dir.to_path_buf(),
        })
    }

    /// Load the artifact-backed environment when artifacts can be found,
    /// else fall back to [`Env::synthetic`].  An *explicitly named*
    /// artifacts dir that fails to load is an error (the caller asked for
    /// it); only discovery failure falls through to the sim path.  The
    /// resolution rules (and the fallback notice) live in
    /// [`EnvSpec::resolve`], which the CLI shares.
    pub fn load_or_synthetic(
        explicit_artifacts: Option<&str>,
        out_dir: &Path,
        mode: ExecMode,
    ) -> Result<Self> {
        EnvSpec::resolve(explicit_artifacts, mode)?.build(out_dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Kv;

    #[test]
    fn registry_has_eleven_unique_missions() {
        let reg = registry();
        assert_eq!(reg.len(), 11);
        let names: Vec<&str> = reg.iter().map(|m| m.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate mission names: {names:?}");
        // Only the headline mission touches artifact-only baselines.
        for m in &reg {
            assert_eq!(m.needs_artifacts(), m.name() == "headline", "{}", m.name());
            assert!(!m.summary().is_empty(), "{} has no summary", m.name());
        }
    }

    #[test]
    fn find_resolves_and_rejects() {
        assert!(find("fig9").is_some());
        assert!(find("bogus").is_none());
    }

    #[test]
    fn run_options_from_config_maps_every_field() {
        let kv = Kv::parse(
            "duration = 300\ngoal = throughput\nexec-every = 4\nseed = 9\n\
             hysteresis = 0.1\nuavs = 8\nworkers = 3\nscenario = urban-flood\n\
             name = wildfire-ridge\nmanifest = scenarios/urban-flood.toml\n\
             matrix-count = 24\nbatch-max = 8\ncache-entries = 64\n\
             cache-ttl = 45\nqueue-depth = 32\ndeadline-context = 0.05\n\
             deadline-insight = 2.5\nedf = true\ndeadline-shed = true\n\
             cells = 3\nreplicas = 2\nhop-latency = 0.004\nspill-max = 2\n\
             fault-plan = plans/kill.toml\nretry-budget = 3\nretry-backoff = 0.1\n\
             retry-deadline = 4\ndegrade = true\nprobe-backoff = 0.25\n\
             shards = 4\n",
        )
        .unwrap();
        let cfg = RunConfig::from_kv(&kv).unwrap();
        let opts = RunOptions::from_config(&cfg);
        assert_eq!(opts.duration_secs, 300.0);
        assert_eq!(opts.goal, Some(MissionGoal::PrioritizeThroughput));
        assert_eq!(opts.exec_every, 4);
        assert_eq!(opts.seed, 9);
        assert_eq!(opts.ablate_hysteresis, Some(0.1));
        assert_eq!(opts.uavs, Some(8));
        assert_eq!(opts.workers, Some(3));
        assert_eq!(opts.scenario.as_deref(), Some("urban-flood"));
        assert_eq!(opts.name.as_deref(), Some("wildfire-ridge"));
        assert_eq!(opts.manifest.as_deref(), Some("scenarios/urban-flood.toml"));
        assert_eq!(opts.matrix_count, Some(24));
        assert_eq!(opts.batch_max, Some(8));
        assert_eq!(opts.cache_entries, Some(64));
        assert_eq!(opts.cache_ttl, Some(45.0));
        assert_eq!(opts.queue_depth, Some(32));
        assert_eq!(opts.deadline_context, Some(0.05));
        assert_eq!(opts.deadline_insight, Some(2.5));
        assert!(opts.edf);
        assert!(opts.deadline_shed);
        assert_eq!(opts.cells, Some(3));
        assert_eq!(opts.replicas, Some(2));
        assert_eq!(opts.hop_latency, Some(0.004));
        assert_eq!(opts.spill_max, Some(2));
        assert_eq!(opts.fault_plan.as_deref(), Some("plans/kill.toml"));
        assert!(opts.fault_specs.is_empty());
        assert_eq!(opts.retry_budget, Some(3));
        assert_eq!(opts.retry_backoff, Some(0.1));
        assert_eq!(opts.retry_deadline, Some(4.0));
        assert_eq!(opts.degrade, Some(true));
        assert_eq!(opts.probe_backoff, Some(0.25));
        assert_eq!(opts.shards, Some(4));
        // Explicit knobs win over the chaos-armed fallbacks.
        assert_eq!(opts.resilience(true), (3, 0.1, 4.0, true));
        assert_eq!(opts.health().backoff_base_secs, 0.25);
        let cluster = opts.cluster();
        assert!(cluster.multi_cell());
        assert_eq!(cluster.cells, 3);
        assert_eq!(cluster.replicas, 2);
        assert_eq!(cluster.hop_latency_secs, 0.004);
        assert_eq!(cluster.spill_max, 2);
        assert_eq!(cluster.serving.batch_max, 8);
        let serving = opts.serving();
        assert!(serving.enabled());
        assert_eq!(serving.batch_max, 8);
        assert_eq!(serving.cache_entries, 64);
        assert_eq!(serving.cache_ttl_secs, 45.0);
        assert_eq!(serving.queue_depth, 32);
        assert_eq!(serving.deadline_context_secs, 0.05);
        assert_eq!(serving.deadline_insight_secs, 2.5);
        assert!(serving.edf);
        assert!(serving.deadline_shed);

        let defaults = RunOptions::from_config(&RunConfig::from_kv(&Kv::default()).unwrap());
        assert_eq!(defaults.goal, None);
        assert_eq!(defaults.manifest, None);
        assert_eq!(defaults.matrix_count, None);
        assert_eq!(defaults.uavs, None);
        assert_eq!(defaults.workers, None);
        assert_eq!(defaults.shards, None);
        assert_eq!(defaults.duration_secs, 1200.0);
        // Serving defaults are the pre-layer behavior (nothing enabled).
        let serving = defaults.serving();
        assert!(!serving.enabled());
        assert_eq!(serving.batch_max, 1);
        assert_eq!(serving.cache_entries, 0);
        assert_eq!(serving.queue_depth, 0);
        assert!(serving.cache_ttl_secs.is_infinite());
        // Deadline discipline defaults off (byte-identical golden outputs).
        assert!(serving.deadline_context_secs.is_infinite());
        assert!(serving.deadline_insight_secs.is_infinite());
        assert!(!serving.edf);
        assert!(!serving.deadline_shed);
        // Cluster defaults are the single-pool delegate path.
        let cluster = defaults.cluster();
        assert!(!cluster.multi_cell());
        assert_eq!(cluster.cells, 1);
        assert_eq!(cluster.replicas, 1);
        assert_eq!(cluster.hop_latency_secs, crate::cloud::DEFAULT_HOP_LATENCY_SECS);
        assert_eq!(cluster.spill_max, 1);
        assert!(cluster.faults.is_none());
        // Chaos defaults: disarmed everything stays off; armed, the
        // retry budget and degradation switch on unless the user said
        // otherwise.
        assert!(defaults.fault_plan.is_none());
        assert!(defaults.load_fault_specs().unwrap().is_empty());
        assert_eq!(defaults.resilience(false), (0, 0.05, f64::INFINITY, false));
        assert_eq!(
            defaults.resilience(true),
            (CHAOS_DEFAULT_RETRY_BUDGET, 0.05, f64::INFINITY, true)
        );
    }
}
