//! Mission drivers: one per table/figure in the paper's evaluation
//! (DESIGN.md experiment index), plus the fleet-scale driver (`avery
//! fleet`).  Each driver runs the real system through the PJRT artifacts
//! and prints the same rows/series the paper reports, plus CSVs for
//! plotting under `out/`.

mod context;
mod fig10;
mod fig7;
mod fig8;
mod fig9;
mod fleet;
mod headline;
mod scenario;
mod table3;

pub use context::run_streams;
pub use fig10::run_fig10;
pub use fig7::run_fig7;
pub use fig8::run_fig8;
pub use fig9::{run_fig9, Fig9Options};
pub use fleet::{run_fleet, FleetOptions};
pub use headline::run_headline;
pub use scenario::{run_scenario, ScenarioOptions};
pub use table3::run_table3;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::Lut;
use crate::dataset::{Corpus, Dataset};
use crate::energy::DeviceModel;
use crate::manifest::Manifest;
use crate::runtime::{Engine, ExecMode};

/// Shared environment every mission needs.
pub struct Env {
    pub engine: Engine,
    pub manifest_meta: ManifestMeta,
    pub lut: Lut,
    pub device: DeviceModel,
    pub generic_val: Dataset,
    pub flood_val: Dataset,
    pub out_dir: PathBuf,
}

/// The manifest fields missions need after the Engine has consumed it.
#[derive(Clone, Copy, Debug)]
pub struct ManifestMeta {
    pub img: usize,
    pub depth: usize,
}

impl Env {
    /// Load artifacts, datasets and LUT; spawn the engine.
    pub fn load(artifacts_dir: &Path, out_dir: &Path, mode: ExecMode) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let meta = ManifestMeta { img: manifest.img, depth: manifest.depth };
        let lut = Lut::load(artifacts_dir)?;
        let device = DeviceModel::jetson_mode_30w(meta.depth);
        let generic_val =
            Dataset::load(&artifacts_dir.join("data/generic_val.bin"), Corpus::Generic)?;
        let flood_val =
            Dataset::load(&artifacts_dir.join("data/flood_val.bin"), Corpus::Flood)?;
        let engine = Engine::start(manifest, mode)?;
        std::fs::create_dir_all(out_dir).ok();
        Ok(Self {
            engine,
            manifest_meta: meta,
            lut,
            device,
            generic_val,
            flood_val,
            out_dir: out_dir.to_path_buf(),
        })
    }

    pub fn datasets(&self) -> Vec<&Dataset> {
        vec![&self.generic_val, &self.flood_val]
    }

    /// Build an artifact-free environment over the synthetic closed-form
    /// engine (`runtime::synth`): synthetic corpora whose scenes encode
    /// their GT masks, the paper's Table 3 LUT, and the calibrated device
    /// model.  Timing, the controller and the schedulers are *identical* to
    /// the artifact-backed environment — only the numerics are simulated.
    pub fn synthetic(out_dir: &Path) -> Result<Self> {
        let img = 16;
        let depth = 8;
        std::fs::create_dir_all(out_dir).ok();
        Ok(Self {
            engine: Engine::synthetic(),
            manifest_meta: ManifestMeta { img, depth },
            lut: crate::coordinator::Lut::paper(),
            device: DeviceModel::jetson_mode_30w(depth),
            generic_val: Dataset::synthetic(Corpus::Generic, 24, img, 0xA5E17),
            flood_val: Dataset::synthetic(Corpus::Flood, 24, img, 0xF10D0),
            out_dir: out_dir.to_path_buf(),
        })
    }

    /// Load the artifact-backed environment when artifacts can be found,
    /// else fall back to [`Env::synthetic`].  An *explicitly named*
    /// artifacts dir that fails to load is an error (the caller asked for
    /// it); only discovery failure falls through to the sim path.
    pub fn load_or_synthetic(
        explicit_artifacts: Option<&str>,
        out_dir: &Path,
        mode: ExecMode,
    ) -> Result<Self> {
        if explicit_artifacts.is_some() {
            let dir = crate::find_artifacts(explicit_artifacts)?;
            return Self::load(&dir, out_dir, mode);
        }
        match crate::find_artifacts(None) {
            Ok(dir) => Self::load(&dir, out_dir, mode),
            Err(_) => {
                eprintln!(
                    "artifacts/ not found — running the synthetic closed-form engine \
                     (control plane exact, numerics simulated; `make artifacts` for \
                     the real model)"
                );
                Self::synthetic(out_dir)
            }
        }
    }
}
