//! The Mission API: every table/figure of the paper's evaluation — plus
//! the fleet and scenario missions that go beyond it — behind one uniform
//! contract (see DESIGN.md "Mission API").
//!
//! A [`Mission`] names itself, declares whether it needs the PJRT
//! artifacts, and runs against a shared [`Env`] + [`RunOptions`] to a
//! structured [`Report`] (scalars, tables, CSV series, notes) that the
//! caller renders through the sinks in [`crate::report`].  The
//! [`registry`] enumerates all nine missions in the canonical `avery all`
//! order; `avery run <name>`, the legacy subcommands, the benches and the
//! integration tests all resolve missions through it.

mod context;
mod fig10;
mod fig7;
mod fig8;
mod fig9;
mod fleet;
mod headline;
mod runner;
mod scenario;
mod table3;

pub use context::{run_streams, StreamsMission};
pub use runner::{run_collect, EnvSpec};
pub use fig10::{run_fig10, Fig10Mission};
pub use fig7::{run_fig7, Fig7Mission};
pub use fig8::{run_fig8, Fig8Mission};
pub use fig9::{run_fig9, Fig9Mission};
pub use fleet::{run_fleet, FleetMission};
pub use headline::{run_headline, HeadlineMission};
pub use scenario::{run_scenario, ScenarioMission};
pub use table3::{run_table3, Table3Mission};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::coordinator::{Lut, MissionGoal};
use crate::dataset::{Corpus, Dataset};
use crate::energy::DeviceModel;
use crate::manifest::Manifest;
use crate::report::Report;
use crate::runtime::{Engine, ExecMode};

/// Default fleet size when neither the CLI nor a scenario specifies one.
pub const DEFAULT_UAVS: usize = 4;
/// Default cloud-pool worker count.
pub const DEFAULT_WORKERS: usize = 2;

/// One mission behind the uniform API: a named, registry-enumerable driver
/// from `(Env, RunOptions)` to a structured [`Report`].
///
/// `Send + Sync` because the parallel runner ([`run_collect`]) fans
/// registry missions out over scoped worker threads — drivers hold no
/// shared mutable state (everything mission-local hangs off the `Env`
/// and the options they are passed).
pub trait Mission: Send + Sync {
    /// Registry name — also the CLI subcommand (`avery run <name>` and the
    /// legacy `avery <name>` alias).
    fn name(&self) -> &'static str;
    /// One-line description for `avery list`.
    fn summary(&self) -> &'static str;
    /// True when the mission touches artifact-only paths (e.g. the
    /// `full_pipeline` baseline) and cannot fall back to the synthetic
    /// closed-form engine.
    fn needs_artifacts(&self) -> bool;
    /// Run against a loaded environment; pure of rendering — all output
    /// goes through the returned report's sinks.
    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report>;
}

/// Every registered mission, in the canonical `avery all` order.
pub fn registry() -> Vec<Box<dyn Mission>> {
    vec![
        Box::new(Table3Mission),
        Box::new(Fig7Mission),
        Box::new(Fig8Mission),
        Box::new(Fig9Mission),
        Box::new(Fig10Mission),
        Box::new(HeadlineMission),
        Box::new(StreamsMission),
        Box::new(FleetMission),
        Box::new(ScenarioMission),
    ]
}

/// Resolve one mission by registry name.
pub fn find(name: &str) -> Option<Box<dyn Mission>> {
    registry().into_iter().find(|m| m.name() == name)
}

/// Consolidated options for every mission (the union of what the old
/// per-driver option structs carried).  `None` means "the mission's —
/// or the scenario regime's — default", which is how the scenario-goal
/// override works uniformly: a mission resolves
/// `opts.goal.or(scenario_goal).unwrap_or(default)` instead of the CLI
/// plumbing `*_explicit` flags around.
#[derive(Clone, Debug)]
pub struct RunOptions {
    /// Mission length in virtual seconds.
    pub duration_secs: f64,
    /// Mission goal; `None` = PrioritizeAccuracy, or the scenario's goal
    /// when running under a scenario regime.
    pub goal: Option<MissionGoal>,
    /// Execute HLO on every Nth delivered packet (1 = all).
    pub exec_every: usize,
    /// Trace/workload seed.
    pub seed: u64,
    /// fig9 hysteresis ablation margin (`--hysteresis H`).
    pub ablate_hysteresis: Option<f64>,
    /// Fleet size; `None` = [`DEFAULT_UAVS`] (fleet) or the scenario's.
    pub uavs: Option<usize>,
    /// Cloud workers; `None` = [`DEFAULT_WORKERS`] (fleet) or the scenario's.
    pub workers: Option<usize>,
    /// Scenario regime overlay for fig9/fig10/headline/fleet
    /// (`--scenario NAME`): trace, link knobs, schedule and default goal
    /// come from the scenario library.
    pub scenario: Option<String>,
    /// Scenario to run for the `scenario` mission (`--name NAME`; falls
    /// back to `scenario`, then "urban-flood").
    pub name: Option<String>,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            duration_secs: 1200.0,
            goal: None,
            exec_every: 1,
            seed: 7,
            ablate_hysteresis: None,
            uavs: None,
            workers: None,
            scenario: None,
            name: None,
        }
    }
}

impl RunOptions {
    /// The single place a [`RunConfig`] becomes mission options.
    pub fn from_config(cfg: &RunConfig) -> Self {
        Self {
            duration_secs: cfg.duration_secs,
            goal: cfg.goal,
            exec_every: cfg.exec_every,
            seed: cfg.seed,
            ablate_hysteresis: cfg.hysteresis,
            uavs: cfg.uavs,
            workers: cfg.workers,
            scenario: cfg.scenario.clone(),
            name: cfg.name.clone(),
        }
    }
}

/// Shared environment every mission needs.
pub struct Env {
    pub engine: Engine,
    pub manifest_meta: ManifestMeta,
    pub lut: Lut,
    pub device: DeviceModel,
    pub generic_val: Dataset,
    pub flood_val: Dataset,
    pub out_dir: PathBuf,
}

/// The manifest fields missions need after the Engine has consumed it.
#[derive(Clone, Copy, Debug)]
pub struct ManifestMeta {
    pub img: usize,
    pub depth: usize,
}

impl Env {
    /// Load artifacts, datasets and LUT; spawn the engine.
    pub fn load(artifacts_dir: &Path, out_dir: &Path, mode: ExecMode) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let meta = ManifestMeta { img: manifest.img, depth: manifest.depth };
        let lut = Lut::load(artifacts_dir)?;
        let device = DeviceModel::jetson_mode_30w(meta.depth);
        let generic_val =
            Dataset::load(&artifacts_dir.join("data/generic_val.bin"), Corpus::Generic)?;
        let flood_val =
            Dataset::load(&artifacts_dir.join("data/flood_val.bin"), Corpus::Flood)?;
        let engine = Engine::start(manifest, mode)?;
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("creating output dir {}", out_dir.display()))?;
        Ok(Self {
            engine,
            manifest_meta: meta,
            lut,
            device,
            generic_val,
            flood_val,
            out_dir: out_dir.to_path_buf(),
        })
    }

    pub fn datasets(&self) -> Vec<&Dataset> {
        vec![&self.generic_val, &self.flood_val]
    }

    /// Build an artifact-free environment over the synthetic closed-form
    /// engine (`runtime::synth`): synthetic corpora whose scenes encode
    /// their GT masks, the paper's Table 3 LUT, and the calibrated device
    /// model.  Timing, the controller and the schedulers are *identical* to
    /// the artifact-backed environment — only the numerics are simulated.
    pub fn synthetic(out_dir: &Path) -> Result<Self> {
        let img = 16;
        let depth = 8;
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("creating output dir {}", out_dir.display()))?;
        Ok(Self {
            engine: Engine::synthetic(),
            manifest_meta: ManifestMeta { img, depth },
            lut: crate::coordinator::Lut::paper(),
            device: DeviceModel::jetson_mode_30w(depth),
            generic_val: Dataset::synthetic(Corpus::Generic, 24, img, 0xA5E17),
            flood_val: Dataset::synthetic(Corpus::Flood, 24, img, 0xF10D0),
            out_dir: out_dir.to_path_buf(),
        })
    }

    /// Load the artifact-backed environment when artifacts can be found,
    /// else fall back to [`Env::synthetic`].  An *explicitly named*
    /// artifacts dir that fails to load is an error (the caller asked for
    /// it); only discovery failure falls through to the sim path.  The
    /// resolution rules (and the fallback notice) live in
    /// [`EnvSpec::resolve`], which the CLI shares.
    pub fn load_or_synthetic(
        explicit_artifacts: Option<&str>,
        out_dir: &Path,
        mode: ExecMode,
    ) -> Result<Self> {
        EnvSpec::resolve(explicit_artifacts, mode)?.build(out_dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Kv;

    #[test]
    fn registry_has_nine_unique_missions() {
        let reg = registry();
        assert_eq!(reg.len(), 9);
        let names: Vec<&str> = reg.iter().map(|m| m.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate mission names: {names:?}");
        // Only the headline mission touches artifact-only baselines.
        for m in &reg {
            assert_eq!(m.needs_artifacts(), m.name() == "headline", "{}", m.name());
            assert!(!m.summary().is_empty(), "{} has no summary", m.name());
        }
    }

    #[test]
    fn find_resolves_and_rejects() {
        assert!(find("fig9").is_some());
        assert!(find("bogus").is_none());
    }

    #[test]
    fn run_options_from_config_maps_every_field() {
        let kv = Kv::parse(
            "duration = 300\ngoal = throughput\nexec-every = 4\nseed = 9\n\
             hysteresis = 0.1\nuavs = 8\nworkers = 3\nscenario = urban-flood\n\
             name = wildfire-ridge\n",
        )
        .unwrap();
        let cfg = RunConfig::from_kv(&kv).unwrap();
        let opts = RunOptions::from_config(&cfg);
        assert_eq!(opts.duration_secs, 300.0);
        assert_eq!(opts.goal, Some(MissionGoal::PrioritizeThroughput));
        assert_eq!(opts.exec_every, 4);
        assert_eq!(opts.seed, 9);
        assert_eq!(opts.ablate_hysteresis, Some(0.1));
        assert_eq!(opts.uavs, Some(8));
        assert_eq!(opts.workers, Some(3));
        assert_eq!(opts.scenario.as_deref(), Some("urban-flood"));
        assert_eq!(opts.name.as_deref(), Some("wildfire-ridge"));

        let defaults = RunOptions::from_config(&RunConfig::from_kv(&Kv::default()).unwrap());
        assert_eq!(defaults.goal, None);
        assert_eq!(defaults.uavs, None);
        assert_eq!(defaults.workers, None);
        assert_eq!(defaults.duration_secs, 1200.0);
    }
}
