//! Figure 8 — on-device latency and energy per image across split points on
//! the calibrated Jetson AGX Xavier model (MODE_30W_ALL), plus the
//! full-SAM-onboard reference (the 11.8x / 16.6x comparator).

use anyhow::Result;

use crate::report::{Report, ReportTable, Series};
use crate::telemetry::f;

use super::{Env, Mission, RunOptions};

/// `avery fig8` — latency/energy per split point on the device model.
pub struct Fig8Mission;

impl Mission for Fig8Mission {
    fn name(&self) -> &'static str {
        "fig8"
    }

    fn summary(&self) -> &'static str {
        "Fig 8 — on-device latency/energy per split point"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, _opts: &RunOptions) -> Result<Report> {
        run_fig8(env)
    }
}

pub fn run_fig8(env: &Env) -> Result<Report> {
    let title = "Figure 8 — on-device latency & energy per image (Jetson MODE_30W_ALL model)";
    let mut report = Report::new("fig8", title);
    let mut table = ReportTable::new(
        "latency_energy",
        title,
        &["Split", "Paper depth", "Latency (s)", "Energy (J)"],
    );
    let mut csv = Series::new(
        "fig8_latency_energy",
        &["split", "paper_depth", "latency_s", "energy_j"],
    );
    for split in 1..=env.manifest_meta.depth {
        let c = env.device.insight_edge(split);
        let pd = env.device.paper_depth_of(split);
        table.row(&[
            format!("sp{split}"),
            f(pd, 1),
            f(c.latency_s, 4),
            f(c.energy_j, 2),
        ]);
        csv.rowf(&[split as f64, pd, c.latency_s, c.energy_j]);
    }
    let full = env.device.full_edge();
    table.row(&[
        "Full SAM onboard".to_string(),
        "-".to_string(),
        f(full.latency_s, 4),
        f(full.energy_j, 2),
    ]);
    csv.rowf(&[-1.0, -1.0, full.latency_s, full.energy_j]);
    report.push_table(table);
    report.push_series(csv);
    let sp1 = env.device.insight_edge(1);
    let latency_x = full.latency_s / sp1.latency_s;
    let energy_x = full.energy_j / sp1.energy_j;
    let saving = 1.0 - sp1.energy_j / full.energy_j;
    report.push_scalar("full_vs_sp1_latency_x", latency_x);
    report.push_scalar("full_vs_sp1_energy_x", energy_x);
    report.push_scalar("sp1_energy_saving", saving);
    report.push_note(format!(
        "full vs sp1: latency {latency_x:.1}x, energy {energy_x:.1}x  (paper caption: 11.8x / 16.6x)"
    ));
    report.push_note(format!(
        "energy saving of split@1 vs full edge: {:.2}%  (paper headline: 93.98%)",
        saving * 100.0
    ));
    Ok(report)
}
