//! Figure 8 — on-device latency and energy per image across split points on
//! the calibrated Jetson AGX Xavier model (MODE_30W_ALL), plus the
//! full-SAM-onboard reference (the 11.8x / 16.6x comparator).

use anyhow::Result;

use crate::telemetry::{f, Csv, Table};

use super::Env;

pub fn run_fig8(env: &Env) -> Result<()> {
    let mut table = Table::new(
        "Figure 8 — on-device latency & energy per image (Jetson MODE_30W_ALL model)",
        &["Split", "Paper depth", "Latency (s)", "Energy (J)"],
    );
    let mut csv = Csv::create(
        &env.out_dir.join("fig8_latency_energy.csv"),
        &["split", "paper_depth", "latency_s", "energy_j"],
    )?;
    for split in 1..=env.manifest_meta.depth {
        let c = env.device.insight_edge(split);
        let pd = env.device.paper_depth_of(split);
        table.row(&[
            format!("sp{split}"),
            f(pd, 1),
            f(c.latency_s, 4),
            f(c.energy_j, 2),
        ]);
        csv.rowf(&[split as f64, pd, c.latency_s, c.energy_j])?;
    }
    let full = env.device.full_edge();
    table.row(&[
        "Full SAM onboard".to_string(),
        "-".to_string(),
        f(full.latency_s, 4),
        f(full.energy_j, 2),
    ]);
    csv.rowf(&[-1.0, -1.0, full.latency_s, full.energy_j])?;
    table.print();
    let sp1 = env.device.insight_edge(1);
    println!(
        "full vs sp1: latency {:.1}x, energy {:.1}x  (paper caption: 11.8x / 16.6x)",
        full.latency_s / sp1.latency_s,
        full.energy_j / sp1.energy_j
    );
    println!(
        "energy saving of split@1 vs full edge: {:.2}%  (paper headline: 93.98%)",
        (1.0 - sp1.energy_j / full.energy_j) * 100.0
    );
    println!("csv: {}", csv.path.display());
    Ok(())
}
