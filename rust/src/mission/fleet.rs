//! `avery fleet` — the multi-UAV mission driver (DESIGN.md "Fleet
//! subsystem"): N heterogeneous UAVs (mixed Insight/Context intents,
//! staggered starts, per-UAV seeds) contend for the scripted disaster-zone
//! uplink while a concurrent cloud pool serves every session.  Emits
//! per-UAV and aggregate CSV telemetry: tier occupancy, switches, Jain
//! fairness over per-UAV throughput, and server utilization.

use anyhow::Result;

use crate::cloud::CloudCluster;
use crate::coordinator::MissionGoal;
use crate::netsim::{BandwidthTrace, LinkConfig, SharedLink, TraceConfig};
use crate::report::{Report, ReportTable, Series};
use crate::streams::fleet::{run_fleet_mission, FleetConfig, FleetRun};
use crate::streams::shard::run_fleet_mission_sharded;
use crate::streams::{MissionConfig, UavRole};
use crate::telemetry::{f, pct};

use super::{Env, Mission, RunOptions, DEFAULT_UAVS, DEFAULT_WORKERS};

/// `avery fleet` — N UAVs over the contended uplink.
pub struct FleetMission;

impl Mission for FleetMission {
    fn name(&self) -> &'static str {
        "fleet"
    }

    fn summary(&self) -> &'static str {
        "multi-UAV contended-uplink mission (beyond the paper)"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, opts: &RunOptions) -> Result<Report> {
        Ok(run_fleet(env, opts)?.1)
    }
}

/// Run the fleet mission and build its report; the raw [`FleetRun`] comes
/// back alongside for programmatic consumers (benches, examples, tests).
pub fn run_fleet(env: &Env, opts: &RunOptions) -> Result<(FleetRun, Report)> {
    let uavs = opts.uavs.unwrap_or(DEFAULT_UAVS).max(1);
    let workers = opts.workers.unwrap_or(DEFAULT_WORKERS).max(1);

    // The paper's scripted trace by default, or a scenario-library regime
    // (whose own goal applies unless the caller set one explicitly; fleet
    // size/workers stay the caller's).
    let (trace_cfg, link_cfg, schedule, hysteresis, min_dwell, scenario_goal, scenario_faults) =
        match &opts.scenario {
            Some(name) => {
                let sc = crate::scenario::build(name, opts.seed, opts.duration_secs)?;
                eprintln!("fleet over scenario `{}`: {}", sc.name, sc.summary);
                (
                    sc.trace,
                    sc.link,
                    sc.schedule,
                    sc.hysteresis,
                    sc.min_dwell,
                    Some(sc.goal),
                    sc.faults,
                )
            }
            None => (
                TraceConfig::paper_20min(opts.seed).scaled_to(opts.duration_secs),
                LinkConfig { seed: opts.seed, ..LinkConfig::default() },
                Vec::new(),
                0.0,
                0,
                None,
                Vec::new(),
            ),
        };
    let goal = opts.goal.or(scenario_goal).unwrap_or(MissionGoal::PrioritizeAccuracy);
    let trace = BandwidthTrace::generate(&trace_cfg);

    // Serving layer (micro-batching / response cache / admission): the
    // defaults reproduce the pre-layer pool and timing byte-for-byte.  The
    // timing model charges the amortized tail per *effective* batch bound —
    // capped by fleet size, since a batch can only fill from concurrent
    // UAVs (a lone UAV gets no amortization no matter the flag).
    let serving = opts.serving();
    let effective_batch = serving.batch_max.min(uavs);
    // Cloud cluster: K cells of `workers` workers each behind the
    // consistent-hash router.  At the default K=1 the cluster delegates to
    // its single pool and every output byte matches the pre-cluster path.
    let mut cluster_cfg = opts.cluster();
    let cells = cluster_cfg.cells;
    // Chaos layer: union the scenario's bound fault events with the CLI
    // fault plan (and any programmatic specs), then arm the cluster's
    // injector + health machine.  Unarmed — the default — `cfg.faults`
    // stays `None`, the chaos dispatch is never entered, and every output
    // byte matches the pre-chaos path.
    let mut fault_events = scenario_faults;
    fault_events
        .extend(crate::faults::bind_specs(&opts.load_fault_specs()?, opts.duration_secs));
    fault_events.sort_by(|a, b| a.at().partial_cmp(&b.at()).expect("finite fault times"));
    let chaos_armed = !fault_events.is_empty();
    if chaos_armed {
        cluster_cfg.faults =
            Some(crate::faults::FaultPlan::with_events(opts.seed, fault_events)?);
        cluster_cfg.health = opts.health();
    }
    let (retry_budget, retry_backoff_secs, retry_deadline_secs, degrade) =
        opts.resilience(chaos_armed);
    let fleet_cfg = FleetConfig {
        n_uavs: uavs,
        mission: MissionConfig {
            duration_secs: opts.duration_secs,
            goal,
            exec_every: opts.exec_every,
            seed: opts.seed,
            hysteresis,
            min_dwell,
            batch_max: effective_batch,
            retry_budget,
            retry_backoff_secs,
            retry_deadline_secs,
            degrade,
            ..MissionConfig::default()
        },
        // Server-utilization denominator: total workers across all cells
        // (identical to the bare pool at K=1).
        workers: workers * cells,
        schedule,
        ..FleetConfig::default()
    };

    // `--shards T` routes through the sharded megafleet core (epoch-
    // quantized link exchange, identical output for every T at a given
    // seed); unset keeps the legacy single-threaded event loop byte for
    // byte (DESIGN.md "Megafleet core").
    let wall0 = std::time::Instant::now();
    let (run, cluster_stats, chaos_stats, sharded_injected) = match opts.shards {
        Some(t) => {
            let sharded = run_fleet_mission_sharded(
                &env.engine,
                &env.datasets(),
                &env.lut,
                &env.device,
                &trace,
                &link_cfg,
                &fleet_cfg,
                &cluster_cfg,
                workers,
                t,
            )?;
            (sharded.run, sharded.cluster_stats, None, sharded.injected)
        }
        None => {
            let mut link = SharedLink::new(trace, link_cfg, uavs);
            let cluster = CloudCluster::with_config(
                vec![env.engine.clone(); workers],
                cluster_cfg.clone(),
            );
            let run = run_fleet_mission(
                &env.engine,
                &env.datasets(),
                &env.lut,
                &env.device,
                &mut link,
                &fleet_cfg,
                &cluster,
            )?;
            let chaos = cluster.chaos_stats();
            (run, cluster.stats(), chaos, None)
        }
    };
    let wall = wall0.elapsed().as_secs_f64();

    let title = format!(
        "Fleet mission — {} UAVs, {:.0} min, {:?}, contended uplink",
        uavs,
        opts.duration_secs / 60.0,
        goal
    );
    let mut report = Report::new("fleet", &title);

    // ---- CSV series ----
    let mut pu = Series::new(
        "fleet_per_uav",
        &[
            "uav", "role", "start_t", "seed", "delivered", "executed", "avg_pps",
            "avg_iou", "energy_j", "ha_secs", "bal_secs", "ht_secs", "switches",
            "intent_switches", "infeasible_s", "context_acc",
        ],
    );
    for o in &run.per_uav {
        let s = &o.summary;
        pu.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            f(o.start_t, 1),
            o.seed.to_string(),
            s.delivered.to_string(),
            s.executed.to_string(),
            f(s.avg_pps, 4),
            f(s.avg_iou, 6),
            f(s.total_energy_j, 2),
            f(s.tier_secs[0], 1),
            f(s.tier_secs[1], 1),
            f(s.tier_secs[2], 1),
            s.switches.to_string(),
            s.intent_switches.to_string(),
            s.infeasible_epochs.to_string(),
            f(o.context_accuracy, 4),
        ]);
    }
    report.push_series(pu);

    let mut ep = Series::new(
        "fleet_epochs",
        &["uav", "t", "share_true_mbps", "bandwidth_est_mbps", "tier"],
    );
    for (uav, e) in &run.epochs {
        ep.row(&[
            uav.to_string(),
            f(e.t, 1),
            f(e.bandwidth_true_mbps, 4),
            f(e.bandwidth_est_mbps, 4),
            e.tier.map(|t| t.index() as i64).unwrap_or(-1).to_string(),
        ]);
    }
    report.push_series(ep);

    let mut sm = Series::new(
        "fleet_summary",
        &[
            "uavs", "workers", "delivered", "executed", "aggregate_pps", "jain_pps",
            "avg_iou", "switches", "infeasible_s", "server_utilization",
            "total_energy_j", "ctx_p50_s", "ctx_p90_s", "ctx_p99_s", "ins_p50_s",
            "ins_p90_s", "ins_p99_s",
        ],
    );
    sm.row(&[
        uavs.to_string(),
        workers.to_string(),
        run.delivered_total.to_string(),
        run.executed_total.to_string(),
        f(run.aggregate_pps, 4),
        f(run.jain_pps, 4),
        f(run.avg_iou, 6),
        run.switches_total.to_string(),
        run.infeasible_total.to_string(),
        f(run.server_utilization, 4),
        f(run.total_energy_j, 1),
        f(run.lat_context.p50(), 6),
        f(run.lat_context.p90(), 6),
        f(run.lat_context.p99(), 6),
        f(run.lat_insight.p50(), 6),
        f(run.lat_insight.p90(), 6),
        f(run.lat_insight.p99(), 6),
    ]);
    report.push_series(sm);

    // ---- Terminal table ----
    let mut table = ReportTable::new(
        "per_uav",
        &title,
        &[
            "UAV", "Role", "Start", "Delivered", "Avg PPS", "Avg IoU / Ctx Acc",
            "HA/BAL/HT (s)", "Switches", "Infeasible s",
        ],
    );
    for o in &run.per_uav {
        let s = &o.summary;
        let quality = match o.role {
            UavRole::Insight => pct(s.avg_iou),
            UavRole::Context => format!("{} ctx", pct(o.context_accuracy)),
        };
        table.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            f(o.start_t, 0),
            s.delivered.to_string(),
            f(s.avg_pps, 3),
            quality,
            format!(
                "{:.0}/{:.0}/{:.0}",
                s.tier_secs[0], s.tier_secs[1], s.tier_secs[2]
            ),
            s.switches.to_string(),
            s.infeasible_epochs.to_string(),
        ]);
    }
    report.push_table(table);

    // Scalars: the aggregate surface programmatic consumers want.
    let insight_pps: Vec<f64> = run
        .per_uav
        .iter()
        .filter(|o| o.role == UavRole::Insight)
        .map(|o| o.summary.avg_pps)
        .collect();
    let mean_insight_pps = insight_pps.iter().sum::<f64>() / insight_pps.len().max(1) as f64;
    report.push_scalar("uavs", uavs as f64);
    report.push_scalar("workers", workers as f64);
    report.push_scalar("delivered", run.delivered_total as f64);
    report.push_scalar("executed", run.executed_total as f64);
    report.push_scalar("aggregate_pps", run.aggregate_pps);
    report.push_scalar("mean_insight_pps", mean_insight_pps);
    report.push_scalar("jain_pps", run.jain_pps);
    report.push_scalar("avg_iou", run.avg_iou);
    report.push_scalar("tier_switches", run.switches_total as f64);
    report.push_scalar("intent_switches", run.intent_switches_total as f64);
    report.push_scalar("infeasible_s", run.infeasible_total as f64);
    report.push_scalar("server_utilization", run.server_utilization);
    report.push_scalar("total_energy_j", run.total_energy_j);

    // Tail percentiles per stream class, next to the means above.  The
    // histograms accumulate virtual (event-ordered) per-request latency, so
    // these are as deterministic as every other scalar.
    super::push_latency_telemetry(
        &mut report,
        "Per-class request latency (virtual seconds)",
        &run.lat_context,
        &run.lat_insight,
    );

    // Serving-layer telemetry only exists when a serving feature is on, so
    // default runs stay byte-identical to the pre-serving-layer reports.
    if serving.enabled() {
        super::push_serving_telemetry(
            &mut report,
            "fleet_serving",
            "role",
            &run.per_uav,
            &serving,
            effective_batch,
            &cluster_stats.total,
        );
    }
    // Cluster telemetry likewise only exists past K=1.
    if cluster_cfg.multi_cell() {
        super::push_cluster_telemetry(
            &mut report,
            "fleet_cluster",
            &run,
            &cluster_cfg,
            &cluster_stats,
        );
    }
    // Chaos telemetry only exists when a fault schedule was armed.  On the
    // sharded path injector counts come from the per-agent injectors and
    // there is no cluster-level health machine (`cs` stays None).
    if chaos_armed {
        let injected = chaos_stats
            .as_ref()
            .map(|s| s.injected)
            .or(sharded_injected)
            .unwrap_or([0; 5]);
        super::push_chaos_telemetry(
            &mut report,
            "fleet_chaos",
            &run,
            &injected,
            chaos_stats.as_ref(),
        );
    }

    report.push_note(format!(
        "fleet aggregate: {:.2} PPS over {} UAVs, Jain fairness {:.3}, avg IoU {}",
        run.aggregate_pps,
        uavs,
        run.jain_pps,
        pct(run.avg_iou)
    ));
    // Wall-clock is diagnostic only — it stays out of the report so reports
    // remain byte-deterministic per seed.
    eprintln!(
        "cloud: {} cells x {} workers, virtual utilization {:.1}%, {} requests served, wall busy {:.1}s / {:.1}s run",
        cells,
        workers,
        run.server_utilization * 100.0,
        cluster_stats.total.completed,
        cluster_stats.total.busy_secs,
        wall
    );
    Ok((run, report))
}
