//! `avery fleet` — the multi-UAV mission driver (DESIGN.md "Fleet
//! subsystem"): N heterogeneous UAVs (mixed Insight/Context intents,
//! staggered starts, per-UAV seeds) contend for the scripted disaster-zone
//! uplink while a concurrent cloud pool serves every session.  Emits
//! per-UAV and aggregate CSV telemetry: tier occupancy, switches, Jain
//! fairness over per-UAV throughput, and server utilization.

use anyhow::Result;

use crate::cloud::CloudPool;
use crate::coordinator::MissionGoal;
use crate::netsim::{BandwidthTrace, LinkConfig, SharedLink, TraceConfig};
use crate::streams::fleet::{run_fleet_mission, FleetConfig, FleetRun};
use crate::streams::{MissionConfig, UavRole};
use crate::telemetry::{f, pct, Csv, Table};

use super::Env;

#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Fleet size N.
    pub uavs: usize,
    /// Cloud pool worker count.
    pub workers: usize,
    pub duration_secs: f64,
    pub goal: MissionGoal,
    /// Execute HLO on every Nth delivered packet (1 = all; raise to speed up).
    pub exec_every: usize,
    pub seed: u64,
    /// Fly the fleet under a scenario-library regime (`--scenario NAME`):
    /// trace, link knobs and intent schedule come from the scenario; fleet
    /// size/workers stay the CLI's.
    pub scenario: Option<String>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self {
            uavs: 4,
            workers: 2,
            duration_secs: 1200.0,
            goal: MissionGoal::PrioritizeAccuracy,
            exec_every: 1,
            seed: 7,
            scenario: None,
        }
    }
}

pub fn run_fleet(env: &Env, opts: &FleetOptions) -> Result<FleetRun> {
    // The paper's scripted trace by default, or a scenario-library regime.
    let (trace_cfg, link_cfg, schedule, hysteresis, min_dwell) = match &opts.scenario {
        Some(name) => {
            let sc = crate::scenario::build(name, opts.seed, opts.duration_secs)?;
            println!("fleet over scenario `{}`: {}", sc.name, sc.summary);
            (sc.trace, sc.link, sc.schedule, sc.hysteresis, sc.min_dwell)
        }
        None => (
            TraceConfig::paper_20min(opts.seed).scaled_to(opts.duration_secs),
            LinkConfig { seed: opts.seed, ..LinkConfig::default() },
            Vec::new(),
            0.0,
            0,
        ),
    };
    let trace = BandwidthTrace::generate(&trace_cfg);
    let mut link = SharedLink::new(trace, link_cfg, opts.uavs);

    let fleet_cfg = FleetConfig {
        n_uavs: opts.uavs,
        mission: MissionConfig {
            duration_secs: opts.duration_secs,
            goal: opts.goal,
            exec_every: opts.exec_every,
            seed: opts.seed,
            hysteresis,
            min_dwell,
            ..MissionConfig::default()
        },
        workers: opts.workers,
        schedule,
        ..FleetConfig::default()
    };

    let pool = CloudPool::new(vec![env.engine.clone(); opts.workers.max(1)]);
    let wall0 = std::time::Instant::now();
    let run = run_fleet_mission(
        &env.engine,
        &env.datasets(),
        &env.lut,
        &env.device,
        &mut link,
        &fleet_cfg,
        &pool,
    )?;
    let wall = wall0.elapsed().as_secs_f64();

    // ---- CSVs ----
    let mut pu = Csv::create(
        &env.out_dir.join("fleet_per_uav.csv"),
        &[
            "uav", "role", "start_t", "seed", "delivered", "executed", "avg_pps",
            "avg_iou", "energy_j", "ha_secs", "bal_secs", "ht_secs", "switches",
            "intent_switches", "infeasible_s", "context_acc",
        ],
    )?;
    for o in &run.per_uav {
        let s = &o.summary;
        pu.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            f(o.start_t, 1),
            o.seed.to_string(),
            s.delivered.to_string(),
            s.executed.to_string(),
            f(s.avg_pps, 4),
            f(s.avg_iou, 6),
            f(s.total_energy_j, 2),
            f(s.tier_secs[0], 1),
            f(s.tier_secs[1], 1),
            f(s.tier_secs[2], 1),
            s.switches.to_string(),
            s.intent_switches.to_string(),
            s.infeasible_epochs.to_string(),
            f(o.context_accuracy, 4),
        ])?;
    }

    let mut ep = Csv::create(
        &env.out_dir.join("fleet_epochs.csv"),
        &["uav", "t", "share_true_mbps", "bandwidth_est_mbps", "tier"],
    )?;
    for (uav, e) in &run.epochs {
        ep.row(&[
            uav.to_string(),
            f(e.t, 1),
            f(e.bandwidth_true_mbps, 4),
            f(e.bandwidth_est_mbps, 4),
            e.tier.map(|t| t.index() as i64).unwrap_or(-1).to_string(),
        ])?;
    }

    let mut sm = Csv::create(
        &env.out_dir.join("fleet_summary.csv"),
        &[
            "uavs", "workers", "delivered", "executed", "aggregate_pps", "jain_pps",
            "avg_iou", "switches", "infeasible_s", "server_utilization",
            "total_energy_j",
        ],
    )?;
    sm.row(&[
        opts.uavs.to_string(),
        opts.workers.to_string(),
        run.delivered_total.to_string(),
        run.executed_total.to_string(),
        f(run.aggregate_pps, 4),
        f(run.jain_pps, 4),
        f(run.avg_iou, 6),
        run.switches_total.to_string(),
        run.infeasible_total.to_string(),
        f(run.server_utilization, 4),
        f(run.total_energy_j, 1),
    ])?;

    // ---- Terminal summary ----
    let mut table = Table::new(
        &format!(
            "Fleet mission — {} UAVs, {:.0} min, {:?}, contended uplink",
            opts.uavs,
            opts.duration_secs / 60.0,
            opts.goal
        ),
        &[
            "UAV", "Role", "Start", "Delivered", "Avg PPS", "Avg IoU / Ctx Acc",
            "HA/BAL/HT (s)", "Switches", "Infeasible s",
        ],
    );
    for o in &run.per_uav {
        let s = &o.summary;
        let quality = match o.role {
            UavRole::Insight => pct(s.avg_iou),
            UavRole::Context => format!("{} ctx", pct(o.context_accuracy)),
        };
        table.row(&[
            o.id.to_string(),
            o.role.name().to_string(),
            f(o.start_t, 0),
            s.delivered.to_string(),
            f(s.avg_pps, 3),
            quality,
            format!(
                "{:.0}/{:.0}/{:.0}",
                s.tier_secs[0], s.tier_secs[1], s.tier_secs[2]
            ),
            s.switches.to_string(),
            s.infeasible_epochs.to_string(),
        ]);
    }
    table.print();

    let pool_stats = pool.stats();
    println!(
        "fleet aggregate: {:.2} PPS over {} UAVs, Jain fairness {:.3}, avg IoU {}",
        run.aggregate_pps,
        opts.uavs,
        run.jain_pps,
        pct(run.avg_iou)
    );
    println!(
        "cloud: {} workers, virtual utilization {:.1}%, {} requests served, wall busy {:.1}s / {:.1}s run",
        opts.workers,
        run.server_utilization * 100.0,
        pool_stats.completed,
        pool_stats.busy_secs,
        wall
    );
    println!(
        "csv: {} / {} / {}",
        pu.path.display(),
        ep.path.display(),
        sm.path.display()
    );
    Ok(run)
}
