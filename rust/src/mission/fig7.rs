//! Figure 7 — SAM split-point accuracy trends at compression ratio r = 0.10:
//! gIoU and cIoU as the split moves deeper into the backbone, measured by
//! executing each split's head+tail artifacts over the validation set.

use anyhow::Result;

use crate::baselines::eval_split_path;
use crate::coordinator::TierId;
use crate::report::{Report, ReportTable, Series};
use crate::telemetry::f;

use super::{Env, Mission, RunOptions};

/// `avery fig7` — the split-point accuracy sweep at r = 0.10.
pub struct Fig7Mission;

impl Mission for Fig7Mission {
    fn name(&self) -> &'static str {
        "fig7"
    }

    fn summary(&self) -> &'static str {
        "Fig 7 — split-point accuracy sweep (r = 0.10)"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn run(&self, env: &Env, _opts: &RunOptions) -> Result<Report> {
        run_fig7(env)
    }
}

pub fn run_fig7(env: &Env) -> Result<Report> {
    let title = "Figure 7 — split-point accuracy at r = 0.10 (Original model, generic val)";
    let mut report = Report::new("fig7", title);
    let mut table =
        ReportTable::new("split_accuracy", title, &["Split", "gIoU", "cIoU", "Avg IoU", "LUT Avg"]);
    let mut csv = Series::new(
        "fig7_split_accuracy",
        &["split", "giou", "ciou", "avg_iou", "lut_avg"],
    );
    let mut measured = Vec::new();
    for split in 1..=env.manifest_meta.depth {
        let (_, acc) = eval_split_path(
            &env.engine,
            &env.generic_val,
            &env.lut,
            &env.device,
            split,
            TierId::Balanced,
        )?;
        let lut_avg = env
            .lut
            .sweep
            .iter()
            .find(|s| s.split == split)
            .map(|s| 0.5 * (s.giou + s.ciou))
            .unwrap_or(f64::NAN);
        table.row(&[
            format!("sp{split}"),
            f(acc.giou(), 4),
            f(acc.ciou(), 4),
            f(acc.avg_iou(), 4),
            f(lut_avg, 4),
        ]);
        csv.rowf(&[split as f64, acc.giou(), acc.ciou(), acc.avg_iou(), lut_avg]);
        measured.push(acc.avg_iou());
    }
    let first = measured.first().copied().unwrap_or(0.0);
    let last = measured.last().copied().unwrap_or(0.0);
    let min = measured.iter().cloned().fold(f64::INFINITY, f64::min);
    report.push_table(table);
    report.push_series(csv);
    report.push_scalar("sp1_avg_iou", first);
    report.push_scalar("min_avg_iou", min);
    report.push_scalar("last_avg_iou", last);
    report.push_note(format!(
        "shape: sp1 {:.4} -> mid-min {:.4} -> sp{} {:.4}  (paper: 0.8256 -> 0.7615@sp17 \
         -> 0.8267@sp29; early split favored once energy is charged — see Fig 8)",
        first,
        min,
        measured.len(),
        last
    ));
    Ok(report)
}
