//! Cloud (server-side) processing: unpack a received packet, run the
//! matching tail artifact (bottleneck decode -> SAM suffix -> LLM trunk ->
//! mask decoder, or the text-only context responder), and produce the
//! operator-facing response (paper §4.2).
//!
//! Two server shapes share the same request path:
//! * [`CloudServer`] — the original single-session server; synchronous
//!   `process` over one engine handle.
//! * [`CloudPool`] (in [`serving`]) — the concurrent serving layer
//!   (DESIGN.md "Cloud serving layer"): a worker pool draining a shared job
//!   queue through a **micro-batcher**, fronted by a **content-addressed
//!   response cache** and an **admission controller**, with per-session
//!   weight-set routing over the [`crate::transport`] framing and an
//!   in-process fast path ([`CloudPool::process_sync`]) the fleet simulator
//!   uses.
//!
//! This module holds the request path both shapes share (decode ->
//! artifact -> response) and the wire-level response framing, including the
//! admission controller's `busy` shed reply.

pub mod cluster;
pub mod serving;

pub use cluster::{
    route_key, CellState, ChaosStats, CloudCluster, ClusterConfig, ClusterStats, HashRing,
    HealthConfig, DEFAULT_HOP_LATENCY_SECS,
};
pub use serving::{
    cache_key, AdmissionPolicy, CloudPool, PoolStats, ResponseCache, ServeError, ServingConfig,
    Ticket,
};

use std::borrow::Cow;

use anyhow::{bail, Context, Result};

use crate::coordinator::TierId;
use crate::edge::tail_artifact_name;
use crate::packet::{dequantize_code, dequantize_scaled, Packet, StreamKind};
use crate::runtime::Engine;
use crate::telemetry::LatencyHistogram;
use crate::tensor::Tensor;
use crate::transport::BUSY_FRAME;

/// Operator-facing response.
#[derive(Clone, Debug)]
pub struct CloudResponse {
    /// Insight: (img, img) mask logits. Context: None.
    pub mask_logits: Option<Tensor>,
    /// Per-class presence logits (person, vehicle) — the text-level answer.
    pub presence: Vec<f32>,
}

impl CloudResponse {
    /// Render the text answer the operator sees for a Context query
    /// ("Yes, two possible life signs detected ..." in the paper's example).
    pub fn text_answer(&self, class_names: &[&str]) -> String {
        let mut found = Vec::new();
        for (i, &logit) in self.presence.iter().enumerate() {
            if logit > 0.0 {
                found.push(*class_names.get(i).unwrap_or(&"object"));
            }
        }
        if found.is_empty() {
            "No critical targets detected in this sector.".to_string()
        } else {
            format!("Possible {} detected — escalate with an Insight query.", found.join(" and "))
        }
    }
}

/// A served request: the response plus serving-layer provenance.  The
/// virtual-time drivers feed `cache_hit` into the timing model — a hit is
/// answered from the cache index, not by tail execution, so it is charged
/// the (tiny) lookup latency instead of the artifact's tail latency —
/// and add `hop_secs` (the cluster's modeled inter-cell transfer cost)
/// to the request's virtual tail.
#[derive(Clone, Debug)]
pub struct Served {
    pub resp: CloudResponse,
    /// True when the response came from the content-addressed cache
    /// (the home cell's, or — when `hops > 0` — a sibling replica's).
    pub cache_hit: bool,
    /// Ring hops beyond the home cell this request traveled: overflow
    /// spill retries, or 1 for a sibling-replica cache hit.  Always 0 on
    /// a single pool.
    pub hops: u32,
    /// Modeled inter-cell latency charged for those hops
    /// (`hops × hop_latency`, virtual seconds).  Always 0.0 on a single
    /// pool, so the K=1 timing model is byte-identical to pre-cluster.
    pub hop_secs: f64,
    /// Index of the cluster cell that answered (served or cache-hit);
    /// 0 on a single pool.  Agents fold this into a per-UAV cells-hit
    /// bitmask for the fleet telemetry.
    pub cell: usize,
}

impl Served {
    pub(crate) fn executed(resp: CloudResponse) -> Self {
        Self { resp, cache_hit: false, hops: 0, hop_secs: 0.0, cell: 0 }
    }
}

/// Anything that can serve UAV packets — the seam between the mission state
/// machines and the server implementation (single-session or pooled).
pub trait ServePackets {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served>;

    /// Record one served request's end-to-end *virtual* latency (seconds of
    /// simulated time from capture/send to delivery).  The mission timing
    /// model calls this after charging the request, so the histogram is a
    /// pure function of the event-ordered request stream — deterministic
    /// per seed.  Default: discard (the single-session [`CloudServer`]
    /// keeps no telemetry).
    fn observe_latency(&self, _kind: StreamKind, _virtual_secs: f64) {}

    /// Per-class virtual latency histograms `(Context, Insight)`
    /// accumulated through [`ServePackets::observe_latency`], when the
    /// implementation records them.
    fn latency_histograms(&self) -> Option<(LatencyHistogram, LatencyHistogram)> {
        None
    }
}

/// Decode one request into (artifact, engine inputs) — the front half of
/// the request path, shared by single execution ([`process_packet`]) and
/// the serving layer's micro-batcher (which decodes every member, then
/// dispatches ONE `execute_batch` for the whole compatible batch).
pub(crate) fn decode_request_inputs(
    pkt: &Packet,
    prompt_ids: &[i32],
) -> Result<(Cow<'static, str>, Vec<Tensor>)> {
    let clip = dequantize_scaled(&pkt.clip_q, pkt.clip_shape, pkt.clip_scale)?;
    let pids = Tensor::i32(vec![prompt_ids.len()], prompt_ids.to_vec())?;
    match pkt.kind {
        StreamKind::Context => Ok((Cow::Borrowed("context_respond"), vec![clip, pids])),
        StreamKind::Insight => {
            if pkt.code_q.is_empty() {
                bail!("insight packet without code");
            }
            let tier = match pkt.tier {
                0 => TierId::HighAccuracy,
                1 => TierId::Balanced,
                2 => TierId::HighThroughput,
                other => bail!("bad tier index {other}"),
            };
            let code = dequantize_code(&pkt.code_q, pkt.code_shape)?;
            Ok((tail_artifact_name(pkt.split as usize, tier), vec![code, clip, pids]))
        }
    }
}

/// Build the operator-facing response from an artifact's outputs — the back
/// half of the request path.
pub(crate) fn response_from_outputs(
    kind: StreamKind,
    mut outs: Vec<Tensor>,
) -> Result<CloudResponse> {
    match kind {
        StreamKind::Context => {
            let Some(first) = outs.first() else {
                bail!("context responder returned no outputs");
            };
            Ok(CloudResponse { mask_logits: None, presence: first.as_f32()?.to_vec() })
        }
        StreamKind::Insight => {
            if outs.len() < 2 {
                bail!("insight tail returned {} outputs, want (mask, presence)", outs.len());
            }
            let presence = outs[1].as_f32()?.to_vec();
            Ok(CloudResponse { mask_logits: Some(outs.swap_remove(0)), presence })
        }
    }
}

/// Shared request path: dequantize, pick the artifact, execute.
pub(crate) fn process_packet(
    engine: &Engine,
    pkt: &Packet,
    prompt_ids: &[i32],
    set: &str,
) -> Result<CloudResponse> {
    let (artifact, inputs) = decode_request_inputs(pkt, prompt_ids)?;
    let outs = engine
        .execute_owned(&artifact, set, inputs)
        .with_context(|| format!("running {artifact}"))?;
    response_from_outputs(pkt.kind, outs)
}

/// The remote server: owns an engine handle and serves packets.
pub struct CloudServer {
    pub engine: Engine,
}

impl CloudServer {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    /// Process one packet with the operator prompt (token ids) against a
    /// weight set ("orig"/"ft" — which fine-tune serves the query).
    pub fn process(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse> {
        process_packet(&self.engine, pkt, prompt_ids, set)
    }
}

impl ServePackets for CloudServer {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served> {
        Ok(Served::executed(self.process(pkt, prompt_ids, set)?))
    }
}

/// Serialize a [`CloudResponse`] for the transport layer: presence logits
/// then the (possibly empty) flattened mask logits.
pub fn encode_response(resp: &CloudResponse) -> Vec<u8> {
    let mask: Vec<f32> = resp
        .mask_logits
        .as_ref()
        .and_then(|m| m.as_f32().ok().map(|s| s.to_vec()))
        .unwrap_or_default();
    let mut out = Vec::with_capacity(8 + 4 * (resp.presence.len() + mask.len()));
    out.extend_from_slice(&(resp.presence.len() as u32).to_le_bytes());
    for p in &resp.presence {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out.extend_from_slice(&(mask.len() as u32).to_le_bytes());
    for v in &mask {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// A decoded server reply frame: a response, or the admission controller's
/// `busy` shed signal (see [`crate::transport::BUSY_FRAME`]).
#[derive(Clone, Debug, PartialEq)]
pub enum ServerReply {
    /// The admission controller shed the request — back off and resend.
    Busy,
    /// A served response: (presence, mask) — mask empty for Context.
    Response { presence: Vec<f32>, mask: Vec<f32> },
}

/// Decode a server reply frame, busy-aware.  Clients that can handle
/// backpressure should prefer this over [`decode_response`].
pub fn decode_reply(frame: &[u8]) -> Result<ServerReply> {
    if frame == BUSY_FRAME {
        return Ok(ServerReply::Busy);
    }
    let (presence, mask) = decode_response(frame)?;
    Ok(ServerReply::Response { presence, mask })
}

/// Inverse of [`encode_response`]: (presence, mask) — mask empty for
/// Context.  Section counts are sanity-capped against the bytes actually
/// present *before* any offset arithmetic, so a corrupt or hostile length
/// prefix (up to the u32 maximum — 4 GiB of declared payload) is rejected
/// instead of driving a huge allocation or overflowing index math.  Every
/// shortfall — a session dying mid-frame cuts the stream at an arbitrary
/// byte — surfaces the typed [`crate::transport::TruncatedStream`] naming
/// the section the frame died in (every cut point is pinned by the tests
/// below).
pub fn decode_response(frame: &[u8]) -> Result<(Vec<f32>, Vec<f32>)> {
    if frame == BUSY_FRAME {
        bail!("server is busy (admission controller shed the request)");
    }
    let f32s = |bytes: &[u8]| -> Vec<f32> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    if frame.len() < 8 {
        return Err(crate::transport::TruncatedStream {
            section: "header",
            wanted: 8,
            got: frame.len(),
        }
        .into());
    }
    let np = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
    let mut off = 4;
    // The presence section plus the mask-count prefix must fit what's left.
    if np > (frame.len() - off - 4) / 4 {
        return Err(crate::transport::TruncatedStream {
            section: "presence",
            wanted: np * 4,
            got: frame.len() - off - 4,
        }
        .into());
    }
    let presence = f32s(&frame[off..off + np * 4]);
    off += np * 4;
    let nm = u32::from_le_bytes(frame[off..off + 4].try_into().unwrap()) as usize;
    off += 4;
    if nm > (frame.len() - off) / 4 {
        return Err(crate::transport::TruncatedStream {
            section: "mask",
            wanted: nm * 4,
            got: frame.len() - off,
        }
        .into());
    }
    let mask = f32s(&frame[off..off + nm * 4]);
    Ok((presence, mask))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_answer_formats() {
        let r = CloudResponse { mask_logits: None, presence: vec![1.2, -0.5] };
        let s = r.text_answer(&["person", "vehicle"]);
        assert!(s.contains("person") && !s.contains("vehicle"));
        let none = CloudResponse { mask_logits: None, presence: vec![-1.0, -1.0] };
        assert!(none.text_answer(&["person", "vehicle"]).contains("No critical"));
    }

    #[test]
    fn response_roundtrip() {
        let r = CloudResponse {
            mask_logits: Some(Tensor::f32(vec![2, 2], vec![0.5, -0.5, 1.0, -1.0]).unwrap()),
            presence: vec![1.5, -2.5],
        };
        let (presence, mask) = decode_response(&encode_response(&r)).unwrap();
        assert_eq!(presence, vec![1.5, -2.5]);
        assert_eq!(mask, vec![0.5, -0.5, 1.0, -1.0]);
        let ctx = CloudResponse { mask_logits: None, presence: vec![0.1] };
        let (p, m) = decode_response(&encode_response(&ctx)).unwrap();
        assert_eq!(p.len(), 1);
        assert!(m.is_empty());
        assert_eq!(
            decode_reply(&encode_response(&ctx)).unwrap(),
            ServerReply::Response { presence: p, mask: m }
        );
    }

    #[test]
    fn truncated_response_rejected() {
        let r = CloudResponse { mask_logits: None, presence: vec![1.0, 2.0] };
        let frame = encode_response(&r);
        assert!(decode_response(&frame[..frame.len() - 2]).is_err());
        assert!(decode_response(&[]).is_err());
    }

    #[test]
    fn every_reply_cut_point_surfaces_typed_truncation() {
        // The reply to a spilled Insight request (presence logits + mask
        // payload), cut at every possible byte — a session can die
        // mid-frame anywhere.  Each strict prefix must surface the
        // dedicated TruncatedStream error, never a generic one and never a
        // bogus success, on both decode surfaces.
        let r = CloudResponse {
            mask_logits: Some(Tensor::f32(vec![2, 2], vec![0.5, -0.5, 1.0, -1.0]).unwrap()),
            presence: vec![1.5, -2.5],
        };
        let frame = encode_response(&r);
        for cut in 0..frame.len() {
            let err = decode_response(&frame[..cut])
                .expect_err(&format!("prefix of {cut} bytes decoded"));
            assert!(
                err.downcast_ref::<crate::transport::TruncatedStream>().is_some(),
                "cut at {cut}: untyped error {err:#}"
            );
            let err = decode_reply(&frame[..cut])
                .expect_err(&format!("reply prefix of {cut} bytes decoded"));
            assert!(
                err.downcast_ref::<crate::transport::TruncatedStream>().is_some(),
                "reply cut at {cut}: untyped error {err:#}"
            );
        }
        assert!(decode_response(&frame).is_ok());
    }

    #[test]
    fn oversized_section_lengths_rejected() {
        // A 4 GiB presence count in a 12-byte frame must be rejected up
        // front — not by attempting the offset arithmetic.
        let mut frame = vec![0u8; 12];
        frame[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_response(&frame).unwrap_err().to_string();
        assert!(err.contains("presence"), "{err}");
        // Same for the mask count.
        let mut frame = Vec::new();
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&1.0f32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(&[0u8; 4]);
        let err = decode_response(&frame).unwrap_err().to_string();
        assert!(err.contains("mask"), "{err}");
    }

    #[test]
    fn busy_frame_is_distinguished() {
        assert_eq!(decode_reply(crate::transport::BUSY_FRAME).unwrap(), ServerReply::Busy);
        let err = decode_response(crate::transport::BUSY_FRAME).unwrap_err().to_string();
        assert!(err.contains("busy"), "{err}");
    }
}
