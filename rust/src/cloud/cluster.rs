//! The multi-cell cloud cluster (DESIGN.md "Multi-cell cloud cluster"):
//! K [`CloudPool`] cells behind a consistent-hash router, with overflow
//! spill and optional response-cache replication.
//!
//! Three mechanisms compose on top of PR 5's single admission-controlled
//! pool, all inert at the `--cells 1` default (a one-cell cluster delegates
//! every request to its pool untouched, so defaults stay byte-identical to
//! the pre-cluster output):
//!
//! * **Consistent-hash routing** — requests hash on (artifact, weight-set)
//!   ([`route_key`]) onto a vnode ring ([`HashRing`]), so every request for
//!   one artifact/set pair lands on the same *home* cell and micro-batches
//!   stay compatible within a cell.  The ring is pure arithmetic
//!   (splitmix64 vnode points, FNV-1a route keys) — no `HashMap` iteration,
//!   no per-process seed — so placement is deterministic across runs and
//!   platforms (pinned by `rust/tests/cluster.rs`).
//! * **Overflow spill** — a `Shed` verdict at the home cell retries at the
//!   next ring sibling, up to `spill_max` extra cells, each hop charging
//!   `hop_latency_secs` of modeled inter-cell latency onto the request's
//!   virtual tail.  An exhausted spill surfaces
//!   [`ServeError::Shed`]` { hops }` — the typed shed now carries how far
//!   the request traveled before giving up.
//! * **Cache replication** — PR 5's content-addressed keys are
//!   location-independent, so with `replicas R > 1` a home-cell cache miss
//!   probes the R-1 ring-successor replica caches (one modeled hop); an
//!   executed fill propagates to the whole replica set through
//!   [`CloudPool::cache_replicate`] (which counts no extra misses — the
//!   one executed miss is counted at the executing cell), and a remote hit
//!   read-repairs the home cache so the next identical request is local.
//!
//! Aggregation: [`ClusterStats`] merges per-cell [`PoolStats`] through
//! [`PoolStats::merge`] — counters add and the latency histograms merge
//! bucket-wise, so cross-cell percentiles are exact.  Virtual latency
//! ([`ServePackets::observe_latency`]) is recorded cluster-level: the trait
//! observes a request *after* the mission charges it, with no cell
//! identity, and the cluster is the serving endpoint the mission sees.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::coordinator::{classify_intent, TierId};
use crate::edge::tail_artifact_name;
use crate::faults::{FaultCounts, FaultInjector, FaultKind, FaultPlan};
use crate::packet::{Packet, StreamKind};
use crate::runtime::Engine;
use crate::telemetry::LatencyHistogram;
use crate::transport::{decode_request, Transport, BUSY_FRAME};
use crate::util::Rng;

use super::serving::{cache_key, fnv64, CloudPool, PoolStats, ServeError, ServingConfig};
use super::{ServePackets, Served};

/// Default modeled inter-cell hop latency (virtual seconds): one
/// intra-datacenter round trip between serving cells, an order of
/// magnitude below the paper's edge–cloud tail latencies so spill helps
/// rather than dominates.
pub const DEFAULT_HOP_LATENCY_SECS: f64 = 0.002;

/// Vnodes per cell on the ring: enough virtual points that the interned
/// artifact table (≈100 route keys) spreads within a small imbalance
/// factor across up to 16 cells, cheap enough that ring construction is
/// microseconds.
const VNODES_PER_CELL: usize = 96;

/// SplitMix64 finalizer — the vnode point hash.  Pure arithmetic, so ring
/// geometry is identical on every platform and run.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The cluster route key: FNV-1a over the artifact name the request will
/// execute and the weight set it names — exactly the micro-batcher's
/// compatibility class, so co-routable requests are co-batchable.  A
/// packet with an invalid tier index cannot name an artifact; it routes on
/// the raw (kind, tier, split) triple instead and errors at decode
/// wherever it lands.
pub fn route_key(pkt: &Packet, set: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let tier = match pkt.tier {
        0 => Some(TierId::HighAccuracy),
        1 => Some(TierId::Balanced),
        2 => Some(TierId::HighThroughput),
        _ => None,
    };
    match (pkt.kind, tier) {
        (StreamKind::Context, _) => h = fnv64(h, b"context_respond"),
        (StreamKind::Insight, Some(tier)) => {
            h = fnv64(h, tail_artifact_name(pkt.split as usize, tier).as_bytes());
        }
        (StreamKind::Insight, None) => {
            h = fnv64(h, &[pkt.kind as u8, pkt.tier, pkt.split]);
        }
    }
    // Separator byte so (artifact, set) pairs cannot collide by
    // concatenation ("a" + "bc" vs "ab" + "c").
    h = fnv64(h, &[0xFF]);
    fnv64(h, set.as_bytes())
}

/// A consistent-hash ring: each cell contributes [`VNODES_PER_CELL`]
/// points; a key routes to the first point clockwise from its hash.
/// Removing one cell removes only that cell's points, so only keys homed
/// on it remap (the stability property, pinned by `rust/tests/cluster.rs`).
#[derive(Clone, Debug)]
pub struct HashRing {
    /// (point hash, cell index), sorted by hash.
    points: Vec<(u64, usize)>,
    cells: usize,
    /// Vnodes each cell contributes (needed to rebuild a cell's points on
    /// [`HashRing::add_cell`]).
    vnodes: usize,
}

impl HashRing {
    pub fn new(cells: usize) -> Self {
        Self::with_vnodes(cells, VNODES_PER_CELL)
    }

    pub fn with_vnodes(cells: usize, vnodes: usize) -> Self {
        assert!(cells >= 1, "a ring needs at least one cell");
        assert!(vnodes >= 1, "a cell needs at least one vnode");
        let mut points = Vec::with_capacity(cells * vnodes);
        for cell in 0..cells {
            for v in 0..vnodes {
                points.push((splitmix64(((cell as u64) << 32) | v as u64), cell));
            }
        }
        // Sort by (hash, cell); on an (astronomically unlikely) point
        // collision the lowest cell index deterministically keeps it.
        points.sort_unstable();
        points.dedup_by_key(|p| p.0);
        Self { points, cells, vnodes }
    }

    /// Number of cells this ring was built over (removed cells included —
    /// cell indices are stable identities, not a dense range).
    pub fn cells(&self) -> usize {
        self.cells
    }

    /// First point clockwise from `key` (wrapping).
    fn successor_idx(&self, key: u64) -> usize {
        let i = self.points.partition_point(|p| p.0 < key);
        if i == self.points.len() {
            0
        } else {
            i
        }
    }

    /// The home cell for `key`.
    pub fn cell_for(&self, key: u64) -> usize {
        assert!(!self.points.is_empty(), "ring has no cells left");
        self.points[self.successor_idx(key)].1
    }

    /// All distinct cells in ring order starting from `key`'s home — the
    /// spill order (element 0 is home, element 1 the first sibling, …) and
    /// the replica placement (the first R elements hold the entry).
    pub fn cells_from(&self, key: u64) -> Vec<usize> {
        assert!(!self.points.is_empty(), "ring has no cells left");
        let mut out = Vec::with_capacity(self.cells);
        let mut seen = vec![false; self.cells];
        let start = self.successor_idx(key);
        for off in 0..self.points.len() {
            let (_, cell) = self.points[(start + off) % self.points.len()];
            if !seen[cell] {
                seen[cell] = true;
                out.push(cell);
            }
        }
        out
    }

    /// Remove one cell's points (cluster shrink, or a health-layer
    /// quarantine).  Every other cell's points are untouched, so only keys
    /// homed on the removed cell remap.  The last cell cannot be removed.
    pub fn remove_cell(&mut self, cell: usize) {
        assert!(
            self.points.iter().any(|&(_, c)| c != cell),
            "cannot remove the last cell from the ring"
        );
        self.points.retain(|&(_, c)| c != cell);
    }

    /// Re-insert one cell's points — the inverse of
    /// [`HashRing::remove_cell`], used when a quarantined cell recovers.
    /// Points merge under the same (sort, lowest-cell-keeps-collisions)
    /// rule as construction, so insertion order does not matter: any
    /// remove/re-add sequence that ends with the same cell set yields the
    /// byte-identical ring (pinned by `rust/tests/chaos.rs`).  Re-adding a
    /// present cell is a no-op.
    pub fn add_cell(&mut self, cell: usize) {
        assert!(cell < self.cells, "cell {cell} outside this ring's 0..{} id space", self.cells);
        if self.has_cell(cell) {
            return;
        }
        for v in 0..self.vnodes {
            self.points.push((splitmix64(((cell as u64) << 32) | v as u64), cell));
        }
        self.points.sort_unstable();
        self.points.dedup_by_key(|p| p.0);
    }

    /// Whether `cell` currently contributes points to the ring.
    pub fn has_cell(&self, cell: usize) -> bool {
        self.points.iter().any(|&(_, c)| c == cell)
    }

    /// Distinct cells currently contributing points.
    pub fn live_cells(&self) -> usize {
        let mut seen = vec![false; self.cells];
        for &(_, c) in &self.points {
            seen[c] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }
}

/// Cluster configuration.  The defaults are a single cell with no
/// replication — behaviorally identical to a bare [`CloudPool`] running
/// `serving`, which is what keeps `--cells 1` (and flagless) mission
/// output byte-identical to pre-cluster runs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of pool cells (≥ 1; 1 = plain single pool).
    pub cells: usize,
    /// Cache replica count R (≥ 1; 1 = no replication).  An entry lives on
    /// the first R cells in ring order from its route key.
    pub replicas: usize,
    /// Modeled inter-cell latency per hop (virtual seconds), charged onto
    /// the request's tail for spill retries and sibling-cache hits.
    pub hop_latency_secs: f64,
    /// Maximum ring siblings to try after the home cell sheds (0 = no
    /// spill).
    pub spill_max: u32,
    /// Per-cell serving configuration (batching, cache, admission — each
    /// cell runs its own queue, cache and admission bound).
    pub serving: ServingConfig,
    /// Chaos layer: the fault schedule this cluster runs under (`None` =
    /// fault-free, taking the exact pre-chaos request path).
    pub faults: Option<FaultPlan>,
    /// Failure-domain health parameters — only consulted when a fault plan
    /// is armed.
    pub health: HealthConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            cells: 1,
            replicas: 1,
            hop_latency_secs: DEFAULT_HOP_LATENCY_SECS,
            spill_max: 1,
            serving: ServingConfig::default(),
            faults: None,
            health: HealthConfig::default(),
        }
    }
}

impl ClusterConfig {
    /// True when the cluster layer is actually multi-cell — drives whether
    /// the fleet/scenario missions emit the extra cluster telemetry
    /// (single-cell reports stay byte-identical to pre-cluster ones).
    pub fn multi_cell(&self) -> bool {
        self.cells > 1
    }

    /// True when a fault plan is armed (drives the chaos request path and
    /// the recovery telemetry).
    pub fn chaos_enabled(&self) -> bool {
        self.faults.is_some()
    }
}

/// Parameters of the per-cell health state machine (DESIGN.md "Chaos &
/// recovery"): Up → Suspect on a typed error, Suspect → Down after
/// `down_after` consecutive errors (virtual-time quarantine, routed
/// around), Down → Up when a re-probe on seeded exponential backoff
/// succeeds.
#[derive(Clone, Debug, PartialEq)]
pub struct HealthConfig {
    /// Consecutive typed errors that quarantine a cell (the first error
    /// only suspects it; `down_after` total take it Down).
    pub down_after: u32,
    /// Initial quarantine before the first re-probe (virtual seconds).
    pub backoff_base_secs: f64,
    /// Quarantine cap — the backoff doubles per failed probe up to this.
    pub backoff_max_secs: f64,
    /// Jitter fraction: each quarantine interval is scaled by
    /// `1 + jitter·u` with a seeded uniform `u ∈ [0, 1)`, decorrelating
    /// re-probe storms while staying deterministic per seed.
    pub jitter: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self { down_after: 2, backoff_base_secs: 0.5, backoff_max_secs: 8.0, jitter: 0.1 }
    }
}

/// One cell's health verdict (see [`HealthConfig`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellState {
    Up,
    Suspect,
    Down,
}

impl CellState {
    pub fn name(self) -> &'static str {
        match self {
            CellState::Up => "up",
            CellState::Suspect => "suspect",
            CellState::Down => "down",
        }
    }
}

/// Per-cell health bookkeeping (chaos path only).
#[derive(Clone, Debug)]
struct CellHealth {
    state: CellState,
    consec_errors: u32,
    suspect_since: f64,
    down_since: f64,
    /// Next re-probe time while Down.
    probe_at: f64,
    /// Current quarantine interval (doubles per failed probe).
    backoff: f64,
    /// Whether the cell currently contributes points to the live ring.
    in_ring: bool,
}

impl CellHealth {
    fn up() -> Self {
        Self {
            state: CellState::Up,
            consec_errors: 0,
            suspect_since: 0.0,
            down_since: 0.0,
            probe_at: 0.0,
            backoff: 0.0,
            in_ring: true,
        }
    }
}

/// Recovery observability the chaos path accumulates — surfaced through
/// [`CloudCluster::chaos_stats`] into the fleet/scenario reports and
/// `BENCH_chaos.json`.
#[derive(Clone, Debug)]
pub struct ChaosStats {
    /// Injections per fault kind (index via [`FaultKind::index`]).
    pub injected: FaultCounts,
    /// Mean-time-to-recovery samples: virtual seconds from quarantine
    /// (Down) to the successful re-probe, one sample per recovery.
    pub mttr: LatencyHistogram,
    /// Time-to-detect samples: virtual seconds from first Suspect to the
    /// Down transition, one sample per quarantine.
    pub ttd: LatencyHistogram,
    /// Total virtual seconds of completed cell downtime (Down → Up spans;
    /// cells still Down at the end of the run are not counted here).
    pub downtime_secs: f64,
    /// Completed Down → Up recoveries.
    pub recoveries: u64,
    /// Per-cell health transitions in virtual-time order.
    pub timeline: Vec<(f64, usize, CellState)>,
    /// Cells still Down when the stats were taken.
    pub down_now: u32,
}

/// The chaos path's mutable state: the fault injector, the per-cell health
/// machines, the *live* ring (quarantined cells removed) and the recovery
/// telemetry.  One mutex guards it all — the virtual-time fleet loop is
/// serial, so the lock is uncontended and the seeded draws stay in request
/// order (byte-determinism).
struct ChaosState {
    injector: FaultInjector,
    hcfg: HealthConfig,
    rng: Rng,
    cells: Vec<CellHealth>,
    live: HashRing,
    mttr: LatencyHistogram,
    ttd: LatencyHistogram,
    downtime_secs: f64,
    recoveries: u64,
    timeline: Vec<(f64, usize, CellState)>,
}

impl ChaosState {
    fn new(plan: FaultPlan, hcfg: HealthConfig, n_cells: usize) -> Self {
        let seed = plan.seed;
        Self {
            injector: FaultInjector::new(plan),
            hcfg,
            rng: Rng::new(seed ^ 0xBACC_0FF),
            cells: (0..n_cells).map(|_| CellHealth::up()).collect(),
            live: HashRing::new(n_cells),
            mttr: LatencyHistogram::new(),
            ttd: LatencyHistogram::new(),
            downtime_secs: 0.0,
            recoveries: 0,
            timeline: Vec::new(),
        }
    }

    /// Re-probe every quarantined cell whose backoff expired at `t`: a
    /// probe succeeds iff no crash window is open (a health-check ping,
    /// not a request), taking the cell Up and back into the live ring;
    /// a failed probe doubles the quarantine with seeded jitter.
    fn reprobe_due(&mut self, t: f64) {
        for cell in 0..self.cells.len() {
            if self.cells[cell].state != CellState::Down || t < self.cells[cell].probe_at {
                continue;
            }
            if self.injector.crash_active(cell, t) {
                let jitter = 1.0 + self.hcfg.jitter * self.rng.f64();
                let h = &mut self.cells[cell];
                h.backoff = (h.backoff * 2.0).min(self.hcfg.backoff_max_secs);
                h.probe_at = t + h.backoff * jitter;
            } else {
                let down_for = (t - self.cells[cell].down_since).max(0.0);
                self.mttr.record(down_for);
                self.downtime_secs += down_for;
                self.recoveries += 1;
                let h = &mut self.cells[cell];
                h.state = CellState::Up;
                h.consec_errors = 0;
                if !h.in_ring {
                    h.in_ring = true;
                    self.live.add_cell(cell);
                }
                self.timeline.push((t, cell, CellState::Up));
            }
        }
    }

    /// One typed error at `cell`: Up → Suspect, Suspect → Down after
    /// `down_after` consecutive errors.
    fn cell_error(&mut self, cell: usize, t: f64) {
        match self.cells[cell].state {
            CellState::Down => {}
            CellState::Up => {
                let h = &mut self.cells[cell];
                h.state = CellState::Suspect;
                h.consec_errors = 1;
                h.suspect_since = t;
                self.timeline.push((t, cell, CellState::Suspect));
                if self.hcfg.down_after <= 1 {
                    self.quarantine(cell, t);
                }
            }
            CellState::Suspect => {
                self.cells[cell].consec_errors += 1;
                if self.cells[cell].consec_errors >= self.hcfg.down_after {
                    self.quarantine(cell, t);
                }
            }
        }
    }

    /// A successful serve at `cell` clears suspicion.
    fn cell_ok(&mut self, cell: usize, t: f64) {
        if self.cells[cell].state == CellState::Suspect {
            self.cells[cell].state = CellState::Up;
            self.timeline.push((t, cell, CellState::Up));
        }
        self.cells[cell].consec_errors = 0;
    }

    /// Take `cell` Down: record time-to-detect, start the quarantine clock
    /// and route around it (unless it is the last live cell — the ring
    /// never empties; requests keep failing there and the agents degrade).
    fn quarantine(&mut self, cell: usize, t: f64) {
        let ttd = (t - self.cells[cell].suspect_since).max(0.0);
        self.ttd.record(ttd);
        let jitter = 1.0 + self.hcfg.jitter * self.rng.f64();
        {
            let base = self.hcfg.backoff_base_secs;
            let h = &mut self.cells[cell];
            h.state = CellState::Down;
            h.down_since = t;
            h.backoff = base;
            h.probe_at = t + base * jitter;
        }
        self.timeline.push((t, cell, CellState::Down));
        if self.cells[cell].in_ring && self.live.live_cells() > 1 {
            self.cells[cell].in_ring = false;
            self.live.remove_cell(cell);
        }
    }
}

/// Aggregated cluster counters: per-cell [`PoolStats`] plus the merged
/// total ([`PoolStats::merge`] — counters add, histograms merge
/// bucket-wise) and the cluster-level routing telemetry.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    pub cells: usize,
    pub per_cell: Vec<PoolStats>,
    /// Merged across cells.  `lat_context`/`lat_insight` carry the
    /// cluster-level virtual-latency histograms (recorded through
    /// [`ServePackets::observe_latency`], which sees no cell identity);
    /// the wall-clock histograms are exact bucket-wise merges of the
    /// per-cell ones.
    pub total: PoolStats,
    /// Sibling-cache hits answered BY each cell for requests homed
    /// elsewhere (the replication payoff, attributed to the cell that
    /// held the entry).
    pub remote_hits: Vec<u64>,
    /// Requests served at spill hop h (index 0 = home, 1 = first sibling,
    /// …) — the spill-hop distribution the bench reports.
    pub served_at_hop: Vec<u64>,
    /// Requests that exhausted spill and surfaced a cluster-level shed
    /// (distinct from `total.shed`, which counts every per-cell refusal
    /// along the way).
    pub shed: u64,
}

impl ClusterStats {
    /// Requests served off their home cell (spill successes).
    pub fn spilled(&self) -> u64 {
        self.served_at_hop.iter().skip(1).sum()
    }

    /// Sibling-cache hits across all cells.
    pub fn remote_hits_total(&self) -> u64 {
        self.remote_hits.iter().sum()
    }
}

/// K [`CloudPool`] cells behind the consistent-hash router — the module
/// docs describe the routing/spill/replication composition.  Implements
/// [`ServePackets`], so the fleet simulator and the transport sessions use
/// it exactly where a single pool went.
pub struct CloudCluster {
    pools: Vec<CloudPool>,
    ring: HashRing,
    cfg: ClusterConfig,
    /// Cluster-level per-class virtual latency `[Context, Insight]` (the
    /// mission observes latency against the cluster, not a cell).
    vlat: Mutex<[LatencyHistogram; 2]>,
    /// Per-cell sibling-cache hits (see [`ClusterStats::remote_hits`]).
    remote_hits: Vec<AtomicU64>,
    /// Served-at-hop distribution, length `min(cells, spill_max + 1)`.
    served_at_hop: Vec<AtomicU64>,
    /// Exhausted-spill sheds surfaced to callers.
    shed: AtomicU64,
    /// Chaos layer (fault injector + health machines + live ring) — `None`
    /// unless a fault plan is armed, keeping the fault-free request path
    /// byte-identical to pre-chaos builds.
    chaos: Option<Mutex<ChaosState>>,
}

impl CloudCluster {
    /// Build `cfg.cells` cells, each a [`CloudPool`] over a clone of
    /// `cell_engines` (so a cluster with W workers per cell runs K·W
    /// workers total) and a clone of `cfg.serving`.
    pub fn with_config(cell_engines: Vec<Engine>, cfg: ClusterConfig) -> Self {
        let cells = cfg.cells.max(1);
        let pools = (0..cells)
            .map(|_| CloudPool::with_config(cell_engines.clone(), cfg.serving.clone()))
            .collect();
        Self::from_pools_internal(pools, cfg)
    }

    /// Assemble a cluster from pre-built cells — the seam the tests and
    /// benches use to give individual cells distinct shapes (a saturated
    /// home next to an idle sibling).  `cfg.cells` is overridden by
    /// `pools.len()`.
    pub fn from_pools(pools: Vec<CloudPool>, cfg: ClusterConfig) -> Self {
        Self::from_pools_internal(pools, cfg)
    }

    fn from_pools_internal(pools: Vec<CloudPool>, mut cfg: ClusterConfig) -> Self {
        assert!(!pools.is_empty(), "a cluster needs at least one cell");
        cfg.cells = pools.len();
        let hops = (cfg.spill_max as usize + 1).min(pools.len());
        let chaos = cfg.faults.clone().map(|plan| {
            plan.validate().expect("fault plan failed validation");
            Mutex::new(ChaosState::new(plan, cfg.health.clone(), pools.len()))
        });
        Self {
            ring: HashRing::new(pools.len()),
            remote_hits: (0..pools.len()).map(|_| AtomicU64::new(0)).collect(),
            served_at_hop: (0..hops).map(|_| AtomicU64::new(0)).collect(),
            shed: AtomicU64::new(0),
            vlat: Mutex::new([LatencyHistogram::new(); 2]),
            chaos,
            pools,
            cfg,
        }
    }

    pub fn cells(&self) -> usize {
        self.pools.len()
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// One cell's pool (tests/benches introspect per-cell state).
    pub fn cell(&self, i: usize) -> &CloudPool {
        &self.pools[i]
    }

    /// The cells this request maps to, in ring order: element 0 is the
    /// home cell, the first `replicas` elements are the replica set, and
    /// the spill path walks the prefix.
    pub fn placement(&self, pkt: &Packet, set: &str) -> Vec<usize> {
        self.ring.cells_from(route_key(pkt, set))
    }

    /// Route, probe, spill: the cluster request path.  See the module docs
    /// for the state machine; the single-cell fast path delegates straight
    /// to the pool (no ring walk, no probe — byte-identical behavior and
    /// counters to a bare pool).
    pub fn try_process(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Served, ServeError> {
        if self.chaos.is_some() {
            return self.try_process_chaos(pkt, prompt_ids, set);
        }
        if self.pools.len() == 1 {
            return self.pools[0].try_process(pkt, prompt_ids, set);
        }
        let order = self.ring.cells_from(route_key(pkt, set));
        let home = order[0];
        let caching = self.cfg.serving.cache_entries > 0;
        let replicating = caching && self.cfg.replicas > 1;
        let key = caching.then(|| cache_key(pkt, prompt_ids, set));

        if replicating {
            let key = key.expect("replication implies caching");
            // Home probe first (free: same lookup the pool would do), then
            // the R-1 sibling replicas.  Sibling probes model one parallel
            // inter-cell round trip, so a remote hit costs exactly one hop
            // whatever replica rank answered.
            if let Some(resp) = self.pools[home].cache_probe(key, pkt.t_capture) {
                self.served_at_hop[0].fetch_add(1, Ordering::Relaxed);
                return Ok(Served { resp, cache_hit: true, hops: 0, hop_secs: 0.0, cell: home });
            }
            for &cell in order.iter().take(self.cfg.replicas).skip(1) {
                let Some(resp) = self.pools[cell].cache_probe(key, pkt.t_capture) else {
                    continue;
                };
                self.remote_hits[cell].fetch_add(1, Ordering::Relaxed);
                // Read-repair: the next identical request hits home with
                // zero hops.
                self.pools[home].cache_replicate(key, &resp, pkt.t_capture);
                return Ok(Served {
                    resp,
                    cache_hit: true,
                    hops: 1,
                    hop_secs: self.cfg.hop_latency_secs,
                    cell,
                });
            }
        }

        // Execute at home; on a shed, spill clockwise up to `spill_max`
        // ring siblings, each hop charging one inter-cell latency.
        let tries = order.len().min(self.cfg.spill_max as usize + 1);
        for (hop, &cell) in order.iter().take(tries).enumerate() {
            match self.pools[cell].try_process(pkt, prompt_ids, set) {
                Ok(served) => {
                    self.served_at_hop[hop.min(self.served_at_hop.len() - 1)]
                        .fetch_add(1, Ordering::Relaxed);
                    if replicating && !served.cache_hit {
                        let key = key.expect("replication implies caching");
                        // Propagate the executed fill to the replica set;
                        // the executing cell already filled its own cache
                        // (and counted the one miss).
                        for &rc in order.iter().take(self.cfg.replicas) {
                            if rc != cell {
                                self.pools[rc].cache_replicate(key, &served.resp, pkt.t_capture);
                            }
                        }
                    }
                    return Ok(Served {
                        resp: served.resp,
                        cache_hit: served.cache_hit,
                        hops: hop as u32,
                        hop_secs: hop as f64 * self.cfg.hop_latency_secs,
                        cell,
                    });
                }
                // A shed spills to the next sibling; Closed/Exec are
                // request-fatal and surface immediately.
                Err(ServeError::Shed { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        self.shed.fetch_add(1, Ordering::Relaxed);
        Err(ServeError::Shed { hops: tries.saturating_sub(1) as u32 })
    }

    /// The chaos-armed request path: the same route/probe/spill state
    /// machine as [`CloudCluster::try_process`], but routed on the *live*
    /// ring (quarantined cells removed), with fault injection at every
    /// stage and every typed error feeding the per-cell health machines.
    /// A separate function — not branches inside the hot path — so the
    /// fault-free path stays textually and behaviorally untouched.
    fn try_process_chaos(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Served, ServeError> {
        let t = pkt.t_capture;
        let mut st = self.chaos.as_ref().expect("chaos path without state").lock().unwrap();
        // Link-level faults fire before any routing — the wire is at
        // fault, not a cell, so the health machines never see them.
        if st.injector.take_session_drop(t) {
            return Err(ServeError::Fault { kind: FaultKind::SessionDrop });
        }
        if st.injector.draw_wire_corrupt(t) {
            return Err(ServeError::Fault { kind: FaultKind::WireCorrupt });
        }
        // Quarantined cells whose backoff expired re-probe now, so a
        // recovered cell rejoins the live ring before this request routes.
        st.reprobe_due(t);
        let order = st.live.cells_from(route_key(pkt, set));
        let home = order[0];
        let caching = self.cfg.serving.cache_entries > 0;
        let replicating = caching && self.cfg.replicas > 1;
        let key = caching.then(|| cache_key(pkt, prompt_ids, set));

        if replicating {
            let key = key.expect("replication implies caching");
            if st.cells[home].state != CellState::Down {
                if let Some(resp) = self.pools[home].cache_probe(key, t) {
                    self.served_at_hop[0].fetch_add(1, Ordering::Relaxed);
                    return Ok(Served { resp, cache_hit: true, hops: 0, hop_secs: 0.0, cell: home });
                }
            }
            // Sibling replica probes walk the live order, so quarantined
            // replicas are skipped without spending a hop on them.
            for &cell in order.iter().take(self.cfg.replicas).skip(1) {
                let Some(resp) = self.pools[cell].cache_probe(key, t) else {
                    continue;
                };
                self.remote_hits[cell].fetch_add(1, Ordering::Relaxed);
                self.pools[home].cache_replicate(key, &resp, t);
                return Ok(Served {
                    resp,
                    cache_hit: true,
                    hops: 1,
                    hop_secs: self.cfg.hop_latency_secs,
                    cell,
                });
            }
        }

        let tries = order.len().min(self.cfg.spill_max as usize + 1);
        let mut last_fault: Option<FaultKind> = None;
        for (hop, &cell) in order.iter().take(tries).enumerate() {
            if st.cells[cell].state == CellState::Down {
                // Only reachable when the ring is down to its last cell
                // (quarantined cells leave the live ring otherwise) — the
                // quarantine stands until its re-probe clears it.
                last_fault = Some(FaultKind::CellCrash);
                continue;
            }
            if st.injector.crash_active(cell, t) {
                // Connection refused: record, feed the health machine and
                // spill to the next ring sibling like a shed would.
                st.injector.record(FaultKind::CellCrash);
                st.cell_error(cell, t);
                last_fault = Some(FaultKind::CellCrash);
                continue;
            }
            if st.injector.draw_exec_error(cell, t) {
                // The request died mid-execution at this cell: request-
                // fatal here (the agent's retry budget owns recovery),
                // and one more strike against the cell.
                st.cell_error(cell, t);
                return Err(ServeError::Fault { kind: FaultKind::ExecError });
            }
            match self.pools[cell].try_process(pkt, prompt_ids, set) {
                Ok(served) => {
                    st.cell_ok(cell, t);
                    let stall = st.injector.stall_secs(cell, t);
                    self.served_at_hop[hop.min(self.served_at_hop.len() - 1)]
                        .fetch_add(1, Ordering::Relaxed);
                    if replicating && !served.cache_hit {
                        let key = key.expect("replication implies caching");
                        for &rc in order.iter().take(self.cfg.replicas) {
                            if rc != cell {
                                self.pools[rc].cache_replicate(key, &served.resp, t);
                            }
                        }
                    }
                    return Ok(Served {
                        resp: served.resp,
                        cache_hit: served.cache_hit,
                        hops: hop as u32,
                        hop_secs: hop as f64 * self.cfg.hop_latency_secs + stall,
                        cell,
                    });
                }
                Err(ServeError::Shed { .. }) => continue,
                Err(e) => {
                    // A real per-cell failure (worker death, execution
                    // error) is a strike against the cell too.
                    st.cell_error(cell, t);
                    return Err(e);
                }
            }
        }
        if let Some(kind) = last_fault {
            return Err(ServeError::Fault { kind });
        }
        self.shed.fetch_add(1, Ordering::Relaxed);
        Err(ServeError::Shed { hops: tries.saturating_sub(1) as u32 })
    }

    /// Recovery observability when a fault plan is armed (`None`
    /// otherwise) — see [`ChaosStats`].
    pub fn chaos_stats(&self) -> Option<ChaosStats> {
        let st = self.chaos.as_ref()?.lock().unwrap();
        Some(ChaosStats {
            injected: st.injector.counts(),
            mttr: st.mttr,
            ttd: st.ttd,
            downtime_secs: st.downtime_secs,
            recoveries: st.recoveries,
            timeline: st.timeline.clone(),
            down_now: st.cells.iter().filter(|c| c.state == CellState::Down).count() as u32,
        })
    }

    /// [`CloudCluster::try_process`] with the typed error folded into
    /// anyhow (the [`ServePackets`] surface).
    pub fn process_sync(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served> {
        self.try_process(pkt, prompt_ids, set).map_err(anyhow::Error::from)
    }

    /// Per-cell and merged counters — see [`ClusterStats`].
    pub fn stats(&self) -> ClusterStats {
        let per_cell: Vec<PoolStats> = self.pools.iter().map(|p| p.stats()).collect();
        let mut total = PoolStats::default();
        for s in &per_cell {
            total.merge(s);
        }
        // Virtual latency is recorded cluster-level (the per-cell virtual
        // histograms are empty — observe_latency has no cell identity).
        let [lat_context, lat_insight] = *self.vlat.lock().unwrap();
        total.lat_context = lat_context;
        total.lat_insight = lat_insight;
        ClusterStats {
            cells: per_cell.len(),
            per_cell,
            total,
            remote_hits: self.remote_hits.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            served_at_hop: self.served_at_hop.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            shed: self.shed.load(Ordering::Relaxed),
        }
    }

    /// Serve one transport session against the cluster — the same wire
    /// protocol as [`CloudPool::serve_session`] (`hello <set>` pinning,
    /// [`super::encode_response`] framing), but requests route through the
    /// ring: a session request whose home cell sheds spills before the
    /// `busy` frame goes out, so the client sees backpressure only when
    /// the whole spill path is saturated.
    pub fn serve_session<T: Transport>(&self, transport: &mut T, default_set: &str) -> Result<u64> {
        let mut session_set = default_set.to_string();
        let mut served = 0u64;
        loop {
            let frame = match transport.recv() {
                Ok(f) => f,
                Err(_) => break, // peer closed
            };
            if frame == b"shutdown" {
                break;
            }
            if let Some(set) = frame.strip_prefix(b"hello ") {
                session_set = String::from_utf8_lossy(set).trim().to_string();
                transport.send(b"ok")?;
                continue;
            }
            let (pkt_bytes, prompt, set) = decode_request(&frame)?;
            let pkt = Packet::decode(&pkt_bytes)?;
            let intent = classify_intent(&prompt);
            let set = if set.is_empty() { session_set.as_str() } else { set.as_str() };
            match self.try_process(&pkt, &intent.token_ids, set) {
                Ok(r) => {
                    transport.send(&super::encode_response(&r.resp))?;
                    served += 1;
                }
                Err(ServeError::Shed { .. }) => transport.send(BUSY_FRAME)?,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(served)
    }
}

impl ServePackets for CloudCluster {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served> {
        self.process_sync(pkt, prompt_ids, set)
    }

    fn observe_latency(&self, kind: StreamKind, virtual_secs: f64) {
        self.vlat.lock().unwrap()[kind as usize].record(virtual_secs);
    }

    fn latency_histograms(&self) -> Option<(LatencyHistogram, LatencyHistogram)> {
        let l = self.vlat.lock().unwrap();
        Some((l[0], l[1]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{classify_intent, Lut};
    use crate::dataset::{Corpus, Dataset};
    use crate::edge::EdgePipeline;
    use crate::energy::DeviceModel;

    fn sample_packets(n: usize) -> (Vec<Packet>, Vec<i32>) {
        let engine = Engine::synthetic();
        let ds = Dataset::synthetic(Corpus::Flood, n, 16, 0xF10D0);
        let mut edge = EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
        let pkts = ds
            .scenes
            .iter()
            .map(|s| edge.capture_insight(s, 1, TierId::HighAccuracy, 0.0).unwrap().0)
            .collect();
        (pkts, classify_intent("highlight the stranded people").token_ids)
    }

    #[test]
    fn single_cell_cluster_matches_bare_pool() {
        let engine = Engine::synthetic();
        let (pkts, ids) = sample_packets(2);
        let serving = ServingConfig { cache_entries: 8, ..ServingConfig::default() };
        let pool = CloudPool::with_config(vec![engine.clone()], serving.clone());
        let cluster = CloudCluster::with_config(
            vec![engine],
            ClusterConfig { cells: 1, serving, ..ClusterConfig::default() },
        );
        for pkt in &pkts {
            for _ in 0..2 {
                let a = pool.process_sync(pkt, &ids, "ft").unwrap();
                let b = cluster.process_sync(pkt, &ids, "ft").unwrap();
                assert_eq!(a.resp.presence, b.resp.presence);
                assert_eq!(a.resp.mask_logits, b.resp.mask_logits);
                assert_eq!(a.cache_hit, b.cache_hit);
                assert_eq!((b.hops, b.hop_secs, b.cell), (0, 0.0, 0));
            }
        }
        let (ps, cs) = (pool.stats(), cluster.stats());
        assert_eq!(ps.completed, cs.total.completed);
        assert_eq!(ps.cache_hits, cs.total.cache_hits);
        assert_eq!(ps.cache_misses, cs.total.cache_misses);
        assert_eq!(cs.shed, 0);
    }

    #[test]
    fn routing_keeps_batches_compatible_and_sticky() {
        let (pkts, ids) = sample_packets(4);
        let cluster = CloudCluster::with_config(
            vec![Engine::synthetic()],
            ClusterConfig { cells: 4, ..ClusterConfig::default() },
        );
        // Every packet here shares (kind, tier, split, set) — the batch
        // compatibility class — so all land on one cell, repeatedly.
        let homes: Vec<usize> =
            pkts.iter().map(|p| cluster.placement(p, "ft")[0]).collect();
        assert!(homes.windows(2).all(|w| w[0] == w[1]), "{homes:?}");
        // A different weight set (a different compatibility class) may
        // land elsewhere, and its placement is just as deterministic.
        assert_eq!(cluster.placement(&pkts[0], "orig"), cluster.placement(&pkts[0], "orig"));
        let _ = ids;
    }

    #[test]
    fn spill_serves_at_sibling_when_home_sheds() {
        let (pkts, ids) = sample_packets(1);
        let serving = ServingConfig { queue_depth: 1, ..ServingConfig::default() };
        let cfg = ClusterConfig {
            replicas: 1,
            hop_latency_secs: 0.25,
            spill_max: 1,
            serving: serving.clone(),
            ..ClusterConfig::default()
        };
        let home = HashRing::new(2).cell_for(route_key(&pkts[0], "ft"));
        // The home cell has no workers and one admission slot, which a
        // parked ticket holds for the whole test — every arrival there
        // sheds.  The sibling executes inline.
        let mk_cell = |idx: usize| {
            if idx == home {
                CloudPool::with_config(Vec::new(), serving.clone())
            } else {
                CloudPool::with_config(vec![Engine::synthetic()], serving.clone())
            }
        };
        let cluster = CloudCluster::from_pools(vec![mk_cell(0), mk_cell(1)], cfg);
        let _parked = cluster.cell(home).submit(&pkts[0], &ids, "ft").unwrap();
        let served = cluster.try_process(&pkts[0], &ids, "ft").unwrap();
        assert_eq!(served.hops, 1);
        assert!((served.hop_secs - 0.25).abs() < 1e-12);
        assert_eq!(served.cell, 1 - home);
        let st = cluster.stats();
        assert_eq!(st.served_at_hop, vec![0, 1]);
        assert_eq!(st.spilled(), 1);
        assert_eq!(st.per_cell[home].shed, 1, "home refusal still counted per-cell");
        assert_eq!(st.shed, 0, "spill succeeded — no cluster-level shed");
    }

    #[test]
    fn exhausted_spill_sheds_with_hop_count() {
        let (pkts, ids) = sample_packets(1);
        let serving = ServingConfig { queue_depth: 1, ..ServingConfig::default() };
        let cfg = ClusterConfig {
            spill_max: 2,
            serving: serving.clone(),
            ..ClusterConfig::default()
        };
        // Three cells, all workerless with one slot each, all parked full.
        let pools: Vec<CloudPool> =
            (0..3).map(|_| CloudPool::with_config(Vec::new(), serving.clone())).collect();
        let cluster = CloudCluster::from_pools(pools, cfg);
        let parked: Vec<_> =
            (0..3).map(|i| cluster.cell(i).submit(&pkts[0], &ids, "ft").unwrap()).collect();
        match cluster.try_process(&pkts[0], &ids, "ft") {
            Err(ServeError::Shed { hops }) => assert_eq!(hops, 2),
            other => panic!("want exhausted-spill shed, got {other:?}"),
        }
        let st = cluster.stats();
        assert_eq!(st.shed, 1);
        assert_eq!(st.total.shed, 3, "each cell's refusal counted");
        // spill_max 0 never leaves home: hops 0.
        let serving0 = ServingConfig { queue_depth: 1, ..ServingConfig::default() };
        let cfg0 = ClusterConfig { spill_max: 0, serving: serving0.clone(), ..cluster.cfg.clone() };
        let pools0: Vec<CloudPool> =
            (0..3).map(|_| CloudPool::with_config(Vec::new(), serving0.clone())).collect();
        let cluster0 = CloudCluster::from_pools(pools0, cfg0);
        let home = cluster0.placement(&pkts[0], "ft")[0];
        let _p = cluster0.cell(home).submit(&pkts[0], &ids, "ft").unwrap();
        assert!(matches!(
            cluster0.try_process(&pkts[0], &ids, "ft"),
            Err(ServeError::Shed { hops: 0 })
        ));
        drop(parked);
    }

    #[test]
    fn remote_hit_charges_one_hop_and_read_repairs_home() {
        let (pkts, ids) = sample_packets(1);
        let serving = ServingConfig { cache_entries: 8, ..ServingConfig::default() };
        let cluster = CloudCluster::with_config(
            vec![Engine::synthetic()],
            ClusterConfig {
                cells: 3,
                replicas: 2,
                hop_latency_secs: 0.5,
                serving,
                ..ClusterConfig::default()
            },
        );
        let order = cluster.placement(&pkts[0], "ft");
        let (home, replica) = (order[0], order[1]);
        let key = cache_key(&pkts[0], &ids, "ft");
        // Seed ONLY the sibling replica (models the home entry having been
        // evicted while the replica survived).
        let resp = cluster.cell(replica).process_sync(&pkts[0], &ids, "ft").unwrap().resp;
        assert!(cluster.cell(home).cache_probe(key, pkts[0].t_capture).is_none());
        let served = cluster.try_process(&pkts[0], &ids, "ft").unwrap();
        assert!(served.cache_hit);
        assert_eq!((served.hops, served.cell), (1, replica));
        assert!((served.hop_secs - 0.5).abs() < 1e-12);
        assert_eq!(served.resp.presence, resp.presence);
        let st = cluster.stats();
        assert_eq!(st.remote_hits[replica], 1);
        assert_eq!(st.remote_hits_total(), 1);
        // Read-repair: the same request now hits home with zero hops.
        let again = cluster.try_process(&pkts[0], &ids, "ft").unwrap();
        assert!(again.cache_hit);
        assert_eq!((again.hops, again.cell), (0, home));
    }

    #[test]
    fn executed_fill_replicates_to_replica_set_only() {
        let (pkts, ids) = sample_packets(1);
        let serving = ServingConfig { cache_entries: 8, ..ServingConfig::default() };
        let cluster = CloudCluster::with_config(
            vec![Engine::synthetic()],
            ClusterConfig { cells: 4, replicas: 2, serving, ..ClusterConfig::default() },
        );
        let order = cluster.placement(&pkts[0], "ft");
        let key = cache_key(&pkts[0], &ids, "ft");
        let served = cluster.try_process(&pkts[0], &ids, "ft").unwrap();
        assert!(!served.cache_hit);
        assert_eq!(served.cell, order[0]);
        // The entry lives on exactly the first R ring cells.
        let t = pkts[0].t_capture;
        assert!(cluster.cell(order[0]).cache_probe(key, t).is_some());
        assert!(cluster.cell(order[1]).cache_probe(key, t).is_some());
        assert!(cluster.cell(order[2]).cache_probe(key, t).is_none());
        assert!(cluster.cell(order[3]).cache_probe(key, t).is_none());
        // Exactly one executed miss cluster-wide: replication counts none.
        assert_eq!(cluster.stats().total.cache_misses, 1);
    }

    #[test]
    fn route_key_separates_artifact_and_set() {
        let (pkts, _) = sample_packets(2);
        // Same content class routes identically regardless of capture
        // time/sequence (routing is on artifact, not content).
        let mut a = pkts[0].clone();
        let mut b = pkts[1].clone();
        a.t_capture = 0.0;
        b.t_capture = 99.0;
        assert_eq!(route_key(&a, "ft"), route_key(&b, "ft"));
        assert_ne!(route_key(&a, "ft"), route_key(&a, "orig"));
        let mut other_split = a.clone();
        other_split.split = a.split + 1;
        assert_ne!(route_key(&a, "ft"), route_key(&other_split, "ft"));
        let mut bad_tier = a.clone();
        bad_tier.tier = 9;
        // Invalid tiers still route deterministically (and differently).
        assert_eq!(route_key(&bad_tier, "ft"), route_key(&bad_tier, "ft"));
        assert_ne!(route_key(&bad_tier, "ft"), route_key(&a, "ft"));
    }
}
