//! The cloud serving layer (DESIGN.md "Cloud serving layer"): the
//! concurrent [`CloudPool`] behind an admission-controlled frontier.
//!
//! Three mechanisms compose on the request path, all off by default (the
//! [`ServingConfig`] defaults reproduce the pre-serving-layer pool
//! byte-for-byte):
//!
//! * **Micro-batcher** — each worker drains the shared job queue into a
//!   batch of up to `batch_max` *compatible* requests (same artifact —
//!   i.e. same stream kind, tier and split — and same weight set) and
//!   executes them through ONE [`Engine::execute_batch_owned`] dispatch:
//!   the inline synthetic backend loops the closed-form kernel with a
//!   single stats update, the threaded backend crosses its request channel
//!   once per batch instead of once per request.
//! * **Content-addressed response cache** — keyed by
//!   `crc32(packet payload bytes) ⊕ prompt ⊕ set` ([`cache_key`]), an LRU
//!   with configurable capacity and TTL in *virtual* seconds (entries age
//!   on packet capture time, so the cache lives in the simulator's clock,
//!   not the host's).  Swarm fleets over the same disaster zone produce
//!   highly redundant streams; identical content maps to one entry no
//!   matter which UAV or when.
//! * **Admission controller** — a bound on in-flight requests (queued +
//!   executing) with a shed-or-wait policy, so `submit` and `serve_session`
//!   expose backpressure instead of buffering without limit.  A shed
//!   session request is answered with the wire protocol's `busy` frame.
//! * **Deadline discipline** (DESIGN.md "Tail-latency discipline") — each
//!   request derives a virtual deadline from its intent level (`t_capture`
//!   plus a per-class budget: Context tight, Insight loose).  With `edf`
//!   the micro-batcher drains earliest-deadline-first instead of FIFO;
//!   with `deadline_shed` a full queue sheds the request *predicted to
//!   miss* its deadline by the widest margin (EDF-order completion
//!   estimate) rather than the newest arrival, with shed-by-class
//!   counters.  Both default off, preserving the FIFO byte-identical
//!   golden outputs.
//!
//! The in-process fast path ([`CloudPool::process_sync`]) still serves
//! all-inline pools in the caller's thread: it consults the cache but never
//! queues, so the virtual-time fleet simulator stays deterministic — cache
//! hit/miss sequences are a pure function of the (event-ordered) request
//! stream.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::classify_intent;
use crate::faults::{FaultInjector, FaultKind, FaultPlan};
use crate::packet::{Packet, StreamKind};
use crate::runtime::Engine;
use crate::telemetry::LatencyHistogram;
use crate::tensor::Tensor;
use crate::transport::{decode_request, Transport, BUSY_FRAME};
use crate::util::Crc32;

use super::{
    decode_request_inputs, encode_response, process_packet, response_from_outputs,
    CloudResponse, ServePackets, Served,
};

/// Admission policy when the bounded queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Refuse immediately: [`CloudPool::submit`] returns
    /// [`ServeError::Shed`] and `serve_session` replies with the wire
    /// protocol's `busy` frame.
    Shed,
    /// Block the submitter until an in-flight slot frees (backpressure).
    Wait,
}

/// Serving-layer configuration.  The defaults are the pre-serving-layer
/// behavior — no batching, no cache, unbounded queue — so a default pool
/// reproduces the old `CloudPool` byte-for-byte (pinned by
/// `rust/tests/serving.rs`).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Maximum compatible requests per micro-batch (1 = no batching).
    pub batch_max: usize,
    /// Response-cache capacity in entries (0 = cache off).
    pub cache_entries: usize,
    /// Cache TTL in *virtual* seconds (entries age on packet capture time);
    /// `f64::INFINITY` = never expire.
    pub cache_ttl_secs: f64,
    /// Bound on in-flight (queued + executing) requests; 0 = unbounded.
    pub queue_depth: usize,
    /// What to do with a request that finds the queue full.
    pub admission: AdmissionPolicy,
    /// Virtual deadline budget for Context requests (seconds past
    /// `t_capture`; `--deadline-context`).  `INFINITY` = no deadline.
    pub deadline_context_secs: f64,
    /// Virtual deadline budget for Insight requests (`--deadline-insight`).
    pub deadline_insight_secs: f64,
    /// Drain the micro-batcher earliest-deadline-first instead of FIFO
    /// (`--edf`).  Off by default: FIFO order is pinned by the golden
    /// byte-identity tests.
    pub edf: bool,
    /// When the bounded queue is full, shed the request *predicted to
    /// miss* its deadline rather than the newest arrival
    /// (`--deadline-shed`).  Implies shed-style admission (never blocks).
    pub deadline_shed: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            batch_max: 1,
            cache_entries: 0,
            cache_ttl_secs: f64::INFINITY,
            queue_depth: 0,
            admission: AdmissionPolicy::Shed,
            deadline_context_secs: f64::INFINITY,
            deadline_insight_secs: f64::INFINITY,
            edf: false,
            deadline_shed: false,
        }
    }
}

impl ServingConfig {
    /// True when any serving feature deviates from the pre-layer defaults —
    /// drives whether the fleet/scenario missions emit the extra serving
    /// telemetry (off-mode reports stay byte-identical to the pre-layer
    /// ones).
    pub fn enabled(&self) -> bool {
        self.batch_max > 1
            || self.cache_entries > 0
            || self.queue_depth > 0
            || self.edf
            || self.deadline_shed
            || self.deadline_context_secs.is_finite()
            || self.deadline_insight_secs.is_finite()
    }

    /// Per-class deadline budget (seconds past `t_capture`).
    pub fn deadline_budget(&self, kind: StreamKind) -> f64 {
        match kind {
            StreamKind::Context => self.deadline_context_secs,
            StreamKind::Insight => self.deadline_insight_secs,
        }
    }
}

/// Why a pool request produced no response — the typed distinction
/// [`Ticket::wait`] used to erase by double-wrapping everything into one
/// anyhow chain (a worker death and an execution failure were
/// indistinguishable; a shed had no representation at all).
#[derive(Debug)]
pub enum ServeError {
    /// The admission controller refused the request (bounded queue full
    /// under [`AdmissionPolicy::Shed`]).  `hops` is how many ring siblings
    /// a cluster retried after the home cell refused (0 for a single
    /// pool — there is nowhere to spill).
    Shed {
        hops: u32,
    },
    /// The pool shut down — or a worker died — before replying.
    Closed,
    /// The request executed and failed.
    Exec(anyhow::Error),
    /// The chaos layer injected a failure (see [`crate::faults`]): a
    /// crashed cell, a failed execution draw, a corrupted frame or a
    /// dropped session.  Typed so the failover/retry layers can tell an
    /// injected fault from a real execution bug ([`ServeError::Exec`]
    /// stays request-fatal; faults are retryable).
    Fault { kind: FaultKind },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed { hops: 0 } => {
                write!(f, "cloud pool shed the request (queue full)")
            }
            ServeError::Shed { hops } => write!(
                f,
                "cloud cluster shed the request after {hops} spill hops (all cells full)"
            ),
            ServeError::Closed => write!(f, "cloud pool closed before replying"),
            ServeError::Exec(e) => write!(f, "cloud execution failed: {e:#}"),
            ServeError::Fault { kind } => write!(f, "injected fault: {}", kind.name()),
        }
    }
}

impl std::error::Error for ServeError {}

/// FNV-1a 64-bit over raw bytes (cache-key folding; the cluster router
/// folds its (artifact, weight-set) route keys through the same mix).
pub(crate) fn fnv64(h: u64, bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(h, |h, &b| (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3))
}

/// View an i8 payload as bytes (same layout; the packet encoder uses the
/// identical cast).
fn i8_bytes(v: &[i8]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}

/// Content-addressed cache key: `crc32(packet payload bytes) ⊕ prompt ⊕
/// set`.  "Payload" is exactly the fields that determine the response —
/// stream kind, tier, split, shapes, quantizer scale, code and CLIP bytes —
/// and never `seq`, `t_capture` or `wire_bytes`, so the same scene captured
/// by two different UAVs at two different times addresses the same entry.
/// A crc32 alone carries only 32 bits of content entropy (a ~77k-distinct-
/// payload working set would reach birthday-bound collision odds — and a
/// collision silently serves the wrong response), so an independent FNV-1a
/// 64 pass over the same payload bytes is folded in on a different
/// rotation, as are the prompt (token ids) and weight set, each on distinct
/// rotations so no two components can cancel.
pub fn cache_key(pkt: &Packet, prompt_ids: &[i32], set: &str) -> u64 {
    let mut crc = Crc32::new();
    let mut content = 0xcbf2_9ce4_8422_2325u64;
    let mut absorb = |bytes: &[u8]| {
        crc.update(bytes);
        content = fnv64(content, bytes);
    };
    absorb(&[pkt.kind as u8, pkt.tier, pkt.split]);
    absorb(&(pkt.code_shape.0 as u32).to_le_bytes());
    absorb(&(pkt.code_shape.1 as u32).to_le_bytes());
    absorb(&(pkt.clip_shape.0 as u32).to_le_bytes());
    absorb(&(pkt.clip_shape.1 as u32).to_le_bytes());
    absorb(&pkt.clip_scale.to_le_bytes());
    absorb(i8_bytes(&pkt.code_q));
    absorb(i8_bytes(&pkt.clip_q));
    let mut prompt_h = 0xcbf2_9ce4_8422_2325u64;
    for id in prompt_ids {
        prompt_h = fnv64(prompt_h, &id.to_le_bytes());
    }
    let set_h = fnv64(0xcbf2_9ce4_8422_2325, set.as_bytes());
    (crc.finish() as u64)
        ^ content.rotate_left(31)
        ^ prompt_h.rotate_left(20)
        ^ set_h.rotate_left(42)
}

/// Cache counters.  All are pure counts of the (deterministic) request
/// stream in the virtual-time sim, so they are safe to surface in reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    /// Served requests that missed and were executed (counted at cache
    /// fill, so a request the admission controller sheds never skews the
    /// hit rate).
    pub misses: u64,
    /// Entries displaced by the LRU capacity bound.
    pub evictions: u64,
    /// Entries dropped because their virtual age exceeded the TTL.
    pub expirations: u64,
}

struct CacheEntry {
    /// Arc so a hit hands back a refcount bump under the cache lock and the
    /// (possibly multi-MB mask) deep copy — when a caller needs one —
    /// happens outside it.
    resp: Arc<CloudResponse>,
    /// Virtual insertion time (the inserting packet's capture time).
    t_insert: f64,
    /// Recency tick — the key into the LRU order map.
    access: u64,
}

/// The content-addressed response cache: an LRU over [`cache_key`]s with a
/// TTL in virtual seconds.  Recency is a monotone tick; the LRU order map
/// (tick -> key) makes eviction O(log n) and fully deterministic.
pub struct ResponseCache {
    capacity: usize,
    ttl_secs: f64,
    map: HashMap<u64, CacheEntry>,
    lru: BTreeMap<u64, u64>,
    tick: u64,
    stats: CacheStats,
}

impl ResponseCache {
    pub fn new(capacity: usize, ttl_secs: f64) -> Self {
        Self {
            capacity,
            ttl_secs,
            map: HashMap::with_capacity(capacity.min(1 << 16)),
            lru: BTreeMap::new(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Look up `key` at virtual time `now`.  A hit returns the stored
    /// response behind an `Arc` (byte-identical — responses are immutable
    /// once built; the refcount bump keeps the lock hold O(1)) and
    /// refreshes recency; an entry older than the TTL is dropped and
    /// counted as an expiration.  Misses are NOT counted here — they are
    /// counted at [`ResponseCache::insert`] (i.e. when the missed request
    /// actually executes), so shed requests cannot deflate the hit rate.
    pub fn get(&mut self, key: u64, now: f64) -> Option<Arc<CloudResponse>> {
        self.tick += 1;
        let tick = self.tick;
        let ttl = self.ttl_secs;
        let (prev, resp) = match self.map.get_mut(&key) {
            None => return None,
            Some(e) if now - e.t_insert > ttl => (e.access, None),
            Some(e) => {
                let prev = std::mem::replace(&mut e.access, tick);
                (prev, Some(Arc::clone(&e.resp)))
            }
        };
        let Some(resp) = resp else {
            self.map.remove(&key);
            self.lru.remove(&prev);
            self.stats.expirations += 1;
            return None;
        };
        self.lru.remove(&prev);
        self.lru.insert(tick, key);
        self.stats.hits += 1;
        Some(resp)
    }

    /// Insert (or refresh) an entry at virtual time `now`, evicting the
    /// least-recently-used entries over capacity.  Every insert is one
    /// executed miss — the counterpart of [`ResponseCache::get`]'s hits.
    pub fn insert(&mut self, key: u64, resp: CloudResponse, now: f64) {
        self.stats.misses += 1;
        self.store(key, resp, now);
    }

    /// Store an entry WITHOUT counting a miss — the cluster's replication
    /// path: the one executed fill counts its miss at the executing cell's
    /// [`ResponseCache::insert`]; propagating the same response to R-1
    /// replica cells is not R-1 extra misses.  Same LRU/TTL mechanics.
    pub fn replicate(&mut self, key: u64, resp: CloudResponse, now: f64) {
        self.store(key, resp, now);
    }

    fn store(&mut self, key: u64, resp: CloudResponse, now: f64) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if let Some(old) = self.map.insert(
            key,
            CacheEntry { resp: Arc::new(resp), t_insert: now, access: self.tick },
        ) {
            self.lru.remove(&old.access);
        }
        self.lru.insert(self.tick, key);
        while self.map.len() > self.capacity {
            let Some((_, victim)) = self.lru.pop_first() else { break };
            self.map.remove(&victim);
            self.stats.evictions += 1;
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// One queued job for the pool.
struct Job {
    pkt: Packet,
    prompt_ids: Vec<i32>,
    set: String,
    /// Precomputed cache key (cache enabled only): the worker inserts the
    /// executed response under it.
    key: Option<u64>,
    /// Absolute virtual deadline: `pkt.t_capture` plus the per-class
    /// budget ([`ServingConfig::deadline_budget`]); `INFINITY` when no
    /// deadline is configured.
    deadline: f64,
    /// Wall-clock admission stamp; completion records
    /// admission→completion into the pool's wall-latency histograms
    /// (diagnostic/bench only — never surfaced in mission reports).
    t_submit: Instant,
    reply: Sender<Result<CloudResponse, ServeError>>,
}

impl Job {
    /// Batch-compatibility class: two jobs may share a micro-batch iff they
    /// resolve to the same artifact — i.e. same stream kind, tier and
    /// split — and name the same weight set.
    fn compatible(&self, other: &Job) -> bool {
        self.pkt.kind == other.pkt.kind
            && self.pkt.tier == other.pkt.tier
            && self.pkt.split == other.pkt.split
            && self.set == other.set
    }
}

struct QueueState {
    jobs: VecDeque<Job>,
    /// Admitted and not yet replied (queued + executing) — what the
    /// admission bound counts.
    in_flight: usize,
    closed: bool,
}

/// The admission-controlled job queue: a Condvar-guarded deque (mpsc cannot
/// give workers the selective drain the micro-batcher needs).
struct JobQueue {
    state: Mutex<QueueState>,
    /// Wakes workers (a job arrived / the pool closed).
    ready: Condvar,
    /// Wakes `Wait`-policy submitters (an in-flight slot freed).
    space: Condvar,
}

impl JobQueue {
    fn new() -> Self {
        Self {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                in_flight: 0,
                closed: false,
            }),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Reserve one in-flight admission slot (shed-or-wait).  Split from
    /// [`JobQueue::enqueue`] so a shed request is refused before the caller
    /// builds a job at all — no packet clone, no allocation.
    fn reserve(&self, depth: usize, policy: AdmissionPolicy) -> Result<(), ServeError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(ServeError::Closed);
        }
        if depth > 0 {
            match policy {
                AdmissionPolicy::Shed => {
                    if st.in_flight >= depth {
                        return Err(ServeError::Shed { hops: 0 });
                    }
                }
                AdmissionPolicy::Wait => {
                    while st.in_flight >= depth && !st.closed {
                        st = self.space.wait(st).unwrap();
                    }
                    if st.closed {
                        return Err(ServeError::Closed);
                    }
                }
            }
        }
        st.in_flight += 1;
        Ok(())
    }

    /// Enqueue a job under a slot already held via [`JobQueue::reserve`];
    /// releases the slot if the pool closed in between.
    fn enqueue(&self, job: Job) -> Result<(), ServeError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            st.in_flight = st.in_flight.saturating_sub(1);
            drop(st);
            self.space.notify_all();
            return Err(ServeError::Closed);
        }
        st.jobs.push_back(job);
        drop(st);
        self.ready.notify_one();
        Ok(())
    }

    /// Pop the next lead job plus up to `max - 1` more compatible jobs
    /// (queue order is preserved for the jobs left behind).  The lead is
    /// the oldest job (FIFO), or with `edf` the job with the *strictly*
    /// earliest deadline — ties keep queue order, so an all-infinite
    /// deadline set degrades to exact FIFO.  Blocks while the queue is
    /// empty; returns `None` once the pool is closed *and* drained —
    /// queued work is always served before shutdown.
    fn pop_batch(&self, max: usize, edf: bool) -> Option<Vec<Job>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.jobs.is_empty() {
                let lead = if edf {
                    let mut best = 0;
                    for i in 1..st.jobs.len() {
                        if st.jobs[i].deadline < st.jobs[best].deadline {
                            best = i;
                        }
                    }
                    st.jobs.remove(best).unwrap()
                } else {
                    st.jobs.pop_front().unwrap()
                };
                let mut batch = Vec::with_capacity(max.max(1));
                batch.push(lead);
                let mut i = 0;
                while batch.len() < max && i < st.jobs.len() {
                    if batch[0].compatible(&st.jobs[i]) {
                        let job = st.jobs.remove(i).unwrap();
                        batch.push(job);
                    } else {
                        i += 1;
                    }
                }
                return Some(batch);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Deadline-aware admission (`deadline_shed`): admit `job`, and when
    /// the queue is full shed the request *predicted to miss* its deadline
    /// by the widest margin instead of the newest arrival.
    ///
    /// Prediction: with `now` = the arrival's `t_capture` and `service_est`
    /// = the pool's mean observed virtual service time, the job at EDF rank
    /// `k` (0-based over queued ∪ {arrival}) completes around
    /// `now + (k+1)·service_est`; negative slack = predicted miss.  With no
    /// service estimate yet (`0.0`) only already-late jobs
    /// (`deadline < now`) are predicted misses.
    ///
    /// Returns `Ok(None)` (admitted, slot free), `Ok(Some(kind))`
    /// (admitted by shedding a queued victim of that class — its ticket
    /// resolves [`ServeError::Shed`]; the slot transfers, `in_flight`
    /// unchanged), or `Err(Shed)` when the arrival itself is the widest
    /// predicted misser — or nothing is predicted to miss.
    fn admit_or_shed_misser(
        &self,
        job: Job,
        depth: usize,
        service_est: f64,
    ) -> Result<Option<StreamKind>, ServeError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(ServeError::Closed);
        }
        if depth == 0 || st.in_flight < depth {
            st.in_flight += 1;
            st.jobs.push_back(job);
            drop(st);
            self.ready.notify_one();
            return Ok(None);
        }
        let now = job.pkt.t_capture;
        let n = st.jobs.len();
        let mut order: Vec<(f64, usize)> =
            st.jobs.iter().enumerate().map(|(i, j)| (j.deadline, i)).collect();
        order.push((job.deadline, n));
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut victim: Option<(f64, usize)> = None;
        for (k, &(deadline, idx)) in order.iter().enumerate() {
            let slack = deadline - (now + (k + 1) as f64 * service_est);
            if slack < 0.0 && victim.is_none_or(|(s, _)| slack < s) {
                victim = Some((slack, idx));
            }
        }
        match victim {
            Some((_, idx)) if idx < n => {
                let dead = st.jobs.remove(idx).unwrap();
                st.jobs.push_back(job);
                drop(st);
                self.ready.notify_one();
                let kind = dead.pkt.kind;
                let _ = dead.reply.send(Err(ServeError::Shed { hops: 0 }));
                Ok(Some(kind))
            }
            _ => Err(ServeError::Shed { hops: 0 }),
        }
    }

    /// Mark `n` jobs replied — frees admission slots.
    fn done(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        st.in_flight = st.in_flight.saturating_sub(n);
        drop(st);
        self.space.notify_all();
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.ready.notify_all();
        self.space.notify_all();
    }
}

/// Aggregate pool counters.  `busy_secs` is wall-clock (diagnostic only);
/// every other field is a deterministic count of the request stream, so
/// the fleet/scenario reports may surface them.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub workers: usize,
    /// Requests served (executions, failures and cache hits alike).
    pub completed: u64,
    /// Summed wall-clock seconds workers spent inside artifact execution.
    pub busy_secs: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_expirations: u64,
    /// Requests refused by the admission controller (shed policy).
    pub shed: u64,
    /// Shed requests by stream class (Context / Insight) — the
    /// deadline-shed policy is class-aware, so the split is the telemetry
    /// that shows *who* paid for an overload.
    pub shed_context: u64,
    pub shed_insight: u64,
    /// Worker queue drains (each serves one micro-batch; 1 when batching
    /// is off) and the requests they carried — queued path only, the
    /// in-process direct path never batches.
    pub batches: u64,
    pub batched_requests: u64,
    /// Per-class end-to-end *virtual* latency (seconds of simulated time,
    /// recorded through [`ServePackets::observe_latency`]) — deterministic
    /// per seed, safe to surface in mission reports.
    pub lat_context: LatencyHistogram,
    pub lat_insight: LatencyHistogram,
    /// Per-class admission→completion *wall-clock* latency on the queued
    /// path (diagnostic/bench only — like `busy_secs`, never surfaced in
    /// byte-deterministic reports).
    pub wall_lat_context: LatencyHistogram,
    pub wall_lat_insight: LatencyHistogram,
}

impl PoolStats {
    /// Merge another cell's counters into this one — the cluster
    /// aggregation primitive ([`ClusterStats`]: counts, worker slots and
    /// busy seconds add; the four latency histograms merge bucket-wise
    /// through [`LatencyHistogram::merge`], so cross-cell percentiles are
    /// exact, not approximated from per-cell quantiles.  Merged totals
    /// cannot drift from per-cell accounting because this is the only
    /// aggregation path (pinned by `merged_stats_equal_per_cell_sums`).
    ///
    /// [`ClusterStats`]: crate::cloud::ClusterStats
    pub fn merge(&mut self, other: &PoolStats) {
        self.workers += other.workers;
        self.completed += other.completed;
        self.busy_secs += other.busy_secs;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.cache_evictions += other.cache_evictions;
        self.cache_expirations += other.cache_expirations;
        self.shed += other.shed;
        self.shed_context += other.shed_context;
        self.shed_insight += other.shed_insight;
        self.batches += other.batches;
        self.batched_requests += other.batched_requests;
        self.lat_context.merge(&other.lat_context);
        self.lat_insight.merge(&other.lat_insight);
        self.wall_lat_context.merge(&other.wall_lat_context);
        self.wall_lat_insight.merge(&other.wall_lat_insight);
    }

    /// Fraction of worker capacity used over a wall-clock window.
    pub fn utilization(&self, wall_secs: f64) -> f64 {
        if self.workers == 0 || wall_secs <= 0.0 {
            return 0.0;
        }
        self.busy_secs / (self.workers as f64 * wall_secs)
    }

    /// Cache hit rate over all lookups (0 when the cache is off).
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache_hits as f64 / (self.cache_hits + self.cache_misses).max(1) as f64
    }
}

/// Response handle returned by [`CloudPool::submit`]: either resolved at
/// admission time from the content-addressed cache (no channel, no queue),
/// or pending a worker reply.
pub struct Ticket {
    inner: TicketInner,
}

enum TicketInner {
    Ready(CloudResponse),
    Pending(Receiver<Result<CloudResponse, ServeError>>),
}

impl Ticket {
    fn ready(resp: CloudResponse) -> Self {
        Self { inner: TicketInner::Ready(resp) }
    }

    fn pending(rx: Receiver<Result<CloudResponse, ServeError>>) -> Self {
        Self { inner: TicketInner::Pending(rx) }
    }

    /// True when the response was resolved from the content-addressed cache
    /// at admission time (it never entered the queue; `wait` returns
    /// immediately).
    pub fn cache_hit(&self) -> bool {
        matches!(self.inner, TicketInner::Ready(_))
    }

    /// Typed wait: a closed reply channel (pool shutdown, worker death) is
    /// [`ServeError::Closed`]; an execution failure is
    /// [`ServeError::Exec`]; a queued job displaced by the deadline-shed
    /// policy is [`ServeError::Shed`].
    pub fn wait(self) -> Result<CloudResponse, ServeError> {
        match self.inner {
            TicketInner::Ready(resp) => Ok(resp),
            TicketInner::Pending(rx) => match rx.recv() {
                Err(_) => Err(ServeError::Closed),
                Ok(r) => r,
            },
        }
    }
}

/// Concurrent multi-session cloud server: a fixed worker pool draining a
/// shared job queue through the micro-batcher, behind the response cache
/// and the admission controller.  See the module docs and DESIGN.md
/// "Cloud serving layer".
pub struct CloudPool {
    queue: Arc<JobQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_workers: usize,
    cfg: ServingConfig,
    completed: Arc<AtomicU64>,
    busy_micros: Arc<AtomicU64>,
    shed: AtomicU64,
    shed_context: AtomicU64,
    shed_insight: AtomicU64,
    /// Per-class end-to-end virtual latency `[Context, Insight]`, fed by
    /// [`ServePackets::observe_latency`] from the mission timing model.
    vlat: Mutex<[LatencyHistogram; 2]>,
    /// Per-class admission→completion wall latency `[Context, Insight]`
    /// on the queued path (shared with the workers; diagnostic/bench only).
    wlat: Arc<Mutex<[LatencyHistogram; 2]>>,
    batches: Arc<AtomicU64>,
    batched_requests: Arc<AtomicU64>,
    cache: Option<Arc<Mutex<ResponseCache>>>,
    /// Direct-call fast path for [`CloudPool::process_sync`]: set when every
    /// worker engine executes inline (caller-thread synthetic backend), in
    /// which case an in-process request needs no job-queue hop — and no
    /// `Packet` clone.
    direct: Option<Engine>,
    /// Programmatically injected fault plan (chaos layer) — `None` by
    /// default, so fault-free pools take no lock and behave byte-identically
    /// to pre-chaos builds.  A cluster injects at the cluster level instead
    /// (cell identity lives there); this hook covers bare-pool serving.
    faults: Option<Mutex<FaultInjector>>,
}

impl CloudPool {
    /// Spawn one worker per engine handle with the default (pre-layer)
    /// serving configuration: no batching, no cache, unbounded queue.
    pub fn new(engines: Vec<Engine>) -> Self {
        Self::with_config(engines, ServingConfig::default())
    }

    /// Spawn one worker per engine handle.  Threaded handles may be clones
    /// of one engine (shared execution thread — models a queueing server)
    /// or independently started engines; inline synthetic handles always
    /// execute truly in parallel, worker- and caller-side.
    pub fn with_config(engines: Vec<Engine>, cfg: ServingConfig) -> Self {
        let direct = if !engines.is_empty() && engines.iter().all(|e| e.is_inline()) {
            Some(engines[0].clone())
        } else {
            None
        };
        let cache = (cfg.cache_entries > 0).then(|| {
            Arc::new(Mutex::new(ResponseCache::new(cfg.cache_entries, cfg.cache_ttl_secs)))
        });
        let queue = Arc::new(JobQueue::new());
        let completed = Arc::new(AtomicU64::new(0));
        let busy_micros = Arc::new(AtomicU64::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let batched_requests = Arc::new(AtomicU64::new(0));
        let wlat = Arc::new(Mutex::new([LatencyHistogram::new(); 2]));
        let n_workers = engines.len();
        let batch_max = cfg.batch_max.max(1);
        let edf = cfg.edf;
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let queue = Arc::clone(&queue);
                let completed = Arc::clone(&completed);
                let busy = Arc::clone(&busy_micros);
                let batches = Arc::clone(&batches);
                let batched_requests = Arc::clone(&batched_requests);
                let cache = cache.clone();
                let wlat = Arc::clone(&wlat);
                std::thread::Builder::new()
                    .name(format!("avery-cloud-{i}"))
                    .spawn(move || {
                        while let Some(batch) = queue.pop_batch(batch_max, edf) {
                            let n = batch.len();
                            // Count before replying so the counters are
                            // consistent the moment a ticket resolves.
                            completed.fetch_add(n as u64, Ordering::Relaxed);
                            batches.fetch_add(1, Ordering::Relaxed);
                            batched_requests.fetch_add(n as u64, Ordering::Relaxed);
                            let t0 = Instant::now();
                            serve_batch(&engine, batch, cache.as_deref(), &wlat);
                            busy.fetch_add(
                                t0.elapsed().as_micros() as u64,
                                Ordering::Relaxed,
                            );
                            queue.done(n);
                        }
                    })
                    .expect("spawning cloud worker")
            })
            .collect();
        Self {
            queue,
            workers,
            n_workers,
            cfg,
            completed,
            busy_micros,
            shed: AtomicU64::new(0),
            shed_context: AtomicU64::new(0),
            shed_insight: AtomicU64::new(0),
            vlat: Mutex::new([LatencyHistogram::new(); 2]),
            wlat,
            batches,
            batched_requests,
            cache,
            direct,
            faults: None,
        }
    }

    /// Arm this pool with a fault plan: requests consult the injector at
    /// entry (crash window → [`ServeError::Fault`], seeded exec-error draw
    /// → [`ServeError::Fault`], stall window → extra `hop_secs` on the
    /// [`Served`]).  Cell-scoped events target cell 0 — a bare pool is its
    /// own (only) failure domain.
    pub fn inject_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(Mutex::new(FaultInjector::new(plan)));
    }

    /// Per-kind injection counters when a fault plan is armed.
    pub fn fault_counts(&self) -> Option<crate::faults::FaultCounts> {
        self.faults.as_ref().map(|f| f.lock().unwrap().counts())
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// The serving configuration this pool runs with.
    pub fn config(&self) -> &ServingConfig {
        &self.cfg
    }

    /// Enqueue one request through the cache and the admission controller;
    /// the returned [`Ticket`] resolves when a worker finishes it (or
    /// immediately, on a cache hit — hits cost one index lookup and bypass
    /// admission entirely).
    pub fn submit(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Ticket, ServeError> {
        let key = match self.cache_lookup(pkt, prompt_ids, set) {
            Ok(resp) => return Ok(Ticket::ready(resp)),
            Err(key) => key,
        };
        if self.cfg.deadline_shed {
            // Deadline-aware admission: the job is built first (the victim
            // choice needs its deadline), then admitted in one queue
            // transaction that may shed a queued predicted-misser instead.
            let (reply, rx) = channel();
            let job = self.build_job(pkt, prompt_ids, set, key, reply);
            return match self.queue.admit_or_shed_misser(
                job,
                self.cfg.queue_depth,
                self.mean_service_secs(),
            ) {
                Ok(None) => Ok(Ticket::pending(rx)),
                Ok(Some(victim_kind)) => {
                    self.count_shed(victim_kind);
                    Ok(Ticket::pending(rx))
                }
                Err(e) => {
                    if matches!(e, ServeError::Shed { .. }) {
                        self.count_shed(pkt.kind);
                    }
                    Err(e)
                }
            };
        }
        // Reserve the admission slot BEFORE building the job: a shed
        // request clones no packet and (since misses are counted at cache
        // fill) never skews the hit rate.
        self.reserve_slot(pkt.kind)?;
        let (reply, rx) = channel();
        let job = self.build_job(pkt, prompt_ids, set, key, reply);
        self.queue.enqueue(job)?;
        Ok(Ticket::pending(rx))
    }

    /// Materialize one queued job (packet clone, deadline stamp,
    /// admission wall-clock stamp).
    fn build_job(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
        key: Option<u64>,
        reply: Sender<Result<CloudResponse, ServeError>>,
    ) -> Job {
        Job {
            pkt: pkt.clone(),
            prompt_ids: prompt_ids.to_vec(),
            set: set.to_string(),
            key,
            deadline: pkt.t_capture + self.cfg.deadline_budget(pkt.kind),
            t_submit: Instant::now(),
            reply,
        }
    }

    /// Mean observed virtual service time across both classes — the
    /// deadline-shed policy's completion estimate.  0.0 until the mission
    /// has observed any latency (then only already-late jobs are predicted
    /// misses).
    fn mean_service_secs(&self) -> f64 {
        let l = self.vlat.lock().unwrap();
        let n = l[0].count() + l[1].count();
        if n == 0 {
            0.0
        } else {
            (l[0].mean() * l[0].count() as f64 + l[1].mean() * l[1].count() as f64)
                / n as f64
        }
    }

    /// Bump the total and per-class shed counters.
    fn count_shed(&self, kind: StreamKind) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        match kind {
            StreamKind::Context => self.shed_context.fetch_add(1, Ordering::Relaxed),
            StreamKind::Insight => self.shed_insight.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// The cache front door shared by [`CloudPool::submit`] and the direct
    /// path: `Ok` is a hit (counted as completed; the lock is released
    /// before the response deep-copy), `Err` carries the precomputed key
    /// to fill after execution (`Err(None)` when the cache is off).
    fn cache_lookup(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<CloudResponse, Option<u64>> {
        let Some(cache) = &self.cache else {
            return Err(None);
        };
        let k = cache_key(pkt, prompt_ids, set);
        let hit = cache.lock().unwrap().get(k, pkt.t_capture);
        match hit {
            Some(resp) => {
                self.completed.fetch_add(1, Ordering::Relaxed);
                Ok(resp.as_ref().clone())
            }
            None => Err(Some(k)),
        }
    }

    /// Probe this pool's response cache by precomputed key — the cluster's
    /// sibling-replica lookup.  A hit refreshes recency and counts toward
    /// this cell's cache hits and completed requests (the sibling served
    /// the request); an absent key counts nothing (misses are counted at
    /// fill), so a cluster probing several replicas cannot deflate any
    /// cell's hit rate.  `None` when the cache is off or the key is
    /// absent/expired.
    pub fn cache_probe(&self, key: u64, now: f64) -> Option<CloudResponse> {
        let cache = self.cache.as_ref()?;
        let hit = cache.lock().unwrap().get(key, now)?;
        self.completed.fetch_add(1, Ordering::Relaxed);
        Some(hit.as_ref().clone())
    }

    /// Propagate an already-executed response into this pool's cache — the
    /// cluster's replication fill / read-repair path.  Unlike the executing
    /// cell's own fill ([`ResponseCache::insert`]) this counts no miss.
    /// No-op when the cache is off.
    pub fn cache_replicate(&self, key: u64, resp: &CloudResponse, now: f64) {
        if let Some(cache) = &self.cache {
            // Clone outside the lock — the guard is only held for the
            // O(log n) index update.
            let stored = resp.clone();
            cache.lock().unwrap().replicate(key, stored, now);
        }
    }

    /// Reserve one admission slot, counting a shed (total and per-class)
    /// on refusal.
    fn reserve_slot(&self, kind: StreamKind) -> Result<(), ServeError> {
        match self.queue.reserve(self.cfg.queue_depth, self.cfg.admission) {
            Ok(()) => Ok(()),
            Err(e) => {
                if matches!(e, ServeError::Shed { .. }) {
                    self.count_shed(kind);
                }
                Err(e)
            }
        }
    }

    /// In-process request path with typed errors: serve in the caller's
    /// thread when the backend executes inline (no job-queue hop, no
    /// `pkt.clone()`/`prompt_ids.to_vec()`), else enqueue and block.  This
    /// is what the fleet simulator calls — virtual time is charged by the
    /// mission's timing model, so only the numerics (and the cache-hit
    /// flag) flow through here, and responses are pure functions of the
    /// request on either route.
    pub fn try_process(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Served, ServeError> {
        let Some(faults) = &self.faults else {
            return self.try_process_inner(pkt, prompt_ids, set);
        };
        // Chaos hook (armed via [`CloudPool::inject_faults`] only): consult
        // the injector at entry — link faults first, then cell-scoped ones
        // against cell 0 — before any cache or queue work, so an injected
        // failure costs the caller nothing but the typed error.
        let stall = {
            let mut inj = faults.lock().unwrap();
            let t = pkt.t_capture;
            if inj.take_session_drop(t) {
                return Err(ServeError::Fault { kind: FaultKind::SessionDrop });
            }
            if inj.draw_wire_corrupt(t) {
                return Err(ServeError::Fault { kind: FaultKind::WireCorrupt });
            }
            if inj.crash_active(0, t) {
                inj.record(FaultKind::CellCrash);
                return Err(ServeError::Fault { kind: FaultKind::CellCrash });
            }
            if inj.draw_exec_error(0, t) {
                return Err(ServeError::Fault { kind: FaultKind::ExecError });
            }
            inj.stall_secs(0, t)
        };
        let mut served = self.try_process_inner(pkt, prompt_ids, set)?;
        // A stalled worker still answers — late.  The stall rides the
        // hop-latency channel the timing model already charges.
        served.hop_secs += stall;
        Ok(served)
    }

    fn try_process_inner(
        &self,
        pkt: &Packet,
        prompt_ids: &[i32],
        set: &str,
    ) -> Result<Served, ServeError> {
        if let Some(engine) = &self.direct {
            let key = match self.cache_lookup(pkt, prompt_ids, set) {
                Ok(resp) => {
                    return Ok(Served { resp, cache_hit: true, hops: 0, hop_secs: 0.0, cell: 0 })
                }
                Err(key) => key,
            };
            // The direct path skips the queue, not the admission bound: it
            // holds an in-flight slot for the duration of the execution, so
            // a bounded pool sheds concurrent in-process callers exactly
            // like transport sessions.  (The serial virtual-time fleet loop
            // keeps in_flight <= 1, so the sim never sheds and stays
            // deterministic.)
            let bounded = self.cfg.queue_depth > 0;
            if bounded {
                self.reserve_slot(pkt.kind)?;
            }
            let t0 = Instant::now();
            let r = process_packet(engine, pkt, prompt_ids, set);
            self.busy_micros
                .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            self.completed.fetch_add(1, Ordering::Relaxed);
            if bounded {
                self.queue.done(1);
            }
            let resp = r.map_err(ServeError::Exec)?;
            if let (Some(k), Some(cache)) = (key, &self.cache) {
                // Clone outside the lock — the guard is only held for the
                // O(log n) index update.
                let stored = resp.clone();
                cache.lock().unwrap().insert(k, stored, pkt.t_capture);
            }
            return Ok(Served::executed(resp));
        }
        let ticket = self.submit(pkt, prompt_ids, set)?;
        let cache_hit = ticket.cache_hit();
        ticket.wait().map(|resp| Served { resp, cache_hit, hops: 0, hop_secs: 0.0, cell: 0 })
    }

    /// [`CloudPool::try_process`] with the typed error folded into anyhow
    /// (the historical surface most call sites want).
    pub fn process_sync(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served> {
        self.try_process(pkt, prompt_ids, set).map_err(anyhow::Error::from)
    }

    pub fn stats(&self) -> PoolStats {
        let cs = self
            .cache
            .as_ref()
            .map(|c| c.lock().unwrap().stats())
            .unwrap_or_default();
        let [lat_context, lat_insight] = *self.vlat.lock().unwrap();
        let [wall_lat_context, wall_lat_insight] = *self.wlat.lock().unwrap();
        PoolStats {
            workers: self.n_workers,
            completed: self.completed.load(Ordering::Relaxed),
            busy_secs: self.busy_micros.load(Ordering::Relaxed) as f64 / 1e6,
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_evictions: cs.evictions,
            cache_expirations: cs.expirations,
            shed: self.shed.load(Ordering::Relaxed),
            shed_context: self.shed_context.load(Ordering::Relaxed),
            shed_insight: self.shed_insight.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            lat_context,
            lat_insight,
            wall_lat_context,
            wall_lat_insight,
        }
    }

    /// Serve one transport session until the peer closes or sends
    /// `shutdown`.  Per-session weight-set routing: a `hello <set>` frame
    /// pins the session's default weight set; individual requests may still
    /// override it by naming a non-empty set (see
    /// [`crate::transport::encode_request`]).  Responses use
    /// [`encode_response`]/[`super::decode_reply`] framing; a request the
    /// admission controller sheds is answered with the `busy` frame and
    /// does not count as served.
    pub fn serve_session<T: Transport>(&self, transport: &mut T, default_set: &str) -> Result<u64> {
        let mut session_set = default_set.to_string();
        let mut served = 0u64;
        loop {
            let frame = match transport.recv() {
                Ok(f) => f,
                Err(_) => break, // peer closed
            };
            if frame == b"shutdown" {
                break;
            }
            if let Some(set) = frame.strip_prefix(b"hello ") {
                session_set = String::from_utf8_lossy(set).trim().to_string();
                transport.send(b"ok")?;
                continue;
            }
            let (pkt_bytes, prompt, set) = decode_request(&frame)?;
            let pkt = Packet::decode(&pkt_bytes)?;
            let intent = classify_intent(&prompt);
            let set = if set.is_empty() { session_set.as_str() } else { set.as_str() };
            match self.try_process(&pkt, &intent.token_ids, set) {
                Ok(r) => {
                    transport.send(&encode_response(&r.resp))?;
                    served += 1;
                }
                Err(ServeError::Shed { .. }) => transport.send(BUSY_FRAME)?,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(served)
    }
}

impl Drop for CloudPool {
    fn drop(&mut self) {
        // Closing the queue unblocks every worker; queued jobs are drained
        // before the workers exit.
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl ServePackets for CloudPool {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Served> {
        self.process_sync(pkt, prompt_ids, set)
    }

    fn observe_latency(&self, kind: StreamKind, virtual_secs: f64) {
        self.vlat.lock().unwrap()[kind as usize].record(virtual_secs);
    }

    fn latency_histograms(&self) -> Option<(LatencyHistogram, LatencyHistogram)> {
        let l = self.vlat.lock().unwrap();
        Some((l[0], l[1]))
    }
}

/// Serve one popped micro-batch: decode every member, dispatch ONE
/// `execute_batch` for the whole batch (or the single-request path for a
/// batch of one), build and send each reply, and fill the cache.
fn serve_batch(
    engine: &Engine,
    mut jobs: Vec<Job>,
    cache: Option<&Mutex<ResponseCache>>,
    wlat: &Mutex<[LatencyHistogram; 2]>,
) {
    if jobs.len() == 1 {
        let job = jobs.pop().unwrap();
        let r = process_packet(engine, &job.pkt, &job.prompt_ids, &job.set);
        finish_job(job, r, cache, wlat);
        return;
    }
    // Decode first: a member that fails to decode is answered individually
    // and excluded; the rest still batch.
    let mut decoded = Vec::with_capacity(jobs.len());
    for job in jobs {
        match decode_request_inputs(&job.pkt, &job.prompt_ids) {
            Ok((artifact, inputs)) => decoded.push((job, artifact, inputs)),
            Err(e) => finish_job(job, Err(e), cache, wlat),
        }
    }
    let Some((first, artifact, _)) = decoded.first() else {
        return;
    };
    let artifact = artifact.clone();
    let set = first.set.clone();
    let inputs: Vec<Vec<Tensor>> =
        decoded.iter_mut().map(|(_, _, i)| std::mem::take(i)).collect();
    match engine.execute_batch_owned(&artifact, &set, inputs) {
        Ok(outs) => {
            for ((job, _, _), out) in decoded.into_iter().zip(outs) {
                let r = response_from_outputs(job.pkt.kind, out);
                finish_job(job, r, cache, wlat);
            }
        }
        Err(_) => {
            // A batch fails as a whole, but one bad member must not fail
            // its co-batched neighbors — re-run every member individually
            // so only the offending request sees its error.  Rare path:
            // the re-decode cost is irrelevant next to correctness.
            for (job, _, _) in decoded {
                let r = process_packet(engine, &job.pkt, &job.prompt_ids, &job.set);
                finish_job(job, r, cache, wlat);
            }
        }
    }
}

/// Reply to one job, filling the cache on success and recording its
/// admission→completion wall latency into the per-class histograms.
fn finish_job(
    job: Job,
    r: Result<CloudResponse>,
    cache: Option<&Mutex<ResponseCache>>,
    wlat: &Mutex<[LatencyHistogram; 2]>,
) {
    if let (Ok(resp), Some(key), Some(cache)) = (&r, job.key, cache) {
        // Clone outside the lock — the guard is only held for the O(log n)
        // index update.
        let stored = resp.clone();
        cache.lock().unwrap().insert(key, stored, job.pkt.t_capture);
    }
    wlat.lock().unwrap()[job.pkt.kind as usize].record(job.t_submit.elapsed().as_secs_f64());
    let _ = job.reply.send(r.map_err(ServeError::Exec));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::CloudServer;
    use crate::coordinator::{classify_intent, Lut, TierId};
    use crate::dataset::{Corpus, Dataset};
    use crate::edge::EdgePipeline;
    use crate::energy::DeviceModel;

    fn sample_packets(n: usize) -> (Vec<Packet>, Vec<i32>) {
        let engine = Engine::synthetic();
        let ds = Dataset::synthetic(Corpus::Flood, n, 16, 0xF10D0);
        let mut edge =
            EdgePipeline::new(engine, DeviceModel::jetson_mode_30w(8), Lut::paper());
        let pkts = ds
            .scenes
            .iter()
            .map(|s| edge.capture_insight(s, 1, TierId::HighAccuracy, 0.0).unwrap().0)
            .collect();
        (pkts, classify_intent("highlight the stranded people").token_ids)
    }

    #[test]
    fn pool_direct_path_matches_queue_and_server() {
        let engine = Engine::synthetic();
        let (pkts, ids) = sample_packets(1);
        let pkt = &pkts[0];

        let pool = CloudPool::new(vec![engine.clone(), engine.clone()]);
        let direct = pool.process_sync(pkt, &ids, "ft").unwrap();
        assert!(!direct.cache_hit);
        let queued = pool.submit(pkt, &ids, "ft").unwrap().wait().unwrap();
        let server = CloudServer::new(engine).process(pkt, &ids, "ft").unwrap();
        assert_eq!(direct.resp.presence, queued.presence);
        assert_eq!(direct.resp.presence, server.presence);
        assert_eq!(direct.resp.mask_logits, queued.mask_logits);
        assert_eq!(direct.resp.mask_logits, server.mask_logits);
        // Both routes count toward the pool's aggregate counters.
        assert_eq!(pool.stats().completed, 2);
    }

    #[test]
    fn cache_hit_returns_byte_identical_response() {
        let engine = Engine::synthetic();
        let (pkts, ids) = sample_packets(1);
        let pool = CloudPool::with_config(
            vec![engine],
            ServingConfig { cache_entries: 8, ..ServingConfig::default() },
        );
        let first = pool.process_sync(&pkts[0], &ids, "ft").unwrap();
        assert!(!first.cache_hit);
        let second = pool.process_sync(&pkts[0], &ids, "ft").unwrap();
        assert!(second.cache_hit);
        assert_eq!(first.resp.presence, second.resp.presence);
        assert_eq!(first.resp.mask_logits, second.resp.mask_logits);
        // A different weight set is a different key.
        let other = pool.process_sync(&pkts[0], &ids, "orig").unwrap();
        assert!(!other.cache_hit);
        let st = pool.stats();
        assert_eq!((st.cache_hits, st.cache_misses), (1, 2));
        assert_eq!(st.completed, 3);
    }

    #[test]
    fn cache_ttl_expires_in_virtual_time() {
        let mut cache = ResponseCache::new(4, 10.0);
        let resp = CloudResponse { mask_logits: None, presence: vec![1.0] };
        cache.insert(42, resp, 0.0);
        assert!(cache.get(42, 5.0).is_some());
        // Virtual age 15 s > TTL 10 s: expired, dropped, counted.
        assert!(cache.get(42, 15.0).is_none());
        assert!(cache.is_empty());
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.expirations), (1, 1, 1));
    }

    #[test]
    fn cache_lru_evicts_in_recency_order() {
        let mut cache = ResponseCache::new(2, f64::INFINITY);
        let resp = |v: f32| CloudResponse { mask_logits: None, presence: vec![v] };
        cache.insert(1, resp(1.0), 0.0);
        cache.insert(2, resp(2.0), 1.0);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(cache.get(1, 2.0).is_some());
        cache.insert(3, resp(3.0), 3.0);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(2, 4.0).is_none(), "2 should have been evicted");
        assert_eq!(cache.get(1, 5.0).unwrap().presence, vec![1.0]);
        assert_eq!(cache.get(3, 6.0).unwrap().presence, vec![3.0]);
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn cache_key_is_content_addressed() {
        let (pkts, ids) = sample_packets(2);
        let mut a = pkts[0].clone();
        let mut b = pkts[0].clone();
        // Same content at different times / sequence numbers: same key.
        a.seq = 1;
        a.t_capture = 0.0;
        b.seq = 99;
        b.t_capture = 500.0;
        assert_eq!(cache_key(&a, &ids, "ft"), cache_key(&b, &ids, "ft"));
        // Different scene content, prompt, or set: different keys.
        assert_ne!(cache_key(&pkts[0], &ids, "ft"), cache_key(&pkts[1], &ids, "ft"));
        assert_ne!(cache_key(&pkts[0], &ids, "ft"), cache_key(&pkts[0], &ids, "orig"));
        let other = classify_intent("mark the submerged vehicles").token_ids;
        assert_ne!(cache_key(&pkts[0], &ids, "ft"), cache_key(&pkts[0], &other, "ft"));
    }

    #[test]
    fn admission_sheds_then_closes() {
        // A pool with no workers never drains: admission outcomes are
        // exactly determined by what was submitted.
        let (pkts, ids) = sample_packets(1);
        let pool = CloudPool::with_config(
            Vec::new(),
            ServingConfig { queue_depth: 1, ..ServingConfig::default() },
        );
        let ticket = pool.submit(&pkts[0], &ids, "ft").unwrap();
        assert!(matches!(pool.submit(&pkts[0], &ids, "ft"), Err(ServeError::Shed { hops: 0 })));
        assert_eq!(pool.stats().shed, 1);
        drop(pool);
        // The pool died with the job queued: Closed, not Exec.
        assert!(matches!(ticket.wait(), Err(ServeError::Closed)));
    }

    #[test]
    fn direct_path_honors_admission_bound() {
        // Inline pool bounded to ONE in-flight request: while a slow
        // request executes through the direct path, a concurrent caller is
        // shed — the bound applies to in-process serving, not just the
        // queued transport path.  (Both sides retry on shed so neither can
        // starve the other; the serial fleet sim never sees this because
        // its in_flight never exceeds 1.)
        let engine = Engine::synthetic();
        let ds = Dataset::synthetic(Corpus::Flood, 1, 1024, 0xF10D0);
        let mut edge =
            EdgePipeline::new(engine.clone(), DeviceModel::jetson_mode_30w(8), Lut::paper());
        let (big, _) =
            edge.capture_insight(&ds.scenes[0], 1, TierId::Balanced, 0.0).unwrap();
        let (small, ids) = sample_packets(1);
        let pool = CloudPool::with_config(
            vec![engine],
            ServingConfig { queue_depth: 1, ..ServingConfig::default() },
        );
        std::thread::scope(|s| {
            let pool = &pool;
            let big = &big;
            let blocker_ids = ids.clone();
            s.spawn(move || loop {
                match pool.try_process(big, &blocker_ids, "ft") {
                    Ok(_) => break,
                    Err(ServeError::Shed { .. }) => continue,
                    Err(e) => panic!("blocker: {e}"),
                }
            });
            let mut shed_seen = false;
            for _ in 0..200_000 {
                match pool.try_process(&small[0], &ids, "ft") {
                    Err(ServeError::Shed { .. }) => {
                        shed_seen = true;
                        break;
                    }
                    Ok(_) => {}
                    Err(e) => panic!("probe: {e}"),
                }
            }
            assert!(shed_seen, "bounded direct path never shed a concurrent caller");
        });
        assert!(pool.stats().shed >= 1);
    }

    #[test]
    fn ticket_distinguishes_execution_errors() {
        let engine = Engine::synthetic();
        let (pkts, ids) = sample_packets(1);
        let pool = CloudPool::new(vec![engine]);
        // An insight packet with its code stripped fails execution-side.
        let mut bad = pkts[0].clone();
        bad.code_q = Vec::new();
        match pool.submit(&bad, &ids, "ft").unwrap().wait() {
            Err(ServeError::Exec(e)) => assert!(format!("{e:#}").contains("code"), "{e:#}"),
            other => panic!("want Exec error, got {other:?}"),
        }
    }

    #[test]
    fn bad_batch_member_fails_alone() {
        // A member that decodes but fails kernel-side must not take its
        // co-batched neighbors down with it: the batch falls back to
        // per-element execution and only the offender sees an error.
        let (pkts, ids) = sample_packets(3);
        let mut bad = pkts[0].clone();
        bad.code_shape = (2, 3); // decodes fine; the tail rejects non-square planes
        bad.code_q = vec![0; 6];
        let pool = CloudPool::with_config(
            vec![Engine::synthetic_threaded()],
            ServingConfig { batch_max: 4, ..ServingConfig::default() },
        );
        let good: Vec<Ticket> =
            pkts.iter().map(|p| pool.submit(p, &ids, "ft").unwrap()).collect();
        let bad_ticket = pool.submit(&bad, &ids, "ft").unwrap();
        for t in good {
            t.wait().unwrap();
        }
        assert!(matches!(bad_ticket.wait(), Err(ServeError::Exec(_))));
    }

    #[test]
    fn batched_queue_path_matches_direct() {
        // Force the queued path (threaded engine => no direct fast path)
        // with batching on; results must match the inline direct path
        // byte for byte, whatever batches actually formed.
        let (pkts, ids) = sample_packets(6);
        let inline_pool = CloudPool::new(vec![Engine::synthetic()]);
        let batched = CloudPool::with_config(
            vec![Engine::synthetic_threaded()],
            ServingConfig { batch_max: 4, ..ServingConfig::default() },
        );
        let tickets: Vec<Ticket> =
            pkts.iter().map(|p| batched.submit(p, &ids, "ft").unwrap()).collect();
        for (pkt, ticket) in pkts.iter().zip(tickets) {
            let want = inline_pool.process_sync(pkt, &ids, "ft").unwrap().resp;
            let got = ticket.wait().unwrap();
            assert_eq!(want.presence, got.presence);
            assert_eq!(want.mask_logits, got.mask_logits);
        }
        let st = batched.stats();
        assert_eq!(st.batched_requests, 6);
        assert!(st.batches <= 6, "drains {}", st.batches);
        // Every queued completion stamped admission→completion wall time.
        assert_eq!(st.wall_lat_insight.count(), 6);
        assert_eq!(st.wall_lat_context.count(), 0);
    }

    #[test]
    fn default_config_keeps_deadline_discipline_off() {
        let cfg = ServingConfig::default();
        assert!(!cfg.enabled());
        assert!(cfg.deadline_context_secs.is_infinite());
        assert!(ServingConfig { edf: true, ..ServingConfig::default() }.enabled());
        assert!(ServingConfig { deadline_shed: true, ..ServingConfig::default() }.enabled());
        assert!(ServingConfig { deadline_context_secs: 0.5, ..ServingConfig::default() }
            .enabled());
    }

    fn queue_job(
        pkts: &[Packet],
        ids: &[i32],
        t_capture: f64,
        deadline: f64,
    ) -> (Job, Receiver<Result<CloudResponse, ServeError>>) {
        let (reply, rx) = channel();
        let mut pkt = pkts[0].clone();
        pkt.t_capture = t_capture;
        (
            Job {
                pkt,
                prompt_ids: ids.to_vec(),
                set: "ft".to_string(),
                key: None,
                deadline,
                t_submit: Instant::now(),
                reply,
            },
            rx,
        )
    }

    #[test]
    fn edf_pop_drains_earliest_deadline_first() {
        let (pkts, ids) = sample_packets(1);
        let q = JobQueue::new();
        for d in [5.0, 1.0, 3.0] {
            q.reserve(0, AdmissionPolicy::Shed).unwrap();
            q.enqueue(queue_job(&pkts, &ids, 0.0, d).0).unwrap();
        }
        // EDF pops by deadline, not arrival order.
        assert_eq!(q.pop_batch(1, true).unwrap()[0].deadline, 1.0);
        assert_eq!(q.pop_batch(1, true).unwrap()[0].deadline, 3.0);
        assert_eq!(q.pop_batch(1, true).unwrap()[0].deadline, 5.0);
        // FIFO (edf off) keeps arrival order even with deadlines set.
        for d in [5.0, 1.0] {
            q.reserve(0, AdmissionPolicy::Shed).unwrap();
            q.enqueue(queue_job(&pkts, &ids, 0.0, d).0).unwrap();
        }
        assert_eq!(q.pop_batch(1, false).unwrap()[0].deadline, 5.0);
        assert_eq!(q.pop_batch(1, false).unwrap()[0].deadline, 1.0);
        // All-infinite deadlines degrade EDF to exact FIFO (strict-< keeps
        // the oldest job as lead).
        for t in [7.0, 8.0] {
            q.reserve(0, AdmissionPolicy::Shed).unwrap();
            q.enqueue(queue_job(&pkts, &ids, t, f64::INFINITY).0).unwrap();
        }
        assert_eq!(q.pop_batch(1, true).unwrap()[0].pkt.t_capture, 7.0);
        assert_eq!(q.pop_batch(1, true).unwrap()[0].pkt.t_capture, 8.0);
    }

    #[test]
    fn edf_lead_still_gathers_compatible_batch() {
        let (pkts, ids) = sample_packets(1);
        let q = JobQueue::new();
        for d in [9.0, 2.0, 4.0] {
            q.reserve(0, AdmissionPolicy::Shed).unwrap();
            q.enqueue(queue_job(&pkts, &ids, 0.0, d).0).unwrap();
        }
        // Lead = deadline 2.0; the other two (same artifact/set) co-batch.
        let batch = q.pop_batch(4, true).unwrap();
        assert_eq!(batch[0].deadline, 2.0);
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn deadline_shed_displaces_queued_predicted_misser() {
        let (pkts, ids) = sample_packets(1);
        let pool = CloudPool::with_config(
            Vec::new(),
            ServingConfig {
                queue_depth: 2,
                deadline_shed: true,
                deadline_insight_secs: 10.0,
                ..ServingConfig::default()
            },
        );
        let mk = |t: f64| {
            let mut p = pkts[0].clone();
            p.t_capture = t;
            p
        };
        // Two queued jobs with deadlines 10 and 11 (virtual).
        let t0 = pool.submit(&mk(0.0), &ids, "ft").unwrap();
        let _t1 = pool.submit(&mk(1.0), &ids, "ft").unwrap();
        // Arrival at virtual time 100: both queued jobs are already past
        // their deadlines; the widest misser (deadline 10) is shed and the
        // arrival takes its slot.
        let t2 = pool.submit(&mk(100.0), &ids, "ft").unwrap();
        assert!(matches!(t0.wait(), Err(ServeError::Shed { hops: 0 })));
        assert!(!t2.cache_hit());
        let st = pool.stats();
        assert_eq!((st.shed, st.shed_context, st.shed_insight), (1, 0, 1));
    }

    #[test]
    fn deadline_shed_refuses_arrival_when_queue_will_hold() {
        let (pkts, ids) = sample_packets(1);
        let pool = CloudPool::with_config(
            Vec::new(),
            ServingConfig {
                queue_depth: 2,
                deadline_shed: true,
                deadline_insight_secs: 10.0,
                ..ServingConfig::default()
            },
        );
        let mk = |t: f64| {
            let mut p = pkts[0].clone();
            p.t_capture = t;
            p
        };
        // Queue full of future-deadline jobs (deadline 110 at now=0): no
        // queued job is predicted to miss, so the arrival is refused — the
        // plain shed-newest fallback.
        let _a = pool.submit(&mk(100.0), &ids, "ft").unwrap();
        let _b = pool.submit(&mk(100.0), &ids, "ft").unwrap();
        assert!(matches!(pool.submit(&mk(0.0), &ids, "ft"), Err(ServeError::Shed { .. })));
        let st = pool.stats();
        assert_eq!((st.shed, st.shed_insight), (1, 1));
    }

    #[test]
    fn replicate_fills_without_counting_a_miss() {
        let mut cache = ResponseCache::new(4, f64::INFINITY);
        let resp = CloudResponse { mask_logits: None, presence: vec![1.0] };
        cache.replicate(7, resp.clone(), 0.0);
        assert_eq!(cache.len(), 1);
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (0, 0));
        // The replicated entry serves hits like any executed fill.
        assert_eq!(cache.get(7, 1.0).unwrap().presence, vec![1.0]);
        assert_eq!(cache.stats().hits, 1);
        // Replication still honors the LRU capacity bound.
        let mut small = ResponseCache::new(1, f64::INFINITY);
        small.replicate(1, resp.clone(), 0.0);
        small.replicate(2, resp, 1.0);
        assert_eq!(small.len(), 1);
        assert_eq!(small.stats().evictions, 1);
    }

    #[test]
    fn pool_cache_probe_and_replicate_roundtrip() {
        let engine = Engine::synthetic();
        let (pkts, ids) = sample_packets(1);
        let a = CloudPool::with_config(
            vec![engine.clone()],
            ServingConfig { cache_entries: 8, ..ServingConfig::default() },
        );
        let b = CloudPool::with_config(
            vec![engine],
            ServingConfig { cache_entries: 8, ..ServingConfig::default() },
        );
        let key = cache_key(&pkts[0], &ids, "ft");
        // Nothing cached anywhere yet; probing counts nothing.
        assert!(a.cache_probe(key, 0.0).is_none());
        let first = a.process_sync(&pkts[0], &ids, "ft").unwrap();
        // Replicate a's executed fill into b: b answers the probe without
        // ever executing, and the propagated fill counted no miss there.
        b.cache_replicate(key, &first.resp, pkts[0].t_capture);
        let remote = b.cache_probe(key, pkts[0].t_capture).unwrap();
        assert_eq!(remote.presence, first.resp.presence);
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!((sa.cache_hits, sa.cache_misses), (0, 1));
        assert_eq!((sb.cache_hits, sb.cache_misses), (1, 0));
        assert_eq!(sb.completed, 1, "a probe hit counts as served by that cell");
    }

    #[test]
    fn pool_stats_merge_sums_counters_and_histograms() {
        let mut a = PoolStats {
            workers: 2,
            completed: 10,
            busy_secs: 1.5,
            cache_hits: 3,
            cache_misses: 7,
            shed: 2,
            shed_insight: 2,
            batches: 4,
            batched_requests: 10,
            ..PoolStats::default()
        };
        a.lat_insight.record(0.5);
        let mut b = PoolStats {
            workers: 1,
            completed: 5,
            busy_secs: 0.5,
            cache_hits: 1,
            cache_misses: 4,
            shed: 1,
            shed_context: 1,
            batches: 5,
            batched_requests: 5,
            ..PoolStats::default()
        };
        b.lat_insight.record(0.7);
        b.lat_context.record(0.02);
        a.merge(&b);
        assert_eq!(a.workers, 3);
        assert_eq!(a.completed, 15);
        assert!((a.busy_secs - 2.0).abs() < 1e-12);
        assert_eq!((a.cache_hits, a.cache_misses), (4, 11));
        assert_eq!((a.shed, a.shed_context, a.shed_insight), (3, 1, 2));
        assert_eq!((a.batches, a.batched_requests), (9, 15));
        assert_eq!(a.lat_insight.count(), 2);
        assert_eq!(a.lat_context.count(), 1);
        assert!(a.lat_insight.p99() >= 0.5);
    }

    #[test]
    fn observe_latency_feeds_per_class_histograms() {
        let pool = CloudPool::new(vec![Engine::synthetic()]);
        // Virtual quantities from the mission timing model.
        pool.observe_latency(StreamKind::Context, 0.02);
        pool.observe_latency(StreamKind::Insight, 0.5);
        pool.observe_latency(StreamKind::Insight, 0.7);
        let (ctx, ins) = pool.latency_histograms().unwrap();
        assert_eq!((ctx.count(), ins.count()), (1, 2));
        assert_eq!(ctx.p50(), 0.02);
        let st = pool.stats();
        assert_eq!(st.lat_insight.count(), 2);
        assert!(st.lat_insight.p99() <= 0.7 && st.lat_insight.p50() >= 0.5);
        // The single-session server keeps the trait defaults (no-op).
        let server = CloudServer::new(Engine::synthetic());
        server.observe_latency(StreamKind::Context, 1.0);
        assert!(server.latency_histograms().is_none());
    }
}
