//! Telemetry sinks: CSV writers for the figure-regenerating missions and a
//! compact fixed-width table printer for terminal summaries.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A CSV writer with a fixed header.
pub struct Csv {
    file: std::fs::File,
    pub path: PathBuf,
    cols: usize,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating csv dir {}", dir.display()))?;
            }
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Self { file, path: path.to_path_buf(), cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols, "csv column mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        let vs: Vec<String> = values.iter().map(|v| format!("{v:.6}")).collect();
        self.row(&vs)
    }
}

/// Fixed-width terminal table (the "same rows the paper reports").
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n{}", self.title);
        println!("{}", "-".repeat(total.min(120)));
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!(" {c:>w$} |"));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!("{}", "-".repeat(total.min(120)));
    }
}

/// Format a float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Format a percentage cell.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join("avery_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.rowf(&[1.0, 2.0]).unwrap();
        c.row(&["x".into(), "y".into()]).unwrap();
        drop(c);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("1.000000,2.000000"));
        assert!(text.contains("x,y"));
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("demo", &["col1", "col2"]);
        t.row(&["a".into(), "b".into()]);
        t.print();
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.9398), "93.98%");
    }
}
