//! Telemetry sinks: CSV writers for the figure-regenerating missions, a
//! compact fixed-width table printer for terminal summaries, and the
//! fixed-bucket log-scale [`LatencyHistogram`] behind the repo's
//! tail-latency accounting (DESIGN.md "Tail-latency discipline").
//!
//! The CSV writer is strict in **all** builds: a ragged row (cell count ≠
//! header count) is a hard error, and a non-finite cell is a typed
//! [`NonFiniteCell`] error naming the column — a release binary must never
//! silently corrupt a downstream parser with `NaN` literals or shifted
//! columns.

use std::fmt;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Typed error: a non-finite value was handed to [`Csv::rowf`].  Carried
/// through `anyhow` so call sites can `downcast_ref::<NonFiniteCell>()` to
/// learn which column produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct NonFiniteCell {
    /// Header name of the offending column.
    pub column: String,
    /// The rejected value (`NaN`, `inf` or `-inf`).
    pub value: f64,
}

impl fmt::Display for NonFiniteCell {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "non-finite value {} for csv column `{}`", self.value, self.column)
    }
}

impl std::error::Error for NonFiniteCell {}

/// A CSV writer with a fixed header.
pub struct Csv {
    file: std::fs::File,
    pub path: PathBuf,
    header: Vec<String>,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating csv dir {}", dir.display()))?;
            }
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Self {
            file,
            path: path.to_path_buf(),
            header: header.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// Write one pre-formatted row.  A cell count that disagrees with the
    /// header is a hard error in every build profile — nothing is written.
    pub fn row(&mut self, values: &[String]) -> Result<()> {
        if values.len() != self.header.len() {
            bail!(
                "csv {}: row has {} cells but header has {} columns",
                self.path.display(),
                values.len(),
                self.header.len()
            );
        }
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    /// Write one all-float row (`{v:.6}`).  Non-finite values are rejected
    /// with a [`NonFiniteCell`] error naming the column; nothing is written.
    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        for (i, v) in values.iter().enumerate() {
            if !v.is_finite() {
                let column = self.header.get(i).cloned().unwrap_or_else(|| format!("#{i}"));
                return Err(NonFiniteCell { column, value: *v }.into());
            }
        }
        let vs: Vec<String> = values.iter().map(|v| format!("{v:.6}")).collect();
        self.row(&vs)
    }
}

/// Fixed-width terminal table (the "same rows the paper reports").
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render the table body: rule, header, rule, rows, rule.  Rules and
    /// rows share one width computed from the widest cell per column, so a
    /// wide table never prints rows longer than its rules.
    fn render(&self) -> Vec<String> {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        let rule = "-".repeat(total);
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!(" {c:>w$} |"));
            }
            s
        };
        let mut lines = vec![rule.clone(), fmt_row(&self.header), rule.clone()];
        for row in &self.rows {
            lines.push(fmt_row(row));
        }
        lines.push(rule);
        lines
    }

    pub fn print(&self) {
        println!("\n{}", self.title);
        for line in self.render() {
            println!("{line}");
        }
    }
}

/// Format a float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Format a percentage cell.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

// ---------------------------------------------------------------------------
// Log-scale latency histogram
// ---------------------------------------------------------------------------

/// Bucket count for [`LatencyHistogram`].
pub const HIST_BUCKETS: usize = 64;
/// Lower edge of bucket 0: 10 µs.
const HIST_LO_SECS: f64 = 1e-5;
/// Upper edge of the last bucket: 100 s.
const HIST_HI_SECS: f64 = 1e2;

/// Fixed-bucket log-scale latency histogram: [`HIST_BUCKETS`] buckets with
/// geometrically-spaced edges spanning [`10µs`, `100s`], O(1) record, exact
/// min/max/count/sum, percentiles by within-bucket linear interpolation
/// clamped to the observed `[min, max]` (so a single sample reports its
/// exact value and p50 ≤ p90 ≤ p99 ≤ p999 always holds).
///
/// Deterministic and allocation-free after construction: `Copy`, no heap,
/// and every operation is a pure function of the recorded sequence — safe
/// to surface in byte-deterministic mission reports (values recorded must
/// themselves be virtual quantities; see DESIGN.md).
#[derive(Clone, Copy, Debug)]
pub struct LatencyHistogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Lower edge of bucket `i` in seconds (`edge(HIST_BUCKETS)` = 100 s).
    fn edge(i: usize) -> f64 {
        HIST_LO_SECS * (HIST_HI_SECS / HIST_LO_SECS).powf(i as f64 / HIST_BUCKETS as f64)
    }

    fn bucket_of(v: f64) -> usize {
        if v <= HIST_LO_SECS {
            return 0;
        }
        let span = (HIST_HI_SECS / HIST_LO_SECS).log10();
        let idx = ((v / HIST_LO_SECS).log10() / span * HIST_BUCKETS as f64) as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Record one latency sample (seconds).  O(1).  Values outside the
    /// bucket range clamp into the first/last bucket (min/max stay exact);
    /// non-finite samples are a caller bug and are dropped.
    pub fn record(&mut self, v_secs: f64) {
        debug_assert!(v_secs.is_finite(), "non-finite latency sample {v_secs}");
        if !v_secs.is_finite() {
            return;
        }
        let v = v_secs.max(0.0);
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of recorded samples; 0.0 when empty (finite for CSV sinks).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum; 0.0 when empty.
    pub fn min_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum; 0.0 when empty.
    pub fn max_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &Self) {
        if other.count == 0 {
            return;
        }
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Percentile `q` ∈ [0, 1] by within-bucket linear interpolation,
    /// clamped to the observed `[min, max]`.  Empty → 0.0 (finite, so the
    /// strict CSV sinks accept it).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= target {
                let lo = Self::edge(i);
                let hi = Self::edge(i + 1);
                let frac = (target - cum) as f64 / c as f64;
                return (lo + (hi - lo) * frac).clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.percentile(0.999)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join("avery_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.rowf(&[1.0, 2.0]).unwrap();
        c.row(&["x".into(), "y".into()]).unwrap();
        drop(c);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("1.000000,2.000000"));
        assert!(text.contains("x,y"));
    }

    #[test]
    fn csv_rejects_ragged_rows_in_all_builds() {
        let dir = std::env::temp_dir().join("avery_telemetry_ragged");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ragged.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        let err = c.row(&["only".into()]).unwrap_err();
        assert!(err.to_string().contains("1 cells"), "{err}");
        assert!(c.row(&["1".into(), "2".into(), "3".into()]).is_err());
        c.row(&["1".into(), "2".into()]).unwrap();
        drop(c);
        // The rejected rows never reached the file.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn csv_rejects_non_finite_cells_with_typed_error() {
        let dir = std::env::temp_dir().join("avery_telemetry_nonfinite");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("nf.csv");
        let mut c = Csv::create(&path, &["t", "avg_pps"]).unwrap();
        let err = c.rowf(&[1.0, f64::NAN]).unwrap_err();
        let cell = err.downcast_ref::<NonFiniteCell>().expect("typed error");
        assert_eq!(cell.column, "avg_pps");
        assert!(cell.value.is_nan());
        let err = c.rowf(&[f64::INFINITY, 2.0]).unwrap_err();
        assert_eq!(err.downcast_ref::<NonFiniteCell>().unwrap().column, "t");
        c.rowf(&[3.0, 4.0]).unwrap();
        drop(c);
        // Rejected rows are all-or-nothing: only the finite row landed.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "t,avg_pps\n3.000000,4.000000\n");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("demo", &["col1", "col2"]);
        t.row(&["a".into(), "b".into()]);
        t.print();
    }

    #[test]
    fn table_rules_and_rows_share_one_width() {
        // Wide enough that the old 120-char separator cap would have left
        // the rules shorter than the rows.
        let cols = ["c0", "c1", "c2", "c3", "c4", "c5", "c6", "c7"];
        let mut t = Table::new("wide", &cols);
        t.row(&vec!["x".repeat(24); cols.len()]);
        let lines = t.render();
        let width = lines[0].len();
        assert!(width > 120, "test table not wide enough: {width}");
        for line in &lines {
            assert_eq!(line.len(), width, "line width drifted: {line:?}");
        }
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.9398), "93.98%");
    }

    #[test]
    fn histogram_empty_is_finite_zero() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p999(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min_secs(), 0.0);
        assert_eq!(h.max_secs(), 0.0);
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let mut h = LatencyHistogram::new();
        h.record(0.037);
        assert_eq!(h.count(), 1);
        for q in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile(q), 0.037, "q={q}");
        }
        assert_eq!(h.min_secs(), 0.037);
        assert_eq!(h.max_secs(), 0.037);
        assert_eq!(h.mean(), 0.037);
    }

    #[test]
    fn histogram_all_one_bucket_clamps_to_observed_range() {
        // Samples inside one bucket: interpolation stays within [min, max].
        let mut h = LatencyHistogram::new();
        for v in [0.01001, 0.01002, 0.01003] {
            h.record(v);
        }
        for q in [0.5, 0.9, 0.99] {
            let p = h.percentile(q);
            assert!((0.01001..=0.01003).contains(&p), "q={q} p={p}");
        }
    }

    #[test]
    fn histogram_percentiles_are_monotone() {
        let mut h = LatencyHistogram::new();
        let mut x = 1u64;
        for _ in 0..5000 {
            // Deterministic spread over several decades.
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 1e-4 * (1.0 + (x >> 40) as f64 / 1e3);
            h.record(v * ((x >> 60) + 1) as f64);
        }
        let (p50, p90, p99, p999) = (h.p50(), h.p90(), h.p99(), h.p999());
        assert!(h.min_secs() <= p50, "{} > {p50}", h.min_secs());
        assert!(p50 <= p90 && p90 <= p99 && p99 <= p999, "{p50} {p90} {p99} {p999}");
        assert!(p999 <= h.max_secs());
    }

    #[test]
    fn histogram_clamps_out_of_range_samples() {
        let mut h = LatencyHistogram::new();
        h.record(1e-9); // below bucket 0 lower edge
        h.record(1e6); // above the last bucket
        assert_eq!(h.count(), 2);
        // Exact extremes survive the bucket clamp.
        assert_eq!(h.min_secs(), 1e-9);
        assert_eq!(h.max_secs(), 1e6);
        assert!(h.p999() <= 1e6);
    }

    #[test]
    fn histogram_merge_accumulates() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(0.001);
        b.record(0.1);
        b.record(0.2);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min_secs(), 0.001);
        assert_eq!(a.max_secs(), 0.2);
        let mut empty = LatencyHistogram::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 3);
        a.merge(&LatencyHistogram::new());
        assert_eq!(a.count(), 3);
    }
}
