//! Minimal benchmark harness (criterion is not in the offline crate set).
//! Provides warmup + timed iterations with mean/p50/p95 reporting, used by
//! every `cargo bench` target under rust/benches/.

use std::time::Instant;

use crate::util::Stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub stats: Stats,
}

impl BenchResult {
    pub fn print(&self) {
        let s = &self.stats;
        println!(
            "{:<44} {:>5} iters  mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}",
            self.name,
            self.iters,
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            fmt_secs(s.min),
        );
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult { name: name.to_string(), iters, stats: Stats::from(&samples) };
    r.print();
    r
}

/// Time a fallible closure, panicking on error (bench setup bugs should be
/// loud, not silently timed).
pub fn bench_result<F: FnMut() -> anyhow::Result<()>>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchResult {
    bench(name, warmup, iters, || f().expect("bench case failed"))
}

/// Standard bench header so `cargo bench` output is self-describing.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12);
        assert!(r.stats.mean >= 0.0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" us"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
