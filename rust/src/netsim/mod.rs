//! Network substrate: the scripted disaster-zone bandwidth trace, the link
//! model that turns payload bytes into transmission delay, the contended
//! multi-UAV [`SharedLink`] (fleet missions), and the EWMA bandwidth
//! estimator that feeds the controller's **Sense** stage.
//!
//! The paper (§5.3.1) evaluates over a 20-minute scripted trace "with stable
//! periods, high volatility, and sustained drops, all within an 8–20 Mbps
//! range" as a proxy for degraded 5G uplink in disaster zones.  We model the
//! same three phase kinds over a virtual clock; everything is deterministic
//! given the seed.

mod link;
mod shared;
mod trace;

pub use link::{Link, LinkConfig, TxOutcome};
pub use shared::SharedLink;
pub use trace::{BandwidthTrace, Phase, PhaseKind, TraceConfig, OUTAGE_FLOOR_MBPS};

use crate::util::Ewma;

/// An uplink as seen by one UAV — implemented by the dedicated [`Link`]
/// (single-UAV missions; the `uav` id is ignored) and the contended
/// [`SharedLink`] (fleet missions; each UAV senses its fair share).
/// The [`crate::streams::UavAgent`] state machine is generic over this, so
/// the same Sense→Gate→Evaluate→Select loop runs unmodified in both worlds.
pub trait Uplink {
    /// Ground-truth bandwidth available to `uav` at virtual time `t` (Mbps)
    /// — the quantity its periodic probe samples (with noise).
    fn ground_truth(&self, uav: usize, t: f64) -> f64;
    /// Transmit `wire_bytes` for `uav` starting at `t`.
    fn transmit(&mut self, uav: usize, t: f64, wire_bytes: f64) -> TxOutcome;
}

impl Uplink for Link {
    fn ground_truth(&self, _uav: usize, t: f64) -> f64 {
        self.bandwidth_at(t)
    }

    fn transmit(&mut self, _uav: usize, t: f64, wire_bytes: f64) -> TxOutcome {
        Link::transmit(self, t, wire_bytes)
    }
}

impl Uplink for SharedLink {
    fn ground_truth(&self, uav: usize, t: f64) -> f64 {
        self.share_at(uav, t)
    }

    fn transmit(&mut self, uav: usize, t: f64, wire_bytes: f64) -> TxOutcome {
        SharedLink::transmit(self, uav, t, wire_bytes)
    }
}

/// EWMA bandwidth estimator — the controller's Sense stage observes link
/// goodput samples rather than the (unknowable) ground-truth trace.
#[derive(Clone, Debug)]
pub struct BandwidthEstimator {
    ewma: Ewma,
    last_mbps: f64,
}

impl BandwidthEstimator {
    pub fn new(alpha: f64) -> Self {
        Self { ewma: Ewma::new(alpha), last_mbps: 0.0 }
    }

    /// Feed one goodput observation (payload bits / measured tx seconds).
    pub fn observe(&mut self, mbps: f64) -> f64 {
        self.last_mbps = self.ewma.update(mbps);
        self.last_mbps
    }

    /// Current estimate in Mbps (0 until the first observation).
    pub fn estimate_mbps(&self) -> f64 {
        self.ewma.get().unwrap_or(self.last_mbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_tracks_step_change() {
        let mut e = BandwidthEstimator::new(0.3);
        for _ in 0..50 {
            e.observe(16.0);
        }
        assert!((e.estimate_mbps() - 16.0).abs() < 0.1);
        for _ in 0..50 {
            e.observe(9.0);
        }
        assert!((e.estimate_mbps() - 9.0).abs() < 0.1);
    }
}
