//! Network substrate: the scripted disaster-zone bandwidth trace, the link
//! model that turns payload bytes into transmission delay, and the EWMA
//! bandwidth estimator that feeds the controller's **Sense** stage.
//!
//! The paper (§5.3.1) evaluates over a 20-minute scripted trace "with stable
//! periods, high volatility, and sustained drops, all within an 8–20 Mbps
//! range" as a proxy for degraded 5G uplink in disaster zones.  We model the
//! same three phase kinds over a virtual clock; everything is deterministic
//! given the seed.

mod link;
mod trace;

pub use link::{Link, LinkConfig, TxOutcome};
pub use trace::{BandwidthTrace, Phase, PhaseKind, TraceConfig};

use crate::util::Ewma;

/// EWMA bandwidth estimator — the controller's Sense stage observes link
/// goodput samples rather than the (unknowable) ground-truth trace.
#[derive(Clone, Debug)]
pub struct BandwidthEstimator {
    ewma: Ewma,
    last_mbps: f64,
}

impl BandwidthEstimator {
    pub fn new(alpha: f64) -> Self {
        Self { ewma: Ewma::new(alpha), last_mbps: 0.0 }
    }

    /// Feed one goodput observation (payload bits / measured tx seconds).
    pub fn observe(&mut self, mbps: f64) -> f64 {
        self.last_mbps = self.ewma.update(mbps);
        self.last_mbps
    }

    /// Current estimate in Mbps (0 until the first observation).
    pub fn estimate_mbps(&self) -> f64 {
        self.ewma.get().unwrap_or(self.last_mbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_tracks_step_change() {
        let mut e = BandwidthEstimator::new(0.3);
        for _ in 0..50 {
            e.observe(16.0);
        }
        assert!((e.estimate_mbps() - 16.0).abs() < 0.1);
        for _ in 0..50 {
            e.observe(9.0);
        }
        assert!((e.estimate_mbps() - 9.0).abs() < 0.1);
    }
}
