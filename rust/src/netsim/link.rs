//! Link model: turns wire bytes into virtual-time transmission delay under
//! the current trace bandwidth, with optional jitter and loss.
//!
//! The **wire model** applies the paper's payload scale: our mini-LISA
//! tensors are ~1000x smaller than LISA-7B's (10.49 MB SAM activation), so
//! packets carry a `wire_bytes` field set from the paper's Table 3 payload
//! sizes (2.92 / 1.35 / 0.83 MB per tier).  Transmission delay is computed
//! from `wire_bytes`, which puts every feasibility crossover (e.g. the
//! High-Accuracy tier needing >= 11.68 Mbps at 0.5 PPS) exactly where the
//! paper has it.  See DESIGN.md "Substitutions" #4.

use crate::util::Rng;

use super::trace::BandwidthTrace;

#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// Multiplicative jitter stddev on each transmission (0 = none).
    pub jitter_std: f64,
    /// Packet loss probability per transmission (lost packets are
    /// retransmitted once; a second loss drops the packet).
    pub loss_prob: f64,
    /// Fixed per-attempt latency added on top of the bandwidth-derived
    /// transfer time (propagation / satellite RTT; scenario knob, default 0).
    pub extra_latency_s: f64,
    pub seed: u64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        Self { jitter_std: 0.03, loss_prob: 0.0, extra_latency_s: 0.0, seed: 1 }
    }
}

/// Outcome of one simulated transmission.
#[derive(Clone, Copy, Debug)]
pub struct TxOutcome {
    /// Seconds of virtual time the transfer occupied the uplink.
    pub tx_secs: f64,
    /// Goodput observed by the sender (Mbps) — feeds the Sense estimator.
    pub goodput_mbps: f64,
    /// Whether the packet was ultimately delivered.
    pub delivered: bool,
    /// Number of transmission attempts (1 or 2).
    pub attempts: u32,
}

/// A simulated uplink bound to a bandwidth trace and a virtual clock.
#[derive(Clone, Debug)]
pub struct Link {
    trace: BandwidthTrace,
    cfg: LinkConfig,
    rng: Rng,
}

impl Link {
    pub fn new(trace: BandwidthTrace, cfg: LinkConfig) -> Self {
        let seed = cfg.seed;
        Self { trace, cfg, rng: Rng::new(seed) }
    }

    pub fn trace(&self) -> &BandwidthTrace {
        &self.trace
    }

    /// Ground-truth bandwidth at virtual time `t`.
    pub fn bandwidth_at(&self, t: f64) -> f64 {
        self.trace.at(t)
    }

    /// Transmit `wire_bytes` starting at virtual time `t`.
    ///
    /// Delay integrates the trace across the transfer: long transfers that
    /// straddle a bandwidth change pay the changed rate for the remainder,
    /// which is what makes the High-Accuracy baseline "collapse" when the
    /// trace drops mid-mission (paper Fig 9(d)).
    pub fn transmit(&mut self, t: f64, wire_bytes: f64) -> TxOutcome {
        let mut attempts = 1u32;
        let mut total_secs = self.transfer_secs(t, wire_bytes) + self.cfg.extra_latency_s;
        let mut delivered = true;
        if self.cfg.loss_prob > 0.0 && self.rng.f64() < self.cfg.loss_prob {
            attempts = 2;
            let retry_secs =
                self.transfer_secs(t + total_secs, wire_bytes) + self.cfg.extra_latency_s;
            if self.rng.f64() < self.cfg.loss_prob {
                delivered = false;
            }
            total_secs += retry_secs;
        }
        let goodput = if total_secs > 0.0 {
            wire_bytes * 8.0 / 1e6 / total_secs
        } else {
            f64::INFINITY
        };
        TxOutcome { tx_secs: total_secs, goodput_mbps: goodput, delivered, attempts }
    }

    /// Integrate the trace to find how long `wire_bytes` takes from time `t`.
    fn transfer_secs(&mut self, t: f64, wire_bytes: f64) -> f64 {
        let jitter = 1.0 + self.cfg.jitter_std * self.rng.normal();
        let mut bits = wire_bytes * 8.0 * jitter.max(0.5);
        let mut now = t;
        let mut secs = 0.0;
        // Step at trace resolution; cap pathological transfers at 10 minutes.
        for _ in 0..6000 {
            let bw_bps = self.trace.at(now) * 1e6;
            let step = self.trace.dt.min(1.0);
            let can = bw_bps * step;
            if bits <= can {
                secs += bits / bw_bps;
                return secs;
            }
            bits -= can;
            secs += step;
            now += step;
        }
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::trace::{BandwidthTrace, TraceConfig};

    fn flat_trace(mbps: f64, secs: usize) -> BandwidthTrace {
        BandwidthTrace { dt: 1.0, samples_mbps: vec![mbps; secs] }
    }

    #[test]
    fn delay_matches_bandwidth() {
        let mut link = Link::new(
            flat_trace(11.68, 600),
            LinkConfig { jitter_std: 0.0, loss_prob: 0.0, seed: 1, ..LinkConfig::default() },
        );
        // Paper: High-Accuracy 2.92 MB at 11.68 Mbps => exactly 0.5 PPS.
        let out = link.transmit(0.0, 2.92e6);
        assert!((out.tx_secs - 2.0).abs() < 1e-6, "tx {}", out.tx_secs);
        assert!((out.goodput_mbps - 11.68).abs() < 1e-6);
    }

    #[test]
    fn straddling_a_drop_slows_transfer() {
        let mut samples = vec![20.0; 2];
        samples.extend(vec![8.0; 600]);
        let trace = BandwidthTrace { dt: 1.0, samples_mbps: samples };
        let mut link = Link::new(
            trace,
            LinkConfig { jitter_std: 0.0, loss_prob: 0.0, seed: 1, ..LinkConfig::default() },
        );
        // 10 MB from t=0: 2 s at 20 Mbps moves 5 MB, the rest at 8 Mbps.
        let out = link.transmit(0.0, 10e6);
        let expect = 2.0 + (10e6 * 8.0 - 2.0 * 20e6) / 8e6;
        assert!((out.tx_secs - expect).abs() < 1e-6, "tx {}", out.tx_secs);
    }

    #[test]
    fn loss_triggers_retry() {
        let mut link = Link::new(
            flat_trace(10.0, 600),
            LinkConfig { jitter_std: 0.0, loss_prob: 1.0, seed: 2, ..LinkConfig::default() },
        );
        let out = link.transmit(0.0, 1e6);
        assert_eq!(out.attempts, 2);
        assert!(!out.delivered); // loss_prob 1.0 drops the retry too
    }

    #[test]
    fn extra_latency_slows_every_attempt() {
        let mut link = Link::new(
            flat_trace(11.68, 600),
            LinkConfig {
                jitter_std: 0.0,
                loss_prob: 0.0,
                extra_latency_s: 0.25,
                seed: 1,
            },
        );
        let out = link.transmit(0.0, 2.92e6);
        assert!((out.tx_secs - 2.25).abs() < 1e-6, "tx {}", out.tx_secs);
        // Goodput reflects the added latency (sender-observed).
        assert!(out.goodput_mbps < 11.68);
    }

    #[test]
    fn paper_trace_transfers_complete() {
        let tr = BandwidthTrace::generate(&TraceConfig::paper_20min(5));
        let mut link = Link::new(tr, LinkConfig::default());
        let out = link.transmit(300.0, 2.92e6);
        assert!(out.tx_secs > 0.5 && out.tx_secs < 5.0, "tx {}", out.tx_secs);
    }
}
