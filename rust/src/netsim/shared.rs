//! Contended multi-UAV uplink: N UAVs share the scripted disaster-zone
//! bandwidth trace, each receiving a fair share of the instantaneous rate
//! (see DESIGN.md "Fleet subsystem" for the contention model).
//!
//! The model is processor-sharing at trace resolution: while k transfers
//! overlap at time t, each progresses at `trace(t) / k`.  A transfer's
//! duration is integrated step-by-step against the *current* set of
//! concurrent transfers, so a UAV that starts uploading while two others are
//! mid-transfer pays a third of the trace rate until they drain.  Each
//! controller therefore senses *its slice* of the uplink (through goodput
//! feedback and probes) and adapts to fleet load exactly as it adapts to
//! trace dynamics — no explicit coordination channel exists between UAVs,
//! matching AVERY's self-aware, decentralized controller design.
//!
//! Determinism: every UAV owns an independent xorshift stream seeded from
//! `(seed, uav_id)`, so outcomes depend only on the (deterministic)
//! event order of the fleet scheduler, never on wall-clock interleaving.

use crate::util::Rng;

use super::link::{LinkConfig, TxOutcome};
use super::trace::BandwidthTrace;

/// One (possibly already drained) transfer on the shared uplink.  Drained
/// transfers are retained for [`HISTORY_SECS`] so `share_at` can answer
/// *historical* queries — agents backfill per-second epoch telemetry for
/// times inside their last multi-second cycle.
#[derive(Clone, Copy, Debug)]
struct InFlight {
    uav: usize,
    /// Virtual time the transfer started occupying the uplink.
    from: f64,
    /// Virtual time at which this transfer releases its share.
    until: f64,
}

/// How long drained transfers stay queryable (far beyond any single cycle).
const HISTORY_SECS: f64 = 64.0;

/// A contended uplink shared by a fleet of UAVs.
#[derive(Clone, Debug)]
pub struct SharedLink {
    trace: BandwidthTrace,
    cfg: LinkConfig,
    /// Per-UAV jitter/loss RNG streams (index = uav id).
    rngs: Vec<Rng>,
    inflight: Vec<InFlight>,
}

impl SharedLink {
    pub fn new(trace: BandwidthTrace, cfg: LinkConfig, n_uavs: usize) -> Self {
        let rngs = (0..n_uavs)
            .map(|i| Rng::new(cfg.seed ^ (0xF1EE7 + i as u64).wrapping_mul(0x9E37)))
            .collect();
        Self { trace, cfg, rngs, inflight: Vec::new() }
    }

    pub fn trace(&self) -> &BandwidthTrace {
        &self.trace
    }

    /// Number of transfers (other than `uav`'s own) occupying the uplink at
    /// virtual time `t` — answers historical `t` within [`HISTORY_SECS`].
    fn others_active(&self, uav: usize, t: f64) -> usize {
        self.inflight
            .iter()
            .filter(|f| f.uav != uav && f.from <= t && f.until > t)
            .count()
    }

    /// Drop transfers that drained more than [`HISTORY_SECS`] before `t`.
    fn reap(&mut self, t: f64) {
        self.inflight.retain(|f| f.until > t - HISTORY_SECS);
    }

    /// Ground-truth fair share `uav` received (or would receive) at `t`
    /// (Mbps) — the quantity its probe senses; also valid for recent past
    /// times, which epoch-telemetry backfill relies on.
    pub fn share_at(&self, uav: usize, t: f64) -> f64 {
        let n = 1 + self.others_active(uav, t);
        self.trace.at(t) / n as f64
    }

    /// Full (uncontended) trace bandwidth at `t` — telemetry only.
    pub fn bandwidth_at(&self, t: f64) -> f64 {
        self.trace.at(t)
    }

    /// Transmit `wire_bytes` for `uav` starting at virtual time `t`,
    /// sharing the trace rate with every concurrent transfer.
    pub fn transmit(&mut self, uav: usize, t: f64, wire_bytes: f64) -> TxOutcome {
        self.reap(t);
        let mut attempts = 1u32;
        // Air time (bits on the channel) and propagation latency are
        // tracked separately: only air time registers as fair-share
        // occupancy — a satellite RTT delays the sender without denying
        // bandwidth to anyone else.
        let air_secs = self.transfer_secs(uav, t, wire_bytes);
        let mut total_secs = air_secs + self.cfg.extra_latency_s;
        let mut delivered = true;
        let loss = self.cfg.loss_prob;
        self.inflight.push(InFlight { uav, from: t, until: t + air_secs });
        if loss > 0.0 && self.rngs[uav].f64() < loss {
            attempts = 2;
            // The retry goes on the air only after the first attempt's
            // propagation delay elapses — its occupancy window starts where
            // its bandwidth integration starts.
            let retry_from = t + total_secs;
            let retry = self.transfer_secs(uav, retry_from, wire_bytes);
            if self.rngs[uav].f64() < loss {
                delivered = false;
            }
            self.inflight.push(InFlight { uav, from: retry_from, until: retry_from + retry });
            total_secs += retry + self.cfg.extra_latency_s;
        }
        let goodput = if total_secs > 0.0 {
            wire_bytes * 8.0 / 1e6 / total_secs
        } else {
            f64::INFINITY
        };
        TxOutcome { tx_secs: total_secs, goodput_mbps: goodput, delivered, attempts }
    }

    /// Integrate the fair-share rate to find how long `wire_bytes` takes
    /// from `t`.  Concurrent transfers are frozen at their current
    /// deadlines during the integration (they were sized under the load
    /// they observed when they started) — a first-order processor-sharing
    /// approximation that stays deterministic under event ordering.
    fn transfer_secs(&mut self, uav: usize, t: f64, wire_bytes: f64) -> f64 {
        let jitter = 1.0 + self.cfg.jitter_std * self.rngs[uav].normal();
        let mut bits = wire_bytes * 8.0 * jitter.max(0.5);
        let mut now = t;
        let mut secs = 0.0;
        // Step at trace resolution; cap pathological transfers at 10 minutes
        // of occupancy (mirrors Link::transfer_secs).
        for _ in 0..6000 {
            let n = 1 + self.others_active(uav, now);
            let bw_bps = self.trace.at(now) * 1e6 / n as f64;
            let step = self.trace.dt.min(1.0);
            let can = bw_bps * step;
            if bits <= can {
                secs += bits / bw_bps;
                return secs;
            }
            bits -= can;
            secs += step;
            now += step;
        }
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::trace::BandwidthTrace;

    fn flat_trace(mbps: f64, secs: usize) -> BandwidthTrace {
        BandwidthTrace { dt: 1.0, samples_mbps: vec![mbps; secs] }
    }

    fn quiet_cfg(seed: u64) -> LinkConfig {
        LinkConfig { jitter_std: 0.0, loss_prob: 0.0, seed, ..LinkConfig::default() }
    }

    #[test]
    fn single_uav_matches_unshared_link() {
        let mut shared = SharedLink::new(flat_trace(11.68, 600), quiet_cfg(1), 1);
        // Same arithmetic as Link: 2.92 MB at 11.68 Mbps => 2.0 s.
        let out = shared.transmit(0, 0.0, 2.92e6);
        assert!((out.tx_secs - 2.0).abs() < 1e-6, "tx {}", out.tx_secs);
        assert!((out.goodput_mbps - 11.68).abs() < 1e-6);
    }

    #[test]
    fn two_overlapping_transfers_halve_the_rate() {
        let mut shared = SharedLink::new(flat_trace(16.0, 600), quiet_cfg(1), 2);
        let a = shared.transmit(0, 0.0, 2e6); // alone: 1 s at 16 Mbps
        assert!((a.tx_secs - 1.0).abs() < 1e-6);
        // UAV 1 starts while UAV 0 is mid-transfer: it shares 8 Mbps for the
        // first trace-resolution step from its start, then gets the full
        // 16 Mbps — 1 s at 8 Mbps moves 1 MB, the last 1 MB takes 0.5 s.
        let b = shared.transmit(1, 0.5, 2e6);
        assert!((b.tx_secs - 1.5).abs() < 1e-6, "tx {}", b.tx_secs);
    }

    #[test]
    fn share_at_counts_other_transfers() {
        let mut shared = SharedLink::new(flat_trace(12.0, 600), quiet_cfg(1), 3);
        assert!((shared.share_at(0, 0.0) - 12.0).abs() < 1e-9);
        shared.transmit(1, 0.0, 3e6); // occupies [0, 2)
        assert!((shared.share_at(0, 1.0) - 6.0).abs() < 1e-9);
        // After it drains, the full rate returns (the drained transfer stays
        // in history for past-time queries but is not active at t=5).
        assert!((shared.share_at(0, 5.0) - 12.0).abs() < 1e-9);
        // Historical query: the share UAV 0 saw mid-transfer stays queryable.
        shared.transmit(0, 4.0, 1e6);
        assert!((shared.share_at(0, 1.0) - 6.0).abs() < 1e-9);
        // The transmitting UAV itself is the implicit +1, never doubled.
        assert!((shared.share_at(1, 1.0) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn reap_keeps_history_strictly_inside_the_window() {
        let mut shared = SharedLink::new(flat_trace(12.0, 600), quiet_cfg(1), 2);
        shared.transmit(1, 0.0, 3e6); // occupies [0, 2)
        // Just inside the window: a reap shy of drain + HISTORY_SECS keeps
        // the record, so the historical share is still answerable.
        shared.reap(2.0 + HISTORY_SECS - 0.01);
        assert!((shared.share_at(0, 1.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn reap_drops_history_exactly_at_the_boundary() {
        let mut shared = SharedLink::new(flat_trace(12.0, 600), quiet_cfg(1), 2);
        shared.transmit(1, 0.0, 3e6); // drains at until = 2.0
        // Exactly HISTORY_SECS after the drain, the retain predicate
        // `until > t - HISTORY_SECS` (strict) evicts the record: the
        // historical query now sees an uncontended channel.
        shared.reap(2.0 + HISTORY_SECS);
        assert!((shared.share_at(0, 1.0) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn drained_transfer_inactive_at_its_drain_instant() {
        let mut shared = SharedLink::new(flat_trace(12.0, 600), quiet_cfg(1), 2);
        shared.transmit(1, 0.0, 3e6); // occupies the half-open [0, 2)
        // At exactly t = 2.0 the occupancy is over (`until > t` is strict)
        // even though the record is retained for past-time queries...
        assert!((shared.share_at(0, 2.0) - 12.0).abs() < 1e-9);
        assert!((shared.share_at(0, 2.0 - 1e-9) - 6.0).abs() < 1e-9);
        // ...and `from <= t` is inclusive: occupancy starts at the start.
        assert!((shared.share_at(0, 0.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn transmit_reaps_stale_history() {
        let mut shared = SharedLink::new(flat_trace(12.0, 600), quiet_cfg(1), 3);
        shared.transmit(1, 0.0, 3e6); // occupies [0, 2)
        // A transmit at drain + HISTORY_SECS reaps the stale record before
        // registering its own occupancy window.
        shared.transmit(2, 2.0 + HISTORY_SECS, 3e6);
        assert!((shared.share_at(0, 1.0) - 12.0).abs() < 1e-9);
        assert!((shared.share_at(0, 2.0 + HISTORY_SECS + 1.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn extra_latency_delays_sender_without_occupying_the_channel() {
        let mut shared = SharedLink::new(
            flat_trace(16.0, 600),
            LinkConfig {
                jitter_std: 0.0,
                loss_prob: 0.0,
                extra_latency_s: 0.5,
                seed: 1,
            },
            2,
        );
        // 2 MB at 16 Mbps = 1 s of air time + 0.5 s propagation.
        let out = shared.transmit(0, 0.0, 2e6);
        assert!((out.tx_secs - 1.5).abs() < 1e-6, "tx {}", out.tx_secs);
        // While bits are on the air the other UAV shares the channel...
        assert!((shared.share_at(1, 0.5) - 8.0).abs() < 1e-9);
        // ...but pure propagation time does not count as occupancy.
        assert!((shared.share_at(1, 1.2) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_seed_and_order() {
        let run = |seed: u64| {
            let mut s = SharedLink::new(
                flat_trace(14.0, 600),
                LinkConfig { jitter_std: 0.03, loss_prob: 0.0, seed, ..LinkConfig::default() },
                4,
            );
            let mut out = Vec::new();
            for k in 0..12 {
                out.push(s.transmit(k % 4, k as f64 * 0.7, 1.5e6).tx_secs);
            }
            out
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn contention_slows_late_arrivals() {
        // 4 UAVs starting together (processor-sharing sizes each transfer
        // against the load visible when it starts, so the k-th arrival sees
        // k-1 concurrent transfers): later arrivals pay progressively more,
        // and the fleet average is well above the solo time.
        let mut shared = SharedLink::new(flat_trace(16.0, 600), quiet_cfg(2), 4);
        let solo = {
            let mut one = SharedLink::new(flat_trace(16.0, 600), quiet_cfg(2), 1);
            one.transmit(0, 0.0, 2e6).tx_secs
        };
        let times: Vec<f64> =
            (0..4).map(|u| shared.transmit(u, 0.0, 2e6).tx_secs).collect();
        for w in times.windows(2) {
            assert!(w[1] > w[0], "arrival order not reflected: {times:?}");
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        assert!(mean > solo * 1.5, "mean {mean} vs solo {solo}");
    }
}
