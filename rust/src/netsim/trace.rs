//! Scripted bandwidth trace generator (paper §5.3.1) plus the richer
//! regime kinds the scenario library layers on top.
//!
//! A trace is a sequence of phases; each phase has a kind that controls how
//! bandwidth evolves second-by-second:
//! * `Stable`   — small jitter around a level,
//! * `Volatile` — large random-walk swings (clamped to the global range),
//! * `Drop`     — a sustained fall to a low level, held, then recovery,
//! * `Outage`   — full blackout: bandwidth collapses to a near-zero floor
//!   (exempt from the `min_mbps` clamp; never below 0.01 Mbps so in-flight
//!   transfers stall rather than divide by zero),
//! * `Sawtooth` — satellite-handoff pattern: bandwidth ramps linearly from
//!   the ceiling down to the phase level as the satellite sinks toward the
//!   horizon, then snaps back on handoff (five handoffs per phase).
//!
//! The default 20-minute script mirrors the paper's: stable opening,
//! volatility in the middle, two sustained drops (one dipping below the
//! High-Accuracy tier's 11.68 Mbps feasibility threshold so the controller
//! demonstrably switches to Balanced), and a stable tail.  The scenario
//! library (`crate::scenario`) composes the other kinds into named disaster
//! regimes, including Markov-modulated regime switching
//! ([`TraceConfig::markov_modulated`]).

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    Stable,
    Volatile,
    Drop,
    Outage,
    Sawtooth,
}

/// Bandwidth floor during an [`PhaseKind::Outage`] phase (Mbps).  Strictly
/// positive so transfer integration always terminates; low enough that no
/// Insight tier is feasible (High-Throughput needs 3.32 Mbps at 0.5 PPS).
pub const OUTAGE_FLOOR_MBPS: f64 = 0.01;

/// Handoffs (ramp resets) per Sawtooth phase.
const SAWTOOTH_HANDOFFS: f64 = 5.0;

#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub kind: PhaseKind,
    /// Duration in seconds (virtual time).
    pub secs: f64,
    /// Anchor level in Mbps (for Drop: the floor reached).
    pub level_mbps: f64,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub phases: Vec<Phase>,
    /// Global clamp range (paper: 8–20 Mbps).
    pub min_mbps: f64,
    pub max_mbps: f64,
    /// Trace sampling resolution in seconds.
    pub dt: f64,
    pub seed: u64,
}

impl TraceConfig {
    /// The paper's 20-minute disaster-zone script.
    pub fn paper_20min(seed: u64) -> Self {
        Self {
            phases: vec![
                Phase { kind: PhaseKind::Stable, secs: 180.0, level_mbps: 17.0 },
                Phase { kind: PhaseKind::Volatile, secs: 240.0, level_mbps: 14.0 },
                Phase { kind: PhaseKind::Drop, secs: 150.0, level_mbps: 8.5 },
                Phase { kind: PhaseKind::Stable, secs: 120.0, level_mbps: 16.0 },
                Phase { kind: PhaseKind::Drop, secs: 180.0, level_mbps: 9.5 },
                Phase { kind: PhaseKind::Volatile, secs: 180.0, level_mbps: 13.0 },
                Phase { kind: PhaseKind::Stable, secs: 150.0, level_mbps: 18.0 },
            ],
            min_mbps: 8.0,
            max_mbps: 20.0,
            dt: 1.0,
            seed,
        }
    }

    pub fn total_secs(&self) -> f64 {
        self.phases.iter().map(|p| p.secs).sum()
    }

    /// Rescale every phase so the script spans `duration_secs` (the pattern
    /// every driver used inline before the scenario library needed it too).
    pub fn scaled_to(mut self, duration_secs: f64) -> Self {
        let total = self.total_secs();
        if total > 0.0 && (duration_secs - total).abs() > 1e-9 {
            let k = duration_secs / total;
            for p in &mut self.phases {
                p.secs *= k;
            }
        }
        self
    }

    /// `(start_sec, end_sec, kind)` for every phase, in script order.
    pub fn phase_windows(&self) -> Vec<(f64, f64, PhaseKind)> {
        let mut t = 0.0;
        self.phases
            .iter()
            .map(|p| {
                let w = (t, t + p.secs, p.kind);
                t += p.secs;
                w
            })
            .collect()
    }

    /// Markov-modulated regime switching: dwell in one regime kind for a
    /// random 0.5–1.5× of `mean_dwell_secs`, then hop to a different kind
    /// (uniform over the others — a symmetric transition matrix with no
    /// self-loops).  Anchor levels are drawn per-regime from kind-specific
    /// bands of the `[min_mbps, max_mbps]` range.  Fully deterministic in
    /// `seed`; the phase count is the trace's "regime switch count".
    pub fn markov_modulated(
        seed: u64,
        duration_secs: f64,
        min_mbps: f64,
        max_mbps: f64,
        mean_dwell_secs: f64,
        kinds: &[PhaseKind],
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x4D41524B_4F56u64); // "MARKOV"
        let mut phases = Vec::new();
        if kinds.is_empty() {
            // Degenerate but total: an empty regime set yields an empty
            // script (generate() then returns an empty trace).
            return Self { phases, min_mbps, max_mbps, dt: 1.0, seed };
        }
        let mut ki = 0usize;
        let mut t = 0.0;
        while t < duration_secs {
            let kind = kinds[ki % kinds.len()];
            let rem = duration_secs - t;
            // Floor of one second so a zero/tiny mean dwell still advances
            // the clock (the loop must terminate for any input).
            let mut dwell = (mean_dwell_secs * (0.5 + rng.f64())).max(1.0);
            // Absorb a short tail into the final regime.
            if rem - dwell < 2.0 {
                dwell = rem;
            }
            let level_mbps = match kind {
                PhaseKind::Stable => min_mbps + (max_mbps - min_mbps) * rng.range(0.6, 0.95),
                PhaseKind::Volatile => min_mbps + (max_mbps - min_mbps) * rng.range(0.4, 0.8),
                PhaseKind::Drop => min_mbps + (max_mbps - min_mbps) * rng.range(0.0, 0.15),
                PhaseKind::Outage => OUTAGE_FLOOR_MBPS,
                PhaseKind::Sawtooth => min_mbps + (max_mbps - min_mbps) * rng.range(0.0, 0.3),
            };
            phases.push(Phase { kind, secs: dwell, level_mbps });
            t += dwell;
            if kinds.len() > 1 {
                ki = (ki + 1 + rng.below(kinds.len() - 1)) % kinds.len();
            }
        }
        Self { phases, min_mbps, max_mbps, dt: 1.0, seed }
    }
}

/// A fully materialized trace: bandwidth (Mbps) sampled every `dt` seconds.
#[derive(Clone, Debug)]
pub struct BandwidthTrace {
    pub dt: f64,
    pub samples_mbps: Vec<f64>,
}

impl BandwidthTrace {
    pub fn generate(cfg: &TraceConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut samples = Vec::new();
        let mut level = cfg.phases.first().map(|p| p.level_mbps).unwrap_or(15.0);
        for phase in &cfg.phases {
            let n = (phase.secs / cfg.dt).round() as usize;
            match phase.kind {
                PhaseKind::Stable => {
                    // Ease toward the anchor, then jitter +-0.4 Mbps.
                    for i in 0..n {
                        let pull = (phase.level_mbps - level) * 0.2;
                        level += pull + rng.normal() * 0.25;
                        level = level.clamp(cfg.min_mbps, cfg.max_mbps);
                        let _ = i;
                        samples.push(level);
                    }
                }
                PhaseKind::Volatile => {
                    for _ in 0..n {
                        let pull = (phase.level_mbps - level) * 0.05;
                        level += pull + rng.normal() * 1.4;
                        level = level.clamp(cfg.min_mbps, cfg.max_mbps);
                        samples.push(level);
                    }
                }
                PhaseKind::Outage => {
                    // Blackout: collapse to the floor immediately; tiny
                    // positive jitter so the floor is never exactly constant.
                    let floor = phase.level_mbps.max(OUTAGE_FLOOR_MBPS);
                    for _ in 0..n {
                        level = (floor + rng.f64() * 0.02)
                            .clamp(OUTAGE_FLOOR_MBPS, cfg.max_mbps);
                        samples.push(level);
                    }
                }
                PhaseKind::Sawtooth => {
                    // Satellite pass: ramp from the ceiling down to the phase
                    // level, snap back on handoff.  Five handoffs per phase.
                    let period = (phase.secs / SAWTOOTH_HANDOFFS).max(cfg.dt);
                    for i in 0..n {
                        let pos = ((i as f64 * cfg.dt) % period) / period;
                        let v = cfg.max_mbps + (phase.level_mbps - cfg.max_mbps) * pos;
                        level = (v + rng.normal() * 0.2).clamp(cfg.min_mbps, cfg.max_mbps);
                        samples.push(level);
                    }
                }
                PhaseKind::Drop => {
                    // Fall over the first quarter, hold at the floor for half,
                    // recover over the last quarter.
                    let fall = n / 4;
                    let hold = n / 2;
                    let start = level;
                    for i in 0..n {
                        level = if i < fall {
                            start + (phase.level_mbps - start) * (i as f64 / fall.max(1) as f64)
                        } else if i < fall + hold {
                            phase.level_mbps + rng.normal() * 0.2
                        } else {
                            let k = (i - fall - hold) as f64 / (n - fall - hold).max(1) as f64;
                            phase.level_mbps + (start - phase.level_mbps) * k
                        };
                        level = level.clamp(cfg.min_mbps, cfg.max_mbps);
                        samples.push(level);
                    }
                }
            }
        }
        BandwidthTrace { dt: cfg.dt, samples_mbps: samples }
    }

    /// Ground-truth bandwidth at virtual time `t` seconds.
    pub fn at(&self, t: f64) -> f64 {
        if self.samples_mbps.is_empty() {
            return 0.0;
        }
        let idx = ((t / self.dt) as usize).min(self.samples_mbps.len() - 1);
        self.samples_mbps[idx]
    }

    pub fn duration_secs(&self) -> f64 {
        self.samples_mbps.len() as f64 * self.dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_trace_is_20min_and_bounded() {
        let cfg = TraceConfig::paper_20min(7);
        assert!((cfg.total_secs() - 1200.0).abs() < 1e-9);
        let tr = BandwidthTrace::generate(&cfg);
        assert_eq!(tr.samples_mbps.len(), 1200);
        for &b in &tr.samples_mbps {
            assert!((8.0..=20.0).contains(&b), "bandwidth {b} out of range");
        }
    }

    #[test]
    fn trace_deterministic_per_seed() {
        let a = BandwidthTrace::generate(&TraceConfig::paper_20min(3));
        let b = BandwidthTrace::generate(&TraceConfig::paper_20min(3));
        assert_eq!(a.samples_mbps, b.samples_mbps);
        let c = BandwidthTrace::generate(&TraceConfig::paper_20min(4));
        assert_ne!(a.samples_mbps, c.samples_mbps);
    }

    #[test]
    fn drop_phase_reaches_floor() {
        let tr = BandwidthTrace::generate(&TraceConfig::paper_20min(7));
        // First drop phase spans [420, 570): must dip below 11.68 Mbps (the
        // High-Accuracy feasibility threshold) so Fig 9 shows a tier switch.
        let min_in_drop = tr.samples_mbps[440..560]
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(min_in_drop < 11.68, "drop floor {min_in_drop}");
    }

    #[test]
    fn at_clamps_past_end() {
        let tr = BandwidthTrace::generate(&TraceConfig::paper_20min(7));
        assert_eq!(tr.at(1e9), *tr.samples_mbps.last().unwrap());
    }

    #[test]
    fn scaled_to_preserves_structure() {
        let cfg = TraceConfig::paper_20min(7).scaled_to(120.0);
        assert!((cfg.total_secs() - 120.0).abs() < 1e-9);
        assert_eq!(cfg.phases.len(), 7);
        assert_eq!(cfg.phases[0].kind, PhaseKind::Stable);
    }

    #[test]
    fn outage_phase_collapses_below_min() {
        let cfg = TraceConfig {
            phases: vec![
                Phase { kind: PhaseKind::Stable, secs: 30.0, level_mbps: 16.0 },
                Phase { kind: PhaseKind::Outage, secs: 30.0, level_mbps: 0.05 },
                Phase { kind: PhaseKind::Stable, secs: 30.0, level_mbps: 16.0 },
            ],
            min_mbps: 8.0,
            max_mbps: 20.0,
            dt: 1.0,
            seed: 3,
        };
        let tr = BandwidthTrace::generate(&cfg);
        let blackout = &tr.samples_mbps[30..60];
        assert!(blackout.iter().all(|&b| b < 1.0), "outage not dark: {blackout:?}");
        assert!(blackout.iter().all(|&b| b >= OUTAGE_FLOOR_MBPS));
        // Non-outage samples still respect the global clamp.
        assert!(tr.samples_mbps[..30].iter().all(|&b| (8.0..=20.0).contains(&b)));
    }

    #[test]
    fn sawtooth_ramps_and_resets() {
        let cfg = TraceConfig {
            phases: vec![Phase { kind: PhaseKind::Sawtooth, secs: 100.0, level_mbps: 9.0 }],
            min_mbps: 8.0,
            max_mbps: 20.0,
            dt: 1.0,
            seed: 5,
        };
        let tr = BandwidthTrace::generate(&cfg);
        // 5 handoffs over 100 s => 20 s period.  Sample just before and just
        // after a reset boundary: the snap-back must be large and positive.
        let before = tr.samples_mbps[19];
        let after = tr.samples_mbps[20];
        assert!(after - before > 5.0, "no handoff snap: {before} -> {after}");
        assert!(tr.samples_mbps.iter().all(|&b| (8.0..=20.0).contains(&b)));
    }

    #[test]
    fn markov_modulated_deterministic_and_covers_duration() {
        let kinds = [PhaseKind::Stable, PhaseKind::Volatile, PhaseKind::Drop];
        let a = TraceConfig::markov_modulated(9, 600.0, 8.0, 20.0, 60.0, &kinds);
        let b = TraceConfig::markov_modulated(9, 600.0, 8.0, 20.0, 60.0, &kinds);
        assert_eq!(a.phases.len(), b.phases.len());
        assert!((a.total_secs() - 600.0).abs() < 1e-6);
        assert_eq!(
            BandwidthTrace::generate(&a).samples_mbps,
            BandwidthTrace::generate(&b).samples_mbps
        );
        let c = TraceConfig::markov_modulated(10, 600.0, 8.0, 20.0, 60.0, &kinds);
        assert_ne!(
            BandwidthTrace::generate(&a).samples_mbps,
            BandwidthTrace::generate(&c).samples_mbps
        );
        // No self-loops: consecutive regimes always differ in kind.
        for w in a.phases.windows(2) {
            assert_ne!(w[0].kind, w[1].kind);
        }
    }
}
