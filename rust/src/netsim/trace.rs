//! Scripted bandwidth trace generator (paper §5.3.1).
//!
//! A trace is a sequence of phases; each phase has a kind that controls how
//! bandwidth evolves second-by-second:
//! * `Stable`   — small jitter around a level,
//! * `Volatile` — large random-walk swings (clamped to the global range),
//! * `Drop`     — a sustained fall to a low level, held, then recovery.
//!
//! The default 20-minute script mirrors the paper's: stable opening,
//! volatility in the middle, two sustained drops (one dipping below the
//! High-Accuracy tier's 11.68 Mbps feasibility threshold so the controller
//! demonstrably switches to Balanced), and a stable tail.

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    Stable,
    Volatile,
    Drop,
}

#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub kind: PhaseKind,
    /// Duration in seconds (virtual time).
    pub secs: f64,
    /// Anchor level in Mbps (for Drop: the floor reached).
    pub level_mbps: f64,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub phases: Vec<Phase>,
    /// Global clamp range (paper: 8–20 Mbps).
    pub min_mbps: f64,
    pub max_mbps: f64,
    /// Trace sampling resolution in seconds.
    pub dt: f64,
    pub seed: u64,
}

impl TraceConfig {
    /// The paper's 20-minute disaster-zone script.
    pub fn paper_20min(seed: u64) -> Self {
        Self {
            phases: vec![
                Phase { kind: PhaseKind::Stable, secs: 180.0, level_mbps: 17.0 },
                Phase { kind: PhaseKind::Volatile, secs: 240.0, level_mbps: 14.0 },
                Phase { kind: PhaseKind::Drop, secs: 150.0, level_mbps: 8.5 },
                Phase { kind: PhaseKind::Stable, secs: 120.0, level_mbps: 16.0 },
                Phase { kind: PhaseKind::Drop, secs: 180.0, level_mbps: 9.5 },
                Phase { kind: PhaseKind::Volatile, secs: 180.0, level_mbps: 13.0 },
                Phase { kind: PhaseKind::Stable, secs: 150.0, level_mbps: 18.0 },
            ],
            min_mbps: 8.0,
            max_mbps: 20.0,
            dt: 1.0,
            seed,
        }
    }

    pub fn total_secs(&self) -> f64 {
        self.phases.iter().map(|p| p.secs).sum()
    }
}

/// A fully materialized trace: bandwidth (Mbps) sampled every `dt` seconds.
#[derive(Clone, Debug)]
pub struct BandwidthTrace {
    pub dt: f64,
    pub samples_mbps: Vec<f64>,
}

impl BandwidthTrace {
    pub fn generate(cfg: &TraceConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut samples = Vec::new();
        let mut level = cfg.phases.first().map(|p| p.level_mbps).unwrap_or(15.0);
        for phase in &cfg.phases {
            let n = (phase.secs / cfg.dt).round() as usize;
            match phase.kind {
                PhaseKind::Stable => {
                    // Ease toward the anchor, then jitter +-0.4 Mbps.
                    for i in 0..n {
                        let pull = (phase.level_mbps - level) * 0.2;
                        level += pull + rng.normal() * 0.25;
                        level = level.clamp(cfg.min_mbps, cfg.max_mbps);
                        let _ = i;
                        samples.push(level);
                    }
                }
                PhaseKind::Volatile => {
                    for _ in 0..n {
                        let pull = (phase.level_mbps - level) * 0.05;
                        level += pull + rng.normal() * 1.4;
                        level = level.clamp(cfg.min_mbps, cfg.max_mbps);
                        samples.push(level);
                    }
                }
                PhaseKind::Drop => {
                    // Fall over the first quarter, hold at the floor for half,
                    // recover over the last quarter.
                    let fall = n / 4;
                    let hold = n / 2;
                    let start = level;
                    for i in 0..n {
                        level = if i < fall {
                            start + (phase.level_mbps - start) * (i as f64 / fall.max(1) as f64)
                        } else if i < fall + hold {
                            phase.level_mbps + rng.normal() * 0.2
                        } else {
                            let k = (i - fall - hold) as f64 / (n - fall - hold).max(1) as f64;
                            phase.level_mbps + (start - phase.level_mbps) * k
                        };
                        level = level.clamp(cfg.min_mbps, cfg.max_mbps);
                        samples.push(level);
                    }
                }
            }
        }
        BandwidthTrace { dt: cfg.dt, samples_mbps: samples }
    }

    /// Ground-truth bandwidth at virtual time `t` seconds.
    pub fn at(&self, t: f64) -> f64 {
        if self.samples_mbps.is_empty() {
            return 0.0;
        }
        let idx = ((t / self.dt) as usize).min(self.samples_mbps.len() - 1);
        self.samples_mbps[idx]
    }

    pub fn duration_secs(&self) -> f64 {
        self.samples_mbps.len() as f64 * self.dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_trace_is_20min_and_bounded() {
        let cfg = TraceConfig::paper_20min(7);
        assert!((cfg.total_secs() - 1200.0).abs() < 1e-9);
        let tr = BandwidthTrace::generate(&cfg);
        assert_eq!(tr.samples_mbps.len(), 1200);
        for &b in &tr.samples_mbps {
            assert!((8.0..=20.0).contains(&b), "bandwidth {b} out of range");
        }
    }

    #[test]
    fn trace_deterministic_per_seed() {
        let a = BandwidthTrace::generate(&TraceConfig::paper_20min(3));
        let b = BandwidthTrace::generate(&TraceConfig::paper_20min(3));
        assert_eq!(a.samples_mbps, b.samples_mbps);
        let c = BandwidthTrace::generate(&TraceConfig::paper_20min(4));
        assert_ne!(a.samples_mbps, c.samples_mbps);
    }

    #[test]
    fn drop_phase_reaches_floor() {
        let tr = BandwidthTrace::generate(&TraceConfig::paper_20min(7));
        // First drop phase spans [420, 570): must dip below 11.68 Mbps (the
        // High-Accuracy feasibility threshold) so Fig 9 shows a tier switch.
        let min_in_drop = tr.samples_mbps[440..560]
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(min_in_drop < 11.68, "drop floor {min_in_drop}");
    }

    #[test]
    fn at_clamps_past_end() {
        let tr = BandwidthTrace::generate(&TraceConfig::paper_20min(7));
        assert_eq!(tr.at(1e9), *tr.samples_mbps.last().unwrap());
    }
}
