//! Cloud (server-side) processing: unpack a received packet, run the
//! matching tail artifact (bottleneck decode -> SAM suffix -> LLM trunk ->
//! mask decoder, or the text-only context responder), and produce the
//! operator-facing response (paper §4.2).
//!
//! Two server shapes share the same request path:
//! * [`CloudServer`] — the original single-session server; synchronous
//!   `process` over one engine handle.
//! * [`CloudPool`] — a concurrent multi-session server (DESIGN.md "Fleet
//!   subsystem"): a worker pool draining a shared job queue, with
//!   per-session weight-set routing over the [`crate::transport`] framing
//!   and an in-process fast path ([`CloudPool::process_sync`]) the fleet
//!   simulator uses.  Pass one engine handle per worker: clones of a single
//!   *threaded* engine serialize at its thread (queueing model), while
//!   inline synthetic handles — clones or not — execute truly in parallel,
//!   and in-process requests skip the job queue entirely via the
//!   direct-call fast path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{classify_intent, TierId};
use crate::edge::tail_artifact_name;
use crate::packet::{dequantize_code, dequantize_scaled, Packet, StreamKind};
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::transport::{decode_request, Transport};

/// Operator-facing response.
#[derive(Clone, Debug)]
pub struct CloudResponse {
    /// Insight: (img, img) mask logits. Context: None.
    pub mask_logits: Option<Tensor>,
    /// Per-class presence logits (person, vehicle) — the text-level answer.
    pub presence: Vec<f32>,
}

impl CloudResponse {
    /// Render the text answer the operator sees for a Context query
    /// ("Yes, two possible life signs detected ..." in the paper's example).
    pub fn text_answer(&self, class_names: &[&str]) -> String {
        let mut found = Vec::new();
        for (i, &logit) in self.presence.iter().enumerate() {
            if logit > 0.0 {
                found.push(*class_names.get(i).unwrap_or(&"object"));
            }
        }
        if found.is_empty() {
            "No critical targets detected in this sector.".to_string()
        } else {
            format!("Possible {} detected — escalate with an Insight query.", found.join(" and "))
        }
    }
}

/// Anything that can serve UAV packets — the seam between the mission state
/// machines and the server implementation (single-session or pooled).
pub trait ServePackets {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse>;
}

/// Shared request path: dequantize, pick the artifact, execute.
fn process_packet(
    engine: &Engine,
    pkt: &Packet,
    prompt_ids: &[i32],
    set: &str,
) -> Result<CloudResponse> {
    let clip = dequantize_scaled(&pkt.clip_q, pkt.clip_shape, pkt.clip_scale)?;
    let pids = Tensor::i32(vec![prompt_ids.len()], prompt_ids.to_vec())?;
    match pkt.kind {
        StreamKind::Context => {
            let outs = engine
                .execute_owned("context_respond", set, vec![clip, pids])
                .context("running context_respond")?;
            Ok(CloudResponse { mask_logits: None, presence: outs[0].as_f32()?.to_vec() })
        }
        StreamKind::Insight => {
            if pkt.code_q.is_empty() {
                bail!("insight packet without code");
            }
            let tier = match pkt.tier {
                0 => TierId::HighAccuracy,
                1 => TierId::Balanced,
                2 => TierId::HighThroughput,
                other => bail!("bad tier index {other}"),
            };
            let code = dequantize_code(&pkt.code_q, pkt.code_shape)?;
            let artifact = tail_artifact_name(pkt.split as usize, tier);
            let mut outs = engine
                .execute_owned(&artifact, set, vec![code, clip, pids])
                .with_context(|| format!("running {artifact}"))?;
            let presence = outs[1].as_f32()?.to_vec();
            Ok(CloudResponse { mask_logits: Some(outs.swap_remove(0)), presence })
        }
    }
}

/// The remote server: owns an engine handle and serves packets.
pub struct CloudServer {
    pub engine: Engine,
}

impl CloudServer {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    /// Process one packet with the operator prompt (token ids) against a
    /// weight set ("orig"/"ft" — which fine-tune serves the query).
    pub fn process(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse> {
        process_packet(&self.engine, pkt, prompt_ids, set)
    }
}

impl ServePackets for CloudServer {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse> {
        self.process(pkt, prompt_ids, set)
    }
}

/// One queued job for the pool.
struct Job {
    pkt: Packet,
    prompt_ids: Vec<i32>,
    set: String,
    reply: Sender<Result<CloudResponse>>,
}

/// Aggregate pool counters (wall-clock; the simulator's *virtual* server
/// utilization is derived by the fleet driver from tail latencies).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub workers: usize,
    pub completed: u64,
    /// Summed wall-clock seconds workers spent inside artifact execution.
    pub busy_secs: f64,
}

impl PoolStats {
    /// Fraction of worker capacity used over a wall-clock window.
    pub fn utilization(&self, wall_secs: f64) -> f64 {
        if self.workers == 0 || wall_secs <= 0.0 {
            return 0.0;
        }
        self.busy_secs / (self.workers as f64 * wall_secs)
    }
}

/// Pending response handle returned by [`CloudPool::submit`].
pub struct Ticket {
    rx: Receiver<Result<CloudResponse>>,
}

impl Ticket {
    pub fn wait(self) -> Result<CloudResponse> {
        self.rx.recv().map_err(|_| anyhow!("cloud pool worker dropped reply"))?
    }
}

/// Concurrent multi-session cloud server: a fixed worker pool draining a
/// shared job queue.
pub struct CloudPool {
    jobs: Sender<Job>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_workers: usize,
    completed: Arc<AtomicU64>,
    busy_micros: Arc<AtomicU64>,
    /// Direct-call fast path for [`CloudPool::process_sync`]: set when every
    /// worker engine executes inline (caller-thread synthetic backend), in
    /// which case an in-process request needs no job-queue hop — and no
    /// `Packet` clone.
    direct: Option<Engine>,
}

impl CloudPool {
    /// Spawn one worker per engine handle.  Threaded handles may be clones
    /// of one engine (shared execution thread — models a queueing server)
    /// or independently started engines; inline synthetic handles always
    /// execute truly in parallel, worker- and caller-side.
    pub fn new(engines: Vec<Engine>) -> Self {
        let direct = if !engines.is_empty() && engines.iter().all(|e| e.is_inline()) {
            Some(engines[0].clone())
        } else {
            None
        };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let completed = Arc::new(AtomicU64::new(0));
        let busy_micros = Arc::new(AtomicU64::new(0));
        let n_workers = engines.len();
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let rx = Arc::clone(&rx);
                let completed = Arc::clone(&completed);
                let busy = Arc::clone(&busy_micros);
                std::thread::Builder::new()
                    .name(format!("avery-cloud-{i}"))
                    .spawn(move || loop {
                        // Hold the lock only while popping, never while serving.
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => break, // pool dropped
                        };
                        let t0 = Instant::now();
                        let r = process_packet(&engine, &job.pkt, &job.prompt_ids, &job.set);
                        busy.fetch_add(
                            t0.elapsed().as_micros() as u64,
                            Ordering::Relaxed,
                        );
                        completed.fetch_add(1, Ordering::Relaxed);
                        let _ = job.reply.send(r);
                    })
                    .expect("spawning cloud worker")
            })
            .collect();
        Self { jobs: tx, workers, n_workers, completed, busy_micros, direct }
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Enqueue one request; the returned [`Ticket`] resolves when a worker
    /// finishes it.
    pub fn submit(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<Ticket> {
        let (reply, rx) = channel();
        self.jobs
            .send(Job {
                pkt: pkt.clone(),
                prompt_ids: prompt_ids.to_vec(),
                set: set.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("cloud pool shut down"))?;
        Ok(Ticket { rx })
    }

    /// In-process fast path: serve the request without leaving the caller's
    /// thread when the backend executes inline (no job-queue hop, no
    /// `pkt.clone()`/`prompt_ids.to_vec()`), else enqueue and block.  This
    /// is what the fleet simulator calls — virtual time is charged by the
    /// mission's timing model, so only the numerics flow through here, and
    /// responses are pure functions of the request on either route.
    pub fn process_sync(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse> {
        if let Some(engine) = &self.direct {
            let t0 = Instant::now();
            let r = process_packet(engine, pkt, prompt_ids, set);
            self.busy_micros.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            self.completed.fetch_add(1, Ordering::Relaxed);
            return r;
        }
        self.submit(pkt, prompt_ids, set)?.wait()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.n_workers,
            completed: self.completed.load(Ordering::Relaxed),
            busy_secs: self.busy_micros.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }

    /// Serve one transport session until the peer closes or sends
    /// `shutdown`.  Per-session weight-set routing: a `hello <set>` frame
    /// pins the session's default weight set; individual requests may still
    /// override it by naming a non-empty set (see
    /// [`crate::transport::encode_request`]).  Responses use
    /// [`encode_response`]/[`decode_response`] framing.
    pub fn serve_session<T: Transport>(&self, transport: &mut T, default_set: &str) -> Result<u64> {
        let mut session_set = default_set.to_string();
        let mut served = 0u64;
        loop {
            let frame = match transport.recv() {
                Ok(f) => f,
                Err(_) => break, // peer closed
            };
            if frame == b"shutdown" {
                break;
            }
            if let Some(set) = frame.strip_prefix(b"hello ") {
                session_set = String::from_utf8_lossy(set).trim().to_string();
                transport.send(b"ok")?;
                continue;
            }
            let (pkt_bytes, prompt, set) = decode_request(&frame)?;
            let pkt = Packet::decode(&pkt_bytes)?;
            let intent = classify_intent(&prompt);
            let set = if set.is_empty() { session_set.as_str() } else { set.as_str() };
            let resp = self.process_sync(&pkt, &intent.token_ids, set)?;
            transport.send(&encode_response(&resp))?;
            served += 1;
        }
        Ok(served)
    }
}

impl Drop for CloudPool {
    fn drop(&mut self) {
        // Closing the job channel unblocks every worker's recv.
        let (dead_tx, _) = channel::<Job>();
        drop(std::mem::replace(&mut self.jobs, dead_tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl ServePackets for CloudPool {
    fn serve(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse> {
        self.process_sync(pkt, prompt_ids, set)
    }
}

/// Serialize a [`CloudResponse`] for the transport layer: presence logits
/// then the (possibly empty) flattened mask logits.
pub fn encode_response(resp: &CloudResponse) -> Vec<u8> {
    let mask: Vec<f32> = resp
        .mask_logits
        .as_ref()
        .and_then(|m| m.as_f32().ok().map(|s| s.to_vec()))
        .unwrap_or_default();
    let mut out = Vec::with_capacity(8 + 4 * (resp.presence.len() + mask.len()));
    out.extend_from_slice(&(resp.presence.len() as u32).to_le_bytes());
    for p in &resp.presence {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out.extend_from_slice(&(mask.len() as u32).to_le_bytes());
    for v in &mask {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`encode_response`]: (presence, mask) — mask empty for Context.
pub fn decode_response(frame: &[u8]) -> Result<(Vec<f32>, Vec<f32>)> {
    let f32s = |bytes: &[u8]| -> Vec<f32> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    if frame.len() < 4 {
        bail!("response truncated");
    }
    let np = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
    let mut off = 4;
    if off + np * 4 + 4 > frame.len() {
        bail!("response truncated reading presence");
    }
    let presence = f32s(&frame[off..off + np * 4]);
    off += np * 4;
    let nm = u32::from_le_bytes(frame[off..off + 4].try_into().unwrap()) as usize;
    off += 4;
    if off + nm * 4 > frame.len() {
        bail!("response truncated reading mask");
    }
    let mask = f32s(&frame[off..off + nm * 4]);
    Ok((presence, mask))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_answer_formats() {
        let r = CloudResponse { mask_logits: None, presence: vec![1.2, -0.5] };
        let s = r.text_answer(&["person", "vehicle"]);
        assert!(s.contains("person") && !s.contains("vehicle"));
        let none = CloudResponse { mask_logits: None, presence: vec![-1.0, -1.0] };
        assert!(none.text_answer(&["person", "vehicle"]).contains("No critical"));
    }

    #[test]
    fn response_roundtrip() {
        let r = CloudResponse {
            mask_logits: Some(Tensor::f32(vec![2, 2], vec![0.5, -0.5, 1.0, -1.0]).unwrap()),
            presence: vec![1.5, -2.5],
        };
        let (presence, mask) = decode_response(&encode_response(&r)).unwrap();
        assert_eq!(presence, vec![1.5, -2.5]);
        assert_eq!(mask, vec![0.5, -0.5, 1.0, -1.0]);
        let ctx = CloudResponse { mask_logits: None, presence: vec![0.1] };
        let (p, m) = decode_response(&encode_response(&ctx)).unwrap();
        assert_eq!(p.len(), 1);
        assert!(m.is_empty());
    }

    #[test]
    fn pool_direct_path_matches_queue_and_server() {
        use crate::coordinator::{classify_intent, Lut, TierId};
        use crate::dataset::{Corpus, Dataset};
        use crate::edge::EdgePipeline;
        use crate::energy::DeviceModel;
        use crate::runtime::Engine;

        let engine = Engine::synthetic();
        let ds = Dataset::synthetic(Corpus::Flood, 2, 16, 0xF10D0);
        let mut edge =
            EdgePipeline::new(engine.clone(), DeviceModel::jetson_mode_30w(8), Lut::paper());
        let (pkt, _) =
            edge.capture_insight(&ds.scenes[0], 1, TierId::HighAccuracy, 0.0).unwrap();
        let intent = classify_intent("highlight the stranded people");

        let pool = CloudPool::new(vec![engine.clone(), engine.clone()]);
        let direct = pool.process_sync(&pkt, &intent.token_ids, "ft").unwrap();
        let queued = pool.submit(&pkt, &intent.token_ids, "ft").unwrap().wait().unwrap();
        let server = CloudServer::new(engine).process(&pkt, &intent.token_ids, "ft").unwrap();
        assert_eq!(direct.presence, queued.presence);
        assert_eq!(direct.presence, server.presence);
        assert_eq!(direct.mask_logits, queued.mask_logits);
        assert_eq!(direct.mask_logits, server.mask_logits);
        // Both routes count toward the pool's aggregate counters.
        assert_eq!(pool.stats().completed, 2);
    }

    #[test]
    fn truncated_response_rejected() {
        let r = CloudResponse { mask_logits: None, presence: vec![1.0, 2.0] };
        let frame = encode_response(&r);
        assert!(decode_response(&frame[..frame.len() - 2]).is_err());
        assert!(decode_response(&[]).is_err());
    }
}
