//! Cloud (server-side) processing: unpack a received packet, run the
//! matching tail artifact (bottleneck decode -> SAM suffix -> LLM trunk ->
//! mask decoder, or the text-only context responder), and produce the
//! operator-facing response (paper §4.2).

use anyhow::{bail, Context, Result};

use crate::coordinator::TierId;
use crate::edge::tail_artifact;
use crate::packet::{dequantize_code, dequantize_scaled, Packet, StreamKind};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Operator-facing response.
#[derive(Clone, Debug)]
pub struct CloudResponse {
    /// Insight: (img, img) mask logits. Context: None.
    pub mask_logits: Option<Tensor>,
    /// Per-class presence logits (person, vehicle) — the text-level answer.
    pub presence: Vec<f32>,
}

impl CloudResponse {
    /// Render the text answer the operator sees for a Context query
    /// ("Yes, two possible life signs detected ..." in the paper's example).
    pub fn text_answer(&self, class_names: &[&str]) -> String {
        let mut found = Vec::new();
        for (i, &logit) in self.presence.iter().enumerate() {
            if logit > 0.0 {
                found.push(*class_names.get(i).unwrap_or(&"object"));
            }
        }
        if found.is_empty() {
            "No critical targets detected in this sector.".to_string()
        } else {
            format!("Possible {} detected — escalate with an Insight query.", found.join(" and "))
        }
    }
}

/// The remote server: owns an engine handle and serves packets.
pub struct CloudServer {
    pub engine: Engine,
}

impl CloudServer {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    /// Process one packet with the operator prompt (token ids) against a
    /// weight set ("orig"/"ft" — which fine-tune serves the query).
    pub fn process(&self, pkt: &Packet, prompt_ids: &[i32], set: &str) -> Result<CloudResponse> {
        let clip = dequantize_scaled(&pkt.clip_q, pkt.clip_shape, pkt.clip_scale)?;
        let pids = Tensor::i32(vec![prompt_ids.len()], prompt_ids.to_vec())?;
        match pkt.kind {
            StreamKind::Context => {
                let outs = self
                    .engine
                    .execute("context_respond", set, vec![clip, pids])
                    .context("running context_respond")?;
                Ok(CloudResponse { mask_logits: None, presence: outs[0].as_f32()?.to_vec() })
            }
            StreamKind::Insight => {
                if pkt.code_q.is_empty() {
                    bail!("insight packet without code");
                }
                let tier = match pkt.tier {
                    0 => TierId::HighAccuracy,
                    1 => TierId::Balanced,
                    2 => TierId::HighThroughput,
                    other => bail!("bad tier index {other}"),
                };
                let code = dequantize_code(&pkt.code_q, pkt.code_shape)?;
                let artifact = tail_artifact(pkt.split as usize, tier);
                let outs = self
                    .engine
                    .execute(&artifact, set, vec![code, clip, pids])
                    .with_context(|| format!("running {artifact}"))?;
                Ok(CloudResponse {
                    mask_logits: Some(outs[0].clone()),
                    presence: outs[1].as_f32()?.to_vec(),
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_answer_formats() {
        let r = CloudResponse { mask_logits: None, presence: vec![1.2, -0.5] };
        let s = r.text_answer(&["person", "vehicle"]);
        assert!(s.contains("person") && !s.contains("vehicle"));
        let none = CloudResponse { mask_logits: None, presence: vec![-1.0, -1.0] };
        assert!(none.text_answer(&["person", "vehicle"]).contains("No critical"));
    }
}
