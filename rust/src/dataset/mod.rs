//! Loader for the synthetic Flood-ReasonSeg / generic corpora emitted by
//! `python/compile/data.py::write_scenes` (binary format documented there),
//! plus the round-robin scene streamer the missions consume (the paper
//! streams "both Original and flood-related datasets in round-robin
//! fashion", §5.3.1).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::Rng;

pub const MAGIC: u32 = 0x41565259;

/// Which corpus a scene came from (selects the LUT accuracy column and the
/// tail weight set: Original vs Fine-tuned).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corpus {
    Generic,
    Flood,
}

impl Corpus {
    /// The weight-set name for the cloud tail / responder.
    pub fn weight_set(self) -> &'static str {
        match self {
            Corpus::Generic => "orig",
            Corpus::Flood => "ft",
        }
    }
}

/// One annotated scene: image, per-class GT masks, insight prompts.
#[derive(Clone, Debug)]
pub struct Scene {
    /// (img, img, 3) f32 in [0,1].
    pub image: Tensor,
    /// per-class flattened (img*img) masks, indexed by class id.
    pub masks: Vec<Vec<f32>>,
    /// (class id, instruction text).
    pub prompts: Vec<(usize, String)>,
}

/// A loaded corpus file.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub img: usize,
    pub scenes: Vec<Scene>,
    pub corpus: Corpus,
}

impl Dataset {
    pub fn load(path: &Path, corpus: Corpus) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        Self::parse(&bytes, corpus)
    }

    pub fn parse(bytes: &[u8], corpus: Corpus) -> Result<Self> {
        let mut off = 0usize;
        let u32_at = |o: &mut usize| -> Result<u32> {
            if *o + 4 > bytes.len() {
                bail!("dataset truncated at offset {o}");
            }
            let v = u32::from_le_bytes(bytes[*o..*o + 4].try_into().unwrap());
            *o += 4;
            Ok(v)
        };
        let magic = u32_at(&mut off)?;
        if magic != MAGIC {
            bail!("bad dataset magic {magic:08x}");
        }
        let version = u32_at(&mut off)?;
        if version != 1 {
            bail!("unsupported dataset version {version}");
        }
        let n = u32_at(&mut off)? as usize;
        let img = u32_at(&mut off)? as usize;
        let mut scenes = Vec::with_capacity(n);
        let f32_block = |bytes: &[u8], off: &mut usize, count: usize| -> Result<Vec<f32>> {
            let need = count * 4;
            if *off + need > bytes.len() {
                bail!("dataset truncated reading {count} f32s at {off}");
            }
            let v = bytes[*off..*off + need]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            *off += need;
            Ok(v)
        };
        for _ in 0..n {
            let image = f32_block(bytes, &mut off, img * img * 3)?;
            let mask_all = f32_block(bytes, &mut off, 2 * img * img)?;
            let masks = vec![
                mask_all[..img * img].to_vec(),
                mask_all[img * img..].to_vec(),
            ];
            let np = u32_at(&mut off)? as usize;
            let mut prompts = Vec::with_capacity(np);
            for _ in 0..np {
                let cls = u32_at(&mut off)? as usize;
                let len = u32_at(&mut off)? as usize;
                if off + len > bytes.len() {
                    bail!("dataset truncated reading prompt");
                }
                let text = std::str::from_utf8(&bytes[off..off + len])
                    .context("prompt utf8")?
                    .to_string();
                off += len;
                prompts.push((cls, text));
            }
            scenes.push(Scene {
                image: Tensor::f32(vec![img, img, 3], image)?,
                masks,
                prompts,
            });
        }
        Ok(Dataset { img, scenes, corpus })
    }
}

/// Insight prompts rotated per class by [`Dataset::synthetic`] — phrased so
/// `classify_intent` grounds each to the class whose mask the scene carries.
const SYNTH_PROMPTS: [[&str; 2]; 2] = [
    ["highlight the stranded people", "mark the survivors on the rooftops"],
    ["mark the submerged vehicles", "segment the stranded cars"],
];

impl Dataset {
    /// Generate a synthetic annotated corpus for the artifact-free sim path
    /// (see `runtime::synth`): each scene encodes its GT masks into the
    /// image channels (channel c = mask of class c, channel 2 = low-level
    /// clutter below the 0.5 mask threshold), with rectangular blobs
    /// covering ~6–25 % of the frame and at least one class present.
    /// Deterministic in `(corpus, seed)`.
    pub fn synthetic(corpus: Corpus, n_scenes: usize, img: usize, seed: u64) -> Self {
        let salt = match corpus {
            Corpus::Generic => 0x47_45_4Eu64, // "GEN"
            Corpus::Flood => 0x46_4C_44u64,   // "FLD"
        };
        let mut rng = Rng::new(seed ^ salt);
        let mut scenes = Vec::with_capacity(n_scenes);
        for si in 0..n_scenes {
            let mut present = [rng.f64() < 0.75, rng.f64() < 0.6];
            if !present[0] && !present[1] {
                present[rng.below(2)] = true;
            }
            let mut masks = vec![vec![0.0f32; img * img], vec![0.0f32; img * img]];
            for (c, mask) in masks.iter_mut().enumerate() {
                if !present[c] {
                    continue;
                }
                // One axis-aligned blob, between a quarter and half the
                // frame on each side.
                let side = |rng: &mut Rng| (img / 4 + rng.below(img / 4 + 1)).max(1);
                let (w, h) = (side(&mut rng), side(&mut rng));
                let x0 = rng.below(img - w + 1);
                let y0 = rng.below(img - h + 1);
                for y in y0..y0 + h {
                    for x in x0..x0 + w {
                        mask[y * img + x] = 1.0;
                    }
                }
            }
            let mut image = vec![0.0f32; img * img * 3];
            for i in 0..img * img {
                image[i * 3] = masks[0][i];
                image[i * 3 + 1] = masks[1][i];
                image[i * 3 + 2] = (rng.f64() * 0.3) as f32;
            }
            let mut prompts = Vec::new();
            for c in 0..2 {
                if present[c] {
                    prompts.push((c, SYNTH_PROMPTS[c][si % 2].to_string()));
                }
            }
            scenes.push(Scene {
                image: Tensor::f32(vec![img, img, 3], image).expect("synthetic scene shape"),
                masks,
                prompts,
            });
        }
        Dataset { img, scenes, corpus }
    }
}

/// Round-robin streamer over two corpora (paper §5.3.1): generic, flood,
/// generic, flood, ... wrapping each corpus independently.
pub struct RoundRobin<'a> {
    sets: Vec<&'a Dataset>,
    next_set: usize,
    cursors: Vec<usize>,
}

/// One streamed work item: a scene plus one of its insight prompts.
pub struct WorkItem<'a> {
    pub scene: &'a Scene,
    pub corpus: Corpus,
    pub class_id: usize,
    pub prompt: &'a str,
}

impl<'a> RoundRobin<'a> {
    pub fn new(sets: Vec<&'a Dataset>) -> Self {
        let cursors = vec![0; sets.len()];
        Self { sets, next_set: 0, cursors }
    }

    pub fn next_item(&mut self) -> Option<WorkItem<'a>> {
        if self.sets.is_empty() {
            return None;
        }
        for _ in 0..self.sets.len() {
            let si = self.next_set;
            self.next_set = (self.next_set + 1) % self.sets.len();
            let ds = self.sets[si];
            if ds.scenes.is_empty() {
                continue;
            }
            // Walk scene-prompt pairs; cursor indexes into the flat list.
            let total: usize = ds.scenes.iter().map(|s| s.prompts.len().max(1)).sum();
            let mut idx = self.cursors[si] % total.max(1);
            self.cursors[si] = (self.cursors[si] + 1) % total.max(1);
            for scene in &ds.scenes {
                let np = scene.prompts.len().max(1);
                if idx < np {
                    let (class_id, prompt) = scene
                        .prompts
                        .get(idx)
                        .map(|(c, p)| (*c, p.as_str()))
                        .unwrap_or((0, ""));
                    return Some(WorkItem { scene, corpus: ds.corpus, class_id, prompt });
                }
                idx -= np;
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset(corpus: Corpus, n: usize) -> Dataset {
        let img = 4;
        let scenes = (0..n)
            .map(|i| Scene {
                image: Tensor::zeros_f32(vec![img, img, 3]),
                masks: vec![vec![0.0; img * img], vec![0.0; img * img]],
                prompts: vec![(i % 2, format!("prompt {i}"))],
            })
            .collect();
        Dataset { img, scenes, corpus }
    }

    #[test]
    fn round_robin_alternates() {
        let a = tiny_dataset(Corpus::Generic, 2);
        let b = tiny_dataset(Corpus::Flood, 2);
        let mut rr = RoundRobin::new(vec![&a, &b]);
        let c1 = rr.next_item().unwrap().corpus;
        let c2 = rr.next_item().unwrap().corpus;
        let c3 = rr.next_item().unwrap().corpus;
        assert_eq!(c1, Corpus::Generic);
        assert_eq!(c2, Corpus::Flood);
        assert_eq!(c3, Corpus::Generic);
    }

    #[test]
    fn round_robin_wraps() {
        let a = tiny_dataset(Corpus::Generic, 1);
        let mut rr = RoundRobin::new(vec![&a]);
        for _ in 0..5 {
            assert!(rr.next_item().is_some());
        }
    }

    #[test]
    fn synthetic_dataset_well_formed_and_deterministic() {
        let a = Dataset::synthetic(Corpus::Flood, 12, 16, 7);
        assert_eq!(a.scenes.len(), 12);
        for s in &a.scenes {
            assert_eq!(s.image.shape(), &[16, 16, 3]);
            // Every prompt names a class whose mask is non-empty.
            assert!(!s.prompts.is_empty());
            for (cls, _) in &s.prompts {
                assert!(s.masks[*cls].iter().any(|&m| m > 0.5), "empty class {cls}");
            }
            // The image channels ARE the masks (the synthetic head's contract).
            for i in 0..16 * 16 {
                assert_eq!(s.image.as_f32().unwrap()[i * 3], s.masks[0][i]);
                assert_eq!(s.image.as_f32().unwrap()[i * 3 + 1], s.masks[1][i]);
                assert!(s.image.as_f32().unwrap()[i * 3 + 2] < 0.5);
            }
        }
        let b = Dataset::synthetic(Corpus::Flood, 12, 16, 7);
        assert_eq!(a.scenes[3].masks, b.scenes[3].masks);
        let c = Dataset::synthetic(Corpus::Flood, 12, 16, 8);
        assert!(a.scenes.iter().zip(&c.scenes).any(|(x, y)| x.masks != y.masks));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Dataset::parse(&[0u8; 8], Corpus::Flood).is_err());
        assert!(Dataset::parse(&[], Corpus::Flood).is_err());
    }

    #[test]
    fn weight_set_mapping() {
        assert_eq!(Corpus::Generic.weight_set(), "orig");
        assert_eq!(Corpus::Flood.weight_set(), "ft");
    }
}
