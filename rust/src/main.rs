//! AVERY CLI — the leader entrypoint, a thin shell over the Mission API
//! (`avery::mission`): every subcommand is registry iteration.
//!
//! ```text
//! avery list            # enumerate registered missions
//! avery run <mission>   # run one mission by registry name
//! avery all             # every mission, in registry order
//! avery <mission>       # legacy alias for `avery run <mission>`
//! ```
//!
//! Missions (registry order — see DESIGN.md experiment index):
//!
//! ```text
//! table3     Table 3 — System LUT (per-tier accuracy/payload)
//! fig7       Fig 7  — split-point accuracy sweep (r = 0.10)
//! fig8       Fig 8  — latency/energy per split point
//! fig9       Fig 9  — 20-min dynamic run, AVERY vs static tiers
//! fig10      Fig 10 — accuracy/throughput trade-off scatter
//! headline   abstract claims H1..H4 (needs artifacts)
//! streams    §5.2.2 dual-stream characterization + §4.3 demo
//! fleet      multi-UAV contended-uplink mission (beyond the paper)
//! scenario   scenario library: named disaster/network regimes
//! matrix     generated scenario matrix under invariant gates
//! chaos      fault-schedule matrix under conservation/determinism gates
//! ```
//!
//! Common options: `--artifacts DIR`, `--out DIR`, `--duration SECS`,
//! `--goal accuracy|throughput`, `--exec-every N`, `--seed N`,
//! `--hysteresis H`, `--exec-mode buffers|literals`, `--config FILE`,
//! `--uavs N`, `--workers N` (fleet), `--scenario NAME` (fleet/fig9),
//! `--name NAME` / `--manifest PATH` / `--list` (scenario),
//! `--matrix-count N` (matrix), `--format text|json`,
//! `--jobs N` (parallel mission fan-out for `avery all`), and the cloud
//! serving layer's `--batch-max N`, `--cache-entries N`, `--cache-ttl SECS`,
//! `--queue-depth N`, `--deadline-context SECS`, `--deadline-insight SECS`,
//! `--edf` and `--deadline-shed` (fleet/scenario; defaults preserve the
//! unbatched, uncached, FIFO behavior byte-for-byte), plus the cloud
//! cluster's `--cells K`, `--replicas R`, `--hop-latency SECS` and
//! `--spill-max H` (fleet/scenario; `--cells 1` — the default — delegates
//! to the single pool byte-for-byte), plus the chaos layer's
//! `--fault-plan PATH`, `--retry-budget N`, `--retry-backoff SECS`,
//! `--retry-deadline SECS`, `--degrade` and `--probe-backoff SECS`
//! (fleet/scenario/chaos; with no fault plan armed every knob defaults
//! off and outputs stay byte-identical).
//!
//! Every artifact-free-capable mission (all but `headline`) falls back to
//! the synthetic closed-form engine when `artifacts/` is missing (control
//! plane exact, numerics simulated), so the whole evaluation surface runs
//! in CI.  CSV outputs are always written; `--format json` renders the
//! structured report as one JSON object on stdout instead of tables.
//! With `--jobs N` missions *run* in parallel but reports are *rendered*
//! serially in registry order, so stdout/CSV/JSON bytes are identical to a
//! serial run (pinned by `rust/tests/mission_api.rs`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use avery::config::{Kv, RunConfig};
use avery::mission::{self, EnvSpec, Mission, RunOptions};
use avery::report::{emit_text, CsvSink, JsonSink, OutputFormat, Sink};

const USAGE: &str = "usage: avery <run <mission>|list|all|MISSION> [--options]
missions: table3 fig7 fig8 fig9 fig10 headline streams fleet scenario matrix chaos
  --artifacts DIR      artifact directory (default: discover ./artifacts)
  --out DIR            CSV output directory (default: out)
  --duration SECS      mission length for fig9/fig10/headline/fleet/scenario (default 1200)
  --goal MODE          accuracy | throughput (default: mission/scenario's)
  --exec-every N       execute HLO every Nth packet (default 1)
  --seed N             trace/workload seed (default 7)
  --hysteresis H       also run the hysteresis ablation at margin H
  --exec-mode M        buffers | literals (default buffers)
  --uavs N             fleet size (default 4, or the scenario's)
  --workers N          cloud pool workers (default 2, or the scenario's)
  --scenario NAME      run fleet/fig9 under a scenario regime
  --name NAME          scenario to run for `avery run scenario`
  --manifest PATH      compile + run a scenario manifest (see scenarios/)
  --matrix-count N     scenarios sampled by `avery run matrix` (default 16)
  --list               list registered scenarios (`avery scenario --list`)
  --batch-max N        cloud micro-batch bound for fleet/scenario serving
                       (default 1 = unbatched)
  --cache-entries N    cloud response-cache capacity (default 0 = off)
  --cache-ttl SECS     response-cache TTL in virtual seconds (default: never)
  --queue-depth N      cloud admission bound on in-flight requests
                       (default 0 = unbounded; full queues shed with `busy`)
  --deadline-context S deadline budget for Context requests in virtual
                       seconds (default: none)
  --deadline-insight S deadline budget for Insight requests (default: none)
  --edf                drain the serving queue earliest-deadline-first
                       (default: FIFO)
  --deadline-shed      shed the queued request predicted to miss its
                       deadline instead of the newest arrival
  --cells K            cloud cluster cells behind the consistent-hash router
                       (default 1 = single pool, byte-identical output)
  --replicas R         response-cache replication factor across ring
                       siblings (default 1 = home cell only)
  --hop-latency SECS   modeled inter-cell latency charged per ring hop
                       (default 0.002)
  --spill-max H        max spill hops past a shedding home cell before the
                       request is shed for good (default 1)
  --fault-plan PATH    standalone [[fault]] manifest armed for fleet/scenario
                       (default: no injected faults)
  --retry-budget N     agent retries per request once served an outage
                       (default 0, or 2 when a fault plan is armed)
  --retry-backoff SECS first retry backoff, doubling per attempt, in virtual
                       seconds (default 0.05)
  --retry-deadline S   give up retrying once cumulative backoff exceeds S
                       virtual seconds (default: never)
  --degrade            degrade abandoned Insight requests to edge-local
                       Context-tier execution (default off; on when a fault
                       plan is armed — disable with --degrade false)
  --probe-backoff SECS first re-probe backoff for a quarantined cell,
                       doubling per failed probe (default 0.5)
  --format FMT         text | json report rendering (CSVs always written)
  --jobs N             run missions N at a time (`avery all`); output bytes
                       are identical to --jobs 1 (default 1)
  --config FILE        key = value config file (CLI overrides it)

Every mission except `headline` needs no artifacts: without them it runs
the synthetic closed-form engine (control plane exact, numerics simulated);
`avery all` skips artifact-gated missions with a note instead of failing.";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut kv = Kv::default();
    // Config file first (if named, in either `--config FILE` or
    // `--config=FILE` form), then CLI overrides.
    for (i, a) in args.iter().enumerate() {
        if let Some(path) = a.strip_prefix("--config=") {
            kv = Kv::from_file(Path::new(path))?;
        } else if a == "--config" {
            match args.get(i + 1) {
                Some(path) if !path.starts_with("--") => {
                    kv = Kv::from_file(Path::new(path))?;
                }
                _ => bail!("--config requires a file path"),
            }
        }
    }
    let positional = kv.apply_cli(&args)?;
    let cfg = RunConfig::from_kv(&kv)?;
    let Some(cmd) = positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };

    match cmd {
        "list" => {
            print_mission_list();
            Ok(())
        }
        "run" => {
            let Some(name) = positional.get(1) else {
                bail!("usage: avery run <mission>  (see `avery list`)");
            };
            if name == "scenario" && cfg.list {
                print_scenario_list();
                return Ok(());
            }
            let Some(m) = mission::find(name) else {
                bail!("unknown mission `{name}` — see `avery list`");
            };
            run_missions(vec![m], &cfg, false)
        }
        "all" => run_missions(mission::registry(), &cfg, true),
        // Legacy subcommands are registry aliases.  `avery scenario` with
        // neither a name nor a manifest keeps its listing behavior.
        "scenario" if cfg.list || (cfg.name.is_none() && cfg.manifest.is_none()) => {
            print_scenario_list();
            Ok(())
        }
        other => match mission::find(other) {
            Some(m) => run_missions(vec![m], &cfg, false),
            None => bail!("unknown command `{other}`\n{USAGE}"),
        },
    }
}

fn print_mission_list() {
    println!("registered missions (run with `avery run NAME`):");
    for m in mission::registry() {
        let gate = if m.needs_artifacts() { "artifacts" } else { "artifact-free" };
        println!("  {:<10} [{gate:>13}] {}", m.name(), m.summary());
    }
}

fn print_scenario_list() {
    println!("registered scenarios (run with `avery scenario --name NAME`):");
    for (name, summary) in avery::scenario::list() {
        println!("  {name:<20} {summary}");
    }
}

/// Resolve the execution environment once (so parallel workers neither
/// race artifact discovery nor repeat the fallback notice), run the
/// missions `--jobs` at a time, then render every report serially in
/// registry order: CSVs always, tables+notes or JSON per `--format`.
/// `skip_gated` (the `avery all` path) drops artifact-needing missions
/// with a note when no artifacts exist instead of failing the whole run.
fn run_missions(
    missions: Vec<Box<dyn Mission>>,
    cfg: &RunConfig,
    skip_gated: bool,
) -> Result<()> {
    let out_dir = Path::new(&cfg.out_dir);
    let needs_artifacts = missions.iter().any(|m| m.needs_artifacts());
    // One shared resolution path with the library (`EnvSpec::resolve` also
    // backs `Env::load_or_synthetic`): explicit dir must exist, discovery
    // falls back to the synthetic engine with a one-time notice.
    let spec = EnvSpec::resolve(cfg.artifacts.as_deref(), cfg.exec_mode)?;
    if let EnvSpec::Artifacts { dir, .. } = &spec {
        if needs_artifacts {
            eprintln!("artifacts: {}", dir.display());
        }
    }
    let missions: Vec<Box<dyn Mission>> = match &spec {
        EnvSpec::Artifacts { .. } => missions,
        EnvSpec::Synthetic if needs_artifacts && !skip_gated => bail!(
            "this mission needs artifacts/ — run `make artifacts` first \
             (or set AVERY_ARTIFACTS)"
        ),
        EnvSpec::Synthetic => missions
            .into_iter()
            .filter(|m| {
                if m.needs_artifacts() {
                    eprintln!("skipping `{}` (needs artifacts)", m.name());
                    false
                } else {
                    true
                }
            })
            .collect(),
    };

    let opts = RunOptions::from_config(cfg);
    let jobs = cfg.jobs.max(1);
    if jobs > 1 && missions.len() > 1 {
        eprintln!("running {} missions, {} at a time", missions.len(), jobs.min(missions.len()));
    }
    let reports = mission::run_collect(&missions, &spec, out_dir, &opts, jobs);
    for (m, r) in missions.iter().zip(reports) {
        let report = r.with_context(|| format!("mission `{}`", m.name()))?;
        match cfg.format {
            OutputFormat::Text => emit_text(&report, out_dir)?,
            OutputFormat::Json => {
                // Stdout stays pure JSON (one object per mission); the CSV
                // files are still written, silently.
                CsvSink::new(out_dir).announce(false).emit(&report)?;
                JsonSink.emit(&report)?;
            }
        }
    }
    Ok(())
}
