//! AVERY CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables/figures through the real
//! three-layer stack (see DESIGN.md experiment index):
//!
//! ```text
//! avery table3     # Table 3 — System LUT (per-tier accuracy/payload)
//! avery fig7       # Fig 7  — split-point accuracy sweep (r = 0.10)
//! avery fig8       # Fig 8  — latency/energy per split point
//! avery fig9       # Fig 9  — 20-min dynamic run, AVERY vs static tiers
//! avery fig10      # Fig 10 — accuracy/throughput trade-off scatter
//! avery headline   # abstract claims H1..H4
//! avery streams    # §5.2.2 dual-stream characterization + §4.3 demo
//! avery fleet      # multi-UAV contended-uplink mission (beyond the paper)
//! avery scenario   # scenario library: named disaster/network regimes
//! avery all        # everything above
//! ```
//!
//! Common options: `--artifacts DIR`, `--out DIR`, `--duration SECS`,
//! `--goal accuracy|throughput`, `--exec-every N`, `--seed N`,
//! `--hysteresis H`, `--exec-mode buffers|literals`, `--config FILE`,
//! `--uavs N`, `--workers N` (fleet), `--scenario NAME` (fleet/fig9),
//! `--name NAME` / `--list` (scenario).
//!
//! `avery scenario` runs with or without artifacts: when `artifacts/` is
//! missing it falls back to the synthetic closed-form engine (control plane
//! exact, numerics simulated), so the scenario matrix also runs in CI.

use std::path::Path;

use anyhow::{bail, Result};

use avery::config::{Kv, RunConfig};
use avery::mission::{
    run_fig10, run_fig7, run_fig8, run_fig9, run_fleet, run_headline, run_scenario,
    run_streams, run_table3, Env, Fig9Options, FleetOptions, ScenarioOptions,
};

const USAGE: &str = "usage: avery <table3|fig7|fig8|fig9|fig10|headline|streams|fleet|scenario|all> [--options]
  --artifacts DIR      artifact directory (default: discover ./artifacts)
  --out DIR            CSV output directory (default: out)
  --duration SECS      mission length for fig9/fig10/headline/fleet/scenario (default 1200)
  --goal MODE          accuracy | throughput (default accuracy)
  --exec-every N       execute HLO every Nth packet (default 1)
  --seed N             trace/workload seed (default 7)
  --hysteresis H       also run the hysteresis ablation at margin H
  --exec-mode M        buffers | literals (default buffers)
  --uavs N             fleet size for `avery fleet` (default 4)
  --workers N          cloud pool workers for `avery fleet` (default 2)
  --scenario NAME      run `avery fleet`/`avery fig9` under a scenario regime
  --name NAME          scenario to run for `avery scenario`
  --list               list registered scenarios (`avery scenario --list`)
  --config FILE        key = value config file (CLI overrides it)

`avery scenario` needs no artifacts: without them it runs the synthetic
closed-form engine (control plane exact, numerics simulated).";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut kv = Kv::default();
    // Config file first (if named), then CLI overrides.
    if let Some(i) = args.iter().position(|a| a == "--config") {
        if let Some(path) = args.get(i + 1) {
            kv = Kv::from_file(Path::new(path))?;
        }
    }
    let positional = kv.apply_cli(&args)?;
    let cfg = RunConfig::from_kv(&kv)?;
    let Some(cmd) = positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };

    // `avery scenario` is self-sufficient: `--list` needs no environment at
    // all, and a run falls back to the synthetic engine without artifacts.
    if cmd == "scenario" {
        if cfg.list || cfg.name.is_none() {
            println!("registered scenarios (run with `avery scenario --name NAME`):");
            for (name, summary) in avery::scenario::list() {
                println!("  {name:<20} {summary}");
            }
            return Ok(());
        }
        let env = Env::load_or_synthetic(
            cfg.artifacts.as_deref(),
            Path::new(&cfg.out_dir),
            cfg.exec_mode,
        )?;
        let opts = ScenarioOptions {
            name: cfg.name.clone().unwrap(),
            duration_secs: cfg.duration_secs,
            seed: cfg.seed,
            exec_every: cfg.exec_every,
            uavs: cfg.uavs_explicit.then_some(cfg.uavs),
            workers: cfg.workers_explicit.then_some(cfg.workers),
            goal: cfg.goal_explicit.then_some(cfg.goal),
        };
        run_scenario(&env, &opts)?;
        return Ok(());
    }

    let artifacts = avery::find_artifacts(cfg.artifacts.as_deref())?;
    eprintln!("artifacts: {}", artifacts.display());
    let env = Env::load(&artifacts, Path::new(&cfg.out_dir), cfg.exec_mode)?;

    // Under `--scenario` the regime's own mission goal applies unless the
    // user passed `--goal` explicitly — keeping `avery fleet --scenario X`
    // consistent with `avery scenario --name X`.
    let mut goal = cfg.goal;
    if !cfg.goal_explicit {
        if let Some(name) = &cfg.scenario {
            goal = avery::scenario::build(name, cfg.seed, cfg.duration_secs)?.goal;
        }
    }

    let fig9_opts = Fig9Options {
        duration_secs: cfg.duration_secs,
        goal,
        exec_every: cfg.exec_every,
        ablate_hysteresis: cfg.hysteresis,
        seed: cfg.seed,
        scenario: cfg.scenario.clone(),
    };
    let fleet_opts = FleetOptions {
        uavs: cfg.uavs,
        workers: cfg.workers,
        duration_secs: cfg.duration_secs,
        goal,
        exec_every: cfg.exec_every,
        seed: cfg.seed,
        scenario: cfg.scenario.clone(),
    };

    match cmd {
        "table3" => run_table3(&env)?,
        "fig7" => run_fig7(&env)?,
        "fig8" => run_fig8(&env)?,
        "fig9" => {
            run_fig9(&env, &fig9_opts)?;
        }
        "fig10" => run_fig10(&env, &fig9_opts)?,
        "headline" => run_headline(&env, &fig9_opts)?,
        "streams" => run_streams(&env)?,
        "fleet" => {
            run_fleet(&env, &fleet_opts)?;
        }
        "all" => {
            run_table3(&env)?;
            run_fig7(&env)?;
            run_fig8(&env)?;
            run_fig9(&env, &fig9_opts)?;
            run_fig10(&env, &fig9_opts)?;
            run_headline(&env, &fig9_opts)?;
            run_streams(&env)?;
            run_fleet(&env, &fleet_opts)?;
            run_scenario(
                &env,
                &ScenarioOptions {
                    name: cfg
                        .name
                        .clone()
                        .or_else(|| cfg.scenario.clone())
                        .unwrap_or_else(|| "urban-flood".to_string()),
                    duration_secs: cfg.duration_secs,
                    seed: cfg.seed,
                    exec_every: cfg.exec_every,
                    uavs: cfg.uavs_explicit.then_some(cfg.uavs),
                    workers: cfg.workers_explicit.then_some(cfg.workers),
                    goal: cfg.goal_explicit.then_some(cfg.goal),
                },
            )?;
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
    Ok(())
}
