//! AVERY CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables/figures through the real
//! three-layer stack (see DESIGN.md experiment index):
//!
//! ```text
//! avery table3     # Table 3 — System LUT (per-tier accuracy/payload)
//! avery fig7       # Fig 7  — split-point accuracy sweep (r = 0.10)
//! avery fig8       # Fig 8  — latency/energy per split point
//! avery fig9       # Fig 9  — 20-min dynamic run, AVERY vs static tiers
//! avery fig10      # Fig 10 — accuracy/throughput trade-off scatter
//! avery headline   # abstract claims H1..H4
//! avery streams    # §5.2.2 dual-stream characterization + §4.3 demo
//! avery fleet      # multi-UAV contended-uplink mission (beyond the paper)
//! avery all        # everything above
//! ```
//!
//! Common options: `--artifacts DIR`, `--out DIR`, `--duration SECS`,
//! `--goal accuracy|throughput`, `--exec-every N`, `--seed N`,
//! `--hysteresis H`, `--exec-mode buffers|literals`, `--config FILE`,
//! `--uavs N`, `--workers N` (fleet).

use std::path::Path;

use anyhow::{bail, Result};

use avery::config::{Kv, RunConfig};
use avery::mission::{
    run_fig10, run_fig7, run_fig8, run_fig9, run_fleet, run_headline, run_streams,
    run_table3, Env, Fig9Options, FleetOptions,
};

const USAGE: &str = "usage: avery <table3|fig7|fig8|fig9|fig10|headline|streams|fleet|all> [--options]
  --artifacts DIR      artifact directory (default: discover ./artifacts)
  --out DIR            CSV output directory (default: out)
  --duration SECS      mission length for fig9/fig10/headline/fleet (default 1200)
  --goal MODE          accuracy | throughput (default accuracy)
  --exec-every N       execute HLO every Nth packet (default 1)
  --seed N             trace/workload seed (default 7)
  --hysteresis H       also run the hysteresis ablation at margin H
  --exec-mode M        buffers | literals (default buffers)
  --uavs N             fleet size for `avery fleet` (default 4)
  --workers N          cloud pool workers for `avery fleet` (default 2)
  --config FILE        key = value config file (CLI overrides it)";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut kv = Kv::default();
    // Config file first (if named), then CLI overrides.
    if let Some(i) = args.iter().position(|a| a == "--config") {
        if let Some(path) = args.get(i + 1) {
            kv = Kv::from_file(Path::new(path))?;
        }
    }
    let positional = kv.apply_cli(&args)?;
    let cfg = RunConfig::from_kv(&kv)?;
    let Some(cmd) = positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };

    let artifacts = avery::find_artifacts(cfg.artifacts.as_deref())?;
    eprintln!("artifacts: {}", artifacts.display());
    let env = Env::load(&artifacts, Path::new(&cfg.out_dir), cfg.exec_mode)?;

    let fig9_opts = Fig9Options {
        duration_secs: cfg.duration_secs,
        goal: cfg.goal,
        exec_every: cfg.exec_every,
        ablate_hysteresis: cfg.hysteresis,
        seed: cfg.seed,
    };
    let fleet_opts = FleetOptions {
        uavs: cfg.uavs,
        workers: cfg.workers,
        duration_secs: cfg.duration_secs,
        goal: cfg.goal,
        exec_every: cfg.exec_every,
        seed: cfg.seed,
    };

    match cmd {
        "table3" => run_table3(&env)?,
        "fig7" => run_fig7(&env)?,
        "fig8" => run_fig8(&env)?,
        "fig9" => {
            run_fig9(&env, &fig9_opts)?;
        }
        "fig10" => run_fig10(&env, &fig9_opts)?,
        "headline" => run_headline(&env, &fig9_opts)?,
        "streams" => run_streams(&env)?,
        "fleet" => {
            run_fleet(&env, &fleet_opts)?;
        }
        "all" => {
            run_table3(&env)?;
            run_fig7(&env)?;
            run_fig8(&env)?;
            run_fig9(&env, &fig9_opts)?;
            run_fig10(&env, &fig9_opts)?;
            run_headline(&env, &fig9_opts)?;
            run_streams(&env)?;
            run_fleet(&env, &fleet_opts)?;
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
    Ok(())
}
