//! Structured mission reports and pluggable sinks (the Mission API's data
//! plane — see DESIGN.md "Mission API").
//!
//! Every mission driver returns a [`Report`]: named **scalars** (the
//! headline numbers a programmatic consumer wants), terminal **tables**
//! (the same rows the paper prints), CSV-bound **series** (timeseries /
//! per-row telemetry, one per output file), and free-form **notes** (the
//! paper-comparison one-liners).  Rendering is the caller's choice of
//! [`Sink`]:
//!
//! * [`StdoutSink`] — the classic terminal rendering (fixed-width tables
//!   then notes), unchanged from the pre-API drivers;
//! * [`CsvSink`] — writes every series to `<out_dir>/<name>.csv`,
//!   byte-identical to the files the drivers used to write inline
//!   (pinned by `rust/tests/scenario.rs`);
//! * [`JsonSink`] — one schema-stable JSON object on stdout
//!   (`avery run <mission> --format json`), hand-rolled because the
//!   offline crate set has no serde.
//!
//! Reports are deliberately **wall-clock-free and path-free**: every cell
//! is a virtual quantity formatted by the mission itself, so a report is
//! byte-deterministic per `(mission, options, seed)` and two same-seed
//! runs serialize identically (pinned by `rust/tests/mission_api.rs`).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::telemetry::{f, Csv, LatencyHistogram, Table};

/// Report rendering format selected by the CLI (`--format`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OutputFormat {
    /// Fixed-width tables + notes on stdout (the classic rendering).
    #[default]
    Text,
    /// One JSON object per report on stdout.
    Json,
}

impl OutputFormat {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "text" => Ok(Self::Text),
            "json" => Ok(Self::Json),
            other => anyhow::bail!("format must be text|json, got {other}"),
        }
    }
}

/// One named headline number.
#[derive(Clone, Debug)]
pub struct Scalar {
    pub name: String,
    pub value: f64,
}

/// A terminal-facing table (title + pre-formatted cells).
#[derive(Clone, Debug)]
pub struct ReportTable {
    /// Machine key (stable across runs; JSON consumers select on it).
    pub name: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl ReportTable {
    pub fn new(name: &str, title: &str, columns: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; a cell count that disagrees with the columns is a
    /// hard panic in every build profile (a ragged table row would render
    /// shifted cells and serialize misaligned JSON).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "table `{}`: row has {} cells for {} columns",
            self.name,
            cells.len(),
            self.columns.len()
        );
        self.rows.push(cells.to_vec());
    }
}

/// A CSV-bound series: `name` is the output file stem, rows are
/// pre-formatted cells (the mission owns the numeric formatting so the CSV
/// bytes cannot drift through a sink change).
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Series {
    pub fn new(name: &str, columns: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; a cell count that disagrees with the columns is a
    /// hard panic in every build profile — the CSV sink would otherwise
    /// write a ragged row that silently shifts every downstream parse.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "series `{}`: row has {} cells for {} columns",
            self.name,
            cells.len(),
            self.columns.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// All-float row with the legacy `Csv::rowf` formatting (`{v:.6}`).
    /// Non-finite values are a hard panic naming the series and column —
    /// a `NaN` literal in a CSV cell corrupts every downstream parser.
    pub fn rowf(&mut self, values: &[f64]) {
        if let Some((i, v)) = values.iter().enumerate().find(|(_, v)| !v.is_finite()) {
            let column = self.columns.get(i).map(String::as_str).unwrap_or("?");
            panic!("series `{}`: non-finite value {v} for column `{column}`", self.name);
        }
        let vs: Vec<String> = values.iter().map(|v| format!("{v:.6}")).collect();
        self.row(&vs);
    }
}

/// The structured result of one mission run.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Registry name of the mission that produced this report.
    pub mission: String,
    pub title: String,
    pub scalars: Vec<Scalar>,
    pub tables: Vec<ReportTable>,
    pub series: Vec<Series>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(mission: &str, title: &str) -> Self {
        Self {
            mission: mission.to_string(),
            title: title.to_string(),
            ..Self::default()
        }
    }

    pub fn push_scalar(&mut self, name: &str, value: f64) {
        self.scalars.push(Scalar { name: name.to_string(), value });
    }

    /// First scalar with this name (compositions may repeat names).
    pub fn scalar_value(&self, name: &str) -> Option<f64> {
        self.scalars.iter().find(|s| s.name == name).map(|s| s.value)
    }

    pub fn push_table(&mut self, table: ReportTable) {
        self.tables.push(table);
    }

    pub fn push_series(&mut self, series: Series) {
        self.series.push(series);
    }

    pub fn push_note(&mut self, note: impl Into<String>) {
        self.notes.push(note.into());
    }

    /// Append another report's content (composed missions: fig10 and
    /// headline absorb the fig9 report they run internally, preserving the
    /// sub-report's tables, CSV series and notes in order).
    pub fn absorb(&mut self, other: Report) {
        self.scalars.extend(other.scalars);
        self.tables.extend(other.tables);
        self.series.extend(other.series);
        self.notes.extend(other.notes);
    }

    /// Surface one latency histogram as headline scalars:
    /// `<prefix>_requests`, `<prefix>_p50_s`, `<prefix>_p90_s`,
    /// `<prefix>_p99_s` (seconds, virtual).  Empty histograms push zeros so
    /// the scalar set stays schema-stable across runs.
    pub fn push_latency_scalars(&mut self, prefix: &str, h: &LatencyHistogram) {
        self.push_scalar(&format!("{prefix}_requests"), h.count() as f64);
        self.push_scalar(&format!("{prefix}_p50_s"), h.p50());
        self.push_scalar(&format!("{prefix}_p90_s"), h.p90());
        self.push_scalar(&format!("{prefix}_p99_s"), h.p99());
    }
}

/// Render per-class latency histograms as a terminal table (one row per
/// stream class, milliseconds) — the `Histogram → Report` adapter used by
/// the fleet/scenario missions; follows the p50/p90/p99/min/max table shape
/// of the open-nexus IPC benchmarks (ROADMAP "Tail-latency discipline").
pub fn latency_table(
    name: &str,
    title: &str,
    classes: &[(&str, &LatencyHistogram)],
) -> ReportTable {
    let ms = |v: f64| f(v * 1e3, 3);
    let mut t = ReportTable::new(
        name,
        title,
        &["Class", "Requests", "Min ms", "p50 ms", "p90 ms", "p99 ms", "p999 ms", "Max ms"],
    );
    for (class, h) in classes {
        t.row(&[
            class.to_string(),
            h.count().to_string(),
            ms(h.min_secs()),
            ms(h.p50()),
            ms(h.p90()),
            ms(h.p99()),
            ms(h.p999()),
            ms(h.max_secs()),
        ]);
    }
    t
}

/// A report consumer.
pub trait Sink {
    fn emit(&mut self, report: &Report) -> Result<()>;
}

/// Classic terminal rendering: every table through the fixed-width
/// printer, then the notes.
pub struct StdoutSink;

impl Sink for StdoutSink {
    fn emit(&mut self, report: &Report) -> Result<()> {
        for t in &report.tables {
            Table {
                title: t.title.clone(),
                header: t.columns.clone(),
                rows: t.rows.clone(),
            }
            .print();
        }
        for n in &report.notes {
            println!("{n}");
        }
        Ok(())
    }
}

/// Writes each series to `<out_dir>/<name>.csv`.  Series are written in
/// report order, so a composed report that carries the same series twice
/// (fig10 re-runs fig9) overwrites exactly as the inline drivers did.
pub struct CsvSink {
    out_dir: PathBuf,
    announce: bool,
}

impl CsvSink {
    pub fn new(out_dir: &Path) -> Self {
        Self { out_dir: out_dir.to_path_buf(), announce: true }
    }

    /// Print (or suppress) the classic `csv: path / path` line.
    pub fn announce(mut self, on: bool) -> Self {
        self.announce = on;
        self
    }
}

impl Sink for CsvSink {
    fn emit(&mut self, report: &Report) -> Result<()> {
        let mut paths: Vec<String> = Vec::new();
        for s in &report.series {
            let path = self.out_dir.join(format!("{}.csv", s.name));
            let cols: Vec<&str> = s.columns.iter().map(|c| c.as_str()).collect();
            let mut csv = Csv::create(&path, &cols)?;
            for row in &s.rows {
                csv.row(row)?;
            }
            let shown = path.display().to_string();
            if !paths.contains(&shown) {
                paths.push(shown);
            }
        }
        if self.announce && !paths.is_empty() {
            println!("csv: {}", paths.join(" / "));
        }
        Ok(())
    }
}

/// One JSON object per report on stdout (schema below, `"schema": 1`).
pub struct JsonSink;

impl Sink for JsonSink {
    fn emit(&mut self, report: &Report) -> Result<()> {
        println!("{}", to_json(report));
        Ok(())
    }
}

/// Emit a report the way the text-mode CLI does: terminal rendering first,
/// then the CSV files with their `csv:` announcement.  Shared by the CLI,
/// the benches and the examples.
pub fn emit_text(report: &Report, out_dir: &Path) -> Result<()> {
    StdoutSink.emit(report)?;
    CsvSink::new(out_dir).emit(report)
}

// ---------------------------------------------------------------------------
// JSON serialization (hand-rolled; the offline crate set has no serde)
// ---------------------------------------------------------------------------

/// Escape a string for a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A JSON number token — finite floats via shortest-roundtrip `Display`,
/// non-finite values as `null` (JSON has no NaN/Infinity).
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn jstr_array(items: &[String]) -> String {
    let parts: Vec<String> = items.iter().map(|s| format!("\"{}\"", esc(s))).collect();
    format!("[{}]", parts.join(","))
}

fn jrows(rows: &[Vec<String>]) -> String {
    let parts: Vec<String> = rows.iter().map(|r| jstr_array(r)).collect();
    format!("[{}]", parts.join(","))
}

/// Serialize a report to its stable JSON schema:
///
/// ```json
/// {"schema":1,"mission":"...","title":"...",
///  "scalars":[{"name":"...","value":1.5}],
///  "tables":[{"name":"...","title":"...","columns":[...],"rows":[[...]]}],
///  "series":[{"name":"...","columns":[...],"rows":[[...]]}],
///  "notes":["..."]}
/// ```
///
/// Key order is fixed; scalars are an array (not an object) because
/// composed reports may legitimately repeat a name.
pub fn to_json(report: &Report) -> String {
    let scalars: Vec<String> = report
        .scalars
        .iter()
        .map(|s| format!("{{\"name\":\"{}\",\"value\":{}}}", esc(&s.name), jnum(s.value)))
        .collect();
    let tables: Vec<String> = report
        .tables
        .iter()
        .map(|t| {
            format!(
                "{{\"name\":\"{}\",\"title\":\"{}\",\"columns\":{},\"rows\":{}}}",
                esc(&t.name),
                esc(&t.title),
                jstr_array(&t.columns),
                jrows(&t.rows)
            )
        })
        .collect();
    let series: Vec<String> = report
        .series
        .iter()
        .map(|s| {
            format!(
                "{{\"name\":\"{}\",\"columns\":{},\"rows\":{}}}",
                esc(&s.name),
                jstr_array(&s.columns),
                jrows(&s.rows)
            )
        })
        .collect();
    format!(
        "{{\"schema\":1,\"mission\":\"{}\",\"title\":\"{}\",\"scalars\":[{}],\"tables\":[{}],\"series\":[{}],\"notes\":{}}}",
        esc(&report.mission),
        esc(&report.title),
        scalars.join(","),
        tables.join(","),
        series.join(","),
        jstr_array(&report.notes)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_report() -> Report {
        let mut r = Report::new("demo", "Demo mission");
        r.push_scalar("answer", 42.0);
        r.push_scalar("ratio", 0.25);
        let mut t = ReportTable::new("t", "A table", &["a", "b"]);
        t.row(&["1".into(), "x\"y".into()]);
        r.push_table(t);
        let mut s = Series::new("demo_series", &["t", "v"]);
        s.rowf(&[1.0, 2.5]);
        r.push_series(s);
        r.push_note("note with\nnewline");
        r
    }

    #[test]
    fn json_schema_is_stable_and_escaped() {
        let j = to_json(&demo_report());
        assert!(j.starts_with("{\"schema\":1,\"mission\":\"demo\",\"title\":\"Demo mission\""));
        assert!(j.contains("{\"name\":\"answer\",\"value\":42}"));
        assert!(j.contains("x\\\"y"));
        assert!(j.contains("note with\\nnewline"));
        assert!(j.contains("\"series\":[{\"name\":\"demo_series\""));
        // Deterministic serialization.
        assert_eq!(j, to_json(&demo_report()));
    }

    #[test]
    fn json_maps_non_finite_to_null() {
        let mut r = Report::new("m", "t");
        r.push_scalar("bad", f64::NAN);
        assert!(to_json(&r).contains("{\"name\":\"bad\",\"value\":null}"));
    }

    #[test]
    fn csv_sink_writes_series_files() {
        let dir = std::env::temp_dir().join("avery_report_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let r = demo_report();
        CsvSink::new(&dir).announce(false).emit(&r).unwrap();
        let text = std::fs::read_to_string(dir.join("demo_series.csv")).unwrap();
        assert_eq!(text, "t,v\n1.000000,2.500000\n");
    }

    #[test]
    fn scalar_lookup_finds_first() {
        let mut r = demo_report();
        r.push_scalar("answer", 7.0);
        assert_eq!(r.scalar_value("answer"), Some(42.0));
        assert_eq!(r.scalar_value("missing"), None);
    }

    #[test]
    fn absorb_preserves_order() {
        let mut a = Report::new("outer", "outer");
        let inner = demo_report();
        a.absorb(inner);
        a.push_note("outer note");
        assert_eq!(a.tables.len(), 1);
        assert_eq!(a.series.len(), 1);
        assert_eq!(a.notes, vec!["note with\nnewline".to_string(), "outer note".to_string()]);
    }

    #[test]
    #[should_panic(expected = "table `t`: row has 1 cells for 2 columns")]
    fn table_row_panics_on_ragged_row_in_all_builds() {
        let mut t = ReportTable::new("t", "A table", &["a", "b"]);
        t.row(&["lonely".into()]);
    }

    #[test]
    #[should_panic(expected = "series `s`: row has 3 cells for 2 columns")]
    fn series_row_panics_on_ragged_row_in_all_builds() {
        let mut s = Series::new("s", &["a", "b"]);
        s.row(&["1".into(), "2".into(), "3".into()]);
    }

    #[test]
    #[should_panic(expected = "series `s`: non-finite value NaN for column `v`")]
    fn series_rowf_panics_on_non_finite() {
        let mut s = Series::new("s", &["t", "v"]);
        s.rowf(&[1.0, f64::NAN]);
    }

    #[test]
    fn latency_adapter_pushes_scalars_and_table() {
        let mut h = LatencyHistogram::new();
        h.record(0.010);
        let mut r = Report::new("m", "t");
        r.push_latency_scalars("context", &h);
        assert_eq!(r.scalar_value("context_requests"), Some(1.0));
        assert_eq!(r.scalar_value("context_p99_s"), Some(0.010));
        let t = latency_table("lat", "Latency", &[("Context", &h)]);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][0], "Context");
        assert_eq!(t.rows[0][4], "10.000"); // p90 in ms
        // Empty histograms still produce schema-stable zero scalars.
        let mut r2 = Report::new("m", "t");
        r2.push_latency_scalars("insight", &LatencyHistogram::new());
        assert_eq!(r2.scalar_value("insight_p50_s"), Some(0.0));
    }

    #[test]
    fn output_format_parses() {
        assert_eq!(OutputFormat::parse("text").unwrap(), OutputFormat::Text);
        assert_eq!(OutputFormat::parse("json").unwrap(), OutputFormat::Json);
        assert!(OutputFormat::parse("yaml").is_err());
    }
}
