//! Seeded scenario-matrix generator: crosses trace regimes × link regimes
//! × fleet mixes × intent schedules into ~500 valid manifests.
//!
//! The generator emits manifest *text*, not `CompiledScenario` values, so
//! every generated scenario exercises the real parse + compile pipeline —
//! the matrix property tests (`rust/tests/matrix.rs`) assert that every
//! output compiles clean and that a seeded sample passes the golden-trace
//! invariant gates from PR 2.  Per-manifest level perturbations come from
//! a seeded [`Rng`], so `generate(seed)` is a pure function of the seed
//! and the matrix is reproducible anywhere.

use crate::util::Rng;

/// One generated scenario manifest (name + TOML text).
#[derive(Clone, Debug)]
pub struct GeneratedManifest {
    pub name: String,
    pub text: String,
}

/// A phase script entry: `(kind, duration, anchor level)`.  `frac` mode
/// durations are mission fractions summing to 1; `secs` mode durations are
/// absolute and rescaled by the compiler.
struct TraceAxis {
    tag: &'static str,
    body: TraceBody,
}

enum TraceBody {
    Frac(&'static [(&'static str, f64, f64)]),
    Secs(&'static [(&'static str, f64, f64)]),
    Markov { kinds: &'static [&'static str], dwell_div: f64, dwell_min_s: f64 },
}

const TRACES: [TraceAxis; 8] = [
    TraceAxis {
        tag: "steady",
        body: TraceBody::Frac(&[
            ("stable", 0.40, 16.0),
            ("volatile", 0.30, 13.0),
            ("stable", 0.30, 17.0),
        ]),
    },
    TraceAxis {
        tag: "canyon",
        body: TraceBody::Frac(&[
            ("stable", 0.20, 15.0),
            ("outage", 0.08, 0.05),
            ("volatile", 0.22, 12.0),
            ("outage", 0.10, 0.05),
            ("drop", 0.20, 8.5),
            ("stable", 0.20, 16.0),
        ]),
    },
    TraceAxis {
        tag: "droppy",
        body: TraceBody::Frac(&[
            ("drop", 0.25, 9.0),
            ("stable", 0.25, 15.0),
            ("drop", 0.25, 8.5),
            ("volatile", 0.25, 12.0),
        ]),
    },
    TraceAxis {
        tag: "sawtooth",
        body: TraceBody::Frac(&[
            ("sawtooth", 0.30, 9.0),
            ("stable", 0.20, 17.0),
            ("sawtooth", 0.30, 8.5),
            ("volatile", 0.20, 12.0),
        ]),
    },
    TraceAxis {
        tag: "relay",
        body: TraceBody::Secs(&[
            ("stable", 180.0, 16.0),
            ("drop", 120.0, 9.0),
            ("volatile", 150.0, 13.0),
            ("stable", 150.0, 17.0),
        ]),
    },
    TraceAxis {
        tag: "mksmoke",
        body: TraceBody::Markov {
            kinds: &["stable", "volatile", "drop"],
            dwell_div: 12.0,
            dwell_min_s: 20.0,
        },
    },
    TraceAxis {
        tag: "mkstorm",
        body: TraceBody::Markov {
            kinds: &["volatile", "drop", "outage"],
            dwell_div: 10.0,
            dwell_min_s: 15.0,
        },
    },
    TraceAxis {
        tag: "mkpass",
        body: TraceBody::Markov {
            kinds: &["sawtooth", "stable"],
            dwell_div: 8.0,
            dwell_min_s: 25.0,
        },
    },
];

/// `(tag, loss_prob, jitter_std, extra_latency_s)`.
const LINKS: [(&str, f64, f64, f64); 4] = [
    ("clean", 0.0, 0.03, 0.0),
    ("lossy", 0.02, 0.03, 0.0),
    ("jittery", 0.01, 0.05, 0.0),
    ("sat", 0.01, 0.04, 0.28),
];

/// `(tag, uavs, context_every, stagger_secs, workers)`.
const FLEETS: [(&str, usize, usize, f64, usize); 4] = [
    ("solo", 1, 0, 0.0, 1),
    ("patrol", 4, 4, 5.0, 2),
    ("swarm", 6, 3, 8.0, 2),
    ("wing", 8, 2, 4.0, 3),
];

/// `(tag, switches as (at_frac, prompt))`.
const INTENTS: [(&str, &[(f64, &str)]); 4] = [
    ("hold", &[]),
    (
        "escalate",
        &[
            (0.40, "are there any living beings on the rooftops"),
            (0.60, "highlight the stranded people"),
        ],
    ),
    ("retask", &[(0.50, "mark the submerged vehicles")]),
    (
        "triage",
        &[
            (0.35, "give me a quick status of this scene"),
            (0.55, "highlight the stranded people"),
            (0.80, "mark the submerged vehicles"),
        ],
    ),
];

/// Matrix size: 8 traces × 4 links × 4 fleets × 4 intents.
pub const MATRIX_SIZE: usize = TRACES.len() * LINKS.len() * FLEETS.len() * INTENTS.len();

/// Generate the full scenario matrix, deterministically in `seed`.
pub fn generate(seed: u64) -> Vec<GeneratedManifest> {
    let mut out = Vec::with_capacity(MATRIX_SIZE);
    let mut i = 0usize;
    for trace in &TRACES {
        for link in &LINKS {
            for fleet in &FLEETS {
                for intent in &INTENTS {
                    out.push(emit(seed, i, trace, link, fleet, intent));
                    i += 1;
                }
            }
        }
    }
    out
}

/// A seeded sample of `count` distinct matrix entries (Fisher–Yates over
/// indices, then matrix order — stable under `count`).
pub fn sample(seed: u64, count: usize) -> Vec<GeneratedManifest> {
    let all = generate(seed);
    let mut idx: Vec<usize> = (0..all.len()).collect();
    let mut rng = Rng::new(seed ^ 0x5EEDED);
    for i in (1..idx.len()).rev() {
        idx.swap(i, rng.below(i + 1));
    }
    idx.truncate(count.min(all.len()));
    idx.sort_unstable();
    let mut all: Vec<Option<GeneratedManifest>> = all.into_iter().map(Some).collect();
    idx.iter().map(|&i| all[i].take().expect("distinct indices")).collect()
}

fn emit(
    seed: u64,
    i: usize,
    trace: &TraceAxis,
    link: &(&str, f64, f64, f64),
    fleet: &(&str, usize, usize, f64, usize),
    intent: &(&str, &[(f64, &str)]),
) -> GeneratedManifest {
    // Per-manifest stream: perturbs anchor levels so same-named phases in
    // different manifests still differ.  Non-outage anchors start within
    // [8.5, 17.0] and move at most ±0.4, staying inside the [8, 20] clamp
    // band the compiler enforces.
    let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC0FFEE);
    let name = format!("gen-{}-{}-{}-{}", trace.tag, link.0, fleet.0, intent.0);
    let goal = if i % 5 == 0 { "throughput" } else { "accuracy" };
    let mut t = String::new();
    t.push_str("schema = 1\n");
    t.push_str(&format!("name = \"{name}\"\n"));
    t.push_str(&format!(
        "summary = \"generated matrix point {i}: {} trace, {} link, {} fleet, {} intent\"\n",
        trace.tag, link.0, fleet.0, intent.0
    ));
    t.push_str(&format!("goal = \"{goal}\"\n"));
    t.push_str("hysteresis = 0.10\nmin_dwell = 2\n\n");

    match &trace.body {
        TraceBody::Markov { kinds, dwell_div, dwell_min_s } => {
            let quoted: Vec<String> = kinds.iter().map(|k| format!("\"{k}\"")).collect();
            t.push_str(&format!(
                "[trace]\nmarkov_kinds = [{}]\nmarkov_dwell_div = {dwell_div}\n\
                 markov_dwell_min_s = {dwell_min_s}\n\n",
                quoted.join(", ")
            ));
        }
        TraceBody::Frac(phases) | TraceBody::Secs(phases) => {
            let frac = matches!(trace.body, TraceBody::Frac(_));
            let dur_key = if frac { "frac" } else { "secs" };
            for (kind, dur, level) in *phases {
                let level = if *kind == "outage" {
                    *level
                } else {
                    *level + rng.range(-0.4, 0.4)
                };
                t.push_str(&format!(
                    "[[phase]]\nkind = \"{kind}\"\n{dur_key} = {dur}\nlevel_mbps = {level:.2}\n\n"
                ));
            }
        }
    }

    t.push_str(&format!(
        "[link]\nloss_prob = {}\njitter_std = {}\nextra_latency_s = {}\n\n",
        link.1, link.2, link.3
    ));
    t.push_str(&format!(
        "[fleet]\nuavs = {}\ncontext_every = {}\nstagger_secs = {}\nworkers = {}\n",
        fleet.1, fleet.2, fleet.3, fleet.4
    ));
    for (at, prompt) in intent.1 {
        t.push_str(&format!("\n[[intent]]\nat_frac = {at}\nprompt = \"{prompt}\"\n"));
    }
    GeneratedManifest { name, text: t }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_has_five_hundred_plus_unique_entries() {
        let all = generate(7);
        assert_eq!(all.len(), MATRIX_SIZE);
        assert!(MATRIX_SIZE >= 500, "matrix shrank to {MATRIX_SIZE}");
        let mut names: Vec<&str> = all.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate generated names");
    }

    #[test]
    fn generation_is_deterministic_in_seed() {
        let a = generate(7);
        let b = generate(7);
        assert!(a.iter().zip(&b).all(|(x, y)| x.text == y.text));
        let c = generate(8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.text != y.text));
    }

    #[test]
    fn sample_is_distinct_stable_and_bounded() {
        let s = sample(7, 64);
        assert_eq!(s.len(), 64);
        let mut names: Vec<&str> = s.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 64);
        let again = sample(7, 64);
        assert!(s.iter().zip(&again).all(|(x, y)| x.text == y.text));
        assert_eq!(sample(7, 10_000).len(), MATRIX_SIZE);
    }
}
